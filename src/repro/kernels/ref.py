"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: (B, H, S, D); k/v: (B, K, S, D)."""
    B, H, S, D = q.shape
    K = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    reps = H // K
    k = jnp.repeat(k, reps, axis=1)
    v = jnp.repeat(v, reps, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, positions, *, scale=None):
    """q: (B, H, D); k/v: (B, S, K, D); positions: (B,)."""
    B, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    reps = H // K
    k = jnp.repeat(k, reps, axis=2)  # (B, S, H, D)
    v = jnp.repeat(v, reps, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, None, :] <= positions[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, positions, *,
                               scale=None):
    """q: (B, H, D); k_pool/v_pool: (n_blocks, bs, K, D);
    block_tables: (B, T); positions: (B,)."""
    B, H, D = q.shape
    bs, K = k_pool.shape[1], k_pool.shape[2]
    T = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    # gather each sequence's logical KV view: (B, T*bs, K, D)
    k = k_pool[block_tables].reshape(B, T * bs, K, D)
    v = v_pool[block_tables].reshape(B, T * bs, K, D)
    return decode_attention_ref(q, k, v, positions, scale=scale)


def paged_prefill_attention_ref(q, k_pool, v_pool, block_tables, starts, *,
                                scale=None):
    """q: (B, C, H, D) chunk queries at positions starts[b] + c;
    k_pool/v_pool: (n_blocks, bs, K, D); block_tables: (B, T);
    starts: (B,)."""
    B, C, H, D = q.shape
    bs, K = k_pool.shape[1], k_pool.shape[2]
    T = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    k = k_pool[block_tables].reshape(B, T * bs, K, D)
    v = v_pool[block_tables].reshape(B, T * bs, K, D)
    reps = H // K
    k = jnp.repeat(k, reps, axis=2)  # (B, S, H, D)
    v = jnp.repeat(v, reps, axis=2)
    s = jnp.einsum("bchd,bshd->bhcs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = starts[:, None] + jnp.arange(C)[None, :]          # (B, C)
    mask = jnp.arange(T * bs)[None, None, :] <= qpos[:, :, None]
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhcs,bshd->bchd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def paged_verify_attention_ref(q, k_pool, v_pool, block_tables, positions, *,
                               scale=None):
    """Multi-query-per-lane decode ("verify") attention oracle.

    q: (B, Q, H, D) — Q query tokens per lane, query i sitting at absolute
    position ``positions[b] + i`` (speculative-decode verification: the
    current input plus K draft tokens); k_pool/v_pool: (n_blocks, bs, K, D)
    with the Q tokens' own KV already written; block_tables: (B, T);
    positions: (B,).  Identical mask walk to chunked prefill with
    ``starts == positions`` — query i sees kpos <= positions + i.
    """
    return paged_prefill_attention_ref(q, k_pool, v_pool, block_tables,
                                       positions, scale=scale)


def rwkv6_wkv_ref(r, k, v, w, u, s0):
    """r/k/v/w: (B, T, H, D); u: (H, D); s0: (B, H, D, D)."""
    def step(s, inp):
        rt, kt, vt, wt = (t.astype(jnp.float32) for t in inp)  # (B, H, D)
        at = kt[..., :, None] * vt[..., None, :]
        bonus = (u[None].astype(jnp.float32) * kt)[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + bonus)
        return wt[..., :, None] * s + at, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    s_f, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), s_f


def int8_matmul_ref(x_q, w_q, sx, sw, out_dtype=jnp.bfloat16):
    acc = jnp.einsum("mk,kn->mn", x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    return (acc.astype(jnp.float32) * sx * sw).astype(out_dtype)
