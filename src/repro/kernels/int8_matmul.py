"""w8a8 int8 GEMM with per-row/per-channel scales — Pallas TPU kernel.

This is the TAPAS instance-configurator's quantization knob realised on
TPU: v5e has no FP8, so bf16 -> int8 symmetric quantization is the
MXU-native low-precision path.  int32 accumulation in VMEM scratch over the
sequential K-block grid dim; scales applied once at the final block.
Tiles are 128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _int8_mm_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_sc, *,
                    out_dtype):
    kb = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    acc_sc[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(kb == nk - 1)
    def _finish():
        sx = sx_ref[...].astype(jnp.float32)  # (bm, 1)
        sw = sw_ref[...].astype(jnp.float32)  # (1, bn)
        o_ref[...] = (acc_sc[...].astype(jnp.float32) * sx * sw).astype(out_dtype)


def int8_matmul(x_q: jax.Array, w_q: jax.Array, sx: jax.Array, sw: jax.Array,
                *, block_m: int = 256, block_n: int = 256, block_k: int = 512,
                out_dtype=jnp.bfloat16, interpret: bool = False) -> jax.Array:
    """x_q: (M, K) int8; w_q: (K, N) int8; sx: (M, 1) f32; sw: (1, N) f32."""
    M, K = x_q.shape
    N = w_q.shape[1]
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    grid = (M // block_m, N // block_n, K // block_k)

    kern = functools.partial(_int8_mm_kernel, out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kb: (kb, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, kb: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, kb: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, w_q, sx, sw)


def quantize_rows(x: jax.Array):
    """Symmetric per-row int8 quantization: returns (x_q, scale (M,1) f32)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    x_q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return x_q, scale


def quantize_cols(w: jax.Array):
    """Symmetric per-output-channel int8 quantization: (w_q, scale (1,N))."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    w_q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return w_q, scale
