"""Paged flash-decode — Pallas TPU kernel over a block-table KV pool.

The KV cache lives in one global pool of fixed-size blocks
(``n_blocks, block_size, K, D``); each sequence owns a per-request *block
table* mapping its logical KV blocks to physical pool blocks (vLLM-style
PagedAttention).  The grid walks (sequence, logical block); the physical
block to DMA is resolved in the BlockSpec index map from the scalar-
prefetched block table (SMEM), so the kernel body is the same running
(m, l, acc) online softmax as the dense flash-decode in
``decode_attention.py`` — only the gather changed.

q packs all heads of one sequence into a single (H, D) MXU operand and GQA
is computed grouped — q reshaped (K, G, D) against k (bs, K, D) — so kv is
never expanded.  Logical blocks past the sequence's length are skipped with
``@pl.when``; their index-map entries must still name a valid physical
block, so callers pad unused block-table slots with 0 (the pool reserves
block 0 as a parking block that no live sequence owns).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _paged_decode_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         m_sc, l_sc, acc_sc, *, scale: float,
                         block_size: int, groups: int):
    b = pl.program_id(0)
    j = pl.program_id(1)          # logical block index within the sequence
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    pos = pos_ref[b]
    k_lo = j * block_size

    @pl.when(k_lo <= pos)
    def _body():
        q = q_ref[0].astype(jnp.float32)      # (H, D), H = K*G
        k = k_ref[...].astype(jnp.float32)    # (bs, K, D) — physical block
        v = v_ref[...].astype(jnp.float32)
        K = k.shape[1]
        qg = q.reshape(K, groups, q.shape[-1])
        # scores (K, G, bs)
        s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        sh = s.reshape(K * groups, block_size)  # (H, bs)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(sh, axis=1))
        p = jnp.exp(sh - m_new[:, None]).reshape(K, groups, block_size)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=2).reshape(-1)
        # (K, G, bs) x (bs, K, D) -> (K, G, D)
        o = jax.lax.dot_general(p, v, (((2,), (0,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32)
        acc_sc[...] = acc_sc[...] * corr[:, None] + o.reshape(K * groups, -1)
        m_sc[...] = m_new

    @pl.when(j == nj - 1)
    def _finish():
        denom = jnp.maximum(l_sc[...], 1e-30)[:, None]
        o_ref[0] = (acc_sc[...] / denom).astype(o_ref.dtype)


def _paged_prefill_kernel(bt_ref, st_ref, q_ref, k_ref, v_ref, o_ref,
                          m_sc, l_sc, acc_sc, *, scale: float,
                          block_size: int, groups: int, chunk: int):
    b = pl.program_id(0)
    j = pl.program_id(1)          # logical block index within the sequence
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    start = st_ref[b]
    k_lo = j * block_size

    # the chunk's own KV is already in the pool; blocks past the chunk's
    # last query position contribute nothing and are skipped entirely
    @pl.when(k_lo <= start + chunk - 1)
    def _body():
        q = q_ref[0].astype(jnp.float32)      # (C, H, D), H = K*G
        k = k_ref[...].astype(jnp.float32)    # (bs, K, D) — physical block
        v = v_ref[...].astype(jnp.float32)
        K = k.shape[1]
        qg = q.reshape(chunk, K, groups, -1).transpose(1, 0, 2, 3) \
              .reshape(K, chunk * groups, -1)
        # scores (K, C*G, bs)
        s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32) * scale
        cidx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) // groups
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(kpos <= start + cidx, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=2)
        # (K, C*G, bs) x (bs, K, D) -> (K, C*G, D)
        o = jax.lax.dot_general(p, v, (((2,), (0,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32)
        acc_sc[...] = acc_sc[...] * corr[..., None] + o
        m_sc[...] = m_new

    @pl.when(j == nj - 1)
    def _finish():
        denom = jnp.maximum(l_sc[...], 1e-30)[..., None]
        o = acc_sc[...] / denom               # (K, C*G, D)
        K = o.shape[0]
        o = o.reshape(K, chunk, groups, -1).transpose(1, 0, 2, 3) \
             .reshape(chunk, K * groups, -1)
        o_ref[0] = o.astype(o_ref.dtype)


def _paged_decode_lse_kernel(bt_ref, pos_ref, own_ref, q_ref, k_ref, v_ref,
                             o_ref, lse_ref, m_sc, l_sc, acc_sc, *,
                             scale: float, block_size: int, groups: int):
    b = pl.program_id(0)
    j = pl.program_id(1)          # logical block index within the sequence
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    pos = pos_ref[b]
    k_lo = j * block_size

    @pl.when((k_lo <= pos) & (own_ref[b, j] != 0))
    def _body():
        q = q_ref[0].astype(jnp.float32)      # (H, D), H = K*G
        k = k_ref[...].astype(jnp.float32)    # (bs, K, D) — physical block
        v = v_ref[...].astype(jnp.float32)
        K = k.shape[1]
        qg = q.reshape(K, groups, q.shape[-1])
        s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        sh = s.reshape(K * groups, block_size)  # (H, bs)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(sh, axis=1))
        p = jnp.exp(sh - m_new[:, None]).reshape(K, groups, block_size)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=2).reshape(-1)
        o = jax.lax.dot_general(p, v, (((2,), (0,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32)
        acc_sc[...] = acc_sc[...] * corr[:, None] + o.reshape(K * groups, -1)
        m_sc[...] = m_new

    @pl.when(j == nj - 1)
    def _finish():
        l = l_sc[...]
        o_ref[0] = (acc_sc[...]
                    / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
        lse_ref[0] = jnp.where(l > 0.0, m_sc[...] + jnp.log(
            jnp.maximum(l, 1e-30)), NEG_INF)


def paged_decode_attention_lse(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_tables: jax.Array,
                               positions: jax.Array, owned: jax.Array, *,
                               scale: float | None = None,
                               interpret: bool = False):
    """Paged decode attention over a *partial* pool, with the LSE exposed.

    The per-KV-shard building block of the block-stripe sharded pool
    (``models/attention._paged_decode_core``): each shard runs this over
    its local stripe and the shards' outputs merge exactly via
    ``combine_lse`` — the same max/sum softmax merge ``_flash_decode_core``
    does with pmax/psum.

    ``owned``: (B, T) nonzero where this shard holds the table's block;
    unowned slots are skipped entirely (never DMA'd), so callers may clip
    their localized table ids into range without masking the contents.
    Returns ``(o, lse)``: o (B, H, D) softmax-normalised over the owned
    blocks only, lse (B, H) float32 ``m + log(l)`` (``NEG_INF`` where the
    shard saw no key), so the combine is exact in one weighted sum.
    """
    B, H, D = q.shape
    bs, K = k_pool.shape[1], k_pool.shape[2]
    T = block_tables.shape[1]
    assert H % K == 0
    groups = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    kern = functools.partial(_paged_decode_lse_kernel, scale=scale,
                             block_size=bs, groups=groups)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,    # block_tables, positions, owned in SMEM
        grid=(B, T),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, j, bt, pos, own: (b, 0, 0)),
            pl.BlockSpec((None, bs, K, D),
                         lambda b, j, bt, pos, own: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((None, bs, K, D),
                         lambda b, j, bt, pos, own: (bt[b, j], 0, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, H, D), lambda b, j, bt, pos, own: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, j, bt, pos, own: (b, 0)),
        ),
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct(q.shape, q.dtype),
                   jax.ShapeDtypeStruct((B, H), jnp.float32)),
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), positions.astype(jnp.int32),
      owned.astype(jnp.int32), q, k_pool, v_pool)


def combine_lse(os: jax.Array, lses: jax.Array) -> jax.Array:
    """Merge per-shard ``paged_decode_attention_lse`` outputs exactly.

    os: (S, B, H, D) per-shard normalised outputs; lses: (S, B, H).
    Weights each shard by ``exp(lse_s - max_s lse)`` times its own
    denominator share — algebraically identical to one softmax over the
    union of the shards' keys.
    """
    m = jnp.max(lses, axis=0)                       # (B, H)
    w = jnp.exp(lses - m[None])                     # (S, B, H)
    denom = jnp.maximum(jnp.sum(w, axis=0), 1e-30)  # (B, H)
    o = jnp.sum(os.astype(jnp.float32) * w[..., None], axis=0) / denom[..., None]
    return o.astype(os.dtype)


def paged_prefill_attention(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, block_tables: jax.Array,
                            starts: jax.Array, *,
                            scale: float | None = None,
                            interpret: bool = False) -> jax.Array:
    """Chunked-prefill attention over a paged KV pool.

    q: (B, C, H, D) — C chunk queries per sequence, query c sitting at
    absolute position ``starts[b] + c``; k_pool/v_pool: (n_blocks, bs, K,
    D) with the chunk's own KV already written; block_tables: (B, T)
    int32 physical ids (pad unused slots with 0); starts: (B,) ->
    o (B, C, H, D).  Same online-softmax walk as the decode kernel with a
    (C*G)-row score tile per KV head and a per-row causal mask
    ``kpos <= starts + c``; pool blocks past the chunk's last query are
    never DMA'd.
    """
    B, C, H, D = q.shape
    bs, K = k_pool.shape[1], k_pool.shape[2]
    T = block_tables.shape[1]
    assert H % K == 0
    groups = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    kern = functools.partial(_paged_prefill_kernel, scale=scale,
                             block_size=bs, groups=groups, chunk=C)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,    # block_tables, starts land in SMEM
        grid=(B, T),
        in_specs=[
            pl.BlockSpec((1, C, H, D), lambda b, j, bt, st: (b, 0, 0, 0)),
            pl.BlockSpec((None, bs, K, D),
                         lambda b, j, bt, st: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((None, bs, K, D),
                         lambda b, j, bt, st: (bt[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, H, D),
                               lambda b, j, bt, st: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((K, C * groups), jnp.float32),
            pltpu.VMEM((K, C * groups), jnp.float32),
            pltpu.VMEM((K, C * groups, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), starts.astype(jnp.int32),
      q, k_pool, v_pool)


def paged_verify_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           positions: jax.Array, *,
                           scale: float | None = None,
                           interpret: bool = False) -> jax.Array:
    """Multi-query-per-lane decode attention (speculative verify).

    q: (B, Q, H, D) — the current input token plus K draft tokens per
    lane, query i at absolute position ``positions[b] + i``, all verified
    against the block table in one pass; k_pool/v_pool: (n_blocks, bs, K,
    D) with the Q tokens' own KV already written; block_tables: (B, T)
    (pad unused slots with 0); positions: (B,) -> o (B, Q, H, D).

    The mask walk is exactly chunked prefill with ``starts == positions``
    (query i sees kpos <= positions + i), so the same online-softmax
    kernel body serves both entry points; only the calling convention —
    decode-style positions instead of prefill starts — differs.
    """
    return paged_prefill_attention(q, k_pool, v_pool, block_tables,
                                   positions, scale=scale,
                                   interpret=interpret)


def paged_decode_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           block_tables: jax.Array, positions: jax.Array, *,
                           scale: float | None = None,
                           interpret: bool = False) -> jax.Array:
    """One-token attention over a paged KV pool.

    q: (B, H, D); k_pool/v_pool: (n_blocks, bs, K, D);
    block_tables: (B, T) int32 physical block ids (pad unused slots with 0);
    positions: (B,) last valid cache index per sequence -> o (B, H, D).
    """
    B, H, D = q.shape
    bs, K = k_pool.shape[1], k_pool.shape[2]
    T = block_tables.shape[1]
    assert H % K == 0
    groups = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    kern = functools.partial(_paged_decode_kernel, scale=scale,
                             block_size=bs, groups=groups)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,    # block_tables, positions land in SMEM
        grid=(B, T),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, j, bt, pos: (b, 0, 0)),
            pl.BlockSpec((None, bs, K, D),
                         lambda b, j, bt, pos: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((None, bs, K, D),
                         lambda b, j, bt, pos: (bt[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, j, bt, pos: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), positions.astype(jnp.int32),
      q, k_pool, v_pool)
