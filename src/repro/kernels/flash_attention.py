"""Causal GQA flash attention — Pallas TPU kernel.

Online-softmax over (block_q x block_k) VMEM tiles; fp32 accumulators in
VMEM scratch; MXU-aligned tile sizes (multiples of 128 on the lane dim).
Layout: q (B, H, S, D); k/v (B, K, S, D); GQA mapping h -> h*K//H resolved
in the BlockSpec index maps, so no kv expansion ever materialises.

The grid's last dimension walks k-blocks ("arbitrary" semantics = sequential
on TPU) and carries running (m, l, acc) in scratch; causal upper blocks are
skipped with @pl.when.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  window: int):
    i = pl.program_id(2)  # q block
    j = pl.program_id(3)  # k block
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_lo = i * block_q
    k_lo = j * block_k
    # skip blocks strictly above the diagonal (causal) or outside the window
    run = True
    if causal:
        run = k_lo <= q_lo + block_q - 1
    if window:
        run = jnp.logical_and(run, k_lo + block_k > q_lo - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = kpos <= qpos
        if window:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(j == nj - 1)
    def _finish():
        denom = jnp.maximum(l_sc[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_sc[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, K, S, D) -> (B, H, S, D)."""
    B, H, S, D = q.shape
    K = k.shape[1]
    assert H % K == 0, (H, K)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    grid = (B, H, S // block_q, S // block_k)

    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             block_q=block_q, block_k=block_k, window=window)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h * K // H, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h * K // H, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
