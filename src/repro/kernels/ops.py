"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in this
CPU container (interpret mode executes the kernel body exactly) and compile
to real Mosaic kernels on TPU.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.int8_matmul import (int8_matmul as _int8_mm,
                                       quantize_cols, quantize_rows)
from repro.kernels.paged_decode_attention import \
    paged_decode_attention as _paged_decode
from repro.kernels.paged_decode_attention import \
    paged_decode_attention_lse as _paged_decode_lse
from repro.kernels.paged_decode_attention import \
    paged_prefill_attention as _paged_prefill
from repro.kernels.paged_decode_attention import combine_lse
from repro.kernels.paged_decode_attention import \
    paged_verify_attention as _paged_verify
from repro.kernels.rwkv6_wkv import rwkv6_wkv as _wkv


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=256,
                    block_k=256, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=interpret)


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, positions, *, block_k=512, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _decode(q, k, v, positions, block_k=block_k, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, positions, *,
                           interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _paged_decode(q, k_pool, v_pool, block_tables, positions,
                         interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_lse(q, k_pool, v_pool, block_tables, positions,
                               owned, *, interpret=None):
    """Per-KV-shard paged decode: (o, lse) over the owned blocks only;
    merge shards with ``combine_lse``."""
    if interpret is None:
        interpret = _default_interpret()
    return _paged_decode_lse(q, k_pool, v_pool, block_tables, positions,
                             owned, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention(q, k_pool, v_pool, block_tables, starts, *,
                            interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _paged_prefill(q, k_pool, v_pool, block_tables, starts,
                          interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def paged_verify_attention(q, k_pool, v_pool, block_tables, positions, *,
                           interpret=None):
    """Verify K+1 query positions per lane in one paged-attention pass."""
    if interpret is None:
        interpret = _default_interpret()
    return _paged_verify(q, k_pool, v_pool, block_tables, positions,
                         interpret=interpret)


@partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_wkv(r, k, v, w, u, s0, *, block_t=64, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _wkv(r, k, v, w, u, s0, block_t=block_t, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def int8_matmul_quantized(x, w, *, interpret=None):
    """Quantize bf16/f32 operands on the fly and run the w8a8 GEMM."""
    if interpret is None:
        interpret = _default_interpret()
    x_q, sx = quantize_rows(x)
    w_q, sw = quantize_cols(w)
    return _int8_mm(x_q, w_q, sx, sw, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def int8_matmul(x_q, w_q, sx, sw, *, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _int8_mm(x_q, w_q, sx, sw, interpret=interpret)


__all__ = ["flash_attention", "decode_attention", "paged_decode_attention",
           "paged_decode_attention_lse", "combine_lse",
           "paged_prefill_attention", "paged_verify_attention", "rwkv6_wkv",
           "int8_matmul", "int8_matmul_quantized", "quantize_rows",
           "quantize_cols"]
