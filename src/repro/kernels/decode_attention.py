"""Flash-decode — Pallas TPU kernel for one-token attention over a long KV
cache.

q packs all heads of one sequence into a single (H, D) MXU operand; the grid
walks KV blocks sequentially with running (m, l, acc) scratch, masking by
per-sequence position.  GQA is computed grouped — q reshaped (K, G, D)
against k (bk, K, D) — so kv never expands.  This is the kernel counterpart
of the sequence-sharded decode core in models/attention.py: on a real pod
each model rank runs it over its local KV shard and LSE-combines via psum.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                   scale: float, block_k: int, groups: int):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    pos = pos_ref[0]
    k_lo = j * block_k

    @pl.when(k_lo <= pos)
    def _body():
        q = q_ref[0].astype(jnp.float32)      # (H, D), H = K*G
        k = k_ref[0].astype(jnp.float32)      # (bk, K, D)
        v = v_ref[0].astype(jnp.float32)
        K = k.shape[1]
        qg = q.reshape(K, groups, q.shape[-1])
        # scores (K, G, bk)
        s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        sh = s.reshape(K * groups, block_k)   # (H, bk)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(sh, axis=1))
        p = jnp.exp(sh - m_new[:, None]).reshape(K, groups, block_k)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=2).reshape(-1)
        # (K, G, bk) x (bk, K, D) -> (K, G, D)
        o = jax.lax.dot_general(p, v, (((2,), (0,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32)
        acc_sc[...] = acc_sc[...] * corr[:, None] + o.reshape(K * groups, -1)
        m_sc[...] = m_new

    @pl.when(j == nj - 1)
    def _finish():
        denom = jnp.maximum(l_sc[...], 1e-30)[:, None]
        o_ref[0] = (acc_sc[...] / denom).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     positions: jax.Array, *, scale: float | None = None,
                     block_k: int = 512, interpret: bool = False) -> jax.Array:
    """q: (B, H, D); k/v: (B, S, K, D); positions: (B,) -> o (B, H, D)."""
    B, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    assert H % K == 0
    groups = H // K
    block_k = min(block_k, S)
    assert S % block_k == 0
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    grid = (B, S // block_k)

    kern = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                             groups=groups)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, H, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, K, D), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_k, K, D), lambda b, j: (b, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(positions, q, k, v)
