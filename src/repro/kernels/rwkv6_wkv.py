"""RWKV6 WKV recurrence — Pallas TPU kernel.

State S (D_k x D_v) per (batch, head) lives in VMEM scratch across the
sequential time-block grid dimension; each block applies ``bt`` recurrence
steps with data-dependent per-channel decay:

    y_t = r_t . (S + (u*k_t) v_t^T);   S <- diag(w_t) S + k_t v_t^T

The in-block loop is a fori_loop over rows of the (bt, D) VMEM tiles —
outer products and (D,) x (D,D) contractions hit the MXU/VPU directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, s_out_ref,
                s_sc, *, block_t: int):
    t = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(t == 0)
    def _init():
        s_sc[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)  # (D,)

    def step(i, s):
        rt = r_ref[0, i, 0].astype(jnp.float32)  # (D,)
        kt = k_ref[0, i, 0].astype(jnp.float32)
        vt = v_ref[0, i, 0].astype(jnp.float32)
        wt = w_ref[0, i, 0].astype(jnp.float32)
        at = kt[:, None] * vt[None, :]           # (Dk, Dv)
        y = (rt[None, :] @ (s + (u * kt)[:, None] * vt[None, :]))[0]
        y_ref[0, i, 0] = y.astype(y_ref.dtype)
        return wt[:, None] * s + at

    s_sc[...] = jax.lax.fori_loop(0, block_t, step, s_sc[...])

    @pl.when(t == nt - 1)
    def _finish():
        s_out_ref[0, 0] = s_sc[...].astype(s_out_ref.dtype)


def rwkv6_wkv(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, s0: jax.Array, *, block_t: int = 64,
              interpret: bool = False):
    """r/k/v/w: (B, T, H, D); u: (H, D); s0: (B, H, D, D).

    Returns (y (B, T, H, D), s_final (B, H, D, D)).
    """
    B, T, H, D = r.shape
    block_t = min(block_t, T)
    assert T % block_t == 0
    grid = (B, H, T // block_t)

    kern = functools.partial(_wkv_kernel, block_t=block_t)
    seq_spec = pl.BlockSpec((1, block_t, 1, D), lambda b, h, t: (b, t, h, 0))
    y, s_f = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, D), lambda b, h, t: (h, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, D, D), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(r.shape, r.dtype),
            jax.ShapeDtypeStruct(s0.shape, jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_f
