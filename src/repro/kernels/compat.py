"""Version-portable aliases for the Pallas TPU API.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
kernels in this package run on both spellings so the pinned container jax
and newer toolchains compile the same source.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def tpu_compiler_params(dimension_semantics: tuple):
    return CompilerParams(dimension_semantics=dimension_semantics)
