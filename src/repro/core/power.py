"""Power models — paper §2.2, Eq. (4) — vectorized JAX.

Server power is a polynomial in chip utilization (idle draw is significant;
fans/CPU/memory follow load — §2.2), aggregated to rows against the
provisioned row envelope.  Capping scales chip frequency (=> util) down
until the row fits, mirroring hardware power capping.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.datacenter import Datacenter


@dataclass
class PowerModel:
    idle_w: jnp.ndarray        # (S,)
    dyn_w: jnp.ndarray         # (S,) peak-idle
    quad_frac: jnp.ndarray     # (S,) fraction of dynamic power that is ~util^2
    fan_w: jnp.ndarray         # (S,) fan power at full airflow

    @staticmethod
    def calibrate(dc: Datacenter) -> "PowerModel":
        cfg = dc.cfg
        rng = np.random.default_rng(cfg.seed + 2)
        s = dc.n_servers
        idle = cfg.hw.idle_power_w * rng.uniform(0.95, 1.05, s)
        fan = 0.06 * cfg.hw.peak_power_w * np.ones(s)
        dyn = (cfg.hw.peak_power_w - idle - fan) * rng.uniform(0.97, 1.03, s)
        quad = rng.uniform(0.3, 0.45, s)
        return PowerModel(jnp.asarray(idle), jnp.asarray(dyn),
                          jnp.asarray(quad), jnp.asarray(fan))

    def server_power(self, chip_util):
        """chip_util: (S, 8) in [0,1] -> watts (S,). Polynomial f_power."""
        u = jnp.mean(chip_util, axis=1)
        dyn = self.dyn_w * ((1 - self.quad_frac) * u + self.quad_frac * u * u)
        return self.idle_w + dyn + self.fan_w * u

    def max_util_for_power(self, budget_w):
        """Invert server_power: mean-util cap under a per-server budget."""
        a = self.quad_frac * self.dyn_w
        b = (1 - self.quad_frac) * self.dyn_w + self.fan_w
        c = self.idle_w - jnp.asarray(budget_w)
        disc = jnp.maximum(b * b - 4 * a * c, 0.0)
        u = (-b + jnp.sqrt(disc)) / (2 * a)
        return jnp.clip(u, 0.0, 1.0)


def row_power(dc: Datacenter, power_s) -> jnp.ndarray:
    """Eq. 4 LHS: per-row aggregate watts."""
    row = jax.nn.one_hot(jnp.asarray(dc.row_of), dc.n_rows, dtype=jnp.float32)
    return jnp.asarray(power_s) @ row


def capping_factors(dc: Datacenter, power_s, limits_w, pm: PowerModel,
                    *, iaas_only_mask=None):
    """Rows over budget -> per-server frequency (util) scale factors.

    Baseline semantics (§5.4): uniform scaling across the row's servers
    (optionally restricted to a mask, e.g. IaaS-only last-resort capping).
    Returns (S,) multiplicative util factors in (0, 1]."""
    p_row = row_power(dc, power_s)
    limits = jnp.asarray(limits_w)
    over = jnp.clip(p_row / jnp.maximum(limits, 1.0), 1.0, None)  # (R,)
    # dynamic power is roughly linear in util at high load: cut utilization
    # by the row overshoot applied to the dynamic fraction
    p_srv = jnp.asarray(power_s)
    dyn_frac = jnp.clip((p_srv - pm.idle_w) / jnp.maximum(p_srv, 1.0), 0.05, 1.0)
    row_over = over[jnp.asarray(dc.row_of)]
    needed_cut = (row_over - 1.0) / row_over  # fraction of row power to shed
    cut = needed_cut / dyn_frac
    if iaas_only_mask is not None:
        cut = jnp.where(jnp.asarray(iaas_only_mask), cut, 0.0)
    return jnp.clip(1.0 - cut, 0.05, 1.0)
