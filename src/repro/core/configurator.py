"""Instance Configurator — paper §4.3 / §4.5.

Per SaaS VM, pick the config point (freq, TP, batch, size, quant) that
maximizes goodput under the server's current power/temperature caps while
holding quality; reload-requiring moves (TP/size/quant) are last-resort and
pause the instance for the reload duration (requests are steered away
during transitions).  In emergencies a per-endpoint quality budget lets a
bounded fraction of load go to smaller/quantized variants (§5.4: TAPAS
takes up to −12% quality instead of capping performance).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import profiles as P
from repro.core.risk import DEFAULT_THRESHOLDS, ReconfigureThresholds
# no cycle: state.py imports allocator/profiles, never this module
from repro.core.state import ConfigChange, InstanceView


@dataclass
class VMConfigState:
    current: P.ConfigPoint = P.NOMINAL
    pause_ticks: int = 0      # draining during reload

    @property
    def entry(self) -> P.ProfileEntry:
        return P._entry(self.current)


class InstanceConfigurator:
    def __init__(self, *, tick_s: float = 300.0,
                 quality_floor: float = 1.0,
                 emergency_quality_floor: float = 0.85):
        self.entries = P.build_profile()
        self.tick_s = tick_s
        self.quality_floor = quality_floor
        self.emergency_floor = emergency_quality_floor
        self.state: dict[int, VMConfigState] = {}

    def get(self, vm_id: int) -> VMConfigState:
        return self.state.setdefault(vm_id, VMConfigState())

    def tick(self) -> None:
        for st in self.state.values():
            if st.pause_ticks > 0:
                st.pause_ticks -= 1

    def decide(self, vm_id: int, *, power_cap: float, temp_cap: float,
               emergency: bool = False,
               min_goodput: float = 0.0) -> VMConfigState:
        """Update the VM's config for the new caps (fractions of nominal)."""
        st = self.get(vm_id)
        floor = self.emergency_floor if emergency else self.quality_floor
        choice = P.best_config(self.entries, power_cap=power_cap,
                               temp_cap=temp_cap, min_quality=floor,
                               current=st.current,
                               min_goodput=min_goodput if emergency else 0.0)
        if choice is None and emergency:
            # deepest emergency: any quality, minimum power point
            feas = [e for e in self.entries
                    if e.power_frac <= power_cap and e.temp_frac <= temp_cap]
            choice = max(feas, key=lambda e: e.goodput) if feas else None
        if choice is None:
            return st  # nothing fits: capping layer will handle it
        if choice.cfg != st.current:
            if choice.cfg.needs_reload_from(st.current):
                st.pause_ticks = max(
                    1, int(round(choice.cfg.reload_cost_s / self.tick_s)))
            st.current = choice.cfg
        return st

    def reset(self, vm_id: int) -> None:
        self.state.pop(vm_id, None)


class ReconfigurePolicy:
    """``ControlPolicy`` reconfigure/lifecycle adapter over the
    ``InstanceConfigurator``.

    ``begin_tick`` advances reload countdowns and publishes every SaaS
    server's current config into ``state.instances``; ``reconfigure`` runs
    the §4.3 loop — servers whose risk exceeds ``thresholds.hot_risk`` get
    power/temperature caps proportional to their remaining margin, servers
    back under ``thresholds.cool_risk`` are restored to nominal — and
    returns the ``ConfigChange`` list so engine backends can mirror the
    decisions onto real serving engines.  ``active=False`` (Baseline)
    publishes telemetry but never reconfigures.
    """

    def __init__(self, configurator: InstanceConfigurator, *,
                 active: bool,
                 thresholds: ReconfigureThresholds | None = None):
        self.configurator = configurator
        self.active = active
        self.thresholds = thresholds or DEFAULT_THRESHOLDS

    def begin_tick(self, state) -> None:
        self.configurator.tick()
        for srv in np.flatnonzero(state.kind == 2):
            st = self.configurator.get(int(srv))
            state.instances[int(srv)] = InstanceView(
                entry=st.entry, paused=st.pause_ticks > 0)

    def release(self, state, server: int) -> None:
        self.configurator.reset(server)

    def _publish(self, state, srv: int, st: VMConfigState,
                 before: P.ConfigPoint, changes: list) -> None:
        reloading = st.pause_ticks > 0
        state.instances[srv] = InstanceView(entry=st.entry, paused=reloading)
        if st.current != before:
            changes.append(ConfigChange(server=srv, entry=st.entry,
                                        reloading=reloading))

    def reconfigure(self, state) -> list:
        if not self.active:
            return []
        th = self.thresholds
        changes: list = []
        hot = state.risk > th.hot_risk
        for srv in np.flatnonzero((state.kind == 2) & hot):
            margin = 1.0 - state.risk[srv]
            before = self.configurator.get(int(srv)).current
            st = self.configurator.decide(
                int(srv),
                power_cap=max(th.cap_floor, margin + th.hot_risk),
                temp_cap=max(th.cap_floor, margin + th.hot_risk),
                emergency=state.emergency,
                min_goodput=float(state.saas_load[srv])
                * state.nominal.goodput)
            self._publish(state, int(srv), st, before, changes)
        # restore drained servers once their risk clears
        cool = state.risk < th.cool_risk
        for srv in np.flatnonzero((state.kind == 2) & cool):
            st0 = self.configurator.state.get(int(srv))
            if st0 is not None and st0.current != P.NOMINAL:
                before = st0.current
                st = self.configurator.decide(
                    int(srv), power_cap=1.0, temp_cap=th.restore_temp_cap)
                self._publish(state, int(srv), st, before, changes)
        return changes
