"""Seeded production-like traces (paper §3, Figs. 12–14).

- IaaS VMs: opaque, whole-server, diurnal utilization with customer
  templates (predictable: row-level error <10% — Fig. 14) and long lifetimes
  (>60% beyond two weeks — Fig. 12a).
- SaaS endpoints: LLM inference services, 23–100 VMs each (Fig. 12b),
  diurnal request load with sharper peaks.
- VM arrivals: Poisson, 50/50 IaaS/SaaS by default (§5.1).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass

import numpy as np


def _stable_seed(*parts) -> int:
    """Process-stable 32-bit seed for string-keyed traces.

    ``hash(str)`` is randomized per interpreter process, which made trace
    phases — and therefore every simulation metric — unreproducible across
    runs of the same seed.  CRC32 of the repr is stable everywhere.
    """
    return zlib.crc32(repr(parts).encode())


def trace_seed(seed: int, namespace: str = "") -> int:
    """Namespace a trace seed by region name (crc32, process-stable).

    Two regions of a fleet configured with the same ``seed`` must not
    replay identical weather wobble, customer phases and endpoint peaks —
    that would make every region's thermal trajectory a copy and
    cross-region steering trivially pointless.  An empty namespace returns
    ``seed`` unchanged, so single-cluster runs (and their golden parity
    numbers) are bit-identical to the pre-fleet behavior.
    """
    if not namespace:
        return seed
    # int32-safe: the seed reaches jitted JAX code (weather wobble phase)
    return _stable_seed("region", namespace, seed) % (2 ** 31)


@dataclass
class VMSpec:
    vm_id: int
    kind: str                  # "iaas" | "saas"
    customer: str              # IaaS: customer template; SaaS: endpoint name
    arrival_h: float
    lifetime_h: float
    peak_util: float           # predicted peak chip utilization


@dataclass
class Workload:
    vms: list
    endpoints: dict            # name -> list of SaaS vm_ids
    horizon_h: float

    def endpoint_of(self, vm_id: int) -> str | None:
        for name, ids in self.endpoints.items():
            if vm_id in ids:
                return name
        return None


def _lifetime(rng) -> float:
    """Fig. 12a: >60% of VMs live over two weeks."""
    if rng.random() < 0.62:
        return float(rng.uniform(14 * 24, 8 * 7 * 24))
    return float(rng.lognormal(mean=3.3, sigma=1.2))  # hours, median ~27h


def generate_workload(*, n_servers: int, horizon_h: float, seed: int = 0,
                      saas_fraction: float = 0.5, occupancy: float = 0.92,
                      n_endpoints: int = 10) -> Workload:
    rng = np.random.default_rng(seed + 3)
    n_vms = int(n_servers * occupancy)
    n_saas = int(n_vms * saas_fraction)
    n_iaas = n_vms - n_saas

    vms: list[VMSpec] = []
    # endpoint sizes 23..100 (Fig. 12b), scaled to the SaaS pool
    sizes = rng.integers(23, 101, n_endpoints).astype(float)
    sizes = np.maximum((sizes / sizes.sum() * n_saas).astype(int), 1)
    endpoints: dict[str, list] = {}
    vid = 0
    for e in range(n_endpoints):
        name = f"ep{e}"
        endpoints[name] = []
        for _ in range(int(sizes[e])):
            # endpoints scale up over days; arrivals interleave with IaaS
            vms.append(VMSpec(vid, "saas", name,
                              arrival_h=float(rng.uniform(0, horizon_h * 0.25)),
                              lifetime_h=horizon_h * 2,
                              peak_util=1.0))
            endpoints[name].append(vid)
            vid += 1
    for i in range(n_iaas):
        cust = f"cust{rng.integers(0, 6)}"  # few big customers => sync'd rows
        vms.append(VMSpec(vid, "iaas", cust,
                          arrival_h=float(rng.uniform(0, horizon_h * 0.3)),
                          lifetime_h=_lifetime(rng),
                          peak_util=float(rng.uniform(0.55, 1.0))))
        vid += 1
    return Workload(vms=vms, endpoints=endpoints, horizon_h=horizon_h)


# ---------------------------------------------------------------------------
# load traces
# ---------------------------------------------------------------------------

_CUST_PHASE: dict[str, float] = {}


def iaas_util(vm: VMSpec, t_h: np.ndarray, *, seed: int = 0) -> np.ndarray:
    """Diurnal utilization trace in [0,1] for an IaaS VM (Fig. 13a)."""
    key = (vm.customer, seed)  # cache keyed by seed: cross-run determinism
    if key not in _CUST_PHASE:
        rng = np.random.default_rng(_stable_seed(*key))
        _CUST_PHASE[key] = float(rng.uniform(0, 24))
    phase = _CUST_PHASE[key]
    rng = np.random.default_rng((vm.vm_id, seed))
    base = 0.62 + 0.3 * np.sin(2 * np.pi * (t_h - phase) / 24.0)
    noise = 0.08 * rng.standard_normal(np.shape(t_h))
    burst = (rng.random(np.shape(t_h)) < 0.02) * rng.uniform(0.1, 0.3)
    return np.clip(vm.peak_util * (base + noise + burst), 0.02, vm.peak_util)


def endpoint_load(name: str, t_h: np.ndarray, *, seed: int = 0) -> np.ndarray:
    """Aggregate request load for a SaaS endpoint, normalized to [0,1]
    per-VM-equivalent units (1.0 == every VM fully busy)."""
    rng = np.random.default_rng(_stable_seed(name, seed))
    phase = rng.uniform(7, 11)  # business-hours peak
    sharp = rng.uniform(1.2, 2.2)
    base = 0.45 + 0.55 * np.maximum(
        np.sin(2 * np.pi * (t_h - phase) / 24.0), 0.0) ** sharp
    spikes = (rng.random(np.shape(t_h)) < 0.01) * rng.uniform(0.15, 0.35)
    noise = 0.05 * np.random.default_rng((_stable_seed(name) % 997, seed)) \
        .standard_normal(np.shape(t_h))
    return np.clip(base + spikes + noise, 0.05, 1.0)


def carbon_intensity(t_h: np.ndarray, *, seed: int = 0,
                     namespace: str = "") -> np.ndarray:
    """Relative grid carbon intensity over time (1.0 == fleet-mean grid).

    Diurnal shape of a mixed solar/fossil grid: intensity dips through the
    midday solar window and peaks into the evening ramp, with a small
    seeded wobble.  ``namespace`` is the region's trace namespace (see
    ``trace_seed``) so two regions of a fleet never replay an identical
    grid — phases, solar depth and evening ramp all differ per region —
    while the trace stays deterministic per (seed, namespace).  Values are
    clipped to [0.3, 1.8]; multiply by a region's ``carbon_scale`` for the
    absolute dirtiness of its grid.
    """
    t_h = np.asarray(t_h, dtype=float)
    rng = np.random.default_rng(_stable_seed("carbon", namespace, seed))
    solar_mid = rng.uniform(12.0, 14.0)     # center of the solar dip
    solar_depth = rng.uniform(0.25, 0.45)
    evening_peak = rng.uniform(17.5, 20.5)
    evening_gain = rng.uniform(0.15, 0.35)
    # half-cosine windows: a 8h solar dip and a 6h evening fossil ramp
    solar = np.cos(np.clip((t_h % 24.0 - solar_mid) / 4.0, -1.0, 1.0)
                   * np.pi / 2.0)
    evening = np.cos(np.clip((t_h % 24.0 - evening_peak) / 3.0, -1.0, 1.0)
                     * np.pi / 2.0)
    wobble = 0.03 * np.sin(2 * np.pi * (t_h - rng.uniform(0, 24)) / 24.0)
    out = 1.0 - solar_depth * solar + evening_gain * evening + wobble
    return np.clip(out, 0.3, 1.8)


def predict_peak_util(vm: VMSpec, *, history_h: float = 168.0,
                      seed: int = 0, quantile: float = 0.99) -> float:
    """Template-based peak prediction (paper §4.1/§4.5: previous-week P99;
    under-prediction <4% of row-hours)."""
    t = np.arange(0, history_h, 1.0)
    if vm.kind == "iaas":
        return float(np.quantile(iaas_util(vm, t, seed=seed), quantile))
    return 1.0  # endpoints can always spike to full
