"""LLM instance profiles + Pareto frontier (paper §3.3, Figs. 15–16).

The offline profiling phase measures goodput / power / peak-temperature /
quality for every configuration point (GPU frequency, tensor parallelism,
batch size, model size, quantization).  On real hardware this comes from
running the serving engine; here the canonical profile is calibrated to the
paper's published curves, and bench_profiles.py cross-checks the *relative*
shape against our engine on reduced-size models.

Conventions: goodput normalized to the best config = 1.0; power/temp
normalized to server TDP / temp-at-TDP = 1.0; quality in [0,1]
(Llama2-70B=1.0; 7B is 30–40% lower — paper §3.3).
"""
from __future__ import annotations

from dataclasses import dataclass
from itertools import product

FREQS = (0.6, 0.7, 0.8, 0.9, 1.0)
TPS = (2, 4, 8)
BATCHES = (1, 16, 64)
SIZES = ("70b", "13b", "7b")
QUANTS = ("bf16", "int8")

_SIZE = {  # speedup vs 70B, quality, compute intensity
    "70b": (1.0, 1.00, 1.00),
    "13b": (3.6, 0.85, 0.55),
    "7b": (6.0, 0.62, 0.40),
}
_QUANT = {  # speedup, quality delta, power scale
    "bf16": (1.0, 0.0, 1.0),
    "int8": (1.45, -0.08, 0.82),
}


@dataclass(frozen=True)
class ConfigPoint:
    freq: float
    tp: int
    batch: int
    size: str
    quant: str

    @property
    def reload_cost_s(self) -> float:
        """§4.3: freq is instant; batch is cheap; TP/size/quant reload."""
        return 0.0 if self.tp == 8 and self.size == "70b" and \
            self.quant == "bf16" else 8.0

    def needs_reload_from(self, other: "ConfigPoint") -> bool:
        return (self.tp, self.size, self.quant) != \
            (other.tp, other.size, other.quant)


@dataclass(frozen=True)
class ProfileEntry:
    cfg: ConfigPoint
    goodput: float     # tokens/s, normalized
    power: float       # fraction of server TDP
    temp: float        # hottest-chip util-equivalent in [0,1]
    quality: float


def _entry(c: ConfigPoint) -> ProfileEntry:
    size_speed, qual, intensity = _SIZE[c.size]
    qspeed, qqual, qpow = _QUANT[c.quant]
    # goodput: prompt phase ~ freq-sensitive (paper: prefill more sensitive);
    # batching amortizes weights until SLO pressure at 64
    batch_eff = {1: 0.25, 16: 0.85, 64: 1.0}[c.batch]
    tp_eff = {8: 1.0, 4: 0.80, 2: 0.55}[c.tp]
    goodput = (c.freq ** 0.85) * batch_eff * tp_eff * size_speed * qspeed
    # power: fewer active chips with lower TP lowers SERVER power; per-chip
    # power rises (work concentrates) -> temp of hottest chip up (paper §3.3)
    util = intensity * batch_eff
    chips_frac = c.tp / 8.0
    per_chip = util * (0.55 + 0.45 * c.freq ** 2.2) / chips_frac ** 0.35
    power = chips_frac * per_chip * qpow
    temp = min(per_chip * qpow, 1.35)
    quality = max(qual + qqual, 0.0)
    return ProfileEntry(c, goodput=goodput, power=min(power, 1.0),
                        temp=temp, quality=quality)


def build_profile() -> list:
    """All config points (Fig. 16 scatter)."""
    out = []
    for f, tp, b, s, q in product(FREQS, TPS, BATCHES, SIZES, QUANTS):
        out.append(_entry(ConfigPoint(f, tp, b, s, q)))
    return out


def pareto_frontier(entries: list) -> list:
    """Configs not dominated in (goodput up, power down, temp down,
    quality up)."""
    front = []
    for e in entries:
        dominated = any(
            (o.goodput >= e.goodput and o.power <= e.power
             and o.temp <= e.temp and o.quality >= e.quality
             and (o.goodput, -o.power, -o.temp, o.quality)
             != (e.goodput, -e.power, -e.temp, e.quality))
            for o in entries)
        if not dominated:
            front.append(e)
    return front


def best_config(entries: list, *, power_cap: float, temp_cap: float,
                min_quality: float, current: ConfigPoint | None = None,
                allow_reload: bool = True,
                min_goodput: float = 0.0) -> ProfileEntry | None:
    """§4.3 Instance Configurator: maximize goodput under caps.

    Reload-requiring moves (TP/size/quant) are last-resort: a candidate that
    needs a reload is only chosen when no no-reload candidate both fits the
    caps and sustains ``min_goodput`` (the instance's assigned load) — this
    is how emergencies push load onto smaller/quantized variants (quality
    cost) instead of dropping throughput (paper §5.4)."""
    feasible = [e for e in entries
                if e.power <= power_cap + 1e-9 and e.temp <= temp_cap + 1e-9
                and e.quality >= min_quality - 1e-9]
    if not feasible:
        return None
    if current is not None:
        no_reload = [e for e in feasible
                     if not e.cfg.needs_reload_from(current)]
        sustaining = [e for e in no_reload if e.goodput >= min_goodput - 1e-9]
        if sustaining:
            return max(sustaining, key=lambda e: (e.goodput, e.quality))
        if no_reload and not allow_reload:
            return max(no_reload, key=lambda e: (e.goodput, e.quality))
        if not allow_reload:
            return None
        if no_reload and max(e.goodput for e in feasible) <= max(
                e.goodput for e in no_reload) + 1e-9:
            return max(no_reload, key=lambda e: (e.goodput, e.quality))
    return max(feasible, key=lambda e: (e.goodput, e.quality))


NOMINAL = ConfigPoint(freq=1.0, tp=8, batch=64, size="70b", quant="bf16")
