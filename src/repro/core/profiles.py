"""LLM instance profiles + Pareto frontier (paper §3.3, Figs. 15–16).

The offline profiling phase measures goodput / power / peak-temperature /
quality for every configuration point (GPU frequency, tensor parallelism,
batch size, model size, quantization).  ``measure_from_engine()`` runs that
phase for real: it sweeps the serving Engine's knobs (max_batch x
freq_scale x variant) on a reduced-size model and turns the measured
token rates into ``ProfileEntry`` rows; ``calibrate()`` then folds the
measured batch efficiencies / frequency exponent / size speedups into the
``_entry`` physics so every downstream consumer (Instance Configurator,
ClusterSim) reads engine-measured numbers through the unchanged
``_entry`` API.  The hand values below remain the paper-calibrated
defaults for axes the smoke engine cannot observe (TP, quantization).

Conventions: goodput normalized to the best config = 1.0; power/temp
normalized to server TDP / temp-at-TDP = 1.0; quality in [0,1]
(Llama2-70B=1.0; 7B is 30–40% lower — paper §3.3).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from itertools import product

FREQS = (0.6, 0.7, 0.8, 0.9, 1.0)
TPS = (2, 4, 8)
BATCHES = (1, 16, 64)
SIZES = ("70b", "13b", "7b")
QUANTS = ("bf16", "int8")

_SIZE = {  # speedup vs 70B, quality, compute intensity
    "70b": (1.0, 1.00, 1.00),
    "13b": (3.6, 0.85, 0.55),
    "7b": (6.0, 0.62, 0.40),
}
_QUANT = {  # speedup, quality delta, power scale
    "bf16": (1.0, 0.0, 1.0),
    "int8": (1.45, -0.08, 0.82),
}

# paper-curve defaults, replaced by calibrate(measure_from_engine(...))
_DEFAULT_BATCH_EFF = {1: 0.25, 16: 0.85, 64: 1.0}
_DEFAULT_FREQ_EXP = 0.85
_CAL: dict = {"batch_eff": dict(_DEFAULT_BATCH_EFF),
              "freq_exp": _DEFAULT_FREQ_EXP, "size_speed": {},
              "source": "paper-calibrated"}


@dataclass(frozen=True)
class ConfigPoint:
    freq: float
    tp: int
    batch: int
    size: str
    quant: str

    @property
    def reload_cost_s(self) -> float:
        """§4.3: freq is instant; batch is cheap; TP/size/quant reload."""
        return 0.0 if self.tp == 8 and self.size == "70b" and \
            self.quant == "bf16" else 8.0

    def needs_reload_from(self, other: "ConfigPoint") -> bool:
        return (self.tp, self.size, self.quant) != \
            (other.tp, other.size, other.quant)


@dataclass(frozen=True)
class ProfileEntry:
    cfg: ConfigPoint
    goodput: float      # tokens/s, normalized
    power_frac: float   # fraction of server TDP
    temp_frac: float    # hottest-chip util-equivalent in [0,1]
    quality: float


def _per_chip_power(util: float, freq: float, chips_frac: float = 1.0) -> float:
    """Per-active-chip draw: static+dynamic split over frequency; work
    concentrates (draw rises) as fewer chips share it (paper §3.3)."""
    return util * (0.55 + 0.45 * freq ** 2.2) / chips_frac ** 0.35


def _entry(c: ConfigPoint) -> ProfileEntry:
    size_speed, qual, intensity = _SIZE[c.size]
    size_speed = _CAL["size_speed"].get(c.size, size_speed)
    qspeed, qqual, qpow = _QUANT[c.quant]
    # goodput: prompt phase ~ freq-sensitive (paper: prefill more sensitive);
    # batching amortizes weights until SLO pressure at the top knob
    batch_eff = _CAL["batch_eff"][c.batch]
    tp_eff = {8: 1.0, 4: 0.80, 2: 0.55}[c.tp]
    goodput = (c.freq ** _CAL["freq_exp"]) * batch_eff * tp_eff \
        * size_speed * qspeed
    # power: fewer active chips with lower TP lowers SERVER power; per-chip
    # power rises (work concentrates) -> temp of hottest chip up (paper §3.3)
    util = intensity * batch_eff
    chips_frac = c.tp / 8.0
    per_chip = _per_chip_power(util, c.freq, chips_frac)
    power = chips_frac * per_chip * qpow
    temp = min(per_chip * qpow, 1.35)
    quality = max(qual + qqual, 0.0)
    return ProfileEntry(c, goodput=goodput, power_frac=min(power, 1.0),
                        temp_frac=temp, quality=quality)


def build_profile() -> list:
    """All config points (Fig. 16 scatter)."""
    out = []
    for f, tp, b, s, q in product(FREQS, TPS, BATCHES, SIZES, QUANTS):
        out.append(_entry(ConfigPoint(f, tp, b, s, q)))
    return out


def pareto_frontier(entries: list) -> list:
    """Configs not dominated in (goodput up, power down, temp down,
    quality up)."""
    front = []
    for e in entries:
        dominated = any(
            (o.goodput >= e.goodput and o.power_frac <= e.power_frac
             and o.temp_frac <= e.temp_frac and o.quality >= e.quality
             and (o.goodput, -o.power_frac, -o.temp_frac, o.quality)
             != (e.goodput, -e.power_frac, -e.temp_frac, e.quality))
            for o in entries)
        if not dominated:
            front.append(e)
    return front


def best_config(entries: list, *, power_cap: float, temp_cap: float,
                min_quality: float, current: ConfigPoint | None = None,
                allow_reload: bool = True,
                min_goodput: float = 0.0) -> ProfileEntry | None:
    """§4.3 Instance Configurator: maximize goodput under caps.

    Reload-requiring moves (TP/size/quant) are last-resort: a candidate that
    needs a reload is only chosen when no no-reload candidate both fits the
    caps and sustains ``min_goodput`` (the instance's assigned load) — this
    is how emergencies push load onto smaller/quantized variants (quality
    cost) instead of dropping throughput (paper §5.4)."""
    feasible = [e for e in entries
                if e.power_frac <= power_cap + 1e-9 and e.temp_frac <= temp_cap + 1e-9
                and e.quality >= min_quality - 1e-9]
    if not feasible:
        return None
    if current is not None:
        no_reload = [e for e in feasible
                     if not e.cfg.needs_reload_from(current)]
        sustaining = [e for e in no_reload if e.goodput >= min_goodput - 1e-9]
        if sustaining:
            return max(sustaining, key=lambda e: (e.goodput, e.quality))
        if no_reload and not allow_reload:
            return max(no_reload, key=lambda e: (e.goodput, e.quality))
        if not allow_reload:
            return None
        if no_reload and max(e.goodput for e in feasible) <= max(
                e.goodput for e in no_reload) + 1e-9:
            return max(no_reload, key=lambda e: (e.goodput, e.quality))
    return max(feasible, key=lambda e: (e.goodput, e.quality))


NOMINAL = ConfigPoint(freq=1.0, tp=8, batch=64, size="70b", quant="bf16")


# ---------------------------------------------------------------------------
# engine-measured profiles (paper's offline profiling phase, §3.3)
# ---------------------------------------------------------------------------

@dataclass
class MeasuredProfile:
    """Engine-measured goodput sweep + the calibration it implies.

    rows: one dict per swept knob point with the raw measured token rate;
    entries: the same points as ProfileEntry rows (goodput normalized to
    the best measured point, power/temp from the _entry physics driven by
    the measured efficiencies); calibration: overrides for _entry.
    """
    rows: list = field(default_factory=list)
    entries: list = field(default_factory=list)
    calibration: dict = field(default_factory=dict)


def _snap(value: float, grid: tuple) -> float:
    return min(grid, key=lambda g: abs(g - value))


def measure_from_engine(*, arch: str = "llama2-7b",
                        batches: tuple = (1, 2, 4),
                        freqs: tuple = (0.6, 0.8, 1.0),
                        variants: tuple = (("full", "70b"), ("small", "7b")),
                        n_requests: int = 8, prompt_len: int = 8,
                        max_new: int = 10, max_seq: int = 96,
                        seed: int = 0) -> MeasuredProfile:
    """Run the offline profiling phase on the real serving engine.

    Sweeps EngineKnobs (max_batch x freq_scale x variant) on a smoke-scale
    model and measures decode tokens per wall-second at each point.  The
    measured batch knobs map onto the profile's BATCHES axis by rank and
    each engine variant onto a SIZES entry (``variants`` pairs knob name
    with size), so the emitted ProfileEntry rows slot straight into the
    configurator/simulator tables.  One engine per variant is built and
    its (mutable) batch/freq knobs swept in place, so every jitted
    prefill bucket and the decode step compile exactly once per variant.
    """
    if len(batches) > len(BATCHES):
        raise ValueError(f"at most {len(BATCHES)} batch knobs map onto the "
                         f"profile's BATCHES axis, got {batches}")
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import build_model, local_plan
    from repro.serving import Engine, EngineKnobs, EngineStats, Request

    cfg_full = get_config(arch).smoke_config()
    cfg_small = cfg_full.replace(num_layers=1, d_ff=max(cfg_full.d_ff // 2, 8),
                                 name=f"{cfg_full.name}-small")
    plan = local_plan(param_dtype=jnp.bfloat16)
    models = {"full": build_model(cfg_full, plan),
              "small": build_model(cfg_small, plan)}
    n_lanes = max(batches)
    rows = []
    for vi, (vname, size) in enumerate(variants):
        model = models[vname]
        params = model.init(jax.random.PRNGKey(vi))
        eng = Engine(model, params, max_seq=max_seq, n_slots=n_lanes,
                     knobs=EngineKnobs(max_batch=n_lanes))

        def submit_load(rng):
            for _ in range(n_requests):
                eng.submit(Request(
                    prompt=[int(t) for t in rng.integers(
                        0, cfg_full.vocab_size, prompt_len)],
                    max_new_tokens=max_new))

        for batch in batches:
            eng.knobs.max_batch = batch
            eng.knobs.freq_scale = 1.0
            # warmup: compile this knob point's prefill buckets + decode
            # step so measured step times are steady-state, not jit traces
            eng.stats = EngineStats()
            submit_load(np.random.default_rng(seed))
            eng.run()
            for freq in freqs:
                eng.knobs.freq_scale = freq
                eng.stats = EngineStats()
                submit_load(np.random.default_rng(seed))
                stats = eng.run()
                wall = max(stats.step_time_total, 1e-9)
                rows.append({
                    "variant": vname, "size": size, "batch": batch,
                    "freq": freq, "tok_per_s": stats.decode_tokens / wall,
                    "decode_tokens": stats.decode_tokens,
                    "preemptions": stats.preemptions,
                })

    # --- calibration: batch efficiency, freq exponent, size speedup ------
    def rate(vname, batch, freq):
        return next(r["tok_per_s"] for r in rows
                    if r["variant"] == vname and r["batch"] == batch
                    and r["freq"] == freq)

    f_top = max(freqs)
    b_top = max(batches)
    base = variants[0][0]
    top_rate = rate(base, b_top, f_top)
    # measured batch knobs map onto the profile's BATCHES axis by rank,
    # aligned at the top (the biggest measured batch defines eff = 1.0);
    # unmeasured low knobs conservatively inherit the smallest measured eff
    eff_of = {b: min(rate(base, b, f_top) / max(top_rate, 1e-9), 1.0)
              for b in batches}
    knob_of = dict(zip(sorted(batches)[::-1], BATCHES[::-1]))
    batch_eff = {knob: min(eff_of.values()) for knob in BATCHES}
    for b, knob in knob_of.items():
        batch_eff[knob] = eff_of[b]
    exps = [math.log(max(rate(base, b_top, f) / max(top_rate, 1e-9), 1e-9))
            / math.log(f) for f in freqs if f != f_top]
    freq_exp = float(np.clip(np.mean(exps), 0.3, 2.0)) if exps \
        else _DEFAULT_FREQ_EXP
    size_speed = {}
    for vname, size in variants:
        size_speed[size] = rate(vname, b_top, f_top) / max(top_rate, 1e-9)
    calibration = {"batch_eff": batch_eff, "freq_exp": freq_exp,
                   "size_speed": size_speed, "source": "engine-measured"}

    # --- ProfileEntry rows for the measured points ------------------------
    best = max(r["tok_per_s"] for r in rows)
    entries = []
    for r in rows:
        c = ConfigPoint(freq=_snap(r["freq"], FREQS), tp=8,
                        batch=knob_of[r["batch"]], size=r["size"],
                        quant="bf16")
        _, qual, intensity = _SIZE[c.size]
        util = intensity * batch_eff[c.batch]
        per_chip = _per_chip_power(util, c.freq)   # measured points run tp=8
        entries.append(ProfileEntry(
            c, goodput=r["tok_per_s"] / max(best, 1e-9),
            power_frac=min(per_chip, 1.0), temp_frac=min(per_chip, 1.35),
            quality=qual))
    return MeasuredProfile(rows=rows, entries=entries,
                           calibration=calibration)


def calibrate(measured: MeasuredProfile) -> None:
    """Fold engine measurements into the ``_entry`` physics so the
    configurator and ClusterSim consume measured numbers through the
    unchanged API (acceptance: nominal entries come from the engine)."""
    _CAL.update(measured.calibration)


def reset_calibration() -> None:
    _CAL.update(batch_eff=dict(_DEFAULT_BATCH_EFF),
                freq_exp=_DEFAULT_FREQ_EXP, size_speed={},
                source="paper-calibrated")
