"""Fault injection + graceful degradation: the failure model under TAPAS.

The paper's headline claim is *emergency handling* — cooling and power
failures absorbed by exploiting SaaS adaptability — but emergencies in a
real fleet are rarely just thermal: serving processes crash, accelerators
emit NaNs, KV memory corrupts, and the telemetry the control plane steers
on goes stale exactly when it matters.  This module defines the
deterministic, seeded fault model the serving tier is hardened against:

* ``EngineFault`` — a windowed fault targeting one bound engine backend
  (or all of them): process crash/restart, NaN-logit burst, KV-block
  corruption, a stuck-slow lane, or a drafter failure.
* ``SensorDropout`` — a window during which the cluster's derived
  telemetry (inlet estimate, risk, thermal ceilings) freezes at its
  last-known-good reading; ``ClusterState.telemetry_age_ticks`` counts
  how stale the frozen snapshot is so policies steer conservatively
  instead of trusting a lying sensor.
* ``ResilienceKnobs`` — the recovery machinery's switches (watchdog,
  re-queue-on-crash, NaN guard, degradation ladder, stale-risk bump).
  ``recovery_off()`` disables all of it — the ablation arm of the
  fault-storm drill (``benchmarks/bench_resilience.py``).
* ``DegradationLadder`` — the SaaS-flexibility story made explicit: under
  an emergency the reconfigure phase walks an engine down the ladder
  (drop drafter -> shrink horizon -> force quantized variant -> cap
  max_batch) one rung per tick, and unwinds it rung by rung once the
  emergency clears and stays clear.

Both event types validate at construction and slot into ``Scenario``
exactly like the existing events (region tags, ``for_region`` slicing).
Every random-looking choice (which request a NaN burst hits) derives from
``traces._stable_seed``, so a fault timeline replays bit-identically for
a given seed + scenario — the property the replay-determinism tests pin.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.traces import _stable_seed

ENGINE_FAULT_KINDS = ("crash", "nan_burst", "kv_corrupt", "stuck_slow",
                      "draft_fail")

#: Request terminal outcomes ("accepted" covers every normally-served
#: completion, including budget/eos finishes).  Mutually exclusive and
#: exhaustive: a request that ends any other way was *lost*, which the
#: resilience bench treats as a hard failure.
REQUEST_OUTCOMES = ("accepted", "timed_out", "rejected")


def _check_window(start_h: float, end_h: float) -> None:
    if start_h < 0.0:
        raise ValueError(f"event start_h must be >= 0, got {start_h}")
    if end_h <= start_h:
        raise ValueError(
            f"event window is empty or inverted: [{start_h}, {end_h})")


def _check_region(region) -> None:
    if region is not None and (not isinstance(region, str) or not region):
        raise ValueError(
            f"event region must be None or a non-empty region name, "
            f"got {region!r}")


@dataclass(frozen=True)
class EngineFault:
    """A windowed fault on bound serving engines.

    ``crash``: the engine process dies for the window (restarts at
    ``end_h``); with recovery on, the watchdog drains its unfinished
    requests onto healthy siblings, with recovery off the in-flight and
    queued work is silently dropped (the loss the audit catches).
    ``nan_burst``: one active request's freshest KV block goes NaN (a
    transient bad logit source); ``kv_corrupt``: one active request's
    oldest KV block goes NaN (cold memory corruption).  Both are caught
    by the engine's NaN guard, which quarantines the lane and re-queues
    the request on the recompute path.  ``stuck_slow``: the engine's
    step clock runs ``slow_factor`` slower for the window (a degraded
    but live replica).  ``draft_fail``: the speculative drafter breaks
    and is dropped for the window (plain decode continues).
    """
    kind: str              # one of ENGINE_FAULT_KINDS
    start_h: float
    end_h: float
    server: int | None = None     # target server id; None hits every
    #                               bound backend
    slow_factor: float = 4.0      # stuck_slow: step-time multiplier
    region: str | None = None     # fleet runs: scope to one region

    def __post_init__(self):
        if self.kind not in ENGINE_FAULT_KINDS:
            raise ValueError(
                f"unknown engine-fault kind {self.kind!r}; expected one of "
                f"{ENGINE_FAULT_KINDS}")
        _check_window(self.start_h, self.end_h)
        _check_region(self.region)
        if self.server is not None and self.server < 0:
            raise ValueError(
                f"fault server must be None or >= 0, got {self.server}")
        if self.slow_factor < 1.0:
            raise ValueError(
                f"slow_factor must be >= 1 (a *slow* lane), "
                f"got {self.slow_factor}")

    def active(self, now_h: float) -> bool:
        return self.start_h <= now_h < self.end_h


@dataclass(frozen=True)
class SensorDropout:
    """Telemetry staleness window: derived sensor readings (inlet
    estimate, risk, thermal ceilings) freeze at their last-known-good
    snapshot while the physics keeps moving underneath."""
    start_h: float
    end_h: float
    region: str | None = None     # fleet runs: scope to one region

    def __post_init__(self):
        _check_window(self.start_h, self.end_h)
        _check_region(self.region)

    def active(self, now_h: float) -> bool:
        return self.start_h <= now_h < self.end_h


def fault_pick(n: int, *parts) -> int:
    """Deterministic index in ``[0, n)`` for fault targeting.

    crc32-folded (the ``trace_seed`` idiom), so which request a NaN burst
    lands on is a pure function of (seed, kind, tick, ...) — never of
    process hash randomization or dict order."""
    if n <= 0:
        raise ValueError(f"fault_pick needs n >= 1, got {n}")
    return _stable_seed("fault", *parts) % n


@dataclass(frozen=True)
class ResilienceKnobs:
    """Switches for the recovery machinery (``SimConfig.resilience``)."""

    #: heartbeat watchdog: drain an unresponsive backend's unfinished
    #: requests onto healthy siblings, restore on recovery.
    watchdog: bool = True
    #: consecutive missed heartbeats before the watchdog declares a
    #: backend unhealthy and drains it.
    heartbeat_misses: int = 1
    #: a crashing engine re-queues its in-flight work for recompute
    #: (False: the crash drops it — the silent-loss failure mode).
    requeue_on_crash: bool = True
    #: NaN/Inf KV guard: scan armed lanes before decode, quarantine and
    #: retry corrupted requests instead of emitting garbage tokens.
    nan_guard: bool = True
    #: walk attached ``DegradationLadder``s under emergencies.
    ladder: bool = True
    #: risk added per tick of telemetry staleness under ``SensorDropout``
    #: (0.0 trusts the frozen reading verbatim).
    stale_risk_bump: float = 0.02

    def __post_init__(self):
        if self.heartbeat_misses < 1:
            raise ValueError(
                f"heartbeat_misses must be >= 1, got {self.heartbeat_misses}")
        if self.stale_risk_bump < 0.0:
            raise ValueError(
                f"stale_risk_bump must be >= 0, got {self.stale_risk_bump}")


def recovery_off() -> ResilienceKnobs:
    """The ablation preset: every recovery mechanism disabled.  Faults
    still fire — crashes drop work, stale telemetry is trusted verbatim —
    which is exactly the arm the fault-storm drill compares against."""
    return ResilienceKnobs(watchdog=False, requeue_on_crash=False,
                           nan_guard=False, ladder=False,
                           stale_risk_bump=0.0)


#: ladder rungs in walk order; ``quantized_variant`` is skipped when the
#: ladder has no quantized variant configured.
LADDER_RUNGS = ("drop_drafter", "shrink_horizon", "quantized_variant",
                "cap_batch")


class DegradationLadder:
    """Graceful-degradation ladder for one ``EngineBackend``.

    Each emergency tick steps one rung *down* (cheaper serving, lower
    quality); each ``calm_ticks``-long quiet stretch steps one rung back
    *up*, restoring the exact pre-emergency knob values.  Rungs, in
    order: drop the speculative drafter, halve the fused decode horizon,
    force the quantized model variant, halve ``max_batch``.

    The ladder is attached per backend (``EngineBackend(ladder=...)``)
    and walked by the simulator's reconfigure phase *after* the tick's
    ``ConfigPoint`` landed, so ladder caps win over the configurator's
    knob turns for the tick; unwinding restores the saved pre-ladder
    values and the next reconfigure re-asserts its own view.
    """

    def __init__(self, *, quantized_variant: str | None = None,
                 calm_ticks: int = 2, min_horizon: int = 1,
                 min_batch: int = 1):
        if calm_ticks < 1:
            raise ValueError(f"calm_ticks must be >= 1, got {calm_ticks}")
        self.quantized_variant = quantized_variant
        self.calm_ticks = calm_ticks
        self.min_horizon = min_horizon
        self.min_batch = min_batch
        self.level = 0            # rungs currently applied
        self.walks = 0            # total step-downs over the run
        self.skipped_rungs = 0    # quantized swaps refused by the engine
        #                           (variant indivisible at the current
        #                           shard degree — reject, don't crash)
        self._calm = 0
        self._saved: dict[str, object] = {}

    def rungs(self) -> list:
        return [r for r in LADDER_RUNGS
                if r != "quantized_variant" or self.quantized_variant]

    def tick(self, backend, emergency: bool) -> None:
        """One reconfigure-phase walk: down a rung under an emergency,
        up a rung after ``calm_ticks`` consecutive quiet ticks."""
        rungs = self.rungs()
        if emergency:
            self._calm = 0
            if self.level < len(rungs):
                self._apply(backend, rungs[self.level])
                self.level += 1
                self.walks += 1
        elif self.level > 0:
            self._calm += 1
            if self._calm >= self.calm_ticks:
                self._calm = 0
                self.level -= 1
                self._unwind(backend, rungs[self.level])
        self._enforce(backend)

    def _apply(self, backend, rung: str) -> None:
        eng = backend.engine
        if rung == "drop_drafter":
            self._saved["drafter"] = eng.draft_name
            if eng.draft_name is not None:
                eng.set_drafter(None)
        elif rung == "shrink_horizon":
            self._saved["horizon"] = eng.horizon
            eng.horizon = max(self.min_horizon, eng.horizon // 2)
        elif rung == "quantized_variant":
            ok = getattr(eng, "variant_compatible", None)
            if ok is not None and not ok(self.quantized_variant):
                # the variant's head count does not divide the engine's
                # shard degree: skip the rung, keep walking the ladder
                self.skipped_rungs += 1
                return
            self._saved["variant"] = eng.knobs.variant
            if eng.knobs.variant != self.quantized_variant:
                eng.set_variant(self.quantized_variant)
        elif rung == "cap_batch":
            self._saved["max_batch"] = eng.knobs.max_batch
            eng.knobs.max_batch = max(self.min_batch,
                                      eng.knobs.max_batch // 2)

    def _unwind(self, backend, rung: str) -> None:
        eng = backend.engine
        if rung == "drop_drafter":
            drafter = self._saved.pop("drafter", None)
            if drafter is not None:
                eng.set_drafter(drafter)
        elif rung == "shrink_horizon":
            eng.horizon = self._saved.pop("horizon", eng.horizon)
        elif rung == "quantized_variant":
            variant = self._saved.pop("variant", None)
            if variant is not None and variant != eng.knobs.variant:
                eng.set_variant(variant)
        elif rung == "cap_batch":
            eng.knobs.max_batch = self._saved.pop("max_batch",
                                                  eng.knobs.max_batch)

    def _enforce(self, backend) -> None:
        """Re-assert active caps: a reconfigure that landed this tick may
        have raised ``max_batch`` past the rung's cap."""
        rungs = self.rungs()[: self.level]
        eng = backend.engine
        if "cap_batch" in rungs:
            cap = max(self.min_batch, self._saved["max_batch"] // 2)
            eng.knobs.max_batch = min(eng.knobs.max_batch, cap)


def audit_requests(requests) -> dict:
    """Zero-silent-loss audit over a request population.

    Every request must end in exactly one terminal outcome
    (``REQUEST_OUTCOMES``); a ``None`` outcome after a drained run means
    the request *vanished* — the failure mode recovery must prevent.
    Returns outcome counts, the lost req_ids, and accepted-token goodput.
    """
    counts = dict.fromkeys(REQUEST_OUTCOMES, 0)
    lost = []
    accepted_tokens = 0
    for r in requests:
        if r.outcome is None:
            lost.append(r.req_id)
            continue
        counts[r.outcome] += 1
        if r.outcome == "accepted":
            accepted_tokens += len(r.output)
    return {"outcomes": counts, "lost": sorted(lost),
            "accepted_tokens": accepted_tokens, "total": len(requests)}
