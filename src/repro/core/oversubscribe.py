"""Oversubscription analysis — paper §5.3 (Fig. 21).

Add racks into existing rows without growing the provisioned cooling/power
envelopes; measure the fraction of time under thermal/power capping per
policy.  The paper's claim: Baseline degrades past ~20% oversubscription
while TAPAS holds capping below 0.7% of time at up to 40% more servers.

Sweeps take an optional ``Scenario`` so planners can size oversubscription
under scripted stress (failure drills, demand surges, heat waves) through
the same event API the failure drills use.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.datacenter import DCConfig, scale_datacenter
from repro.core.scenario import Scenario
from repro.core.simulator import ClusterSim, SimConfig


@dataclass
class OversubPoint:
    ratio: float
    policy: str
    thermal_capped_frac: float
    power_capped_frac: float
    unserved_frac: float

    def row(self) -> dict:
        return {
            "oversub": self.ratio, "policy": self.policy,
            "thermal_capped_pct": round(100 * self.thermal_capped_frac, 3),
            "power_capped_pct": round(100 * self.power_capped_frac, 3),
            "unserved_pct": round(100 * self.unserved_frac, 2),
        }


def sweep(policies: list, ratios=(0.0, 0.1, 0.2, 0.3, 0.4, 0.5), *,
          dc: DCConfig | None = None, horizon_h: float = 24.0,
          seed: int = 0, scenario: Scenario | None = None) -> list:
    dc = dc or DCConfig(n_rows=8, racks_per_row=10, servers_per_rack=4)
    out = []
    for ratio in ratios:
        scaled = scale_datacenter(dc, ratio)
        for pol in policies:
            res = ClusterSim(SimConfig(dc=scaled, horizon_h=horizon_h,
                                       seed=seed, policy=pol,
                                       scenario=scenario)).run()
            out.append(OversubPoint(
                ratio=ratio, policy=pol.name,
                thermal_capped_frac=res.thermal_capped_frac,
                power_capped_frac=res.power_capped_frac,
                unserved_frac=res.unserved_frac).row())
    return out


def max_safe_oversubscription(rows: list, policy: str, *,
                              cap_budget: float = 0.007) -> float:
    """Largest *contiguous* safe ratio: walk the sweep points in ratio
    order and stop at the first one whose (thermal+power) capping exceeds
    the budget.  A failing middle point caps the answer — recommending a
    ratio beyond a known-bad operating point would hide a regression the
    operator must pass through while scaling up."""
    pts = sorted((r["oversub"],
                  (r["thermal_capped_pct"] + r["power_capped_pct"]) / 100.0)
                 for r in rows if r["policy"] == policy)
    best = 0.0
    for ratio, capped in pts:
        if capped > cap_budget:
            break
        best = max(best, ratio)
    return best
