"""Oversubscription analysis — paper §5.3 (Fig. 21) and §4.4 (Fig. 19/20).

Add racks into existing rows without growing the provisioned cooling/power
envelopes; measure the fraction of time under thermal/power capping per
policy.  The paper's claim: Baseline degrades past ~20% oversubscription
while TAPAS holds capping below 0.7% of time at up to 40% more servers.

Sweeps take an optional ``Scenario`` so planners can size oversubscription
under scripted stress (failure drills, demand surges, heat waves) through
the same event API the failure drills use.

``FleetOversubPlanner`` lifts the sizing question to the fleet (the §4.4
TCO argument): every region can provision tighter when the global router
can drain a scripted regional failure cross-region.  It sizes each region
twice — alone (the sweep above, one single-region fleet per region) and
fleet-coordinated (a coordinate-descent search over per-region ratios
through ``FleetSim``) — and reports both plans, so the admitted extra
capacity is directly attributable to the cross-region control plane.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.core.datacenter import DCConfig, scale_datacenter
from repro.core.scenario import PriceShock, Scenario
from repro.core.simulator import ClusterSim, SimConfig


@dataclass
class OversubPoint:
    ratio: float
    policy: str
    thermal_capped_frac: float
    power_capped_frac: float
    unserved_frac: float

    def row(self) -> dict:
        return {
            "oversub": self.ratio, "policy": self.policy,
            "thermal_capped_pct": round(100 * self.thermal_capped_frac, 3),
            "power_capped_pct": round(100 * self.power_capped_frac, 3),
            "unserved_pct": round(100 * self.unserved_frac, 2),
        }


def sweep(policies: list, ratios=(0.0, 0.1, 0.2, 0.3, 0.4, 0.5), *,
          dc: DCConfig | None = None, horizon_h: float = 24.0,
          seed: int = 0, scenario: Scenario | None = None) -> list:
    dc = dc or DCConfig(n_rows=8, racks_per_row=10, servers_per_rack=4)
    out = []
    for ratio in ratios:
        scaled = scale_datacenter(dc, ratio)
        for pol in policies:
            res = ClusterSim(SimConfig(dc=scaled, horizon_h=horizon_h,
                                       seed=seed, policy=pol,
                                       scenario=scenario)).run()
            out.append(OversubPoint(
                ratio=ratio, policy=pol.name,
                thermal_capped_frac=res.thermal_capped_frac,
                power_capped_frac=res.power_capped_frac,
                unserved_frac=res.unserved_frac).row())
    return out


def max_safe_oversubscription(rows: list, policy: str, *,
                              cap_budget: float = 0.007) -> float:
    """Largest *contiguous* safe ratio: walk the sweep points in ratio
    order and stop at the first one whose (thermal+power) capping exceeds
    the budget.  A failing middle point caps the answer — recommending a
    ratio beyond a known-bad operating point would hide a regression the
    operator must pass through while scaling up."""
    pts = sorted((r["oversub"],
                  (r["thermal_capped_pct"] + r["power_capped_pct"]) / 100.0)
                 for r in rows if r["policy"] == policy)
    best = 0.0
    for ratio, capped in pts:
        if capped > cap_budget:
            break
        best = max(best, ratio)
    return best


# ---------------------------------------------------------------------------
# fleet-level planning
# ---------------------------------------------------------------------------

@dataclass
class FleetOversubPlan:
    """The planner's answer: per-region safe oversubscription ratios,
    sized twice — each region alone vs fleet-coordinated.  The difference
    between the two totals is the extra capacity the global router's
    cross-region draining pays for."""

    isolated: dict              # name -> max safe ratio, region alone
    coordinated: dict           # name -> fleet-safe ratio under the router
    cap_budget: float
    rows: list                  # isolated sweep rows (policy == region name)
    trials: list = field(default_factory=list)  # coordinate-descent log
    evaluations: int = 0        # simulation runs the search spent
    coordinated_safe: bool = True   # False: even the all-minimum-ratio
    #                                 fleet blew the capping budget

    def isolated_total(self) -> float:
        return sum(self.isolated.values())

    def coordinated_total(self) -> float:
        return sum(self.coordinated.values())

    def summary(self) -> dict:
        return {
            "cap_budget": self.cap_budget,
            "isolated": dict(self.isolated),
            "coordinated": dict(self.coordinated),
            "isolated_total": self.isolated_total(),
            "coordinated_total": self.coordinated_total(),
            "gain": self.coordinated_total() - self.isolated_total(),
            "coordinated_safe": self.coordinated_safe,
            "evaluations": self.evaluations,
        }


class FleetOversubPlanner:
    """Size per-region oversubscription fleet-wide (§4.4, Fig. 19/20).

    Takes a ``FleetConfig`` describing the fleet at its provisioned sizing
    (ratio 0) — including the scripted stress ``Scenario`` (a regional
    cooling failure, a heat wave) the plan must survive — and answers two
    questions per region:

    * **isolated** — how far can this region oversubscribe alone?  One
      single-region fleet per (region, ratio) grid point under
      ``LatencyOnlyRouter`` (== the standalone ``ClusterSim``, pinned by
      the parity tests), swept exactly like ``sweep()`` and scored with
      ``max_safe_oversubscription`` over the same row format.
    * **coordinated** — how far can every region oversubscribe when the
      global router may drain a stressed region cross-region?  A
      coordinate-descent search over the per-region ratio grid through
      ``FleetSim``: start from the isolated plan, repair any region over
      the capping budget downward, then repeatedly try raising each
      region one grid step, keeping a step only when *every* region's
      (thermal + power) capped fraction stays within ``cap_budget``.

    Every evaluation is a fresh deterministic ``FleetSim`` run, so the
    plan is a pure function of (config, seed, grid) — pass ``cfg.fleet``
    as a policy class/factory (or ``None``), never a live instance whose
    steer memory would leak between evaluations.
    """

    def __init__(self, cfg, *, ratios=(0.0, 0.1, 0.2, 0.3, 0.4, 0.5),
                 cap_budget: float = 0.007):
        from repro.core.fleet import FleetConfig
        if not isinstance(cfg, FleetConfig):
            raise TypeError(f"FleetOversubPlanner needs a FleetConfig, "
                            f"got {cfg!r}")
        if not cfg.regions:
            raise ValueError("a fleet plan needs at least one region")
        self.ratios = tuple(sorted({float(r) for r in ratios}))
        if not self.ratios or self.ratios[0] < 0.0:
            raise ValueError(f"ratio grid must be non-empty and >= 0, "
                             f"got {ratios}")
        if not 0.0 < cap_budget < 1.0:
            raise ValueError(f"cap_budget must be in (0, 1), "
                             f"got {cap_budget}")
        self.cfg = cfg
        self.cap_budget = cap_budget
        self.trials: list = []
        self.evaluations = 0
        self._cache: dict = {}

    # -- shared mechanics --------------------------------------------------
    def _scaled(self, spec, ratio: float):
        return replace(spec, dc=scale_datacenter(spec.dc, ratio))

    @staticmethod
    def _capped(result) -> dict:
        return {n: r.thermal_capped_frac + r.power_capped_frac
                for n, r in result.regions.items()}

    def _safe(self, capped: dict) -> bool:
        return all(c <= self.cap_budget for c in capped.values())

    # -- isolated sizing ---------------------------------------------------
    def _region_slice(self, name: str) -> Scenario:
        """The stress events one region faces alone: its tagged events
        plus the fleet-wide ones (price shocks dropped — $/kWh has no
        bearing on thermal/power safety)."""
        scen = self.cfg.scenario or Scenario()
        return Scenario(tuple(
            ev for ev in scen.events
            if not isinstance(ev, PriceShock)
            and getattr(ev, "region", None) in (None, name)))

    def plan_isolated(self) -> tuple:
        """Per-region max safe ratio with no fleet help: ``(ratios, rows)``
        where ``rows`` reuses the ``sweep()`` row format with the region
        name in the ``policy`` column.  The walk up the grid stops at the
        first unsafe ratio — ``max_safe_oversubscription`` is contiguous,
        so points beyond it cannot change the answer."""
        from repro.core.fleet import FleetSim, LatencyOnlyRouter
        rows: list = []
        iso: dict = {}
        for spec in self.cfg.regions:
            scen = self._region_slice(spec.name)
            for ratio in self.ratios:
                # rtt_ms overrides name the absent sibling regions and
                # are meaningless alone — drop them with the regions
                cfg = replace(self.cfg,
                              regions=(self._scaled(spec, ratio),),
                              fleet=LatencyOnlyRouter, scenario=scen,
                              rtt_ms=None)
                res = FleetSim(cfg).run()
                self.evaluations += 1
                r = res.regions[spec.name]
                rows.append(OversubPoint(
                    ratio=ratio, policy=spec.name,
                    thermal_capped_frac=r.thermal_capped_frac,
                    power_capped_frac=r.power_capped_frac,
                    unserved_frac=r.unserved_frac).row())
                if (r.thermal_capped_frac + r.power_capped_frac
                        > self.cap_budget):
                    break
            iso[spec.name] = max_safe_oversubscription(
                rows, spec.name, cap_budget=self.cap_budget)
        return iso, rows

    # -- coordinated sizing ------------------------------------------------
    def evaluate(self, ratios: dict) -> dict:
        """One full-fleet run at a per-region ratio vector (cached)."""
        from repro.core.fleet import FleetSim
        key = tuple(ratios[s.name] for s in self.cfg.regions)
        if key not in self._cache:
            cfg = replace(self.cfg, regions=tuple(
                self._scaled(s, ratios[s.name]) for s in self.cfg.regions))
            capped = self._capped(FleetSim(cfg).run())
            self.evaluations += 1
            entry = {"ratios": dict(ratios), "capped": capped,
                     "safe": self._safe(capped)}
            self._cache[key] = entry
            self.trials.append(entry)
        return self._cache[key]

    def plan(self) -> FleetOversubPlan:
        grid = list(self.ratios)
        iso, rows = self.plan_isolated()
        # snap the start point onto the grid: an isolated answer of 0.0
        # (the max_safe floor when even the first grid ratio is unsafe)
        # need not be a grid point
        cur = {n: max((r for r in grid if r <= iso[n]), default=grid[0])
               for n in iso}
        # repair: the isolated ratios need not be jointly safe (a helper
        # region absorbing a stressed neighbor's drained load may now cap)
        # — walk the worst over-budget region down until the fleet is safe
        while not self.evaluate(cur)["safe"]:
            capped = self.evaluate(cur)["capped"]
            over = [n for n in sorted(capped)
                    if capped[n] > self.cap_budget and grid.index(cur[n]) > 0]
            if not over:
                break
            worst = max(over, key=lambda n: (capped[n], n))
            cur[worst] = grid[grid.index(cur[worst]) - 1]
        # ascend: one grid step per region per pass while the fleet stays
        # safe; regions visited in name order so the search is deterministic
        improved = True
        while improved:
            improved = False
            for name in sorted(cur):
                i = grid.index(cur[name])
                if i + 1 >= len(grid):
                    continue
                trial = dict(cur)
                trial[name] = grid[i + 1]
                if self.evaluate(trial)["safe"]:
                    cur = trial
                    improved = True
        return FleetOversubPlan(
            isolated=iso, coordinated=cur, cap_budget=self.cap_budget,
            rows=rows, trials=list(self.trials),
            evaluations=self.evaluations,
            coordinated_safe=self.evaluate(cur)["safe"])
