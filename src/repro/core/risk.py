"""Shared risk scoring for the control plane (paper §4.2, Eqs. 1–4).

Every TAPAS policy reasons about the same quantity: the probability that a
server — or the row/aisle it lives in — trips a thermal or power limit if
it is handed more load.  This module owns that computation and the named
knobs behind it, so the simulator, the router, the reconfiguration policy,
and any external driver all score risk identically instead of each carrying
private copies of the constants.

``server_risk`` is the Eq. 1–4 forecast previously buried in
``ClusterSim._risk``; ``RiskKnobs`` names its magic numbers.
``ReconfigureThresholds`` names the inline 0.45/0.25 thresholds the
instance-configuration loop used to hardcode.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.datacenter import Datacenter
from repro.core.power import PowerModel
from repro.core.thermal import ThermalModel


@dataclass(frozen=True)
class RiskKnobs:
    """Named parameters of the Eq. 1–4 violation-risk forecast."""

    #: utilization increase probed when forecasting temperature — the paper
    #: routes on *violation risk* at moderately increased load, not the
    #: full-load worst case (which would mark nearly every warm server risky
    #: and starve routing).
    probe_util_delta: float = 0.35
    #: softness (°C) of the sigmoid mapping forecast GPU temperature
    #: overshoot into [0, 1] risk.
    temp_softness_c: float = 2.0
    #: row power fraction above the fleet mean that saturates the relative
    #: balancing term — above-average rows repel load long before the
    #: envelope (§4.2 Row).
    row_balance_band: float = 0.25
    #: weight of the relative balancing term vs the hard near-limit ramp.
    row_balance_weight: float = 0.7
    #: row power fraction where the hard ramp toward the envelope engages.
    row_near_limit_start: float = 0.85
    #: width of that hard ramp (risk hits 1.0 at start + width).
    row_near_limit_width: float = 0.15
    #: aisle airflow headroom (fraction of max per-server CFM) below which
    #: airflow risk starts accruing.
    air_headroom_margin: float = 0.8


@dataclass(frozen=True)
class ReconfigureThresholds:
    """Named thresholds of the §4.3 instance-reconfiguration loop."""

    #: risk above which a SaaS instance is reconfigured down.  The value is
    #: also reused as the cap offset — ``cap = max(cap_floor, (1 - risk) +
    #: hot_risk)`` — so a server exactly at the threshold keeps cap ≈ 1.0
    #: and caps deepen smoothly as risk rises past it.
    hot_risk: float = 0.45
    #: risk below which a previously drained instance is restored to the
    #: nominal configuration.
    cool_risk: float = 0.25
    #: lowest power/temperature cap ever handed to the configurator; below
    #: this the row-capping layer takes over.
    cap_floor: float = 0.6
    #: temperature cap used when restoring a cooled instance (1.35 == the
    #: profile table's hottest-chip ceiling, i.e. "no temperature cap").
    restore_temp_cap: float = 1.35


DEFAULT_RISK_KNOBS = RiskKnobs()
DEFAULT_THRESHOLDS = ReconfigureThresholds()


def server_risk(dc: Datacenter, thermal: ThermalModel, power: PowerModel, *,
                inlet: np.ndarray, prov_row_power_w: np.ndarray,
                prov_aisle_cfm: np.ndarray, util: np.ndarray,
                kind: np.ndarray,
                knobs: RiskKnobs = DEFAULT_RISK_KNOBS) -> np.ndarray:
    """Per-server violation risk in [0, 1] from the Eq. 1–4 forecasts.

    ``inlet``: (S,) estimated inlet temperature; ``prov_row_power_w`` /
    ``prov_aisle_cfm``: provisioned envelopes *after* failure derates;
    ``util``: (S,) current utilization estimate; ``kind``: (S,) occupancy
    (0 empty, 1 IaaS, 2 SaaS).
    """
    th, pm = thermal, power
    chips = dc.cfg.hw.chips
    # server-level: temperature forecast at moderately increased load
    probe = np.clip(util + knobs.probe_util_delta, 0.0, 1.0)
    t_probe = np.asarray(th.gpu_temp(
        inlet, np.repeat(probe[:, None], chips, axis=1))).max(axis=1)
    t_risk = 1.0 / (1.0 + np.exp(-(t_probe - th.gpu_limit)
                                 / knobs.temp_softness_c))
    # row-level: graded power risk — engages well before the envelope so
    # packing prefers cold rows and hot rows shed SaaS load (§4.2 Row)
    pwr = np.asarray(pm.server_power(
        np.repeat(util[:, None], chips, axis=1)))
    pwr = np.where(kind > 0, pwr, 0.0)
    rowp = dc.row_sum(pwr)
    row_frac = rowp / np.maximum(prov_row_power_w, 1.0)
    rel = np.clip((row_frac - row_frac.mean()) / knobs.row_balance_band,
                  0.0, 1.0)
    near = np.clip((row_frac - knobs.row_near_limit_start)
                   / knobs.row_near_limit_width, 0.0, 1.0)
    p_risk = np.maximum(rel * knobs.row_balance_weight, near)[dc.row_of]
    # aisle airflow headroom
    air = np.asarray(th.airflow(util))
    a_air = dc.aisle_sum(np.where(kind > 0, air, 0.0))
    n_per_aisle = dc.aisle_sum((kind > 0).astype(float))
    a_head = (prov_aisle_cfm - a_air) / np.maximum(
        n_per_aisle * th.airflow_max_cfm, 1.0)
    a_risk = np.clip(knobs.air_headroom_margin - a_head, 0.0, 1.0)[dc.aisle_of]
    return np.maximum.reduce([t_risk, p_risk, a_risk])


def energy_cost_index(price: float, carbon: float, *,
                      carbon_weight: float = 0.5) -> float:
    """One scalar "how expensive is a kWh served here right now".

    Blends the region's effective power price (relative $/kWh, shocks
    applied) with its instantaneous grid carbon intensity (relative,
    1.0 == fleet mean) — both ~1.0-centered, so the blend stays comparable
    across weights.  ``carbon_weight`` 0 prices money only, 1 prices
    carbon only.  The fleet router minimizes this index when regions are
    thermally equivalent; the fleet accounting integrates it over served
    energy.
    """
    if not 0.0 <= carbon_weight <= 1.0:
        raise ValueError(
            f"carbon_weight must be in [0, 1], got {carbon_weight}")
    return (1.0 - carbon_weight) * price + carbon_weight * carbon


def thermally_comparable(risk_origin: float, risk_dest: float, *,
                         band: float, threshold: float) -> bool:
    """True when steering load origin -> dest is thermally a wash: the
    destination sits below the steering ``threshold`` and is no more than
    ``band`` riskier than the origin.  Cost-chasing is only allowed inside
    this band — outside it, thermal steering (cooler regions only) owns
    the decision."""
    return (risk_dest < threshold
            and risk_dest - risk_origin <= band)


def region_risk(risk: np.ndarray, kind: np.ndarray, *,
                quantile: float = 0.8) -> float:
    """Lift per-server violation risk to one regional score in [0, 1].

    The fleet router reasons about regions the way ``server_risk`` lets the
    cluster router reason about servers: "how likely is this region to trip
    a limit if handed more load".  A high quantile of the occupied servers'
    risk (not the mean) is what matters — steering decisions are driven by
    the hot tail that will throttle first, and a mostly-cold region with
    one hot row must still repel load from that row's capacity share.
    """
    occupied = np.asarray(risk)[np.asarray(kind) > 0]
    if occupied.size == 0:
        return 0.0
    return float(np.quantile(occupied, quantile))
