"""Scenario scripting — composable, validated simulation events.

A ``Scenario`` is an ordered bag of typed events that perturb a simulation
run: infrastructure failures (paper §5.4), extra VM arrivals, endpoint
demand surges, weather/region shifts, and power-price shocks.  Every event validates its fields
at construction — a typo'd ``kind="upss"`` raises immediately instead of
being silently ignored mid-drill — and ``failures.py``, ``oversubscribe.py``
and the benchmarks all script their runs through this one API instead of
hand-rolled tuples.

Events carry an optional ``region`` tag for fleet-scale runs
(``core.fleet.FleetSim``): ``region="eu"`` scopes the event to that region's
cluster, ``region=None`` means fleet-wide (every region) — except for
``VMArrival``, where ``region=None`` inside a fleet scenario means "let the
``FleetPolicy.admit_region`` hook choose the region".  A single-cluster
``ClusterSim`` rejects region-tagged events at construction (the tag would
otherwise be silently ignored); ``Scenario.for_region`` strips the tags
when a fleet hands each region its slice.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core.faults import EngineFault, SensorDropout

FAILURE_KINDS = ("ahu", "ups", "cooling", "thermal")
VM_KINDS = ("iaas", "saas")


def _check_window(start_h: float, end_h: float) -> None:
    if start_h < 0.0:
        raise ValueError(f"event start_h must be >= 0, got {start_h}")
    if end_h <= start_h:
        raise ValueError(
            f"event window is empty or inverted: [{start_h}, {end_h})")


def _check_region(region) -> None:
    if region is not None and (not isinstance(region, str) or not region):
        raise ValueError(
            f"event region must be None or a non-empty region name, "
            f"got {region!r}")


@dataclass(frozen=True)
class FailureEvent:
    """Infrastructure failure (paper §5.4, Table 2).

    ``ahu``: one aisle loses 1/N of its AHUs (reduced airflow);
    ``ups``: 4N/3 failover limits every row to 75% power (fleet-wide —
    the redundancy pool is shared, so ``target`` does not apply);
    ``cooling``: DC-level cooling strain (+3 °C inlet, fleet-wide);
    ``thermal``: the §5.4 thermal emergency (AHU loss + cooling strain).
    """
    kind: str          # one of FAILURE_KINDS
    start_h: float
    end_h: float
    target: int = 0    # aisle id (ahu/thermal); must stay 0 for the
    #                    cluster-wide kinds (ups/cooling)
    region: str | None = None   # fleet runs: scope to one region

    def __post_init__(self):
        if self.kind not in FAILURE_KINDS:
            raise ValueError(
                f"unknown failure kind {self.kind!r}; expected one of "
                f"{FAILURE_KINDS}")
        _check_window(self.start_h, self.end_h)
        _check_region(self.region)
        if self.target < 0:
            raise ValueError(f"failure target must be >= 0, got {self.target}")
        if self.kind in ("ups", "cooling") and self.target != 0:
            raise ValueError(
                f"{self.kind} failures are cluster-wide; target={self.target} "
                f"would be silently ignored — leave it at 0")

    def active(self, now_h: float) -> bool:
        return self.start_h <= now_h < self.end_h


@dataclass(frozen=True)
class DemandSurge:
    """Multiply one endpoint's (or every endpoint's) demand for a window."""
    start_h: float
    end_h: float
    scale: float              # multiplier on routed demand (> 0)
    endpoint: str | None = None   # None == every endpoint
    region: str | None = None     # fleet runs: scope to one region

    def __post_init__(self):
        _check_window(self.start_h, self.end_h)
        _check_region(self.region)
        if self.scale <= 0.0:
            raise ValueError(f"surge scale must be > 0, got {self.scale}")

    def active(self, now_h: float) -> bool:
        return self.start_h <= now_h < self.end_h


@dataclass(frozen=True)
class WeatherShift:
    """Add ``delta_c`` °C to the outside temperature for a window (heat
    wave / cold snap / a geo-region swap approximated as an offset)."""
    start_h: float
    end_h: float
    delta_c: float
    region: str | None = None     # fleet runs: scope to one region

    def __post_init__(self):
        _check_window(self.start_h, self.end_h)
        _check_region(self.region)

    def active(self, now_h: float) -> bool:
        return self.start_h <= now_h < self.end_h


@dataclass(frozen=True)
class VMArrival:
    """Script an extra VM arrival on top of the generated workload.

    SaaS arrivals name an endpoint (created if new); IaaS arrivals name a
    customer template.
    """
    arrival_h: float
    kind: str                 # "iaas" | "saas"
    customer: str             # endpoint name (saas) / customer template
    lifetime_h: float
    peak_util: float = 1.0
    region: str | None = None   # fleet runs: pin to a region; None lets
    #                             FleetPolicy.admit_region choose

    def __post_init__(self):
        if self.kind not in VM_KINDS:
            raise ValueError(
                f"unknown VM kind {self.kind!r}; expected one of {VM_KINDS}")
        if self.arrival_h < 0.0:
            raise ValueError(f"arrival_h must be >= 0, got {self.arrival_h}")
        if self.lifetime_h <= 0.0:
            raise ValueError(
                f"lifetime_h must be > 0, got {self.lifetime_h}")
        if not 0.0 < self.peak_util <= 1.0:
            raise ValueError(
                f"peak_util must be in (0, 1], got {self.peak_util}")


@dataclass(frozen=True)
class PriceShock:
    """Multiply a region's effective power price for a window.

    A spot-market spike, a demand-response curtailment price, or a grid
    event folded into $/kWh.  Price is fleet-level economics — the event
    is consumed by ``FleetSim`` (steering/accounting), never by a region's
    ``ClusterSim`` (clusters have no price concept), so ``for_region``
    filters it out of the per-region scenario slices.
    """
    start_h: float
    end_h: float
    scale: float                  # multiplier on power_price_scale (> 0)
    region: str | None = None     # None == every region

    def __post_init__(self):
        _check_window(self.start_h, self.end_h)
        _check_region(self.region)
        if self.scale <= 0.0:
            raise ValueError(
                f"price shock scale must be > 0, got {self.scale}")

    def active(self, now_h: float) -> bool:
        return self.start_h <= now_h < self.end_h


_EVENT_TYPES = (FailureEvent, DemandSurge, WeatherShift, VMArrival,
                PriceShock, EngineFault, SensorDropout)


@dataclass(frozen=True)
class Scenario:
    """A validated, composable set of simulation events.

    Construction rejects anything that is not a known event type; each
    event validated its own fields already.  Accessors answer the per-tick
    questions the simulator asks, so policy code never pattern-matches on
    raw tuples.
    """
    events: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        for ev in self.events:
            if not isinstance(ev, _EVENT_TYPES):
                raise TypeError(
                    f"unknown scenario event {ev!r}; expected one of "
                    f"{[t.__name__ for t in _EVENT_TYPES]}")

    # -- per-tick accessors ------------------------------------------------
    def failures(self, now_h: float) -> list:
        """Failure events active at ``now_h``."""
        return [ev for ev in self.events
                if isinstance(ev, FailureEvent) and ev.active(now_h)]

    def demand_scale(self, now_h: float, endpoint: str) -> float:
        """Combined demand multiplier for ``endpoint`` at ``now_h``."""
        scale = 1.0
        for ev in self.events:
            if (isinstance(ev, DemandSurge) and ev.active(now_h)
                    and ev.endpoint in (None, endpoint)):
                scale *= ev.scale
        return scale

    def weather_delta(self, now_h: float) -> float:
        """Outside-temperature offset (°C) at ``now_h``."""
        return sum(ev.delta_c for ev in self.events
                   if isinstance(ev, WeatherShift) and ev.active(now_h))

    def vm_arrivals(self) -> list:
        return [ev for ev in self.events if isinstance(ev, VMArrival)]

    def engine_faults(self, now_h: float) -> list:
        """Engine faults (``core.faults.EngineFault``) active at ``now_h``."""
        return [ev for ev in self.events
                if isinstance(ev, EngineFault) and ev.active(now_h)]

    def sensor_dropout(self, now_h: float) -> bool:
        """True while any ``SensorDropout`` window covers ``now_h``."""
        return any(isinstance(ev, SensorDropout) and ev.active(now_h)
                   for ev in self.events)

    def price_scale(self, now_h: float, region: str | None = None) -> float:
        """Combined power-price multiplier for ``region`` at ``now_h``
        (untagged shocks hit every region)."""
        scale = 1.0
        for ev in self.events:
            if (isinstance(ev, PriceShock) and ev.active(now_h)
                    and ev.region in (None, region)):
                scale *= ev.scale
        return scale

    # -- fleet accessors ---------------------------------------------------
    def regions_named(self) -> set:
        """Every region name any event is scoped to (for validation)."""
        return {ev.region for ev in self.events if ev.region is not None}

    def for_region(self, name: str) -> "Scenario":
        """The slice of this fleet scenario one region's cluster replays.

        Keeps events scoped to ``name`` and untagged fleet-wide events,
        with the region tag stripped (``ClusterSim`` rejects tagged
        events) — except untagged ``VMArrival``s, which belong to the
        fleet admission path (``FleetPolicy.admit_region``), not to any
        one region's workload.
        """
        out = []
        for ev in self.events:
            if isinstance(ev, VMArrival) and ev.region is None:
                continue
            if isinstance(ev, PriceShock):
                continue          # fleet-level economics, never a cluster's
            if ev.region in (None, name):
                out.append(replace(ev, region=None))
        return Scenario(tuple(out))

    def fleet_arrivals(self) -> list:
        """Untagged VM arrivals a fleet admits via ``admit_region``."""
        return [ev for ev in self.events
                if isinstance(ev, VMArrival) and ev.region is None]

    def __add__(self, other: "Scenario") -> "Scenario":
        return Scenario(self.events + tuple(other.events))


def as_scenario(scenario: Scenario | None, failures: tuple = ()) -> Scenario:
    """Normalize the two SimConfig channels (typed ``scenario`` plus the
    legacy ``failures`` tuple) into one validated Scenario."""
    base = scenario if scenario is not None else Scenario()
    if failures:
        base = base + Scenario(tuple(failures))
    return base
