"""TAPAS core: thermal- and power-aware scheduling for LLM inference.

The paper's primary contribution — placement (allocator), routing (router),
instance configuration (configurator) over the §2 thermal/power models —
behind the typed ``ClusterState``/``ControlPolicy`` control-plane API,
plus the step-wise discrete-time cluster simulator, scenario scripting,
failure drills and oversubscription planner used by §5.
"""
from repro.core.allocator import (AllocatorState, BaselineAllocator,
                                  PlacementPolicy, TapasAllocator)
from repro.core.configurator import InstanceConfigurator, ReconfigurePolicy
from repro.core.datacenter import (Datacenter, DCConfig, HWProfile,
                                   scale_datacenter)
from repro.core.fleet import (FleetConfig, FleetKnobs, FleetPolicy,
                              FleetResult, FleetSim, FleetState,
                              GlobalTapasRouter, LatencyOnlyRouter,
                              Migration, RegionSpec)
from repro.core.power import PowerModel, row_power
from repro.core.risk import (DEFAULT_RISK_KNOBS, DEFAULT_THRESHOLDS,
                             ReconfigureThresholds, RiskKnobs, region_risk,
                             server_risk)
from repro.core.router import (BaselineRouter, RoutingPolicy, TapasRouter)
from repro.core.scenario import (DemandSurge, FailureEvent, Scenario,
                                 VMArrival, WeatherShift)
from repro.core.simulator import (BASELINE, TAPAS, ClusterSim,
                                  CompositeControlPlane, Policy, SimConfig,
                                  SimResult, build_control_policy,
                                  run_policy)
from repro.core.state import (ClusterState, ConfigChange, ControlPolicy,
                              EndpointRoute, InstanceView)
from repro.core.thermal import ThermalModel, outside_temperature

__all__ = [
    "AllocatorState", "BaselineAllocator", "TapasAllocator",
    "PlacementPolicy", "InstanceConfigurator", "ReconfigurePolicy",
    "Datacenter", "DCConfig", "HWProfile", "scale_datacenter",
    "PowerModel", "row_power", "BaselineRouter", "TapasRouter",
    "RoutingPolicy", "DEFAULT_RISK_KNOBS", "DEFAULT_THRESHOLDS",
    "ReconfigureThresholds", "RiskKnobs", "region_risk", "server_risk",
    "FleetConfig", "FleetKnobs", "FleetPolicy", "FleetResult", "FleetSim",
    "FleetState", "GlobalTapasRouter", "LatencyOnlyRouter", "Migration",
    "RegionSpec",
    "DemandSurge", "FailureEvent", "Scenario", "VMArrival", "WeatherShift",
    "BASELINE", "TAPAS", "ClusterSim", "CompositeControlPlane", "Policy",
    "SimConfig", "SimResult", "build_control_policy", "run_policy",
    "ClusterState", "ConfigChange", "ControlPolicy", "EndpointRoute",
    "InstanceView", "ThermalModel", "outside_temperature",
]
