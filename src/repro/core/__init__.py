"""TAPAS core: thermal- and power-aware scheduling for LLM inference.

The paper's primary contribution — placement (allocator), routing (router),
instance configuration (configurator) over the §2 thermal/power models —
plus the discrete-time cluster simulator, failure drills and
oversubscription planner used by §5.
"""
from repro.core.allocator import (AllocatorState, BaselineAllocator,
                                  TapasAllocator)
from repro.core.configurator import InstanceConfigurator
from repro.core.datacenter import (Datacenter, DCConfig, HWProfile,
                                   scale_datacenter)
from repro.core.power import PowerModel, row_power
from repro.core.router import BaselineRouter, TapasRouter
from repro.core.simulator import (BASELINE, TAPAS, ClusterSim, FailureEvent,
                                  Policy, SimConfig, SimResult, run_policy)
from repro.core.thermal import ThermalModel, outside_temperature

__all__ = [
    "AllocatorState", "BaselineAllocator", "TapasAllocator",
    "InstanceConfigurator", "Datacenter", "DCConfig", "HWProfile",
    "scale_datacenter", "PowerModel", "row_power", "BaselineRouter",
    "TapasRouter", "BASELINE", "TAPAS", "ClusterSim", "FailureEvent",
    "Policy", "SimConfig", "SimResult", "run_policy", "ThermalModel",
    "outside_temperature",
]
