"""Request Router — paper §4.2 / §4.5 Load Balancer.

Per endpoint and per tick, distribute the endpoint's demanded load across
its VMs:

  filter   — drop VMs that would trip (a) aisle airflow, (b) row power, or
             (c) server GPU-temperature risk (Eq. 2 forecast at the load
             they'd receive);
  affinity — keep customer shares where they already ran (KV-cache reuse);
  pack     — concentrate load on fewest VMs (energy);
  spread   — distribute the remainder for performance.

The Baseline router splits load uniformly across the endpoint's VMs.
Loads are in "nominal-VM units" (1.0 == one VM fully busy at nominal
config); per-VM capacity comes from the instance's current config.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# no cycle: state.py imports allocator/profiles, never this module
from repro.core.state import EndpointRoute


@dataclass
class RouteDecision:
    load: np.ndarray          # (n_vms,) assigned load
    unserved: float           # demand that found no headroom (queued)


class BaselineRouter:
    def route(self, demand: float, capacity: np.ndarray, risk: np.ndarray,
              affinity: np.ndarray | None = None,
              ids: np.ndarray | None = None) -> RouteDecision:
        n = len(capacity)
        if n == 0:
            return RouteDecision(np.zeros(0), demand)
        load = np.full(n, demand / n)
        over = np.maximum(load - capacity, 0.0).sum()
        return RouteDecision(np.minimum(load, capacity), over)


class TapasRouter:
    """risk: (n_vms,) in [0,1] — probability the VM's server/row/aisle trips
    a limit if given more load (computed by the simulator from Eqs. 1–4);
    VMs with risk >= threshold are filtered (paper: 'high risk')."""

    def __init__(self, *, risk_threshold: float = 0.5, pack: bool = True):
        self.risk_threshold = risk_threshold
        self.pack = pack

    def route(self, demand: float, capacity: np.ndarray, risk: np.ndarray,
              affinity: np.ndarray | None = None,
              ids: np.ndarray | None = None) -> RouteDecision:
        """``ids`` (server ids, positional) breaks packing-order ties:
        candidates equal on (risk, load) fill lowest-id first, so results
        do not depend on the endpoint list's historical insertion order."""
        n = len(capacity)
        if n == 0:
            return RouteDecision(np.zeros(0), demand)
        ids = np.arange(n) if ids is None else np.asarray(ids)
        usable = risk < self.risk_threshold
        cap = np.where(usable, capacity, 0.0)
        load = np.zeros(n)
        remaining = demand

        # 1) affinity: hold the conversation-reuse share in place where safe
        # (most traffic reuses KV state; a quarter is free to move per tick,
        # which also damps tick-to-tick reassignment oscillation)
        if affinity is not None:
            keep = 0.75 * np.minimum(affinity, cap)
            keep = keep * min(1.0, remaining / max(keep.sum(), 1e-9))
            load += keep
            remaining -= keep.sum()

        headroom = cap - load
        if remaining > 1e-12 and headroom.sum() > 0:
            # 2) energy packing only while the endpoint runs light — at high
            # load concentration trades directly against peak row power
            if self.pack and demand < 0.4 * max(cap.sum(), 1e-9):
                order = np.lexsort((ids, -load, risk))
                for i in order:
                    take = min(headroom[i], remaining)
                    load[i] += take
                    remaining -= take
                    if remaining <= 1e-12:
                        break
            else:
                # 3-pre) risk-weighted spread: cooler rows take more
                w = headroom * np.square(1.0 - np.minimum(risk, 1.0))
                if w.sum() <= 1e-12:
                    w = headroom
                share = np.minimum(w / w.sum() * remaining, headroom)
                load += share
                remaining = max(demand - load.sum(), 0.0)

        # 3) spread overflow across *all* VMs (perf beats risk if queueing)
        if remaining > 1e-9:
            headroom_all = capacity - load
            pos = headroom_all > 1e-12
            if pos.any():
                share = np.where(pos, headroom_all, 0.0)
                share = share / share.sum() * min(remaining, share.sum())
                load += share
                remaining -= share.sum()
        return RouteDecision(load, max(remaining, 0.0))


class RoutingPolicy:
    """``ControlPolicy.route`` adapter over a Baseline/Tapas router.

    Owns the per-endpoint affinity memory (KV-cache reuse shares) and the
    translation from ``ClusterState`` telemetry to per-server capacities:
    a paused (reloading) instance serves nothing; otherwise capacity is the
    instance's goodput fraction times its frequency cap, and a
    thermal-aware router additionally ceilings each server at the Eq. 2
    load limit (``state.u_max``) so energy-packing can never push a server
    past its thermal cap.
    """

    def __init__(self, router, *, thermal_aware: bool):
        self.router = router
        self.thermal_aware = thermal_aware
        self._affinity: dict = {}

    def route(self, state, endpoint: str, demand: float) -> EndpointRoute:
        idx = np.asarray(state.endpoints[endpoint])
        caps, quals = [], []
        for srv in idx:
            inst = state.instances[int(srv)]
            e = inst.entry
            cap = (0.0 if inst.paused else
                   (e.goodput / state.nominal.goodput) * state.freq_cap[srv])
            if self.thermal_aware and cap > 0:
                busy_max = min(state.u_max[srv] / max(e.temp_frac, 1e-6), 1.0)
                cap *= busy_max
            caps.append(cap)
            quals.append(e.quality)
        caps = np.asarray(caps)
        # affinity shares are positional, so they are only valid while the
        # endpoint's server membership is unchanged — any churn (not just a
        # size change) resets them, else a departed server's KV-reuse share
        # would pin load onto an unrelated replacement
        prev = self._affinity.get(endpoint)
        if prev is not None and np.array_equal(prev[0], idx):
            aff = prev[1]
        else:
            aff = np.zeros(len(idx))
        dec = self.router.route(demand, caps, state.risk[idx], aff, ids=idx)
        self._affinity[endpoint] = (idx, dec.load.copy())
        return EndpointRoute(servers=idx, load=dec.load,
                             quality=np.asarray(quals),
                             unserved=dec.unserved)
