"""Discrete-time cluster simulator — paper §5.1.

Replays IaaS power traces and SaaS LLM-inference load over the datacenter
of §2, evaluating placement/routing/configuration policies under the
thermal (Eqs. 1–3) and power (Eq. 4) models; tracks throttling/capping
events and their performance/quality impact.

The physics (thermal/power models) run as vectorized JAX over all servers;
policy logic is event-level Python/NumPy, mirroring the control plane.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field

import numpy as np

from repro.core import profiles as P
from repro.core.allocator import (AllocatorState, BaselineAllocator,
                                  TapasAllocator)
from repro.core.configurator import InstanceConfigurator
from repro.core.datacenter import Datacenter, DCConfig
from repro.core.power import PowerModel, capping_factors
from repro.core.router import BaselineRouter, TapasRouter
from repro.core.thermal import ThermalModel, outside_temperature
from repro.core.traces import (Workload, endpoint_load, generate_workload,
                               iaas_util)


@dataclass(frozen=True)
class Policy:
    place: bool = False
    route: bool = False
    config: bool = False

    @property
    def name(self) -> str:
        if not (self.place or self.route or self.config):
            return "baseline"
        parts = [n for n, on in (("place", self.place), ("route", self.route),
                                 ("config", self.config)) if on]
        return "+".join(parts)


BASELINE = Policy()
TAPAS = Policy(place=True, route=True, config=True)


@dataclass
class FailureEvent:
    kind: str       # "ahu" | "ups" | "cooling"
    start_h: float
    end_h: float
    target: int = 0  # aisle id (ahu) / row-block id (ups)


@dataclass
class SimConfig:
    dc: DCConfig = field(default_factory=DCConfig)
    horizon_h: float = 24.0
    tick_min: float = 5.0
    saas_fraction: float = 0.5
    seed: int = 0
    policy: Policy = BASELINE
    failures: tuple = ()
    occupancy: float = 0.88
    demand_scale: float = 0.85   # endpoint demand vs fleet capacity


@dataclass
class SimResult:
    time_h: np.ndarray
    max_gpu_temp: np.ndarray         # (T,)
    peak_row_power_frac: np.ndarray  # (T,) hottest row / provisioned
    thermal_events: int
    power_events: int
    thermal_capped_frac: float       # fraction of server-ticks throttled
    power_capped_frac: float
    unserved_frac: float             # SaaS demand that queued (SLO proxy)
    mean_quality: float              # load-weighted SaaS quality
    iaas_perf_impact: float          # mean freq-cap depth x affected frac
    saas_perf_impact: float
    row_power_frac: np.ndarray       # (T, R)

    def summary(self) -> dict:
        return {
            "max_temp_c": float(self.max_gpu_temp.max()),
            "p99_temp_c": float(np.quantile(self.max_gpu_temp, 0.99)),
            "peak_row_power_frac": float(self.peak_row_power_frac.max()),
            "thermal_events": self.thermal_events,
            "power_events": self.power_events,
            "thermal_capped_frac": self.thermal_capped_frac,
            "power_capped_frac": self.power_capped_frac,
            "unserved_frac": self.unserved_frac,
            "mean_quality": self.mean_quality,
            "iaas_perf_impact": self.iaas_perf_impact,
            "saas_perf_impact": self.saas_perf_impact,
        }


class ClusterSim:
    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.dc = Datacenter(cfg.dc)
        self.thermal = ThermalModel.calibrate(self.dc)
        self.power = PowerModel.calibrate(self.dc)
        self.work = generate_workload(
            n_servers=self.dc.n_servers, horizon_h=cfg.horizon_h,
            seed=cfg.seed, saas_fraction=cfg.saas_fraction,
            occupancy=cfg.occupancy)
        self.alloc_state = AllocatorState.empty(self.dc, self.thermal,
                                                self.power)
        self.allocator = (TapasAllocator(seed=cfg.seed) if cfg.policy.place
                          else BaselineAllocator(seed=cfg.seed))
        self.router = (TapasRouter() if cfg.policy.route
                       else BaselineRouter())
        self.configurator = InstanceConfigurator(tick_s=cfg.tick_min * 60.0)
        self.nominal = P._entry(P.NOMINAL)

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        cfg = self.cfg
        dc, th, pm = self.dc, self.thermal, self.power
        chips = dc.cfg.hw.chips
        s = dc.n_servers
        ticks = int(cfg.horizon_h * 60 / cfg.tick_min)
        t_h = np.arange(ticks) * cfg.tick_min / 60.0
        t_out = np.asarray(outside_temperature(cfg.dc.region, t_h,
                                               seed=cfg.seed))

        # event queues: O(log n) pops instead of pop(0)/rebuild-and-remove
        evseq = itertools.count()
        pending = [(vm.arrival_h, next(evseq), vm) for vm in self.work.vms]
        heapq.heapify(pending)
        departures: list = []   # heap of (depart_h, seq, srv, vm)
        ep_servers: dict[str, list] = {e: [] for e in self.work.endpoints}
        server_ep: dict[int, str] = {}
        freq_cap = np.ones(s)           # persistent power-cap state
        last_util = np.zeros(s)         # previous-tick mean chip util
        affinity: dict[str, np.ndarray] = {}

        max_temp = np.zeros(ticks)
        peak_row = np.zeros(ticks)
        row_frac_t = np.zeros((ticks, dc.n_rows))
        th_events = pw_events = 0
        th_capped = pw_capped = 0
        occupied_acc = 0        # occupied server-ticks, accumulated per tick
        unserved_total = demand_total = 0.0
        quality_acc = quality_w = 0.0
        iaas_impact = saas_impact = 0.0

        for ti in range(ticks):
            now = t_h[ti]
            # -- arrivals / departures ---------------------------------
            while pending and pending[0][0] <= now:
                _, _, vm = heapq.heappop(pending)
                srv = self.allocator.place(self.alloc_state, vm, seed=cfg.seed)
                if srv is not None:
                    heapq.heappush(departures, (vm.arrival_h + vm.lifetime_h,
                                                next(evseq), srv, vm))
                    if vm.kind == "saas":
                        ep_servers[vm.customer].append(srv)
                        server_ep[srv] = vm.customer
            while departures and departures[0][0] <= now:
                _, _, srv, vm = heapq.heappop(departures)
                self.alloc_state.release(srv)
                if vm.kind == "saas" and srv in server_ep:
                    ep_servers[server_ep.pop(srv)].remove(srv)
                self.configurator.reset(srv)

            kind = self.alloc_state.kind_of
            iaas_mask = kind == 1
            occupied_acc += int((kind > 0).sum())

            # -- failure state -----------------------------------------
            ahu_derate = np.ones(dc.n_aisles)
            ups_derate = np.ones(dc.n_rows)
            cooling_extra = 0.0
            emergency = False
            for f in cfg.failures:
                if f.start_h <= now < f.end_h:
                    emergency = True
                    if f.kind == "ahu":
                        n = dc.cfg.ahus_per_aisle
                        ahu_derate[f.target] = (n - 1) / n
                    elif f.kind == "ups":
                        ups_derate[:] = 0.75                 # 4N/3 failover
                    elif f.kind == "cooling":
                        cooling_extra = 3.0
                    elif f.kind == "thermal":
                        # paper §5.4 thermal emergency: ~90% cooling capacity
                        # (an AHU loss in one aisle + DC-level cooling strain)
                        n = dc.cfg.ahus_per_aisle
                        ahu_derate[f.target] = (n - 1) / n
                        cooling_extra = 2.5
            prov_air = dc.prov_ahu_cfm * ahu_derate
            prov_pwr = dc.prov_row_power_w * ups_derate

            # -- IaaS utilization --------------------------------------
            util_srv = np.zeros(s)
            for _, _, srv, vm in departures:
                if vm.kind == "iaas" and self.alloc_state.vm_of[srv] == vm.vm_id:
                    util_srv[srv] = iaas_util(vm, np.asarray([now]),
                                              seed=cfg.seed)[0]

            # -- capacity + risk for SaaS routing ----------------------
            self.configurator.tick()
            dc_load_prev = float(last_util.mean())
            inlet_est = np.asarray(th.inlet_temp(
                t_out[ti], dc_load_prev, cooling_derate=cooling_extra))
            risk_srv = self._risk(inlet_est, freq_cap, prov_pwr, prov_air,
                                  np.maximum(util_srv, last_util), kind)

            # -- route endpoint demand ---------------------------------
            # TAPAS routing sees Eq. 2-derived per-server load ceilings so
            # energy-packing can never push a server past its thermal cap
            u_max = np.asarray(th.max_util_for_temp(
                inlet_est, th.gpu_limit - 3.0))
            saas_load = np.zeros(s)
            quality_srv = np.ones(s)
            for ep, servers in ep_servers.items():
                if not servers:
                    continue
                idx = np.asarray(servers)
                demand = (endpoint_load(ep, np.asarray([now]),
                                        seed=cfg.seed)[0]
                          * len(servers) * cfg.demand_scale)
                caps, quals = [], []
                for srv in idx:
                    st = self.configurator.get(srv)
                    e = st.entry
                    paused = st.pause_ticks > 0
                    cap = (0.0 if paused else
                           (e.goodput / self.nominal.goodput) * freq_cap[srv])
                    if cfg.policy.route and cap > 0:
                        busy_max = min(u_max[srv] / max(e.temp, 1e-6), 1.0)
                        cap *= busy_max
                    caps.append(cap)
                    quals.append(e.quality)
                caps = np.asarray(caps)
                aff = affinity.get(ep)
                if aff is None or len(aff) != len(idx):
                    aff = np.zeros(len(idx))
                dec = self.router.route(demand, caps, risk_srv[idx], aff)
                saas_load[idx] = dec.load
                quality_srv[idx] = np.asarray(quals)
                affinity[ep] = dec.load.copy()
                unserved_total += dec.unserved
                demand_total += demand
                quality_acc += float((dec.load * np.asarray(quals)).sum())
                quality_w += float(dec.load.sum())

            # -- instance configuration (TAPAS) ------------------------
            if cfg.policy.config:
                hot = risk_srv > 0.45
                for srv in np.flatnonzero((kind == 2) & hot):
                    margin = 1.0 - risk_srv[srv]
                    self.configurator.decide(
                        int(srv),
                        power_cap=max(0.6, margin + 0.45),
                        temp_cap=max(0.6, margin + 0.45),
                        emergency=emergency,
                        min_goodput=float(saas_load[srv])
                        * self.nominal.goodput)
                # restore drained servers once their risk clears
                cool = risk_srv < 0.25
                for srv in np.flatnonzero((kind == 2) & cool):
                    st = self.configurator.state.get(int(srv))
                    if st is not None and st.current != P.NOMINAL:
                        self.configurator.decide(int(srv), power_cap=1.0,
                                                 temp_cap=1.35)

            # -- chip utilization --------------------------------------
            chip_util = np.zeros((s, chips))
            # IaaS: capped clocks scale both work done and draw
            chip_util[iaas_mask] = (util_srv[iaas_mask]
                                    * freq_cap[iaas_mask])[:, None]
            for srv in np.flatnonzero(kind == 2):
                st = self.configurator.get(int(srv))
                e = st.entry
                cap = (e.goodput / self.nominal.goodput) * freq_cap[srv]
                busy = min(saas_load[srv] / max(cap, 1e-9), 1.0)
                tp = e.cfg.tp
                # e.temp is the per-active-chip utilization-equivalent of
                # this config at full busy (work concentrates at low TP)
                chip_util[srv, :tp] = min(busy * e.temp, 1.0)
            chip_util = np.clip(chip_util, 0.0, 1.0)

            # -- physics -----------------------------------------------
            power_s = np.asarray(pm.server_power(chip_util))
            power_s = np.where(kind > 0, power_s, 0.12 * dc.cfg.hw.idle_power_w)
            p_row = dc.row_sum(power_s)
            dc_load = float(power_s.sum()
                            / (dc.cfg.hw.peak_power_w * s))
            inlet = np.asarray(th.inlet_temp(t_out[ti], dc_load,
                                             cooling_derate=cooling_extra))
            t_gpu = np.array(th.gpu_temp(inlet, chip_util))
            air = np.asarray(th.airflow(chip_util.mean(axis=1)))
            air = np.where(kind > 0, air, th.airflow_idle * 0.5)
            a_air = dc.aisle_sum(air)

            # heat recirculation: aisles over provisioned airflow push inlet
            recirc = np.maximum(a_air / np.maximum(prov_air, 1.0) - 1.0, 0.0)
            t_gpu += (6.0 * recirc)[dc.aisle_of][:, None]

            # -- throttling / capping ----------------------------------
            hot_srv = (t_gpu.max(axis=1) >= dc.cfg.hw.gpu_temp_limit_c) & (kind > 0)
            over_row = p_row > prov_pwr
            # record the *demanded* (pre-throttle) peak — what the load asked
            # for; hardware clamps the realized temperature at the limit
            max_temp[ti] = (float(t_gpu[kind > 0].max())
                            if (kind > 0).any() else 0.0)
            th_events += int(hot_srv.sum())
            pw_events += int(over_row.sum())
            th_capped += int(hot_srv.sum())
            pw_capped += int(((over_row[dc.row_of]) & (kind > 0)).sum())

            # hardware thermal throttling clamps the hot server within the
            # tick: cut util to the Eq. 2 inversion at the limit, redo physics
            clamp = np.ones(s)
            if hot_srv.any():
                u_lim = np.asarray(th.max_util_for_temp(
                    inlet, dc.cfg.hw.gpu_temp_limit_c))
                cur = chip_util.max(axis=1)
                clamp = np.where(hot_srv, np.minimum(
                    u_lim / np.maximum(cur, 1e-6), 1.0), 1.0)
                chip_util = chip_util * clamp[:, None]
                power_s = np.asarray(pm.server_power(chip_util))
                power_s = np.where(kind > 0, power_s,
                                   0.12 * dc.cfg.hw.idle_power_w)
                p_row = dc.row_sum(power_s)
                t_gpu = np.array(th.gpu_temp(inlet, chip_util))
                t_gpu += (6.0 * recirc)[dc.aisle_of][:, None]
                # throttling costs served throughput on SaaS servers
                loss = saas_load * (1.0 - clamp)
                unserved_total += float(loss[kind == 2].sum())
                saas_load = saas_load - loss

            # power capping: baseline caps every server in the row uniformly;
            # TAPAS caps IaaS only (SaaS was already reconfigured/steered)
            mask = iaas_mask if cfg.policy.config else (kind > 0)
            factors = np.asarray(capping_factors(
                dc, power_s, prov_pwr, pm,
                iaas_only_mask=mask))
            new_cap = np.clip(freq_cap * factors, 0.3, 1.0)
            freq_cap = np.where(factors < 1.0, new_cap,
                                np.minimum(freq_cap * 1.1, 1.0))

            # perf impact = power-cap depth + in-tick thermal-clamp depth
            cap_depth = (1.0 - freq_cap) + (1.0 - clamp)
            iaas_impact += float(cap_depth[iaas_mask].mean()) if iaas_mask.any() else 0.0
            saas_mask = kind == 2
            saas_impact += float(cap_depth[saas_mask].mean()) if saas_mask.any() else 0.0

            rowf = p_row / np.maximum(dc.prov_row_power_w, 1.0)
            row_frac_t[ti] = rowf
            peak_row[ti] = float(rowf.max())
            last_util = chip_util.mean(axis=1)

        # normalize capped-event counts by the true occupied server-ticks
        # (summed per tick — occupancy drifts as VMs arrive and depart)
        occupied_ticks = max(occupied_acc, 1)
        return SimResult(
            time_h=t_h,
            max_gpu_temp=max_temp,
            peak_row_power_frac=peak_row,
            thermal_events=th_events,
            power_events=pw_events,
            thermal_capped_frac=th_capped / occupied_ticks,
            power_capped_frac=pw_capped / occupied_ticks,
            unserved_frac=unserved_total / max(demand_total, 1e-9),
            mean_quality=quality_acc / max(quality_w, 1e-9),
            iaas_perf_impact=iaas_impact / ticks,
            saas_perf_impact=saas_impact / ticks,
            row_power_frac=row_frac_t,
        )

    # ------------------------------------------------------------------
    def _risk(self, inlet, freq_cap, prov_pwr, prov_air, iaas_util_now, kind):
        """Per-server violation risk in [0,1] from Eqs. 1–4 forecasts."""
        dc, th, pm = self.dc, self.thermal, self.power
        s = dc.n_servers
        chips = dc.cfg.hw.chips
        # server-level: temperature forecast at moderately increased load
        # (full-load forecasts mark nearly every warm server risky and
        # starve routing; the paper routes on *violation risk*, not worst case)
        probe = np.clip(iaas_util_now + 0.35, 0.0, 1.0)
        t_probe = np.asarray(th.gpu_temp(
            inlet, np.repeat(probe[:, None], chips, axis=1))).max(axis=1)
        t_risk = 1.0 / (1.0 + np.exp(-(t_probe - th.gpu_limit) / 2.0))
        # row-level: graded power risk — engages well before the envelope so
        # packing prefers cold rows and hot rows shed SaaS load (§4.2 Row)
        pwr = np.asarray(pm.server_power(
            np.repeat(iaas_util_now[:, None], chips, axis=1)))
        pwr = np.where(kind > 0, pwr, 0.0)
        rowp = dc.row_sum(pwr)
        row_frac = rowp / np.maximum(prov_pwr, 1.0)
        # relative balancing: above-fleet-average rows repel load long before
        # the envelope, plus a hard ramp approaching the limit itself
        rel = np.clip((row_frac - row_frac.mean()) / 0.25, 0.0, 1.0)
        near = np.clip((row_frac - 0.85) / 0.15, 0.0, 1.0)
        p_risk = np.maximum(rel * 0.7, near)[dc.row_of]
        # aisle airflow headroom
        air = np.asarray(th.airflow(iaas_util_now))
        a_air = dc.aisle_sum(np.where(kind > 0, air, 0.0))
        n_per_aisle = dc.aisle_sum((kind > 0).astype(float))
        a_head = (prov_air - a_air) / np.maximum(
            n_per_aisle * th.airflow_max, 1.0)
        a_risk = np.clip(0.8 - a_head, 0.0, 1.0)[dc.aisle_of]
        return np.maximum.reduce([t_risk, p_risk, a_risk])


def run_policy(policy: Policy, **kw) -> SimResult:
    cfg = SimConfig(policy=policy, **kw)
    return ClusterSim(cfg).run()
