"""Discrete-time cluster simulator — paper §5.1.

Replays IaaS power traces and SaaS LLM-inference load over the datacenter
of §2, evaluating placement/routing/configuration policies under the
thermal (Eqs. 1–3) and power (Eq. 4) models; tracks throttling/capping
events and their performance/quality impact.

The physics (thermal/power models) run as vectorized JAX over all servers;
policy logic is event-level Python/NumPy, mirroring the control plane.

The simulator is *step-wise*: external drivers advance it one tick at a
time with ``state = sim.step()`` and read (or log) the typed
``ClusterState`` (see ``core.state``) it returns; ``run()`` is just
``reset(); while ...: step(); result()``.  Internally each ``step()``
executes the phases

    state = self.observe()           # arrivals/departures + telemetry
    self.route(state)                # policy.route per endpoint
    changes = self.policy.reconfigure(state)
    self.apply(state)                # physics, throttling, capping

and then advances ``self.tick`` — the phase methods themselves never do,
so drivers that call phases directly (to perturb state between them) must
manage ``self.tick`` and run each phase exactly once per tick.  Real
serving engines bind to simulated SaaS servers via
``sim.attach_backend`` (see ``serving.backend``).

Policies are ``ControlPolicy`` objects; the Baseline/TAPAS control planes
are composed from ``PlacementPolicy`` / ``RoutingPolicy`` /
``ReconfigurePolicy`` adapters over the pre-existing allocator, router and
instance-configurator classes.  Custom policies plug in through
``SimConfig(control=...)``.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field

import numpy as np

from repro.core import profiles as P
from repro.core.allocator import (AllocatorState, BaselineAllocator,
                                  PlacementPolicy, TapasAllocator)
from repro.core.configurator import InstanceConfigurator, ReconfigurePolicy
from repro.core.datacenter import Datacenter, DCConfig
from repro.core.faults import EngineFault, ResilienceKnobs
from repro.core.power import PowerModel, capping_factors
from repro.core.risk import server_risk
from repro.core.router import BaselineRouter, RoutingPolicy, TapasRouter
from repro.core.scenario import (PriceShock, Scenario, WeatherShift,
                                 as_scenario)
# legacy re-exports: FailureEvent and friends used to live in this module
from repro.core.scenario import DemandSurge, FailureEvent, VMArrival  # noqa: F401,E501
from repro.core.state import ClusterState, ControlPolicy, InstanceView
from repro.core.thermal import ThermalModel, outside_temperature
from repro.core.traces import (VMSpec, endpoint_load, generate_workload,
                               iaas_util, trace_seed)


@dataclass(frozen=True)
class Policy:
    place: bool = False
    route: bool = False
    config: bool = False

    @property
    def name(self) -> str:
        if not (self.place or self.route or self.config):
            return "baseline"
        parts = [n for n, on in (("place", self.place), ("route", self.route),
                                 ("config", self.config)) if on]
        return "+".join(parts)


BASELINE = Policy()
TAPAS = Policy(place=True, route=True, config=True)


@dataclass
class SimConfig:
    dc: DCConfig = field(default_factory=DCConfig)
    horizon_h: float = 24.0
    tick_min: float = 5.0
    saas_fraction: float = 0.5
    seed: int = 0
    policy: Policy = BASELINE
    scenario: Scenario | None = None
    failures: tuple = ()         # legacy channel, merged into the scenario
    occupancy: float = 0.88
    demand_scale: float = 0.85   # endpoint demand vs fleet capacity
    # custom control plane: a ControlPolicy instance (good for one run) or a
    # zero-arg factory returning one (rebuilt on every reset(), so repeated
    # run() calls stay deterministic).  None -> built from ``policy`` flags.
    control: ControlPolicy | None = None
    # power-capping semantics (paper §5.4): True caps IaaS only (SaaS was
    # already reconfigured/steered), False caps every server in the row.
    # None derives it from ``policy.config`` — set explicitly when driving
    # a custom ``control`` whose reconfigure behavior the flags don't know.
    iaas_only_capping: bool | None = None
    # fleet identity: the region label stamped on every ClusterState, and
    # the trace-seed namespace (see ``traces.trace_seed``) that keeps two
    # regions with identical configs from replaying identical weather /
    # customer / endpoint noise.  Both default to the standalone behavior.
    region_name: str = ""
    trace_namespace: str = ""
    # recovery machinery switches (core.faults.ResilienceKnobs); None ->
    # everything on at defaults.  Pass faults.recovery_off() for the
    # no-recovery ablation arm.
    resilience: ResilienceKnobs | None = None


@dataclass
class SimResult:
    time_h: np.ndarray
    max_gpu_temp_c: np.ndarray         # (T,)
    peak_row_power_frac: np.ndarray  # (T,) hottest row / provisioned
    thermal_events: int
    power_events: int
    thermal_capped_frac: float       # fraction of server-ticks throttled
    power_capped_frac: float
    unserved_frac: float             # SaaS demand that queued (SLO proxy)
    mean_quality: float              # load-weighted SaaS quality
    iaas_perf_impact: float          # mean freq-cap depth x affected frac
    saas_perf_impact: float
    row_power_frac: np.ndarray       # (T, R)
    energy_kwh: float = 0.0          # IT energy drawn over the run

    def summary(self) -> dict:
        return {
            "energy_kwh": self.energy_kwh,
            "max_temp_c": float(self.max_gpu_temp_c.max()),
            "p99_temp_c": float(np.quantile(self.max_gpu_temp_c, 0.99)),
            "peak_row_power_frac": float(self.peak_row_power_frac.max()),
            "thermal_events": self.thermal_events,
            "power_events": self.power_events,
            "thermal_capped_frac": self.thermal_capped_frac,
            "power_capped_frac": self.power_capped_frac,
            "unserved_frac": self.unserved_frac,
            "mean_quality": self.mean_quality,
            "iaas_perf_impact": self.iaas_perf_impact,
            "saas_perf_impact": self.saas_perf_impact,
        }


class CompositeControlPlane:
    """A ``ControlPolicy`` bundled from placement/routing/reconfigure
    adapters — the shape both built-in control planes share."""

    def __init__(self, placement: PlacementPolicy, routing: RoutingPolicy,
                 reconfig: ReconfigurePolicy):
        self.placement = placement
        self.routing = routing
        self.reconfig = reconfig

    def begin_tick(self, state: ClusterState) -> None:
        self.reconfig.begin_tick(state)

    def place(self, state: ClusterState, vm: VMSpec) -> int | None:
        return self.placement.place(state, vm)

    def route(self, state: ClusterState, endpoint: str, demand: float):
        return self.routing.route(state, endpoint, demand)

    def reconfigure(self, state: ClusterState) -> list:
        return self.reconfig.reconfigure(state)

    def release(self, state: ClusterState, server: int) -> None:
        self.reconfig.release(state, server)


def build_control_policy(policy: Policy, *, tick_s: float,
                         seed: int = 0) -> CompositeControlPlane:
    """Compose the Baseline/TAPAS control plane selected by the per-
    subsystem ``Policy`` flags (paper Fig. 20 ablation axes)."""
    allocator = (TapasAllocator(seed=seed) if policy.place
                 else BaselineAllocator(seed=seed))
    router = TapasRouter() if policy.route else BaselineRouter()
    configurator = InstanceConfigurator(tick_s=tick_s)
    return CompositeControlPlane(
        PlacementPolicy(allocator),
        RoutingPolicy(router, thermal_aware=policy.route),
        ReconfigurePolicy(configurator, active=policy.config))


class ClusterSim:
    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.dc = Datacenter(cfg.dc)
        self.thermal = ThermalModel.calibrate(self.dc)
        self.power = PowerModel.calibrate(self.dc)
        self.scenario = as_scenario(cfg.scenario, cfg.failures)
        self._validate_scenario_targets()
        self.resilience = (cfg.resilience if cfg.resilience is not None
                           else ResilienceKnobs())
        self._tseed = trace_seed(cfg.seed, cfg.trace_namespace)
        self.work = generate_workload(
            n_servers=self.dc.n_servers, horizon_h=cfg.horizon_h,
            seed=self._tseed, saas_fraction=cfg.saas_fraction,
            occupancy=cfg.occupancy)
        self._inject_scripted_vms()
        # pristine workload watermark: reset() truncates back to it, so
        # mid-run inject_vm() calls (fleet admissions/migrations) are not
        # replayed as scripted arrivals on a rerun
        self._n_base_vms = len(self.work.vms)
        self._base_endpoints = set(self.work.endpoints)
        self.nominal = P._entry(P.NOMINAL)
        self.ticks = int(cfg.horizon_h * 60 / cfg.tick_min)
        self.t_h = np.arange(self.ticks) * cfg.tick_min / 60.0
        self.reset()

    def _validate_scenario_targets(self) -> None:
        """Event fields validate themselves, but only the sim knows the
        topology — catch an out-of-range aisle target here instead of an
        IndexError hours into the drill."""
        for ev in self.scenario.events:
            if getattr(ev, "region", None) is not None:
                raise ValueError(
                    f"event {ev!r} is scoped to region {ev.region!r}, but "
                    f"this is a single-cluster sim — region-tagged events "
                    f"need core.fleet.FleetSim (or drop the tag)")
            if isinstance(ev, PriceShock):
                raise ValueError(
                    f"event {ev!r} is fleet-level economics; a single "
                    f"cluster has no power price — price shocks need "
                    f"core.fleet.FleetSim")
            if (isinstance(ev, FailureEvent) and ev.kind in ("ahu", "thermal")
                    and ev.target >= self.dc.n_aisles):
                raise ValueError(
                    f"{ev.kind} failure targets aisle {ev.target}, but the "
                    f"datacenter has {self.dc.n_aisles} aisles")
            if (isinstance(ev, EngineFault) and ev.server is not None
                    and ev.server >= self.dc.n_servers):
                raise ValueError(
                    f"{ev.kind} engine fault targets server {ev.server}, "
                    f"but the datacenter has {self.dc.n_servers} servers")

    def _inject_scripted_vms(self) -> None:
        """Append Scenario VMArrival events to the generated workload."""
        vid = len(self.work.vms)
        for ev in self.scenario.vm_arrivals():
            vm = VMSpec(vid, ev.kind, ev.customer, arrival_h=ev.arrival_h,
                        lifetime_h=ev.lifetime_h, peak_util=ev.peak_util)
            self.work.vms.append(vm)
            if ev.kind == "saas":
                self.work.endpoints.setdefault(ev.customer, []).append(vid)
            vid += 1

    # ------------------------------------------------------------------
    def reset(self) -> None:
        """(Re)initialize all per-run mutable state; each ``run()`` (or
        external step sequence) after a reset is deterministic.

        A custom ``control`` passed as a *factory* is rebuilt here; a bare
        policy instance is reused as-is and keeps whatever internal state
        (affinity memory, RNG position) it accumulated — pass a factory if
        you rerun the same sim."""
        cfg = self.cfg
        if cfg.control is None:
            self.policy: ControlPolicy = build_control_policy(
                cfg.policy, tick_s=cfg.tick_min * 60.0, seed=cfg.seed)
        elif isinstance(cfg.control, type) or (
                callable(cfg.control)
                and not isinstance(cfg.control, ControlPolicy)):
            # a policy class or any other zero-arg factory: build fresh
            # (isinstance(SomeClass, Protocol) is True for the class object
            # itself, so classes must be caught before the protocol check)
            self.policy = cfg.control()
        else:
            self.policy = cfg.control
        self.alloc_state = AllocatorState.empty(self.dc, self.thermal,
                                                self.power)
        # drop VMs injected mid-run (fleet admissions/migrations) so the
        # rerun replays only the generated + scripted workload
        del self.work.vms[self._n_base_vms:]
        for name in list(self.work.endpoints):
            ids = [v for v in self.work.endpoints[name]
                   if v < self._n_base_vms]
            if ids or name in self._base_endpoints:
                self.work.endpoints[name] = ids
            else:
                del self.work.endpoints[name]
        self.tick = 0
        t_out = np.array(outside_temperature(cfg.dc.region, self.t_h,
                                             seed=self._tseed))
        if any(isinstance(ev, WeatherShift) for ev in self.scenario.events):
            t_out = t_out + np.array([self.scenario.weather_delta(float(t))
                                      for t in self.t_h])
        self._t_out = t_out
        # event queues: O(log n) pops instead of pop(0)/rebuild-and-remove
        self._evseq = itertools.count()
        self._pending = [(vm.arrival_h, next(self._evseq), vm)
                         for vm in self.work.vms]
        heapq.heapify(self._pending)
        self._departures: list = []   # heap of (depart_h, seq, srv, vm)
        self._ep_servers: dict[str, list] = {e: []
                                             for e in self.work.endpoints}
        self._server_ep: dict[int, str] = {}
        self._vm_on: dict[int, VMSpec] = {}     # server -> resident VM
        self._freq_cap = np.ones(self.dc.n_servers)
        self._last_util = np.zeros(self.dc.n_servers)
        # engine bindings carry live queues/stats that reset() cannot
        # rewind, so they are per-run: reattach after each reset
        self.backends: dict = {}   # server -> serving.backend.EngineBackend
        self._backends_synced: set = set()
        # resilience: watchdog health tracking + last-known-good telemetry
        self._unhealthy: set = set()
        self._hb_miss: dict = {}          # server -> consecutive misses
        self._parked: list = []           # drained reqs with no healthy home
        self._lkg: dict | None = None     # last-known-good sensor snapshot
        self._telemetry_age = 0           # ticks since the snapshot was live
        self.watchdog_drains = 0          # unhealthy transitions observed
        # accumulators
        self._max_temp = np.zeros(self.ticks)
        self._peak_row = np.zeros(self.ticks)
        self._row_frac_t = np.zeros((self.ticks, self.dc.n_rows))
        self._th_events = self._pw_events = 0
        self._th_capped = self._pw_capped = 0
        self._occupied_acc = 0
        self._energy_kwh = 0.0
        self._unserved_total = self._demand_total = 0.0
        self._quality_acc = self._quality_w = 0.0
        self._iaas_impact = self._saas_impact = 0.0

    def attach_backend(self, server: int, backend) -> None:
        """Bind a real serving engine (``serving.backend.EngineBackend``)
        to a simulated SaaS server: reconfigure decisions for that server
        are mirrored onto the engine's knobs, and the engine's measured
        goodput is reported back into ``ClusterState.measured_goodput``.

        Bindings last until the next ``reset()`` — an engine's queue and
        stats cannot be rewound, so a rerun starts unbound."""
        self.backends[int(server)] = backend

    # ------------------------------------------------------------------
    # observe: arrivals/departures + telemetry -> ClusterState
    # ------------------------------------------------------------------
    def observe(self) -> ClusterState:
        cfg, dc, th = self.cfg, self.dc, self.thermal
        ti = self.tick
        now = float(self.t_h[ti])
        state = self._begin_state(ti, now)

        # -- arrivals / departures -----------------------------------
        while self._pending and self._pending[0][0] <= now:
            _, _, vm = heapq.heappop(self._pending)
            srv = self.policy.place(state, vm)
            if srv is not None:
                heapq.heappush(self._departures,
                               (vm.arrival_h + vm.lifetime_h,
                                next(self._evseq), srv, vm))
                self._vm_on[srv] = vm
                if vm.kind == "saas":
                    self._ep_servers[vm.customer].append(srv)
                    self._server_ep[srv] = vm.customer
        while self._departures and self._departures[0][0] <= now:
            _, _, srv, vm = heapq.heappop(self._departures)
            if self._vm_on.get(srv) is not vm:
                continue   # evicted (fleet migration); server may be reused
            self.alloc_state.release(srv)
            self._vm_on.pop(srv, None)
            if vm.kind == "saas" and srv in self._server_ep:
                self._ep_servers[self._server_ep.pop(srv)].remove(srv)
            self.policy.release(state, srv)

        kind = state.kind
        self._occupied_acc += int((kind > 0).sum())

        # -- IaaS utilization: maintained server -> vm map -----------
        util_srv = np.zeros(dc.n_servers)
        for srv, vm in self._vm_on.items():
            if vm.kind == "iaas":
                util_srv[srv] = iaas_util(vm, np.asarray([now]),
                                          seed=self._tseed)[0]
        state.iaas_util = util_srv

        # -- instance telemetry + capacity/risk forecasts ------------
        self.policy.begin_tick(state)
        dc_load_prev = float(self._last_util.mean())
        state.inlet_est = np.asarray(th.inlet_temp(
            self._t_out[ti], dc_load_prev,
            cooling_derate=state.cooling_extra_c))
        state.risk = server_risk(
            dc, th, self.power, inlet=state.inlet_est,
            prov_row_power_w=state.prov_row_power_w,
            prov_aisle_cfm=state.prov_aisle_cfm,
            util=np.maximum(util_srv, self._last_util), kind=kind)
        # Eq. 2-derived per-server load ceilings: thermal-aware routing
        # can never push a server past its thermal cap
        state.u_max = np.asarray(th.max_util_for_temp(
            state.inlet_est, th.gpu_limit - 3.0))

        # -- sensor dropout: freeze derived telemetry at last-known-good --
        # The physics in apply() keeps using ground truth (hardware does
        # not stop heating because a sensor died); only what the control
        # plane *sees* freezes.  Risk gets a per-tick staleness bump so
        # policies steer conservatively instead of trusting the frozen
        # reading; telemetry_age_ticks exposes the staleness itself.
        if self.scenario.sensor_dropout(now) and self._lkg is not None:
            self._telemetry_age += 1
            state.inlet_est = self._lkg["inlet_est"]
            state.u_max = self._lkg["u_max"]
            state.risk = np.minimum(
                self._lkg["risk"]
                + self.resilience.stale_risk_bump * self._telemetry_age,
                1.0)
            state.telemetry_age_ticks = self._telemetry_age
        else:
            self._lkg = {"inlet_est": state.inlet_est,
                         "u_max": state.u_max, "risk": state.risk.copy()}
            self._telemetry_age = 0
        return state

    def _begin_state(self, ti: int, now: float) -> ClusterState:
        """Construct the tick's state: occupancy views + scenario-derived
        failure derates (available to ``place`` before telemetry)."""
        dc = self.dc
        ahu_derate = np.ones(dc.n_aisles)
        ups_derate = np.ones(dc.n_rows)
        cooling_extra = 0.0
        emergency = False
        for f in self.scenario.failures(now):
            emergency = True
            if f.kind == "ahu":
                n = dc.cfg.ahus_per_aisle
                ahu_derate[f.target] = (n - 1) / n
            elif f.kind == "ups":
                ups_derate[:] = 0.75                 # 4N/3 failover
            elif f.kind == "cooling":
                cooling_extra = 3.0
            elif f.kind == "thermal":
                # paper §5.4 thermal emergency: ~90% cooling capacity
                # (an AHU loss in one aisle + DC-level cooling strain)
                n = dc.cfg.ahus_per_aisle
                ahu_derate[f.target] = (n - 1) / n
                cooling_extra = 2.5
        return ClusterState(
            tick=ti, now_h=now, t_outside_c=float(self._t_out[ti]),
            seed=self._tseed, dc=dc, nominal=self.nominal,
            region=self.cfg.region_name,
            alloc=self.alloc_state, kind=self.alloc_state.kind_of,
            vm_of=self.alloc_state.vm_of, endpoints=self._ep_servers,
            emergency=emergency, ahu_derate=ahu_derate,
            ups_derate=ups_derate, cooling_extra_c=cooling_extra,
            prov_row_power_w=dc.prov_row_power_w * ups_derate,
            prov_aisle_cfm=dc.prov_ahu_cfm * ahu_derate,
            freq_cap=self._freq_cap, last_util=self._last_util,
            saas_load=np.zeros(dc.n_servers),
            quality=np.ones(dc.n_servers))

    # ------------------------------------------------------------------
    # route: endpoint demand through the policy
    # ------------------------------------------------------------------
    def endpoint_demand(self, ep: str, now: float) -> float:
        """This cluster's natural demand for ``ep`` at ``now`` (diurnal
        trace x fleet scale x scenario surges) — the quantity ``route``
        uses when no external driver overrides it, exposed so a fleet
        router can read every region's demand before redistributing it."""
        cfg = self.cfg
        demand = (endpoint_load(ep, np.asarray([now]),
                                seed=self._tseed)[0]
                  * len(self._ep_servers[ep]) * cfg.demand_scale)
        surge = self.scenario.demand_scale(now, ep)
        if surge != 1.0:
            demand = demand * surge
        return demand

    def route(self, state: ClusterState,
              demand_overrides: dict | None = None) -> None:
        """Route every endpoint's demand through the policy.

        ``demand_overrides`` (endpoint -> demand) substitutes an external
        driver's figure — a ``FleetSim`` steering load across regions —
        for the cluster's natural ``endpoint_demand``; endpoints not in
        the dict fall back to the natural demand."""
        now = state.now_h
        for ep, servers in state.endpoints.items():
            if not servers:
                continue
            if demand_overrides is not None and ep in demand_overrides:
                demand = demand_overrides[ep]
            else:
                demand = self.endpoint_demand(ep, now)
            out = self.policy.route(state, ep, demand)
            state.saas_load[out.servers] = out.load
            state.quality[out.servers] = out.quality
            self._unserved_total += out.unserved
            self._demand_total += demand
            self._quality_acc += float((out.load * out.quality).sum())
            self._quality_w += float(out.load.sum())

    # ------------------------------------------------------------------
    # apply: physics, throttling, capping
    # ------------------------------------------------------------------
    def apply(self, state: ClusterState) -> None:
        cfg, dc, th, pm = self.cfg, self.dc, self.thermal, self.power
        ti = state.tick
        s = dc.n_servers
        chips = dc.cfg.hw.chips
        kind = state.kind
        iaas_mask = kind == 1
        freq_cap = self._freq_cap
        util_srv = state.iaas_util
        saas_load = state.saas_load
        prov_air = state.prov_aisle_cfm
        prov_pwr = state.prov_row_power_w

        # -- chip utilization --------------------------------------
        chip_util = np.zeros((s, chips))
        # IaaS: capped clocks scale both work done and draw
        chip_util[iaas_mask] = (util_srv[iaas_mask]
                                * freq_cap[iaas_mask])[:, None]
        for srv in np.flatnonzero(kind == 2):
            e = state.instances[int(srv)].entry
            cap = (e.goodput / self.nominal.goodput) * freq_cap[srv]
            busy = min(saas_load[srv] / max(cap, 1e-9), 1.0)
            tp = e.cfg.tp
            # e.temp_frac is the per-active-chip utilization-equivalent of
            # this config at full busy (work concentrates at low TP)
            chip_util[srv, :tp] = min(busy * e.temp_frac, 1.0)
        chip_util = np.clip(chip_util, 0.0, 1.0)

        # -- physics -----------------------------------------------
        power_s = np.asarray(pm.server_power(chip_util))
        power_s = np.where(kind > 0, power_s, 0.12 * dc.cfg.hw.idle_power_w)
        p_row = dc.row_sum(power_s)
        dc_load = float(power_s.sum()
                        / (dc.cfg.hw.peak_power_w * s))
        inlet = np.asarray(th.inlet_temp(self._t_out[ti], dc_load,
                                         cooling_derate=state.cooling_extra_c))
        t_gpu = np.array(th.gpu_temp(inlet, chip_util))
        air = np.asarray(th.airflow(chip_util.mean(axis=1)))
        air = np.where(kind > 0, air, th.airflow_idle_cfm * 0.5)
        a_air = dc.aisle_sum(air)

        # heat recirculation: aisles over provisioned airflow push inlet
        recirc = np.maximum(a_air / np.maximum(prov_air, 1.0) - 1.0, 0.0)
        t_gpu += (6.0 * recirc)[dc.aisle_of][:, None]

        # -- throttling / capping ----------------------------------
        hot_srv = (t_gpu.max(axis=1) >= dc.cfg.hw.gpu_temp_limit_c) & (kind > 0)
        over_row = p_row > prov_pwr
        # record the *demanded* (pre-throttle) peak — what the load asked
        # for; hardware clamps the realized temperature at the limit
        self._max_temp[ti] = (float(t_gpu[kind > 0].max())
                              if (kind > 0).any() else 0.0)
        self._th_events += int(hot_srv.sum())
        self._pw_events += int(over_row.sum())
        self._th_capped += int(hot_srv.sum())
        self._pw_capped += int(((over_row[dc.row_of]) & (kind > 0)).sum())

        # hardware thermal throttling clamps the hot server within the
        # tick: cut util to the Eq. 2 inversion at the limit, redo physics
        clamp = np.ones(s)
        if hot_srv.any():
            u_lim = np.asarray(th.max_util_for_temp(
                inlet, dc.cfg.hw.gpu_temp_limit_c))
            cur = chip_util.max(axis=1)
            clamp = np.where(hot_srv, np.minimum(
                u_lim / np.maximum(cur, 1e-6), 1.0), 1.0)
            chip_util = chip_util * clamp[:, None]
            power_s = np.asarray(pm.server_power(chip_util))
            power_s = np.where(kind > 0, power_s,
                               0.12 * dc.cfg.hw.idle_power_w)
            p_row = dc.row_sum(power_s)
            t_gpu = np.array(th.gpu_temp(inlet, chip_util))
            t_gpu += (6.0 * recirc)[dc.aisle_of][:, None]
            # throttling costs served throughput on SaaS servers
            loss = saas_load * (1.0 - clamp)
            self._unserved_total += float(loss[kind == 2].sum())
            saas_load = saas_load - loss
            state.saas_load = saas_load

        # power capping: baseline caps every server in the row uniformly;
        # TAPAS caps IaaS only (SaaS was already reconfigured/steered)
        iaas_only = (cfg.iaas_only_capping if cfg.iaas_only_capping
                     is not None else cfg.policy.config)
        mask = iaas_mask if iaas_only else (kind > 0)
        factors = np.asarray(capping_factors(
            dc, power_s, prov_pwr, pm,
            iaas_only_mask=mask))
        new_cap = np.clip(freq_cap * factors, 0.3, 1.0)
        freq_cap = np.where(factors < 1.0, new_cap,
                            np.minimum(freq_cap * 1.1, 1.0))
        self._freq_cap = freq_cap
        state.freq_cap = freq_cap

        # perf impact = power-cap depth + in-tick thermal-clamp depth
        cap_depth = (1.0 - freq_cap) + (1.0 - clamp)
        self._iaas_impact += (float(cap_depth[iaas_mask].mean())
                              if iaas_mask.any() else 0.0)
        saas_mask = kind == 2
        self._saas_impact += (float(cap_depth[saas_mask].mean())
                              if saas_mask.any() else 0.0)

        # served energy this tick (post-throttle/post-capping power draw)
        self._energy_kwh += (float(power_s.sum()) * cfg.tick_min / 60.0
                             / 1000.0)

        rowf = p_row / np.maximum(dc.prov_row_power_w, 1.0)
        self._row_frac_t[ti] = rowf
        self._peak_row[ti] = float(rowf.max())
        self._last_util = chip_util.mean(axis=1)

        # post-physics telemetry for external drivers
        state.last_util = self._last_util
        state.max_gpu_temp_c = self._max_temp[ti]
        state.row_power_frac = rowf
        state.thermal_throttled = hot_srv
        state.power_over_rows = over_row

    # ------------------------------------------------------------------
    def step(self) -> ClusterState:
        """Advance one tick; returns the tick's ``ClusterState``."""
        if self.tick >= self.ticks:
            raise RuntimeError(
                f"simulation horizon reached ({self.ticks} ticks); "
                f"call reset() to rerun")
        state = self.observe()
        self.route(state)
        return self.finish_tick(state)

    def finish_tick(self, state: ClusterState) -> ClusterState:
        """The tick's trailing half: reconfigure, backend sync, physics,
        tick advance.  Split out of ``step`` so external drivers (a
        ``FleetSim`` steering demand between ``observe`` and ``route``)
        share the exact reconfigure/apply code path instead of forking it.
        """
        changes = self.policy.reconfigure(state)
        # fold the decisions into the instance telemetry so the contract is
        # "return your changes" — policies need not also mutate
        # state.instances (the built-in adapter does both, identically)
        for ch in changes:
            state.instances[ch.server] = InstanceView(entry=ch.entry,
                                                      paused=ch.reloading)
        if self.backends:
            self._sync_backends(state, changes)
        self.apply(state)
        self.tick += 1
        return state

    # ------------------------------------------------------------------
    # fleet hooks: mid-run VM injection (scripted fleet admissions,
    # cross-region migrations) and eviction (drains)
    # ------------------------------------------------------------------
    def inject_vm(self, *, kind: str, customer: str, arrival_h: float,
                  lifetime_h: float, peak_util: float = 1.0) -> VMSpec:
        """Append a VM to this cluster's pending arrivals mid-run.

        Placement happens in the next ``observe`` whose time has reached
        ``arrival_h`` — a fleet admission or migration therefore lands one
        tick after the decision (the WAN transfer is not instantaneous).
        New SaaS endpoint names are created on the fly.
        """
        vm = VMSpec(len(self.work.vms), kind, customer, arrival_h=arrival_h,
                    lifetime_h=lifetime_h, peak_util=peak_util)
        self.work.vms.append(vm)
        if kind == "saas":
            self.work.endpoints.setdefault(customer, [])
            self.work.endpoints[customer].append(vm.vm_id)
            self._ep_servers.setdefault(customer, [])
        heapq.heappush(self._pending, (arrival_h, next(self._evseq), vm))
        return vm

    def evict(self, state: ClusterState, server: int) -> VMSpec | None:
        """Immediately remove the VM on ``server`` (fleet drain/migration).

        Releases occupancy and policy state exactly like a departure; the
        VM's scheduled departure event becomes a no-op (guarded by object
        identity).  Returns the evicted ``VMSpec`` so the fleet can
        re-inject it elsewhere, or None when the server is empty."""
        vm = self._vm_on.pop(server, None)
        if vm is None:
            return None
        self.alloc_state.release(server)
        if vm.kind == "saas" and server in self._server_ep:
            self._ep_servers[self._server_ep.pop(server)].remove(server)
        self.policy.release(state, server)
        return vm

    def _sync_backends(self, state: ClusterState, changes: list) -> None:
        """Mirror reconfigure decisions onto bound engines and report the
        engines' measured goodput back into the state.

        The resilience machinery runs here too, in a fixed order: land
        the tick's engine faults, run the watchdog (drain unhealthy
        backends onto healthy siblings), walk each degradation ladder,
        then pump.  Fault application precedes the watchdog so a crash
        is detected the same tick it fires."""
        for ch in changes:
            backend = self.backends.get(ch.server)
            if backend is not None:
                backend.apply_config(ch.entry.cfg, paused=ch.reloading)
                self._backends_synced.add(ch.server)
        res = self.resilience
        faults = self.scenario.engine_faults(state.now_h)
        for srv in sorted(self.backends):
            self.backends[srv].apply_faults(
                [f for f in faults if f.server in (None, srv)],
                now_h=state.now_h, tick=state.tick, knobs=res)
        if res.watchdog:
            self._watchdog_tick(state)
        for srv, backend in self.backends.items():
            inst = state.instances.get(srv)
            if srv not in self._backends_synced and inst is not None:
                # first tick after attach: push the server's *current*
                # config — it may have been reconfigured before binding
                backend.apply_config(inst.entry.cfg, paused=inst.paused)
                self._backends_synced.add(srv)
            if inst is not None:
                # track the reload drain: paused while pause_ticks run,
                # admitting again as soon as the configurator's view clears
                backend.engine.knobs.paused = inst.paused
            if res.ladder:
                backend.tick_ladder(state.emergency)
            load = (float(state.saas_load[srv])
                    if state.kind[srv] == 2 else 0.0)
            backend.pump(now=state.now_h, load=load)
        # batched pump: fleet-attached backends only *submitted* demand
        # above; run each distinct fleet's engines once for all of its
        # servers, then read everyone's settled rate
        fleets: list = []
        for backend in self.backends.values():
            fl = getattr(backend, "fleet", None)
            if fl is not None and all(fl is not f for f in fleets):
                fleets.append(fl)
        for fl in fleets:
            fl.flush(now=state.now_h)
        for srv, backend in self.backends.items():
            state.measured_goodput[srv] = backend.measured_goodput()

    def _watchdog_tick(self, state: ClusterState) -> None:
        """Heartbeat sweep: after ``heartbeat_misses`` consecutive missed
        beats a backend is marked unhealthy and its unfinished requests
        (in-flight, queued, backing off) are drained onto healthy sibling
        engines round-robin — re-homed requests keep their identity, so
        the origin's issued-ledger audit still sees their outcome.  With
        no healthy sibling the drained work parks at the watchdog and is
        re-homed the moment a backend recovers.  Recovery clears the
        unhealthy mark; already re-homed requests stay where they are."""
        res = self.resilience
        healthy = [s for s in sorted(self.backends)
                   if self.backends[s].heartbeat()]
        for srv in sorted(self.backends):
            backend = self.backends[srv]
            if backend.heartbeat():
                self._hb_miss[srv] = 0
                self._unhealthy.discard(srv)
                continue
            self._hb_miss[srv] = self._hb_miss.get(srv, 0) + 1
            if self._hb_miss[srv] < res.heartbeat_misses:
                continue
            if srv not in self._unhealthy:
                self._unhealthy.add(srv)
                self.watchdog_drains += 1
            # drain every tick while unhealthy: requests pumped into the
            # dead backend since the last sweep get re-homed too
            reqs = backend.engine.take_unfinished()
            dests = [self.backends[h] for h in healthy if h != srv]
            if not dests:
                self._parked.extend(reqs)
                continue
            for i, req in enumerate(reqs):
                dests[i % len(dests)].adopt([req])
        if self._parked and healthy:
            parked, self._parked = self._parked, []
            dests = [self.backends[h] for h in healthy]
            for i, req in enumerate(parked):
                dests[i % len(dests)].adopt([req])

    def result(self) -> SimResult:
        """Aggregate the ticks simulated so far into a SimResult."""
        if self.tick == 0:
            raise RuntimeError(
                "no ticks simulated yet; call step() or run() first")
        done = self.tick
        # normalize capped-event counts by the true occupied server-ticks
        # (summed per tick — occupancy drifts as VMs arrive and depart)
        occupied_ticks = max(self._occupied_acc, 1)
        return SimResult(
            time_h=self.t_h[:self.tick],
            max_gpu_temp_c=self._max_temp[:self.tick],
            peak_row_power_frac=self._peak_row[:self.tick],
            thermal_events=self._th_events,
            power_events=self._pw_events,
            thermal_capped_frac=self._th_capped / occupied_ticks,
            power_capped_frac=self._pw_capped / occupied_ticks,
            unserved_frac=self._unserved_total / max(self._demand_total, 1e-9),
            mean_quality=self._quality_acc / max(self._quality_w, 1e-9),
            iaas_perf_impact=self._iaas_impact / done,
            saas_perf_impact=self._saas_impact / done,
            row_power_frac=self._row_frac_t[:self.tick],
            energy_kwh=self._energy_kwh,
        )

    def run(self) -> SimResult:
        if self.tick:
            self.reset()
        while self.tick < self.ticks:
            self.step()
        return self.result()


def run_policy(policy: Policy, **kw) -> SimResult:
    cfg = SimConfig(policy=policy, **kw)
    return ClusterSim(cfg).run()
