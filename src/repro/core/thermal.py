"""Thermal models — paper §2.1, Eqs. (1)–(3) — vectorized JAX.

Eq. 1  T_inlet[s] = f_s(T_outside, Load_DC): piecewise in outside temp
       (flat >= 18 °C floor below 15 °C to limit humidity, linear 15–25 °C,
       compressed above 25 °C when mechanical assist kicks in) plus a
       load-dependent offset (Fig. 5: ~2 °C between idle and full DC load)
       and static spatial offsets (rows up to ~1 °C, racks up to ~2 °C,
       height minor — Fig. 4).

Eq. 2  T_gpu[s,g] = T_inlet[s] + alpha[s,g] * util + beta[s,g]: linear
       regression per chip (paper MAE < 1 °C), with per-chip heterogeneity
       up to ~10 °C inside one server; even-indexed chips run cooler
       (server layout, Fig. 8/9).

Eq. 3  f_air(util): linear fan curve between idle and max CFM; the aisle
       constraint is sum(f_air) <= ProvAHU.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.datacenter import Datacenter

REGION_OUTSIDE = {  # (mean °C, daily amplitude °C)
    "hot": (28.0, 7.0),
    "mild": (20.0, 7.0),
    "cold": (10.0, 6.0),
}


@dataclass
class ThermalModel:
    """Per-server / per-chip regression coefficients (seeded 'calibration')."""
    inlet_base: jnp.ndarray      # (S,) °C at the 18 °C floor
    inlet_slope: jnp.ndarray     # (S,) °C per outside °C in [15, 25]
    inlet_hot_slope: jnp.ndarray  # (S,) compressed slope above 25 °C
    load_coeff: jnp.ndarray      # (S,) °C at full DC load (Fig. 5: ~2)
    gpu_alpha: jnp.ndarray       # (S, 8) °C per unit chip util
    gpu_beta: jnp.ndarray        # (S, 8) static offset
    airflow_idle_cfm: float
    airflow_max_cfm: float
    gpu_limit: float

    # ------------------------------------------------------------------
    @staticmethod
    def calibrate(dc: Datacenter) -> "ThermalModel":
        cfg = dc.cfg
        rng = np.random.default_rng(cfg.seed + 1)
        s = dc.n_servers
        # spatial heterogeneity (Fig. 4): row up to 1 °C, rack up to 2 °C,
        # height within rack minor (0.3 °C); ends of some rows warmer
        row_off = rng.uniform(0.0, 1.0, dc.n_rows)[dc.row_of]
        rack_off = rng.uniform(0.0, 2.0, (dc.n_rows, cfg.racks_per_row))[
            dc.row_of, dc.rack_of]
        height_off = 0.3 * dc.height_of / max(cfg.servers_per_rack - 1, 1)
        inlet_base = 18.0 + row_off + rack_off + height_off
        inlet_slope = rng.uniform(0.75, 0.95, s)   # Fig. 3 regression band
        hot_slope = inlet_slope * rng.uniform(0.45, 0.6, s)
        load_coeff = rng.uniform(1.6, 2.4, s)      # Fig. 5: ~2 °C idle->full

        # per-chip (Eq. 2): even-indexed chips cooler; process variation
        # (Fig. 9: >20 °C spread across a DC, ~10 °C inside one server)
        g = cfg.hw.chips
        layout = np.where(np.arange(g) % 2 == 0, -3.0, 3.0)  # Fig. 9
        proc = rng.normal(0.0, 2.5, (s, g))
        # server-level component (heatsink/airflow lottery) is what makes
        # placement matter; chip-level variation adds the Fig. 9 spread
        server_off = rng.normal(0.0, 4.5, (s, 1))
        gpu_alpha = (35.0 + server_off + rng.normal(0.0, 3.0, (s, g))
                     + layout)  # °C @ util=1
        gpu_beta = 6.0 + proc
        return ThermalModel(
            inlet_base=jnp.asarray(inlet_base),
            inlet_slope=jnp.asarray(inlet_slope),
            inlet_hot_slope=jnp.asarray(hot_slope),
            load_coeff=jnp.asarray(load_coeff),
            gpu_alpha=jnp.asarray(gpu_alpha),
            gpu_beta=jnp.asarray(gpu_beta),
            airflow_idle_cfm=cfg.hw.airflow_idle_cfm,
            airflow_max_cfm=cfg.hw.airflow_max_cfm,
            gpu_limit=cfg.hw.gpu_temp_limit_c,
        )

    # ------------------------------------------------------------------
    def inlet_temp(self, t_outside, dc_load, *, cooling_derate: float = 0.0):
        """Eq. 1. t_outside: scalar °C; dc_load: scalar in [0,1].

        ``cooling_derate``: extra °C from a datacenter cooling-device
        failure (§2.1 Failures / §5.4)."""
        t = jnp.asarray(t_outside, jnp.float32)
        warm = jnp.clip(t - 15.0, 0.0, 10.0) * self.inlet_slope
        hot = jnp.clip(t - 25.0, 0.0, None) * self.inlet_hot_slope
        return (self.inlet_base + warm + hot
                + self.load_coeff * jnp.asarray(dc_load, jnp.float32)
                + cooling_derate)

    def gpu_temp(self, t_inlet, chip_util):
        """Eq. 2. t_inlet: (S,); chip_util: (S, 8) in [0,1] -> (S, 8) °C."""
        return t_inlet[:, None] + self.gpu_alpha * chip_util + self.gpu_beta

    def airflow(self, server_util):
        """Eq. 3 LHS. server_util: (S,) mean chip util -> CFM (S,)."""
        return (self.airflow_idle_cfm
                + (self.airflow_max_cfm - self.airflow_idle_cfm) * server_util)

    def max_util_for_temp(self, t_inlet, t_limit):
        """Invert Eq. 2: hottest-chip util cap to stay below t_limit."""
        worst = jnp.max(self.gpu_alpha, axis=1)
        worst_beta = jnp.max(self.gpu_beta, axis=1)
        return jnp.clip((t_limit - t_inlet - worst_beta) / worst, 0.0, 1.0)


def outside_temperature(region: str, t_hours, *, seed: int = 0):
    """Diurnal outside temperature trace (°C) for t in hours."""
    mean, amp = REGION_OUTSIDE[region]
    t = jnp.asarray(t_hours, jnp.float32)
    base = mean + amp * jnp.sin(2 * jnp.pi * (t - 9.0) / 24.0)
    wob = 1.5 * jnp.sin(2 * jnp.pi * t / (24.0 * 6.3) + seed)
    return base + wob
