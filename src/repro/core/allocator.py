"""VM Allocator — paper §4.1 / §4.5: Protean-style rule engine.

Rules, in order:
  1. validator  — filter servers whose aisle's predicted peak airflow or
     row's predicted peak power would violate Eq. 3 / Eq. 4 if the VM landed
     there (history-based peak prediction; peak assumed when history < 1 wk).
  2. preference — IaaS to cooler servers, SaaS to warmer servers (3 equal
     temperature groups: cold / medium / warm).
  3. preference — keep IaaS/SaaS balanced per aisle+row (3 groups:
     IaaS-heavy / SaaS-heavy / balanced).
Final pick: best rule score, seeded-random tie-break.

The *Baseline* allocator (thermal/power-oblivious Protean) picks uniformly
among empty servers.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.datacenter import Datacenter
from repro.core.power import PowerModel
from repro.core.thermal import ThermalModel
from repro.core.traces import VMSpec, predict_peak_util


@dataclass
class AllocatorState:
    """Mutable cluster occupancy view used for placement decisions."""
    dc: Datacenter
    thermal: ThermalModel
    power: PowerModel
    vm_of: np.ndarray        # (S,) vm_id or -1
    kind_of: np.ndarray      # (S,) 0 empty, 1 iaas, 2 saas
    peak_util: np.ndarray    # (S,) predicted per-VM peak util

    @staticmethod
    def empty(dc: Datacenter, thermal: ThermalModel, power: PowerModel):
        s = dc.n_servers
        return AllocatorState(dc, thermal, power,
                              vm_of=np.full(s, -1),
                              kind_of=np.zeros(s, np.int64),
                              peak_util=np.zeros(s))

    def place(self, server: int, vm: VMSpec, peak: float) -> None:
        self.vm_of[server] = vm.vm_id
        self.kind_of[server] = 1 if vm.kind == "iaas" else 2
        self.peak_util[server] = peak

    def release(self, server: int) -> None:
        self.vm_of[server] = -1
        self.kind_of[server] = 0
        self.peak_util[server] = 0.0


class TapasAllocator:
    def __init__(self, *, seed: int = 0, typical_outside: float = 30.0):
        self.rng = np.random.default_rng(seed + 4)
        self.typical_outside = typical_outside

    # -- rule 1: validator --------------------------------------------------
    def _validator(self, st: AllocatorState, peak: float) -> np.ndarray:
        dc, th, pm = st.dc, st.thermal, st.power
        util = st.peak_util  # predicted peaks of current residents
        air_now = np.asarray(th.airflow(util))
        air_now = np.where(st.kind_of > 0, air_now, 0.0)
        aisle_air = dc.aisle_sum(air_now)
        add_air = float(th.airflow(np.asarray([peak]))[0])
        air_ok = (aisle_air + add_air) <= dc.prov_ahu_cfm  # (A,)

        pwr_now = np.asarray(pm.server_power(
            np.repeat(util[:, None], dc.cfg.hw.chips, axis=1)))
        pwr_now = np.where(st.kind_of > 0, pwr_now, 0.15 * pwr_now)
        row_pwr = dc.row_sum(pwr_now)
        add_pwr = float(np.asarray(pm.server_power(
            np.full((1, dc.cfg.hw.chips), peak)))[0])
        pwr_ok = (row_pwr + add_pwr) <= dc.prov_row_power_w  # (R,)
        return air_ok[dc.aisle_of] & pwr_ok[dc.row_of]

    # -- rule 2: temperature preference --------------------------------------
    def _peak_temp(self, st: AllocatorState, util: float) -> np.ndarray:
        th = st.thermal
        inlet = np.asarray(th.inlet_temp(self.typical_outside, 0.7))
        u = np.full((st.dc.n_servers, st.dc.cfg.hw.chips), util)
        return np.asarray(th.gpu_temp(inlet, u)).max(axis=1)

    def _temp_groups(self, st: AllocatorState) -> np.ndarray:
        """0=cold 1=medium 2=warm thirds by predicted peak GPU temperature."""
        t_peak = self._peak_temp(st, 1.0)
        q1, q2 = np.quantile(t_peak, [1 / 3, 2 / 3])
        return np.digitize(t_peak, [q1, q2])

    # -- rule 3: IaaS/SaaS balance -------------------------------------------
    def _balance_score(self, st: AllocatorState, kind: str) -> np.ndarray:
        dc = st.dc
        iaas = dc.row_sum((st.kind_of == 1).astype(float))
        saas = dc.row_sum((st.kind_of == 2).astype(float))
        total = np.maximum(iaas + saas, 1.0)
        frac_iaas = iaas / total
        # want balanced rows; placing `kind` where it is under-represented
        target = frac_iaas[dc.row_of]
        return (1.0 - target) if kind == "iaas" else target

    def place(self, st: AllocatorState, vm: VMSpec, *, seed: int = 0) -> int | None:
        peak = predict_peak_util(vm, seed=seed)
        empty = st.kind_of == 0
        ok = empty & self._validator(st, peak)
        if not ok.any():
            ok = empty  # validator exhausted: fall back, capping will manage
            if not ok.any():
                return None
        groups = self._temp_groups(st)
        if vm.kind == "iaas":
            temp_score = {0: 1.0, 1: 0.5, 2: 0.0}
            t_sc = np.vectorize(temp_score.get)(groups)
        else:
            # SaaS to warm servers — but ONLY those whose predicted GPU temp
            # at the endpoint's predicted peak load stays under the limit
            # (paper §4.1); unsafe-at-peak servers rank below cold ones
            t_pred = self._peak_temp(st, 0.95 * peak)
            safe = t_pred <= st.thermal.gpu_limit - 1.0
            temp_score = {0: 0.0, 1: 0.5, 2: 1.0}
            t_sc = np.vectorize(temp_score.get)(groups)
            t_sc = np.where(safe, t_sc, -2.0)
        b_sc = self._balance_score(st, vm.kind)
        # spread predicted peak power across rows (the validator's headroom
        # as a preference, not just a filter — smooths the Fig. 10 tail)
        util = np.where(st.kind_of > 0, st.peak_util, 0.0)
        pwr = np.asarray(st.power.server_power(
            np.repeat(util[:, None], st.dc.cfg.hw.chips, axis=1)))
        pwr = np.where(st.kind_of > 0, pwr, 0.0)
        row_frac = (st.dc.row_sum(pwr)
                    / np.maximum(st.dc.prov_row_power_w, 1.0))
        p_sc = 1.0 - row_frac[st.dc.row_of]
        score = np.where(ok, 1.5 * t_sc + b_sc + 2.5 * p_sc, -np.inf)
        best = score.max()
        cand = np.flatnonzero(score >= best - 1e-9)
        server = int(self.rng.choice(cand))
        st.place(server, vm, peak)
        return server


class BaselineAllocator:
    """Thermal/power-oblivious placement (traditional Protean, §5.1).

    Protean packs arrivals tightly to preserve large free blocks — which is
    exactly what co-locates same-phase VMs into the same rows and produces
    the heavy-tailed row-power distribution of Fig. 10."""

    def __init__(self, *, seed: int = 0):
        self.rng = np.random.default_rng(seed + 5)

    def place(self, st: AllocatorState, vm: VMSpec, *, seed: int = 0) -> int | None:
        empty = np.flatnonzero(st.kind_of == 0)
        if empty.size == 0:
            return None
        # first-fit with a small window (allocation isn't perfectly serial)
        server = int(self.rng.choice(empty[:4]))
        st.place(server, vm, predict_peak_util(vm, seed=seed))
        return server


class PlacementPolicy:
    """``ControlPolicy.place`` adapter over a Baseline/Tapas allocator.

    Reads occupancy from ``state.alloc`` (which the wrapped allocator
    mutates on a successful placement) and the workload seed from
    ``state.seed``; everything else about the decision lives in the
    wrapped rule engine.
    """

    def __init__(self, allocator):
        self.allocator = allocator

    def place(self, state, vm: VMSpec) -> int | None:
        return self.allocator.place(state.alloc, vm, seed=state.seed)
