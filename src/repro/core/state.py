"""Typed control-plane protocol: ``ClusterState`` + ``ControlPolicy``.

The TAPAS contribution is a control plane — placement, routing, instance
reconfiguration — reacting to thermal/power telemetry every tick.  This
module defines the API between the datacenter simulation (physics, traces,
events) and that control plane:

* ``ClusterState`` is the per-tick telemetry snapshot handed to policies:
  per-server occupancy / utilization / frequency caps / violation risk /
  instance configs, per-row and per-aisle provisioned envelopes after
  failure derates, and the endpoint → server map.
* ``ControlPolicy`` is the protocol a policy object implements.  The three
  decision hooks mirror the paper's three subsystems —
  ``place(state, vm)`` (§4.1 allocator), ``route(state, endpoint, demand)``
  (§4.2 load balancer) and ``reconfigure(state)`` (§4.3 instance
  configurator) — plus two lifecycle hooks (``begin_tick``, ``release``)
  for per-tick bookkeeping and VM departures.

``ClusterSim`` drives any ``ControlPolicy`` tick-by-tick; the bundled
Baseline/TAPAS implementations are adapters over the pre-existing
allocator/router/configurator classes (see ``core.simulator``), and custom
policies plug in through ``SimConfig(control=...)``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core import profiles as P
from repro.core.allocator import AllocatorState
from repro.core.datacenter import Datacenter
from repro.core.traces import VMSpec


@dataclass
class InstanceView:
    """A SaaS server's current instance configuration, as telemetry."""
    entry: P.ProfileEntry      # profile row of the active ConfigPoint
    paused: bool               # draining through a reload (§4.3)


@dataclass
class EndpointRoute:
    """One endpoint's routing decision for a tick."""
    servers: np.ndarray        # (n,) server ids, endpoint order
    load: np.ndarray           # (n,) assigned load, nominal-VM units
    quality: np.ndarray        # (n,) quality of each server's config
    unserved: float            # demand that found no headroom (queued)


@dataclass
class ConfigChange:
    """A reconfiguration decision applied to one SaaS server this tick."""
    server: int
    entry: P.ProfileEntry      # the newly active profile row
    reloading: bool            # True when the move costs a reload pause


@dataclass
class ClusterState:
    """Per-tick cluster telemetry snapshot (the policies' world view).

    Filled in phases as the tick progresses: occupancy and scenario state
    exist before arrivals are placed; utilization/risk/instance telemetry
    before routing; ``saas_load`` after routing; post-physics measurements
    (``max_gpu_temp_c``, ``row_power_frac``, throttle masks) after
    ``apply``.  Arrays are live views, not copies — policies must treat
    them as read-only.
    """
    # -- clock / identity --------------------------------------------------
    tick: int
    now_h: float
    t_outside_c: float
    seed: int
    dc: Datacenter
    nominal: P.ProfileEntry            # the nominal instance profile row

    # -- occupancy ---------------------------------------------------------
    alloc: AllocatorState              # mutable occupancy view (placement)
    kind: np.ndarray                   # (S,) 0 empty / 1 iaas / 2 saas
    vm_of: np.ndarray                  # (S,) resident vm_id or -1
    endpoints: dict                    # endpoint -> [server ids]

    # -- scenario / failure state -----------------------------------------
    emergency: bool
    ahu_derate: np.ndarray             # (A,) airflow derate factors
    ups_derate: np.ndarray             # (R,) power derate factors
    cooling_extra_c: float             # inlet offset from cooling failures
    prov_row_power_w: np.ndarray       # (R,) envelope after derates
    prov_aisle_cfm: np.ndarray         # (A,) envelope after derates

    # -- fleet identity ----------------------------------------------------
    region: str = ""                   # region name inside a FleetSim ("" ==
    #                                    standalone single-cluster run)

    # -- telemetry (filled by observe) ------------------------------------
    iaas_util: np.ndarray = None       # (S,) IaaS trace utilization
    freq_cap: np.ndarray = None        # (S,) persistent power-cap state
    last_util: np.ndarray = None       # (S,) previous-tick mean chip util
    inlet_est: np.ndarray = None       # (S,) Eq. 1 inlet estimate
    risk: np.ndarray = None            # (S,) Eq. 1-4 violation risk
    u_max: np.ndarray = None           # (S,) Eq. 2 thermal load ceiling
    telemetry_age_ticks: int = 0       # ticks since inlet_est/risk/u_max
    #                                    were live (> 0 under SensorDropout:
    #                                    the values are a frozen last-known-
    #                                    good snapshot, risk staleness-bumped)
    instances: dict = field(default_factory=dict)  # server -> InstanceView

    # -- routing outcome (filled during the route phase) ------------------
    saas_load: np.ndarray = None       # (S,) routed load, nominal-VM units
    quality: np.ndarray = None         # (S,) served quality per server

    # -- engine-in-the-loop telemetry -------------------------------------
    measured_goodput: dict = field(default_factory=dict)  # server -> tok/s

    # -- post-physics measurements (filled by apply) ----------------------
    max_gpu_temp_c: float = 0.0
    row_power_frac: np.ndarray = None  # (R,) row power / provisioned
    thermal_throttled: np.ndarray = None  # (S,) bool, in-tick hardware clamp
    power_over_rows: np.ndarray = None    # (R,) bool, over the envelope

    @property
    def occupied(self) -> np.ndarray:
        return self.kind > 0


@runtime_checkable
class ControlPolicy(Protocol):
    """The control-plane contract ``ClusterSim`` drives every tick.

    Hooks run in tick order: ``place``/``release`` during the
    arrival/departure phase, ``begin_tick`` before telemetry is observed,
    ``route`` once per endpoint, ``reconfigure`` once after routing.
    Stateful policies (affinity memory, configurator state, RNG) should be
    freshly constructed per run.
    """

    def begin_tick(self, state: ClusterState) -> None:
        """Per-tick bookkeeping before telemetry observation: advance
        reload countdowns and publish ``state.instances`` views."""
        ...

    def place(self, state: ClusterState, vm: VMSpec) -> int | None:
        """Pick a server for an arriving VM (and record it in
        ``state.alloc``), or return None to reject the arrival."""
        ...

    def route(self, state: ClusterState, endpoint: str,
              demand: float) -> EndpointRoute:
        """Distribute ``demand`` across ``state.endpoints[endpoint]``."""
        ...

    def reconfigure(self, state: ClusterState) -> list:
        """Adjust SaaS instance configurations for the observed risk and
        return the ``ConfigChange`` list applied this tick.  The simulator
        folds the returned changes into ``state.instances`` (so they reach
        the physics and any bound engine backends); policies do not need
        to mutate ``state.instances`` themselves."""
        ...

    def release(self, state: ClusterState, server: int) -> None:
        """A VM departed ``server``; drop any per-instance state."""
        ...
