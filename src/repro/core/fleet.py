"""Fleet control plane: a global router over per-region ``ClusterSim``s.

TAPAS manages thermal/power headroom *inside* one cluster; clouds operate
fleets of regions whose cooling headroom diverges with the weather and
whose failures are regional.  This module grows the PR 2 control-plane API
one level up:

* ``RegionSpec`` — one region's identity: datacenter topology/climate
  (``DCConfig``), WAN RTT to the fleet's front door, power price, a
  scripted ``WeatherShift`` schedule, and the trace-seed namespace that
  keeps two identically-configured regions from replaying identical
  weather/customer noise.
* ``FleetState`` — the per-tick fleet snapshot: every region's typed
  ``ClusterState`` plus the lifted per-region telemetry a global policy
  reasons about (``region_risk`` scores, SaaS capacity/headroom, natural
  per-endpoint demand, inter-region RTTs).
* ``FleetPolicy`` — the protocol a global controller implements:
  ``admit_region`` (place a new VM across regions), ``route_region``
  (steer SaaS demand cross-region, paying a WAN-latency goodput penalty),
  and ``rebalance`` (drain/migrate VMs when a region loses cooling or
  power).
* ``FleetSim`` — owns N step-wise ``ClusterSim`` instances and drives
  them through the PR 2 ``observe``/``route``/``finish_tick`` seam.  The
  physics is never forked: each region runs the exact single-cluster code
  path, the fleet only substitutes the demand figures ``route`` would
  have computed locally.  A single-region fleet under the identity policy
  is bit-identical to a standalone ``ClusterSim`` run.
* ``GlobalTapasRouter`` — the reference policy: risk-weighted steering
  via ``core.risk.server_risk`` lifted to region granularity
  (``region_risk``), emergency drains, price/RTT-aware admission.
  ``LatencyOnlyRouter`` is the per-region-greedy baseline (serve
  everything at home, admit to the lowest-RTT region).

Cross-region steering pays for the WAN: demand served ``rtt`` ms away
from home is inflated by ``1 + wan_penalty_per_ms * rtt`` — the remote
region must spend extra capacity to deliver the same within-SLO goodput
(TTFT grows by the round trip, streaming tokens buffer deeper).  Keeping
load home is therefore free, and a global policy must beat that default
on throttling to justify every megabyte it moves.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field, replace
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.datacenter import DCConfig
from repro.core.risk import (energy_cost_index, region_risk,
                             thermally_comparable)
from repro.core.scenario import Scenario, VMArrival, WeatherShift
from repro.core.simulator import TAPAS, ClusterSim, Policy, SimConfig
from repro.core.traces import carbon_intensity


@dataclass(frozen=True)
class RegionSpec:
    """One region of the fleet.

    ``trace_namespace`` seeds the region's weather/customer/endpoint noise
    (see ``traces.trace_seed``); ``None`` derives it from ``name`` so
    distinct regions never replay identical traces, while an explicit
    ``""`` opts into the shared global traces (exact single-cluster
    parity).
    """
    name: str
    dc: DCConfig = field(default_factory=DCConfig)
    wan_rtt_ms: float = 20.0      # RTT to the fleet's user front door
    power_price_scale: float = 1.0  # relative $/kWh multiplier (admission preference)
    carbon_scale: float = 1.0     # grid dirtiness vs the fleet-mean grid
    weather: tuple = ()           # WeatherShift schedule for this region
    trace_namespace: str | None = None
    # custom region control plane, forwarded to SimConfig.control: a
    # ControlPolicy instance or a zero-arg factory.  Prefer a factory —
    # an instance shared across regions (or runs) carries its state with
    # it.  None -> built from the fleet-wide ``policy`` flags.
    control: object | None = None
    # forwarded to SimConfig.iaas_only_capping (None derives from the
    # fleet ``policy`` flags; set when driving a custom ``control``)
    iaas_only_capping: bool | None = None
    # forwarded to SimConfig.resilience (a core.faults.ResilienceKnobs;
    # None -> the region runs with full recovery defaults)
    resilience: object | None = None

    def __post_init__(self):
        if not isinstance(self.name, str) or not self.name:
            raise ValueError(f"region name must be a non-empty string, "
                             f"got {self.name!r}")
        if self.wan_rtt_ms < 0.0:
            raise ValueError(f"wan_rtt_ms must be >= 0, got {self.wan_rtt_ms}")
        if self.power_price_scale <= 0.0:
            raise ValueError(
                f"power_price_scale must be > 0, got {self.power_price_scale}")
        if self.carbon_scale <= 0.0:
            raise ValueError(
                f"carbon_scale must be > 0, got {self.carbon_scale}")
        object.__setattr__(self, "weather", tuple(self.weather))
        for ev in self.weather:
            if not isinstance(ev, WeatherShift):
                raise TypeError(
                    f"RegionSpec.weather takes WeatherShift events, "
                    f"got {ev!r}")
            if ev.region not in (None, self.name):
                raise ValueError(
                    f"weather event for region {ev.region!r} attached to "
                    f"region {self.name!r}")


@dataclass(frozen=True)
class Migration:
    """One ``rebalance`` decision: move the VM on ``server`` of ``src``
    to region ``dst`` (evicted now, re-admitted there next tick)."""
    src: str
    server: int
    dst: str

    def __post_init__(self):
        if self.src == self.dst:
            raise ValueError(f"migration from {self.src!r} to itself")
        if self.server < 0:
            raise ValueError(f"server must be >= 0, got {self.server}")


@dataclass
class FleetState:
    """Per-tick fleet snapshot handed to ``FleetPolicy`` hooks.

    ``regions`` carries each region's full ``ClusterState`` (the same
    live-view caveats apply: treat arrays as read-only); the remaining
    fields are the lifted region-granularity telemetry global policies
    actually route on.
    """
    tick: int
    now_h: float
    regions: dict                  # name -> ClusterState
    specs: dict                    # name -> RegionSpec
    rtt_ms: dict                   # (a, b) -> one-way-pair RTT in ms
    risk: dict                     # name -> region_risk score in [0, 1]
    emergency: dict                # name -> any active failure event
    capacity: dict                 # name -> SaaS capacity, nominal-VM units
    headroom: dict                 # name -> capacity - natural demand
    demand: dict                   # endpoint -> {name: natural demand}
    price: dict = field(default_factory=dict)   # name -> effective $/kWh
    #                                             (shock-scaled power_price_scale)
    telemetry_age: dict = field(default_factory=dict)  # name -> ticks the
    #                                             region's telemetry has been
    #                                             stale (SensorDropout)
    carbon: dict = field(default_factory=dict)  # name -> grid carbon
    #                                             intensity right now
    wan_penalty_per_ms: float = 0.0             # the fleet's WAN tax rate

    def free_servers(self, name: str) -> int:
        return int((self.regions[name].kind == 0).sum())

    def cost_index(self, name: str, *, carbon_weight: float = 0.5) -> float:
        """Blended price/carbon cost of a kWh served in ``name`` now."""
        return energy_cost_index(self.price.get(name, 1.0),
                                 self.carbon.get(name, 1.0),
                                 carbon_weight=carbon_weight)


@runtime_checkable
class FleetPolicy(Protocol):
    """The global-control contract ``FleetSim`` drives every tick.

    Hooks run in tick order: ``admit_region`` for each due fleet-level VM
    arrival, ``rebalance`` once, then ``route_region`` once per endpoint.
    All three see the same ``FleetState`` observed at the top of the tick.
    """

    def admit_region(self, fleet: FleetState, vm: VMArrival) -> str | None:
        """Pick the region a fleet-level VM arrival lands in (placement
        *within* the region stays with that region's ControlPolicy), or
        None to reject the arrival."""
        ...

    def route_region(self, fleet: FleetState, endpoint: str,
                     demands: dict) -> dict:
        """Steer ``endpoint``'s demand across regions.

        ``demands`` maps each region that hosts the endpoint to its
        natural (home) demand this tick.  Return ``{origin: {dest:
        fraction}}``; fractions per origin should sum to 1 (a shortfall
        is assigned back home), and every dest must host the endpoint.
        Demand moved off its origin is inflated by the WAN goodput
        penalty before it lands."""
        ...

    def rebalance(self, fleet: FleetState) -> list:
        """Return ``Migration``s draining load out of failing regions.
        Evictions happen immediately; the VM re-arrives in ``dst`` next
        tick (the WAN transfer is not free)."""
        ...


# ---------------------------------------------------------------------------
# reference policies
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FleetKnobs:
    """Named parameters of the ``GlobalTapasRouter`` reference policy."""

    #: region risk at which cross-region steering engages (mirrors the
    #: §4.3 hot threshold — the fleet reacts when the cluster loop does).
    risk_threshold: float = 0.45
    #: a destination must be at least this much cooler than the origin.
    margin: float = 0.08
    #: ceiling on the fraction of an origin's demand moved per tick.
    shift_max: float = 0.7
    #: links with a higher RTT than this are never worth the goodput
    #: penalty for thermal relief.
    rtt_budget_ms: float = 250.0
    #: emergency + this region risk starts VM migration (not just
    #: request steering).
    drain_risk: float = 0.55
    #: VMs migrated out of a draining region per tick.
    drain_per_tick: int = 2
    #: quantile ``region_risk`` lifts per-server risk with.
    risk_quantile: float = 0.8
    #: steer-fraction decay per tick once the pressure target drops.
    #: Risk is measured *after* steering relieved the region, so acting on
    #: the instantaneous score bang-bangs: steer, look cool, snap the load
    #: back, throttle, repeat.  Holding the steered fraction and releasing
    #: it slowly turns the oscillation into a ramp.
    release: float = 0.75
    #: ceiling on the fraction of a *cool* origin's demand moved purely for
    #: cost (price/carbon).  0.0 disables cost-aware steering — the
    #: default, which preserves the recorded ``BENCH_fleet`` trajectory;
    #: see ``cost_aware_knobs()`` for the enabled preset.
    cost_shift_max: float = 0.0
    #: a cost destination may be at most this much riskier than the origin
    #: (and always below ``risk_threshold``): the thermal tolerance band
    #: inside which regions count as equivalent and $/carbon may decide.
    cost_risk_band: float = 0.15
    #: minimum fractional cost advantage — net of the WAN goodput tax — a
    #: destination must offer before cost-chasing engages.  Paired with
    #: the reused ``release`` hysteresis, this keeps a marginally-cheap
    #: region from flapping demand back and forth across the WAN.
    cost_margin: float = 0.08
    #: weight of grid carbon intensity vs bare power price in the blended
    #: cost index (see ``risk.energy_cost_index``).
    carbon_weight: float = 0.5
    #: a region whose telemetry has been stale (SensorDropout) for more
    #: than this many ticks is not trusted as a steering/drain destination
    #: — its frozen risk score may be hiding a heating region.
    stale_dest_ticks: int = 2


def cost_aware_knobs(**overrides) -> FleetKnobs:
    """The carbon/price-aware preset: thermal steering as recorded, plus
    cost-chasing of up to 35% of a cool origin's demand."""
    kw = dict(cost_shift_max=0.35)
    kw.update(overrides)
    return FleetKnobs(**kw)


class GlobalTapasRouter:
    """Risk-weighted global routing: ``server_risk`` lifted to regions.

    Admission prefers cold, cheap, close regions (deterministic
    ``(risk, price, rtt, name)`` order); steering moves demand from
    regions past the risk threshold toward cooler regions with headroom,
    deeper the hotter the origin runs, with per-origin hysteresis (see
    ``FleetKnobs.release``) so relief does not immediately argue for
    undoing itself; an emergency plus deep risk drains whole VMs.  Every
    candidate ordering ends in the region name or server id, so decisions
    are stable across Python versions and insertion orders.

    With ``FleetKnobs.cost_shift_max > 0`` (see ``cost_aware_knobs()``),
    thermally-cool origins additionally chase cheap/clean energy: demand
    moves toward regions whose blended price/carbon index — inflated by
    the WAN goodput tax — undercuts home by ``cost_margin``, but only
    inside the ``cost_risk_band`` thermal tolerance band, and the moved
    fraction reuses the same hysteresis so price flapping cannot
    oscillate load across the WAN.  The default knobs leave cost-chasing
    off, preserving the recorded thermal-drill trajectories.

    The steer-fraction memory makes the policy stateful — pass the class
    (or a factory) to ``FleetConfig(fleet=...)`` when rerunning one
    ``FleetSim``, exactly like stateful ``SimConfig.control`` policies.
    """

    def __init__(self, knobs: FleetKnobs | None = None):
        self.knobs = knobs or FleetKnobs()
        self._steer: dict = {}   # (endpoint, origin) -> held moved fraction
        self._cost: dict = {}    # (endpoint, origin) -> held cost-move frac

    def admit_region(self, fleet: FleetState, vm: VMArrival) -> str | None:
        cands = [(fleet.risk[n], fleet.specs[n].power_price_scale,
                  fleet.specs[n].wan_rtt_ms, n)
                 for n in sorted(fleet.regions) if fleet.free_servers(n) > 0]
        return min(cands)[3] if cands else None

    def route_region(self, fleet: FleetState, endpoint: str,
                     demands: dict) -> dict:
        k = self.knobs
        shares: dict = {}
        for h in sorted(demands):
            shares[h] = {h: 1.0}
            key = (endpoint, h)
            r_h = fleet.risk[h]
            depth = min(1.0, max(r_h - k.risk_threshold, 0.0)
                        / max(1.0 - k.risk_threshold, 1e-9))
            if fleet.emergency[h]:
                depth = max(depth, 0.8)
            # hysteresis: rise to the target immediately, release slowly
            move = max(k.shift_max * depth,
                       self._steer.get(key, 0.0) * k.release)
            if move < 1e-3:
                self._steer.pop(key, None)
                # a thermally-cool origin is free to chase cheap energy
                self._cost_route(fleet, endpoint, h, demands, shares)
                continue
            dests = []
            for q in sorted(demands):
                if q == h or fleet.rtt_ms[(h, q)] > k.rtt_budget_ms:
                    continue
                # stale telemetry: the frozen risk score may hide a
                # heating region — never steer *toward* blind spots
                if fleet.telemetry_age.get(q, 0) > k.stale_dest_ticks:
                    continue
                # absolute dest gate: a flapping relative-to-origin gate
                # would re-couple the two regions' oscillations
                if fleet.risk[q] >= min(k.risk_threshold,
                                        r_h - k.margin) \
                        or fleet.emergency[q]:
                    continue
                w = max(fleet.headroom[q], 0.0) \
                    * (max(r_h, k.risk_threshold) - fleet.risk[q])
                if w > 0.0:
                    dests.append((q, w))
            if not dests:
                self._steer[key] = move * k.release
                continue
            self._steer[key] = move
            tot = sum(w for _, w in dests)
            shares[h][h] = 1.0 - move
            for q, w in dests:
                shares[h][q] = move * w / tot
        return shares

    def _cost_route(self, fleet: FleetState, endpoint: str, h: str,
                    demands: dict, shares: dict) -> None:
        """Carbon/price-aware steering for a thermally-cool origin ``h``.

        Only engages inside the thermal tolerance band (the destination
        must be no more than ``cost_risk_band`` riskier than the origin
        and below the steering threshold), and only when the destination's
        blended price/carbon index — inflated by the WAN goodput tax for
        the extra capacity remote serving burns — undercuts the origin's
        by at least ``cost_margin``.  The moved fraction reuses the
        thermal hysteresis: it rises to the target immediately, and once
        the advantage shrinks into the ``+-cost_margin`` dead band the
        held share keeps landing on the break-even destinations while
        decaying by ``release`` per tick — so two regions pricing within
        noise of each other ramp demand back gradually instead of
        flipping it across the WAN every tick.  A hard reversal (the dest
        now costlier than home by more than the margin, or thermally
        excluded) sends demand home immediately.
        """
        k = self.knobs
        key = (endpoint, h)
        if k.cost_shift_max <= 0.0:
            return
        r_h = fleet.risk[h]
        c_h = fleet.cost_index(h, carbon_weight=k.carbon_weight)
        # two tiers around the break-even point: a dest must undercut home
        # by cost_margin to *engage* new steering, but a previously-engaged
        # share keeps landing (decaying) on any dest inside the +-margin
        # dead band — advantage hovering around the margin therefore ramps
        # instead of flipping up to cost_shift_max of the demand per tick
        engage, hold = [], []
        for q in sorted(demands):
            if q == h or fleet.rtt_ms[(h, q)] > k.rtt_budget_ms:
                continue
            if fleet.telemetry_age.get(q, 0) > k.stale_dest_ticks:
                continue   # blind spot: cheap-looking but unverifiable
            if fleet.emergency[q] or fleet.headroom[q] <= 0.0 \
                    or not thermally_comparable(
                        r_h, fleet.risk[q], band=k.cost_risk_band,
                        threshold=k.risk_threshold):
                continue
            wan = 1.0 + fleet.wan_penalty_per_ms * fleet.rtt_ms[(h, q)]
            gain = 1.0 - (fleet.cost_index(q, carbon_weight=k.carbon_weight)
                          * wan) / max(c_h, 1e-9)
            if gain >= k.cost_margin:
                engage.append((q, fleet.headroom[q] * gain))
            elif gain > -k.cost_margin:
                hold.append((q, fleet.headroom[q]
                             * max(gain + k.cost_margin, 1e-9)))
        held = self._cost.get(key, 0.0)
        if engage:
            dests, move = engage, k.cost_shift_max
        elif hold and held >= 1e-3:
            dests, move = hold, held * k.release
        else:
            # dests reversed hard (or thermally excluded): the held share
            # decays with nowhere to land — demand returns home at once
            held *= k.release
            if held < 1e-3:
                self._cost.pop(key, None)
            else:
                self._cost[key] = held
            return
        # goodput guard: never move more than the destinations' actual
        # headroom can absorb (with margin for the WAN tax)
        avail = 0.9 * sum(max(fleet.headroom[q], 0.0) for q, _ in dests)
        move = min(move, avail / max(demands[h], 1e-9))
        if move < 1e-3:
            self._cost.pop(key, None)
            return
        self._cost[key] = move
        tot = sum(w for _, w in dests)
        shares[h][h] = 1.0 - move
        for q, w in dests:
            shares[h][q] = shares[h].get(q, 0.0) + move * w / tot

    def rebalance(self, fleet: FleetState) -> list:
        k = self.knobs
        migs: list = []
        placed: dict = {}
        for h in sorted(fleet.regions):
            if not (fleet.emergency[h] and fleet.risk[h] >= k.drain_risk):
                continue
            st = fleet.regions[h]
            dests = sorted(
                (fleet.risk[q], fleet.rtt_ms[(h, q)], q)
                for q in sorted(fleet.regions)
                if q != h and not fleet.emergency[q]
                and fleet.risk[q] < k.risk_threshold
                and fleet.telemetry_age.get(q, 0) <= k.stale_dest_ticks)
            # hottest SaaS servers drain first; ties break on server id
            order = sorted((int(s) for s in np.flatnonzero(st.kind == 2)),
                           key=lambda s: (-float(st.risk[s]), s))
            for s in order[: k.drain_per_tick]:
                dest = next((q for _, _, q in dests
                             if fleet.free_servers(q) - placed.get(q, 0) > 0),
                            None)
                if dest is None:
                    break
                placed[dest] = placed.get(dest, 0) + 1
                migs.append(Migration(src=h, server=s, dst=dest))
        return migs


class LatencyOnlyRouter:
    """The per-region-greedy baseline: every region serves its own demand
    (zero WAN latency paid, zero thermal awareness), and fleet arrivals
    land in the lowest-RTT region with a free server."""

    def admit_region(self, fleet: FleetState, vm: VMArrival) -> str | None:
        cands = [(fleet.specs[n].wan_rtt_ms, n)
                 for n in sorted(fleet.regions) if fleet.free_servers(n) > 0]
        return min(cands)[1] if cands else None

    def route_region(self, fleet: FleetState, endpoint: str,
                     demands: dict) -> dict:
        return {h: {h: 1.0} for h in demands}

    def rebalance(self, fleet: FleetState) -> list:
        return []


# ---------------------------------------------------------------------------
# fleet simulator
# ---------------------------------------------------------------------------

@dataclass
class FleetConfig:
    regions: tuple = ()
    horizon_h: float = 24.0
    tick_min: float = 5.0
    saas_fraction: float = 0.5
    seed: int = 0
    policy: Policy = TAPAS         # each region's control-plane flags
    # global controller: a FleetPolicy instance (good for one run) or a
    # zero-arg factory rebuilt every reset(); None -> GlobalTapasRouter.
    fleet: object | None = None
    scenario: Scenario | None = None
    occupancy: float = 0.88
    demand_scale: float = 0.85
    #: demand served one ms of RTT away from home needs this extra
    #: fraction of capacity to hold the same within-SLO goodput.
    wan_penalty_per_ms: float = 0.002
    #: explicit inter-region RTT overrides {(a, b): ms}; the default is
    #: the star topology through the front door (rtt_a + rtt_b).
    rtt_ms: dict | None = None


@dataclass
class FleetResult:
    regions: dict                  # name -> SimResult
    moved_load: float              # cross-region load (nominal-VM-ticks)
    wan_overhead: float            # extra demand paid to the WAN penalty
    migrations: int
    migrations_failed: int         # dest filled up; tenant sent back home
    fleet_admissions: int
    unserved_frac: float           # fleet-wide, demand-weighted
    mean_quality: float
    energy_kwh: float = 0.0        # fleet IT energy drawn over the run
    energy_cost_kwh: float = 0.0   # price-weighted kWh (power_price_scale is unitless)
    carbon_kg: float = 0.0         # sum of kWh x grid carbon intensity

    def blended_cost(self, carbon_weight: float = 0.5) -> float:
        """The objective cost-aware steering minimizes: served energy
        weighted by the blended price/carbon index (see
        ``risk.energy_cost_index``), integrated over the run."""
        return ((1.0 - carbon_weight) * self.energy_cost_kwh
                + carbon_weight * self.carbon_kg)

    def summary(self) -> dict:
        th = sum(r.thermal_events for r in self.regions.values())
        pw = sum(r.power_events for r in self.regions.values())
        return {
            "thermal_events": th,
            "power_events": pw,
            "throttle_events": th + pw,
            "max_temp_c": max(float(r.max_gpu_temp_c.max())
                              for r in self.regions.values()),
            "unserved_frac": self.unserved_frac,
            "mean_quality": self.mean_quality,
            "moved_load": self.moved_load,
            "wan_overhead": self.wan_overhead,
            "migrations": self.migrations,
            "migrations_failed": self.migrations_failed,
            "fleet_admissions": self.fleet_admissions,
            "energy_kwh": self.energy_kwh,
            "energy_cost": self.energy_cost_kwh,
            "carbon_kg": self.carbon_kg,
            "regions": {n: r.summary() for n, r in self.regions.items()},
        }


class FleetSim:
    """N per-region ``ClusterSim``s under one ``FleetPolicy``.

    Each tick: observe every region, lift the telemetry into a
    ``FleetState``, run the policy's admission/rebalance/steering hooks,
    then let every region finish its tick through the unmodified
    single-cluster code path (reconfigure, backend sync, physics).  The
    per-region physics and control planes are exactly ``ClusterSim``'s —
    the fleet only chooses *where* demand and VMs land.
    """

    def __init__(self, cfg: FleetConfig):
        if not cfg.regions:
            raise ValueError("a fleet needs at least one region")
        names = [spec.name for spec in cfg.regions]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate region names: {names}")
        self.cfg = cfg
        self.specs = {spec.name: spec for spec in cfg.regions}
        scenario = cfg.scenario or Scenario()
        unknown = scenario.regions_named() - set(names)
        if unknown:
            raise ValueError(
                f"scenario events target unknown regions {sorted(unknown)}; "
                f"fleet regions are {names}")
        self.rtt_ms = self._build_rtt(cfg, names)
        self.sims: dict[str, ClusterSim] = {}
        for spec in cfg.regions:
            regional = scenario.for_region(spec.name) + Scenario(
                tuple(replace(w, region=None) for w in spec.weather))
            ns = spec.name if spec.trace_namespace is None \
                else spec.trace_namespace
            # total construction: every SimConfig field is carried
            # explicitly — an omitted field silently reverts to its
            # default (tapaslint TL004, the scale_datacenter bug class)
            self.sims[spec.name] = ClusterSim(SimConfig(
                dc=spec.dc, horizon_h=cfg.horizon_h, tick_min=cfg.tick_min,
                saas_fraction=cfg.saas_fraction, seed=cfg.seed,
                policy=cfg.policy, scenario=regional,
                failures=(),               # legacy channel; region-scoped
                #                            failures ride the scenario
                occupancy=cfg.occupancy, demand_scale=cfg.demand_scale,
                control=spec.control,
                iaas_only_capping=spec.iaas_only_capping,
                resilience=spec.resilience,
                region_name=spec.name, trace_namespace=ns))
        first = next(iter(self.sims.values()))
        self.ticks = first.ticks
        self.t_h = first.t_h
        self._fleet_vms = scenario.fleet_arrivals()
        self._scenario = scenario      # fleet-level events (price shocks)
        # per-region grid carbon-intensity traces, namespaced exactly like
        # the weather/customer noise so identical configs still diverge
        self._carbon = {}
        for spec in cfg.regions:
            ns = spec.name if spec.trace_namespace is None \
                else spec.trace_namespace
            self._carbon[spec.name] = (
                spec.carbon_scale
                * carbon_intensity(self.t_h, seed=cfg.seed, namespace=ns))
        self.reset()

    @staticmethod
    def _build_rtt(cfg: FleetConfig, names: list) -> dict:
        specs = {s.name: s for s in cfg.regions}
        rtt = {}
        for a in names:
            for b in names:
                rtt[(a, b)] = 0.0 if a == b else (specs[a].wan_rtt_ms
                                                  + specs[b].wan_rtt_ms)
        for key, ms in (cfg.rtt_ms or {}).items():
            a, b = key
            if a not in specs or b not in specs:
                raise ValueError(f"rtt_ms override {key} names an unknown "
                                 f"region; fleet regions are {names}")
            if ms < 0.0:
                raise ValueError(f"rtt_ms override {key} must be >= 0")
            rtt[(a, b)] = rtt[(b, a)] = float(ms)
        return rtt

    # ------------------------------------------------------------------
    def reset(self) -> None:
        cfg = self.cfg
        for sim in self.sims.values():
            if sim.tick:
                sim.reset()
        if cfg.fleet is None:
            self.policy = GlobalTapasRouter()
        elif isinstance(cfg.fleet, type) or (
                callable(cfg.fleet)
                and not isinstance(cfg.fleet, FleetPolicy)):
            self.policy = cfg.fleet()
        else:
            self.policy = cfg.fleet
        self.tick = 0
        self._evseq = itertools.count()
        self._pending_fleet = [(ev.arrival_h, next(self._evseq), ev)
                               for ev in self._fleet_vms]
        heapq.heapify(self._pending_fleet)
        self._moved = 0.0
        self._wan_extra = 0.0
        self._migrations = 0
        self._mig_failed = 0
        self._admissions = 0
        self._energy_kwh = 0.0
        self._energy_cost_kwh = 0.0
        self._carbon_kg = 0.0
        self._prev_energy = dict.fromkeys(self.sims, 0.0)
        # migrations whose dest placement has not been confirmed yet:
        # (dst, src, injected VMSpec), resolved after the next observe
        self._inflight: list = []
        self.last_state: FleetState | None = None

    def attach_backend(self, region: str, server: int, backend) -> None:
        """Bind a real serving engine to a SaaS server of one region
        (see ``ClusterSim.attach_backend`` / ``serving.backend``)."""
        self._check_region(region)
        self.sims[region].attach_backend(server, backend)

    def _check_region(self, name) -> None:
        if name not in self.sims:
            raise ValueError(f"unknown region {name!r}; fleet regions are "
                             f"{sorted(self.sims)}")

    # ------------------------------------------------------------------
    def _fleet_state(self, states: dict) -> FleetState:
        k = getattr(self.policy, "knobs", None)
        quantile = getattr(k, "risk_quantile", 0.8)
        risk, emergency, capacity = {}, {}, {}
        for name, st in states.items():
            risk[name] = region_risk(st.risk, st.kind, quantile=quantile)
            emergency[name] = bool(st.emergency)
            cap = 0.0
            for srv, inst in st.instances.items():
                if st.kind[srv] == 2 and not inst.paused:
                    cap += ((inst.entry.goodput / st.nominal.goodput)
                            * float(st.freq_cap[srv]))
            capacity[name] = cap
        demand: dict = {}
        natural = dict.fromkeys(states, 0.0)
        for name, sim in self.sims.items():
            st = states[name]
            for ep, servers in st.endpoints.items():
                if not servers:
                    continue
                d = sim.endpoint_demand(ep, st.now_h)
                demand.setdefault(ep, {})[name] = d
                natural[name] += float(d)
        headroom = {n: capacity[n] - natural[n] for n in states}
        now = float(self.t_h[self.tick])
        price = {n: self.specs[n].power_price_scale
                 * self._scenario.price_scale(now, n) for n in states}
        carbon = {n: float(self._carbon[n][self.tick]) for n in states}
        return FleetState(
            tick=self.tick, now_h=now,
            regions=states, specs=self.specs, rtt_ms=self.rtt_ms,
            risk=risk, emergency=emergency, capacity=capacity,
            headroom=headroom, demand=demand, price=price, carbon=carbon,
            telemetry_age={n: int(st.telemetry_age_ticks)
                           for n, st in states.items()},
            wan_penalty_per_ms=self.cfg.wan_penalty_per_ms)

    def _apply_shares(self, ep: str, demands: dict, shares: dict,
                      overrides: dict) -> None:
        pen = self.cfg.wan_penalty_per_ms
        # every hosting region gets an explicit figure — an origin whose
        # demand was steered away entirely must land at 0.0, not fall back
        # to its natural demand (which would double-serve the moved load)
        for q in demands:
            overrides[q].setdefault(ep, 0.0)
        for h, d in demands.items():
            row = dict(shares.get(h) or {h: 1.0})
            for q, w in row.items():
                if q not in demands:
                    raise ValueError(
                        f"route_region sent {ep!r} load to region {q!r}, "
                        f"which hosts no {ep!r} servers")
                if w < -1e-12:
                    raise ValueError(
                        f"route_region returned a negative share {w} for "
                        f"{ep!r} {h}->{q}")
            tot = sum(row.values())
            if tot > 1.0 + 1e-9:
                raise ValueError(
                    f"route_region shares for {ep!r} origin {h!r} sum to "
                    f"{tot} > 1")
            if tot < 1.0 - 1e-9:      # shortfall stays home
                row[h] = row.get(h, 0.0) + (1.0 - tot)
            for q, w in row.items():
                if w <= 0.0:
                    continue
                eff = d * w
                if q != h:
                    self._moved += float(eff)
                    extra = eff * pen * self.rtt_ms[(h, q)]
                    self._wan_extra += float(extra)
                    eff = eff + extra
                overrides[q][ep] = overrides[q].get(ep, 0.0) + eff

    def step(self) -> FleetState:
        """Advance the whole fleet one tick; returns the ``FleetState``."""
        if self.tick >= self.ticks:
            raise RuntimeError(
                f"simulation horizon reached ({self.ticks} ticks); "
                f"call reset() to rerun")
        states = {name: sim.observe() for name, sim in self.sims.items()}
        fleet = self._fleet_state(states)
        now = fleet.now_h

        # -- confirm last tick's migrations landed -----------------------
        # placement runs inside the dest's observe; a migration whose dest
        # filled up in the meantime must not silently lose a live tenant —
        # send it home (one retry; a drop there is the generic full-fleet
        # arrival-drop semantics) and count the failure
        for dst, src, vm in self._inflight:
            if vm.arrival_h + vm.lifetime_h <= now:
                continue    # reached its scheduled end either way — a
                #             landed-then-departed VM is not a failure,
                #             and an expired one must not be resurrected
            if not (self.sims[dst].alloc_state.vm_of == vm.vm_id).any():
                self._mig_failed += 1
                remaining = max(vm.arrival_h + vm.lifetime_h - now,
                                self.cfg.tick_min / 60.0)
                self.sims[src].inject_vm(
                    kind=vm.kind, customer=vm.customer, arrival_h=now,
                    lifetime_h=remaining, peak_util=vm.peak_util)
        self._inflight = []

        # -- fleet-level VM admissions (policy picks the region) ---------
        while self._pending_fleet and self._pending_fleet[0][0] <= now:
            _, _, ev = heapq.heappop(self._pending_fleet)
            region = self.policy.admit_region(fleet, ev)
            if region is None:
                continue
            self._check_region(region)
            self.sims[region].inject_vm(
                kind=ev.kind, customer=ev.customer, arrival_h=now,
                lifetime_h=ev.lifetime_h, peak_util=ev.peak_util)
            self._admissions += 1

        # -- drains/migrations (before routing: drained servers take no
        #    load this tick; the VM re-arrives at the dest next tick) ----
        for m in self.policy.rebalance(fleet) or []:
            if not isinstance(m, Migration):
                raise TypeError(f"rebalance must return Migrations, "
                                f"got {m!r}")
            self._check_region(m.src)
            self._check_region(m.dst)
            vm = self.sims[m.src].evict(states[m.src], m.server)
            if vm is None:
                continue
            remaining = max(vm.arrival_h + vm.lifetime_h - now,
                            self.cfg.tick_min / 60.0)
            injected = self.sims[m.dst].inject_vm(
                kind=vm.kind, customer=vm.customer, arrival_h=now,
                lifetime_h=remaining, peak_util=vm.peak_util)
            self._inflight.append((m.dst, m.src, injected))
            self._migrations += 1

        # -- global steering, then each region's unmodified tick tail ----
        overrides: dict = {name: {} for name in self.sims}
        for ep in sorted(fleet.demand):
            demands = fleet.demand[ep]
            shares = self.policy.route_region(fleet, ep, dict(demands))
            self._apply_shares(ep, demands, shares, overrides)
        for name, sim in self.sims.items():
            sim.route(states[name], demand_overrides=overrides[name])
            sim.finish_tick(states[name])
        # energy/cost accounting: this tick's per-region energy priced at
        # this tick's effective power price and grid carbon intensity
        for name, sim in self.sims.items():
            kwh = sim._energy_kwh - self._prev_energy[name]
            self._prev_energy[name] = sim._energy_kwh
            self._energy_kwh += kwh
            self._energy_cost_kwh += kwh * fleet.price[name]
            self._carbon_kg += kwh * fleet.carbon[name]
        self.tick += 1
        self.last_state = fleet
        return fleet

    # ------------------------------------------------------------------
    def result(self) -> FleetResult:
        if self.tick == 0:
            raise RuntimeError(
                "no ticks simulated yet; call step() or run() first")
        regions = {name: sim.result() for name, sim in self.sims.items()}
        unserved = sum(sim._unserved_total for sim in self.sims.values())
        demand = sum(sim._demand_total for sim in self.sims.values())
        q_acc = sum(sim._quality_acc for sim in self.sims.values())
        q_w = sum(sim._quality_w for sim in self.sims.values())
        return FleetResult(
            regions=regions, moved_load=self._moved,
            wan_overhead=self._wan_extra, migrations=self._migrations,
            migrations_failed=self._mig_failed,
            fleet_admissions=self._admissions,
            unserved_frac=unserved / max(demand, 1e-9),
            mean_quality=q_acc / max(q_w, 1e-9),
            energy_kwh=self._energy_kwh, energy_cost_kwh=self._energy_cost_kwh,
            carbon_kg=self._carbon_kg)

    def run(self) -> FleetResult:
        if self.tick:
            self.reset()
        while self.tick < self.ticks:
            self.step()
        return self.result()
