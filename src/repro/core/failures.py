"""Failure management — paper §4.4 / §5.4 (Table 2).

AHU failure: one aisle loses 1/N of its AHUs -> reduced airflow (≈90%
capacity); UPS failure under 4N/3 redundancy -> every row limited to 75%
power.  The drill compares Baseline (uniform frequency capping) against
TAPAS (recompute limits -> steer -> reconfigure -> cap IaaS last) over a
peak-load window, reporting perf impact (% frequency capped x fraction of
workloads affected) and quality impact per workload class.

Drills are scripted as ``Scenario`` events — kind typos and inverted
windows fail at construction, and callers can stack extra events (demand
surges, weather shifts) onto the drill through the same API.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.scenario import FailureEvent, Scenario
from repro.core.simulator import (BASELINE, TAPAS, ClusterSim, Policy,
                                  SimConfig)


@dataclass
class DrillReport:
    kind: str
    policy: str
    iaas_perf: float      # negative = slowdown (frequency capped)
    saas_perf: float      # relative goodput vs demand served
    saas_quality: float   # quality delta vs 1.0

    def row(self) -> dict:
        return {
            "failure": self.kind, "policy": self.policy,
            "iaas_perf_pct": round(100 * self.iaas_perf, 1),
            "saas_perf_pct": round(100 * self.saas_perf, 1),
            "quality_pct": round(100 * self.saas_quality, 1),
        }


def run_drill(kind: str, policy: Policy, *, dc=None, seed: int = 0,
              horizon_h: float = 18.0,
              extra: Scenario | None = None) -> DrillReport:
    """Failure strikes at the peak-load hour and lasts 1.5h (the paper
    evaluates a 5-minute peak window; a longer window smooths tick noise).

    ``extra``: additional scenario events stacked onto both the clean and
    failure runs (e.g. a DemandSurge to drill under surge load)."""
    from repro.core.datacenter import DCConfig
    dc = dc or DCConfig(n_rows=8, racks_per_row=10, servers_per_rack=4)
    # strike at the diurnal demand peak (~14:00-16:00) with the fleet hot
    start = min(14.0, horizon_h - 2.5)
    drill = Scenario((FailureEvent(kind=kind, start_h=start,
                                   end_h=start + 1.5, target=0),))
    clean_scenario = extra if extra is not None else Scenario()
    kw = dict(dc=dc, horizon_h=horizon_h, seed=seed, policy=policy,
              occupancy=0.95, demand_scale=0.98)
    clean = ClusterSim(SimConfig(scenario=clean_scenario, **kw)).run()
    failed = ClusterSim(SimConfig(scenario=clean_scenario + drill,
                                  **kw)).run()

    iaas_perf = -(failed.iaas_perf_impact - clean.iaas_perf_impact)
    served_clean = 1.0 - clean.unserved_frac
    served_fail = 1.0 - failed.unserved_frac
    saas_perf = served_fail / max(served_clean, 1e-9) - 1.0
    quality = failed.mean_quality - clean.mean_quality
    return DrillReport(kind=kind, policy=policy.name,
                       iaas_perf=iaas_perf, saas_perf=saas_perf,
                       saas_quality=quality)


def table2(*, seed: int = 0, dc=None) -> list:
    """Both emergencies x both policies (paper Table 2)."""
    rows = []
    for kind in ("ups", "thermal"):
        for pol in (BASELINE, TAPAS):
            rows.append(run_drill(kind, pol, seed=seed, dc=dc).row())
    return rows
