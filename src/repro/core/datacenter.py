"""Datacenter topology + provisioning (paper §2, Fig. 1).

Rows of racks of 8-chip servers; an aisle = two adjacent rows sharing AHUs
and a contained cold aisle.  Power: three-level hierarchy abstracted to the
row envelope (Eq. 4) — the paper's management granularity; UPS redundancy
is 4N/3 (failure => 75% capacity), AHU N+1 per aisle (failure => reduced
aisle airflow).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np


@dataclass(frozen=True)
class HWProfile:
    """Per-server (8-chip tray) envelope — A100-class by default."""
    name: str = "a100"
    chips: int = 8
    idle_power_w: float = 1500.0
    peak_power_w: float = 6500.0
    airflow_idle_cfm: float = 300.0
    airflow_max_cfm: float = 1105.0   # paper: 840/1105 CFM @ 80% PWM A100/H100
    gpu_temp_limit_c: float = 85.0    # thermal throttling threshold
    mem_temp_limit_c: float = 95.0


@dataclass(frozen=True)
class DCConfig:
    n_rows: int = 8
    racks_per_row: int = 10
    servers_per_rack: int = 4
    hw: HWProfile = field(default_factory=HWProfile)
    seed: int = 0
    # provisioning headroom over nominal peak (1.0 = exactly peak-provisioned)
    power_headroom: float = 1.0
    airflow_headroom: float = 1.0
    # operators provision to the *observed* peak, not nameplate TDP
    # (paper §2.2 / Fig. 19: baseline rows run near 1.0 of provisioned)
    power_provision_frac: float = 0.88
    airflow_provision_frac: float = 0.94
    ahus_per_aisle: int = 4           # N+1 redundant
    region: str = "hot"               # hot | mild | cold

    @property
    def n_servers(self) -> int:
        return self.n_rows * self.racks_per_row * self.servers_per_rack

    @property
    def n_aisles(self) -> int:
        return (self.n_rows + 1) // 2


class Datacenter:
    """Static topology arrays + provisioned limits."""

    def __init__(self, cfg: DCConfig):
        self.cfg = cfg
        s = cfg.n_servers
        idx = np.arange(s)
        per_row = cfg.racks_per_row * cfg.servers_per_rack
        self.row_of = idx // per_row                      # (S,)
        self.aisle_of = self.row_of // 2                  # (S,)
        self.rack_of = (idx % per_row) // cfg.servers_per_rack
        self.height_of = idx % cfg.servers_per_rack       # position in rack
        self.n_servers = s
        self.n_rows = cfg.n_rows
        self.n_aisles = cfg.n_aisles

        # provisioned limits: observed peak demand at full occupancy (Eqs. 3, 4)
        servers_per_aisle = np.bincount(self.aisle_of, minlength=self.n_aisles)
        self.prov_ahu_cfm = (servers_per_aisle * cfg.hw.airflow_max_cfm
                             * cfg.airflow_provision_frac
                             * cfg.airflow_headroom)      # (A,)
        servers_per_row = np.bincount(self.row_of, minlength=self.n_rows)
        self.prov_row_power_w = (servers_per_row * cfg.hw.peak_power_w
                                 * cfg.power_provision_frac
                                 * cfg.power_headroom)    # (R,)

    def row_sum(self, per_server: np.ndarray) -> np.ndarray:
        return np.bincount(self.row_of, weights=per_server,
                           minlength=self.n_rows)

    def aisle_sum(self, per_server: np.ndarray) -> np.ndarray:
        return np.bincount(self.aisle_of, weights=per_server,
                           minlength=self.n_aisles)


def scale_datacenter(cfg: DCConfig, oversub: float) -> DCConfig:
    """Add racks into existing rows (paper §4.4): +oversub fraction servers
    without changing provisioned cooling/power (they were sized for the
    original occupancy).  ``dataclasses.replace`` keeps the copy total —
    the hand-rolled field list here once dropped the provision fractions
    (tapaslint TL004)."""
    extra = int(round(cfg.racks_per_row * oversub))
    shrink = cfg.racks_per_row / (cfg.racks_per_row + extra)
    return replace(
        cfg,
        racks_per_row=cfg.racks_per_row + extra,
        power_headroom=cfg.power_headroom * shrink,
        airflow_headroom=cfg.airflow_headroom * shrink,
    )
