import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (sharding
propagates, collectives legal, memory fits) and extracts the roofline
inputs: ``compiled.cost_analysis()`` (FLOPs / bytes per partition),
``compiled.memory_analysis()`` (per-device memory), and collective operand
bytes parsed from the optimized HLO.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
Results are cached as JSON under benchmarks/results/dryrun/.
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.roofline import HW, model_flops, roofline_terms
from repro.configs import ASSIGNED, SHAPES, get_config, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, mesh_plan
from repro.training.train_step import AdamWConfig, init_opt_state, make_train_step

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _peak_mem(mem_d: dict) -> float:
    if not mem_d:
        return 0.0
    return float(mem_d.get("argument_size_in_bytes", 0)
                 + mem_d.get("output_size_in_bytes", 0)
                 + mem_d.get("temp_size_in_bytes", 0)
                 - mem_d.get("alias_size_in_bytes", 0))


def choose_grad_accum(cfg, shape, dp: int) -> int:
    """Microbatch count so the rematted residual stack stays ~<= 4 GB/dev."""
    b_loc = max(shape.global_batch // dp, 1)
    stack = b_loc * shape.seq_len * cfg.d_model * 2 * cfg.num_layers  # bf16
    accum = 1
    while stack / accum > 4e9 and accum < b_loc:
        accum *= 2
    return accum


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               plan_overrides: dict | None = None):
    """Build + lower one cell; returns (lowered, meta dict)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name in cfg.skip_shapes:
        raise ValueError(f"{arch} skips {shape_name}: {cfg.notes}")
    mesh = make_production_mesh(multi_pod=multi_pod)
    is_train = shape.kind == "train"
    kw = dict(
        fsdp=is_train,
        remat="full" if is_train else "none",
        param_dtype=jnp.float32 if is_train else jnp.bfloat16,
    )
    overrides = dict(plan_overrides or {})
    accum_override = overrides.pop("grad_accum", None)
    kw.update(overrides)
    plan = mesh_plan(mesh, **kw)
    if shape.global_batch % max(plan.dp, 1):
        # batch smaller than the data axis (e.g. long_500k B=1): replicate
        # over data — honest for single-stream long-context decode
        import dataclasses as _dc
        plan = _dc.replace(plan, dp_axes=())
    model = build_model(cfg, plan)
    specs = input_specs(cfg, shape)
    dp = plan.dp_axes

    def dsh(ndim):
        return NamedSharding(mesh, P(dp, *([None] * (ndim - 1))))

    p_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = model.param_shardings()

    if shape.kind == "train":
        opt_struct = jax.eval_shape(init_opt_state, p_struct)
        opt_shard = {"m": p_shard, "v": p_shard,
                     "step": NamedSharding(mesh, P())}
        accum = accum_override or choose_grad_accum(cfg, shape, plan.dp)
        step = make_train_step(model, AdamWConfig(), grad_accum=accum)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, opt_shard,
                          dsh(len(specs["inputs"].shape)),
                          dsh(len(specs["labels"].shape))),
            out_shardings=(p_shard, opt_shard, None),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(p_struct, opt_struct, specs["inputs"],
                               specs["labels"])
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        logits_sh = NamedSharding(mesh, P(dp, "model"))
        jitted = jax.jit(
            model.prefill,
            in_shardings=(p_shard, dsh(len(specs["inputs"].shape))),
            out_shardings=(logits_sh, model.cache_shardings()),
        )
        lowered = jitted.lower(p_struct, specs["inputs"])
        tokens = shape.global_batch * shape.seq_len
    else:  # decode
        cache_struct = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        cache_sh = model.cache_shardings()
        logits_sh = NamedSharding(mesh, P(dp, "model"))
        jitted = jax.jit(
            model.decode_step,
            in_shardings=(p_shard, cache_sh, dsh(1), dsh(1)),
            out_shardings=(logits_sh, cache_sh),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(p_struct, cache_struct, specs["tokens"],
                               specs["positions"])
        tokens = shape.global_batch  # one new token per sequence

    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "kind": shape.kind,
        "tokens": tokens,
        "n_params": cfg.param_count(),
        "n_params_active": cfg.param_count(active=True),
    }
    if shape.kind == "train":
        meta["grad_accum"] = accum
    return lowered, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             plan_overrides: dict | None = None, verbose: bool = True) -> dict:
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, multi_pod=multi_pod,
                               plan_overrides=plan_overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = _mem_dict(compiled.memory_analysis())
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    mflops = model_flops(meta["n_params_active"], meta["kind"], meta["tokens"])
    rep = roofline_terms(
        arch=arch, shape=shape_name, mesh=meta["mesh"], chips=meta["chips"],
        cost=cost, hlo_text=hlo, model_flops_total=mflops,
        peak_mem=_peak_mem(mem))
    row = rep.row()
    row.update(meta)
    row["memory_analysis"] = mem
    row["xla_cost_analysis"] = {k: float(v) for k, v in cost.items()
                                if k in ("flops", "bytes accessed")}
    row["fits_hbm"] = bool(_peak_mem(mem) <= HW.hbm_bytes) if mem else None
    row["t_lower_s"] = round(t_lower, 1)
    row["t_compile_s"] = round(t_compile, 1)
    row["_hlo_text"] = hlo  # popped before JSON; saved compressed alongside
    if verbose:
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops/dev={row['hlo_flops_total']/meta['chips']:.3e} "
              f"bytes/dev={cost.get('bytes accessed', 0):.3e}")
        print(f"  collectives/dev: {row['coll_bytes_per_dev']:.3e} B "
              f"{row['coll_breakdown']}")
        print(f"  terms: compute={row['t_compute_s']:.4f}s "
              f"memory={row['t_memory_s']:.4f}s "
              f"collective={row['t_collective_s']:.4f}s "
              f"-> {row['bottleneck']}-bound; "
              f"roofline_fraction={row['roofline_fraction']:.3f}")
    return row


def cell_path(arch, shape, mesh_name, tag="") -> pathlib.Path:
    suffix = f"_{tag}" if tag else ""
    return RESULTS_DIR / f"{arch}_{shape}_{mesh_name}{suffix}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="sweep every runnable cell")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--tag", default="", help="variant tag for perf experiments")
    ap.add_argument("--plan", default="", help="JSON dict of ShardPlan overrides")
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    overrides = json.loads(args.plan) if args.plan else None

    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            if shape in cfg.skip_shapes:
                print(f"[skip] {arch} x {shape}: {cfg.notes}")
                continue
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                out = cell_path(arch, shape, mesh_name, args.tag)
                if out.exists() and not args.force:
                    print(f"[cached] {arch} x {shape} @ {mesh_name}")
                    continue
                print(f"[run] {arch} x {shape} @ {mesh_name}")
                try:
                    row = run_cell(arch, shape, multi_pod=mp,
                                   plan_overrides=overrides)
                    hlo = row.pop("_hlo_text", None)
                    out.write_text(json.dumps(row, indent=1, default=str))
                    if hlo:
                        import zstandard
                        out.with_suffix(".hlo.zst").write_bytes(
                            zstandard.compress(hlo.encode()))
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mesh_name, repr(e)))
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall requested dry-run cells compiled OK")


if __name__ == "__main__":
    main()
