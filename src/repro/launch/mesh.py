"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state.  Single-pod: 16x16 = 256 chips (one v5e pod
slice); multi-pod: 2x16x16 = 512 chips with a leading "pod" data axis.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this before importing jax)")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_mesh_for(n_devices: int, *, want_model: int = 0) -> Mesh:
    """Best-effort (data, model) mesh for an arbitrary device count.

    Used by the elastic runtime when a pod loses nodes: keep the model axis
    intact (TP groups must stay whole) and shrink the data axis.
    """
    devices = jax.devices()[:n_devices]
    model = want_model or min(16, n_devices)
    while n_devices % model:
        model //= 2
    data = n_devices // model
    return jax.make_mesh((data, model), ("data", "model"), devices=devices)
