"""Elastic runtime: survive node loss by re-meshing + checkpoint restore.

Policy (DESIGN.md §3): never break a TP group — shrink the data axis to the
largest value that fits the surviving device count, rebuild shardings from
the same logical axes, and restore the latest committed checkpoint with the
new shardings (restore_checkpoint re-shards transparently).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from repro.launch.mesh import make_mesh_for
from repro.models import build_model, mesh_plan
from repro.training.checkpoint import restore_checkpoint
from repro.training.train_step import init_opt_state


@dataclass
class ElasticDecision:
    survivors: int
    data: int
    model: int
    dropped: int

    @property
    def usable(self) -> int:
        return self.data * self.model


def plan_remesh(n_surviving: int, *, tp: int = 16) -> ElasticDecision:
    """Largest (data x tp) grid fitting the survivors; TP stays whole."""
    while tp > 1 and n_surviving < tp:
        tp //= 2
    data = max(n_surviving // tp, 1)
    used = data * tp
    return ElasticDecision(survivors=n_surviving, data=data, model=tp,
                           dropped=n_surviving - used)


def recover(arch: str, ckpt_dir: str, n_surviving: int, *, fsdp: bool = True):
    """Rebuild model + restore the latest checkpoint onto a shrunken mesh."""
    decision = plan_remesh(min(n_surviving, len(jax.devices())))
    mesh = make_mesh_for(decision.usable, want_model=decision.model)
    plan = mesh_plan(mesh, fsdp=fsdp)
    model = build_model(arch, plan)
    params_t = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_t = jax.eval_shape(init_opt_state, params_t)
    shardings = (model.param_shardings(),
                 {"m": model.param_shardings(), "v": model.param_shardings(),
                  "step": None})
    (params, opt_state), manifest = restore_checkpoint(
        ckpt_dir, (params_t, opt_t), shardings=shardings)
    return model, params, opt_state, manifest, decision
