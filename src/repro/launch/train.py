"""End-to-end training driver (example application of the substrate).

    PYTHONPATH=src python -m repro.launch.train --arch llama2-7b --smoke \
        --steps 50 --ckpt /tmp/ckpt

On this CPU container use --smoke (reduced config); on a pod the same
driver runs the full config under make_production_mesh().
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model, local_plan, mesh_plan
from repro.training.checkpoint import (latest_step, restore_checkpoint,
                                       save_checkpoint)
from repro.training.data import DataConfig, TokenPipeline
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_opt_state, make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke_config()
    if args.production_mesh:
        from repro.launch.mesh import make_production_mesh
        plan = mesh_plan(make_production_mesh(), fsdp=True, remat="full")
    else:
        plan = local_plan(param_dtype=jnp.float32)
    model = build_model(cfg, plan)

    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      grad_accum=args.grad_accum))
    pipe = TokenPipeline(DataConfig(cfg.vocab_size, args.batch, args.seq))

    start = 0
    if args.ckpt and latest_step(args.ckpt) is not None:
        (params, opt_state), manifest = restore_checkpoint(
            args.ckpt, (params, opt_state))
        start = manifest["step"]
        pipe = TokenPipeline(DataConfig(cfg.vocab_size, args.batch, args.seq),
                             step=start)
        print(f"resumed from step {start}")

    losses = []
    for step in range(start, args.steps):
        if cfg.input_kind == "embeds":
            inputs, labels = pipe.next_embed_batch(cfg.d_model)
        else:
            inputs, labels = pipe.next_batch()
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, inputs, labels)
        loss = float(metrics["loss"])
        losses.append(loss)
        print(f"step {step:4d} loss {loss:.4f} "
              f"gnorm {float(metrics['grad_norm']):.3f} "
              f"({time.perf_counter() - t0:.2f}s)")
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, step + 1, (params, opt_state),
                            meta={"arch": cfg.name})
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None}


if __name__ == "__main__":
    out = main()
    print(out)
