"""Serving driver: continuous-batched generation through the Engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \
        --requests 12
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model, local_plan
from repro.serving import Engine, EngineKnobs, Request


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=None,
                    help="TAPAS batch knob (default: --slots)")
    ap.add_argument("--freq-scale", type=float, default=1.0,
                    help="TAPAS frequency knob (1.0 = nominal clock)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged KV pool block size (tokens)")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--paged", dest="paged", action="store_true",
                      default=None, help="force the paged-KV pool")
    mode.add_argument("--no-paged", dest="paged", action="store_false",
                      help="force the legacy slot pool")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke_config()
    if cfg.encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only; nothing to decode")
    plan = local_plan(param_dtype=jnp.bfloat16)
    model = build_model(cfg, plan)
    params = model.init(jax.random.PRNGKey(0))
    knobs = EngineKnobs(max_batch=args.max_batch or args.slots,
                        freq_scale=args.freq_scale)
    eng = Engine(model, params, max_seq=args.max_seq, n_slots=args.slots,
                 knobs=knobs, paged=args.paged,
                 block_size=args.block_size)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        eng.submit(Request(
            prompt=list(rng.integers(0, cfg.vocab_size, plen)),
            max_new_tokens=args.max_new, customer=f"cust{i % 3}",
            arrival_s=0.0))
    stats = eng.run()
    gp = eng.goodput(ttft_slo=50.0, tbt_slo=5.0)
    out = {
        "mode": "paged" if eng.paged else "slots",
        "completed": len(stats.completed),
        "decode_tokens": stats.decode_tokens,
        "prefill_tokens": stats.prefill_tokens,
        "prefill_batches": stats.prefill_batches,
        "preemptions": stats.preemptions,
        "goodput_tok_per_step": round(gp, 3),
    }
    print(out)
    return out


if __name__ == "__main__":
    main()
