"""deepseek-7b — dense llama-arch MHA. [arXiv:2401.02954; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
    rope_theta=10000.0,
    skip_shapes=("long_500k",),
    notes="full attention => long_500k skipped per assignment",
))
