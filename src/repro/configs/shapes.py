"""Assigned input shapes and ShapeDtypeStruct stand-ins (no allocation).

LM transformer shapes are seq_len x global_batch.  ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len), NOT
``train_step``; ``train_*`` lowers the training step; ``prefill_*`` lowers
the prefill step.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class Shape:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


def input_specs(cfg: ArchConfig, shape: Shape) -> dict[str, jax.ShapeDtypeStruct]:
    """Data-input stand-ins for one (arch x shape) cell.

    Parameter and KV-cache stand-ins come from ``jax.eval_shape`` over the
    model's ``init`` / ``init_cache`` (see launch/dryrun.py) so they always
    match the real pytrees.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.input_kind == "embeds":  # modality-frontend stub (audio/vlm)
            return {
                "inputs": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        return {
            "inputs": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    if shape.kind == "prefill":
        if cfg.input_kind == "embeds":
            return {"inputs": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)}
        return {"inputs": jax.ShapeDtypeStruct((b, s), i32)}
    if shape.kind == "decode":
        return {
            "tokens": jax.ShapeDtypeStruct((b,), i32),
            "positions": jax.ShapeDtypeStruct((b,), i32),
        }
    raise ValueError(shape.kind)


def runnable_cells(cfg: ArchConfig) -> list[Shape]:
    """Shapes this arch runs; mandated skips documented in cfg.skip_shapes."""
    return [s for s in SHAPES.values() if s.name not in cfg.skip_shapes]
