"""minicpm3-4b — dense, MLA latent attention. [hf:openbmb/MiniCPM3-4B; hf]

62L d_model=2560 40H d_ff=6400 vocab=73448.  MLA dims follow the HF config
(q_lora_rank=768, kv_lora_rank=256, qk_nope=64, qk_rope=32, v_head=64); the
assignment's "GQA kv=40" denotes MHA-equivalent head count, realised here as
true MLA per the arch note.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=96,  # qk_nope + qk_rope
    d_ff=6400,
    vocab_size=73448,
    attn_kind="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    rope_theta=10000.0,
    skip_shapes=("long_500k",),
    notes="full attention (MLA) => long_500k skipped per assignment",
))
