"""Config registry: one module per assigned architecture + the paper's own."""
from repro.configs.base import ArchConfig, get_config, list_archs, register

# import every arch module so registration happens on package import
from repro.configs import (  # noqa: F401
    chameleon_34b,
    deepseek_7b,
    gemma_7b,
    granite_moe_3b,
    hubert_xlarge,
    hymba_1p5b,
    llama2_7b,
    minicpm3_4b,
    qwen3_1p7b,
    qwen3_moe_235b,
    rwkv6_3b,
)
from repro.configs.drafters import (DRAFT_PAIRS, check_draft_pair,
                                    drafter_for)
from repro.configs.shapes import SHAPES, Shape, input_specs, runnable_cells

ASSIGNED = [
    "minicpm3-4b",
    "qwen3-1.7b",
    "deepseek-7b",
    "gemma-7b",
    "hymba-1.5b",
    "chameleon-34b",
    "granite-moe-3b-a800m",
    "qwen3-moe-235b-a22b",
    "rwkv6-3b",
    "hubert-xlarge",
]

__all__ = [
    "ArchConfig", "get_config", "list_archs", "register",
    "SHAPES", "Shape", "input_specs", "runnable_cells", "ASSIGNED",
    "DRAFT_PAIRS", "check_draft_pair", "drafter_for",
]
