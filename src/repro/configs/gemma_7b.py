"""gemma-7b — dense, GeGLU, head_dim=256, tied embeddings. [arXiv:2403.08295; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    activation="gelu",
    norm_plus_one=True,
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10000.0,
    skip_shapes=("long_500k",),
    notes="full attention => long_500k skipped per assignment",
))
