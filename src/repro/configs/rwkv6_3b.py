"""rwkv6-3b (Finch) — attention-free, data-dependent decay. [arXiv:2404.05892; hf]

32L d_model=2560 d_ff=8960 vocab=65536; head_dim 64 => 40 wkv heads.
O(1) state per layer => runs long_500k.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    attn_kind="none",
    rwkv=True,
    rwkv_lora_w=64,
    mlp_kind="rwkv_cmix",
    notes="attention-free; runs long_500k",
))
