"""hymba-1.5b — hybrid: parallel SWA-attention + mamba heads per layer.
[arXiv:2411.13676; hf]

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Deviation (documented in DESIGN.md): the published Hymba keeps 3 layers on
full attention and uses meta-tokens; we use SWA in every layer (window 1024)
so the stack is uniform under scan and genuinely sub-quadratic for
long_500k, and we omit meta-tokens.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attn_kind="swa",
    window=1024,
    ssm_state=16,
    ssm_expand=2,
    conv_width=4,
    rope_theta=10000.0,
    notes="runs long_500k (SWA + SSM are sub-quadratic)",
))
