"""hubert-xlarge — audio encoder-only (w2v2 arch). [arXiv:2106.07447; unverified]

48L d_model=1280 16H d_ff=5120 vocab=504 (masked-prediction cluster units).
The CNN waveform frontend is a stub: ``input_specs`` supplies precomputed
frame embeddings (batch, frames, d_model).  Encoder-only => decode_32k and
long_500k are skipped per the assignment.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    family="encoder",
    num_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    encoder_only=True,
    input_kind="embeds",
    mlp_kind="gelu2",
    activation="gelu",
    norm_kind="layer",
    rope_theta=10000.0,  # conv-pos-embed replaced by rope (documented)
    skip_shapes=("decode_32k", "long_500k"),
    notes="encoder-only: no autoregressive decode",
))
