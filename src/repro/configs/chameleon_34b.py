"""chameleon-34b — early-fusion VLM; VQ image tokens live in the text vocab.
[arXiv:2405.09818; unverified]

The modality frontend (VQ-GAN tokenizer) is a stub: ``input_specs`` provides
interleaved text+image token ids directly, which is exactly what early
fusion means at the backbone level.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,  # chameleon qk-norm (rms variant here)
    rope_theta=10000.0,
    skip_shapes=("long_500k",),
    notes="full attention => long_500k skipped per assignment",
))
