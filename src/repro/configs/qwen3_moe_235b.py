"""qwen3-moe-235b-a22b — MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B family; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    n_experts=128,
    top_k=8,
    expert_d_ff=1536,
    qk_norm=True,
    router_renorm=True,
    rope_theta=1_000_000.0,
    skip_shapes=("long_500k",),
    notes="full attention => long_500k skipped per assignment",
))
