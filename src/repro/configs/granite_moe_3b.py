"""granite-moe-3b-a800m — MoE 40 experts top-8, per-expert d_ff=512.
[hf:ibm-granite granite-3.0-3b-a800m; hf]

The assignment header says 40e (matching granite-3.0-3b-a800m); its bracket
cites the 1b-a400m card (32e). We implement 40 experts; EP over a 16-way
model axis pads the expert dim to 48 with zero-routed pad experts
(see DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    n_experts=40,
    top_k=8,
    expert_d_ff=512,
    rope_theta=10000.0,
    tie_embeddings=True,
    skip_shapes=("long_500k",),
    notes="full attention => long_500k skipped per assignment",
))
