"""Architecture config schema + registry.

Every assigned architecture gets one module in this package defining a
``CONFIG = ArchConfig(...)`` with the exact published hyper-parameters; the
registry maps the public ``--arch <id>`` names (dashes) to configs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encoder | vlm
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    attn_kind: str = "gqa"  # gqa | mla | swa | none (attn-free)
    qk_norm: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    window: int = 0  # sliding-window size when attn_kind == "swa"

    # MLA (DeepSeek/MiniCPM3-style latent attention)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    router_renorm: bool = True  # renormalise top-k probs (qwen3 norm_topk_prob)
    capacity_factor: float = 1.25

    # SSM / hybrid (mamba branch)
    ssm_state: int = 0
    ssm_expand: int = 2
    conv_width: int = 4

    # rwkv6
    rwkv: bool = False
    rwkv_lora_w: int = 64  # low-rank size of the data-dependent decay MLP

    # block flavour
    activation: str = "silu"
    mlp_kind: str = "glu"  # glu | gelu2 (plain 2-layer, encoder) | rwkv_cmix
    norm_kind: str = "rms"  # rms | layer
    norm_plus_one: bool = False  # gemma (1 + w) RMSNorm
    embed_scale: bool = False  # gemma scales embeddings by sqrt(d_model)
    tie_embeddings: bool = False
    encoder_only: bool = False
    input_kind: str = "tokens"  # tokens | embeds (modality-frontend stub)

    # documentation of mandated shape skips; see DESIGN.md §4
    skip_shapes: tuple = ()
    notes: str = ""

    # ------------------------------------------------------------------
    # derived quantities
    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        """Mamba branch inner width."""
        return self.ssm_expand * self.d_model

    def attn_params_per_layer(self) -> int:
        d = self.d_model
        if self.attn_kind == "none":
            # rwkv time-mix: r,k,v,g,o projections + decay lora + ddlerp lora
            h = self.n_heads * self.head_dim
            lora = self.rwkv_lora_w
            return 5 * d * h + (d * lora + lora * h) + 5 * (d * 32 + 32 * d)
        if self.attn_kind == "mla":
            qk_dim = self.qk_nope_head_dim + self.qk_rope_head_dim
            p = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qk_dim
            p += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            p += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
            p += self.n_heads * self.v_head_dim * d
            return p
        q = d * self.n_heads * self.head_dim
        kv = 2 * d * self.n_kv_heads * self.head_dim
        o = self.n_heads * self.head_dim * d
        p = q + kv + o
        if self.family == "hybrid":  # parallel mamba branch
            di = self.d_inner
            p += d * 2 * di  # in_proj (x, z)
            p += di * self.conv_width
            p += di * (2 * self.ssm_state + 1)  # B, C, dt proj (simplified)
            p += di * d  # out proj
        return p

    def mlp_params_per_layer(self, active: bool = False) -> int:
        d = self.d_model
        if self.n_experts:
            e = self.top_k if active else self.n_experts
            router = d * self.n_experts
            return router + e * 3 * d * self.expert_d_ff
        if self.mlp_kind == "gelu2":
            return 2 * d * self.d_ff
        if self.mlp_kind == "rwkv_cmix":
            return 2 * d * self.d_ff + d * d  # k, v, receptance
        return 3 * d * self.d_ff

    def param_count(self, active: bool = False) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        embed = self.vocab_size * self.d_model
        unembed = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        if self.input_kind == "embeds":
            embed = 0  # frontend stub provides embeddings
        per_layer = self.attn_params_per_layer() + self.mlp_params_per_layer(active)
        norms = self.num_layers * 2 * self.d_model + self.d_model
        return embed + unembed + self.num_layers * per_layer + norms

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def smoke_config(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw = dict(
            num_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab_size=128,
        )
        if self.attn_kind == "mla":
            kw.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16)
        if self.n_experts:
            kw.update(n_experts=4, top_k=2, expert_d_ff=32)
        if self.window:
            kw.update(window=16)
        if self.family in ("hybrid",):
            kw.update(ssm_state=4)
        if self.rwkv:
            kw.update(rwkv_lora_w=8)
        return self.replace(**kw)


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    # lazy import so ``import repro.configs`` pulls in every module once
    from repro import configs as _c  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)
