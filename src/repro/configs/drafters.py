"""Target -> drafter pairing table for speculative decoding.

A drafter proposes tokens the target then verifies, so the two models must
share a tokenizer — enforced here as an exact vocab match — and the
drafter itself must be paged-servable (the draft KV rides the target
pool's block tables, which only plain causal GQA supports).
"""
from __future__ import annotations

from repro.configs.base import ArchConfig

# target arch name -> the small same-tokenizer variant that drafts for it
DRAFT_PAIRS = {
    "llama2-70b": "llama2-7b",
    "llama2-13b": "llama2-7b",
    "qwen3-moe-235b-a22b": "qwen3-1.7b",
}


def drafter_for(name: str) -> str | None:
    """The paired drafter arch for a target, or None if none is known."""
    return DRAFT_PAIRS.get(name)


def check_draft_pair(target: ArchConfig, draft: ArchConfig) -> None:
    """Validate a (target, drafter) pairing; raises ValueError if unfit."""
    if target.vocab_size != draft.vocab_size:
        raise ValueError(
            f"drafter {draft.name!r} (vocab {draft.vocab_size}) does not "
            f"share a tokenizer with target {target.name!r} "
            f"(vocab {target.vocab_size})")
    if (draft.rwkv or draft.family == "hybrid" or draft.attn_kind != "gqa"
            or not draft.causal or draft.input_kind != "tokens"):
        raise ValueError(
            f"drafter {draft.name!r} is not paged-servable "
            f"(family={draft.family!r}, attn_kind={draft.attn_kind!r})")
