"""llama2-7b — the paper's own SaaS model (TAPAS profiles Llama2 7B/13B/70B).

Used by the TAPAS instance-configurator model-size knob and the profile
benchmarks; also a handy small driver model for examples.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama2-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
    rope_theta=10000.0,
    skip_shapes=("long_500k",),
    notes="paper's SaaS workload model",
))

CONFIG_13B = register(ArchConfig(
    name="llama2-13b",
    family="dense",
    num_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=13824,
    vocab_size=32000,
    rope_theta=10000.0,
    skip_shapes=("long_500k",),
    notes="paper's SaaS workload model (mid size)",
))

CONFIG_70B = register(ArchConfig(
    name="llama2-70b",
    family="dense",
    num_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32000,
    rope_theta=10000.0,
    skip_shapes=("long_500k",),
    notes="paper's SaaS workload model (large size)",
))
