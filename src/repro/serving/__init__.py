from repro.serving.backend import EngineBackend, EngineFleet, FleetBackend
from repro.serving.engine import Engine, EngineKnobs, EngineStats, \
    shard_compat
from repro.serving.kvcache import CachePool, PagedCachePool
from repro.serving.request import Request
from repro.serving.spec import EngineSpec, serving_plan

__all__ = ["Engine", "EngineBackend", "EngineFleet", "EngineKnobs",
           "EngineSpec", "EngineStats", "FleetBackend", "CachePool",
           "PagedCachePool", "Request", "serving_plan", "shard_compat"]
