from repro.serving.backend import EngineBackend
from repro.serving.engine import Engine, EngineKnobs, EngineStats
from repro.serving.kvcache import CachePool, PagedCachePool
from repro.serving.request import Request

__all__ = ["Engine", "EngineBackend", "EngineKnobs", "EngineStats",
           "CachePool", "PagedCachePool", "Request"]
