"""Engine-in-the-loop backend: a real serving Engine as a simulated SaaS
server.

``EngineBackend`` binds one ``serving.Engine`` (with its ``EngineKnobs``)
to a server inside ``ClusterSim``.  Each tick the simulator

* mirrors the control plane's ``reconfigure()`` decisions onto the engine —
  a ``ConfigPoint`` becomes ``set_variant`` / ``max_batch`` / ``freq_scale``
  (plus ``paused`` while a reload drains), and
* pumps the engine with requests proportional to the load the router
  assigned to that server, then reports the engine's *measured* goodput
  back into ``ClusterState.measured_goodput``.

This closes the loop that ``profiles.measure_from_engine()`` opened: PR 1
fed engine measurements into the profile tables offline; here the engine
runs live inside the simulated datacenter and the control plane's
decisions land on actual serving knobs.

The backend is telemetry-only with respect to the physics: attaching
engines never changes the simulated thermal/power trajectory, so
simulation results stay reproducible with or without live engines.

Backends work unchanged inside a fleet: ``FleetSim.attach_backend(region,
server, backend)`` binds the engine to one region's cluster, and the
region's own reconfigure decisions keep landing on the engine's knobs
(the fleet layer only redirects demand).  If a fleet migration evicts the
bound server, the backend idles — ``pump`` receives zero load until the
server hosts SaaS again — rather than erroring; rebind to the VM's new
region to follow it across the WAN.
"""
from __future__ import annotations

import numpy as np

from repro.core.profiles import ConfigPoint
from repro.serving.engine import Engine
from repro.serving.request import Request


class EngineBackend:
    """Binds a real ``Engine`` to a simulated SaaS server.

    ``variant_for_size`` maps profile model sizes ("70b"/"13b"/"7b") onto
    engine variant names registered via ``Engine.add_variant``; sizes
    without a mapping leave the variant untouched.  ``batch_for_knob``
    maps the profile's batch axis onto engine ``max_batch`` values
    (default: 1 -> 1, 16 -> half the lanes, 64 -> all lanes).
    """

    def __init__(self, engine: Engine, *,
                 variant_for_size: dict | None = None,
                 batch_for_knob: dict | None = None,
                 requests_per_load: float = 3.0,
                 steps_per_tick: int = 4,
                 prompt_len: int = 6, max_new_tokens: int = 4,
                 seed: int = 0, draft_min_freq: float | None = None):
        n = engine.n_slots
        self.engine = engine
        self.variant_for_size = variant_for_size or {}
        unknown = sorted(set(self.variant_for_size.values())
                         - set(engine.variants))
        if unknown:
            raise ValueError(
                f"variant_for_size names variants {unknown} not registered "
                f"on the engine (has {sorted(engine.variants)}); a typo "
                f"here would silently disable model swaps")
        self.batch_for_knob = batch_for_knob or {1: 1, 16: max(1, n // 2),
                                                 64: n}
        self.requests_per_load = requests_per_load
        self.steps_per_tick = steps_per_tick
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.rng = np.random.default_rng(seed)
        self._next_id = 0
        self._last_rate = 0.0
        self.applied: list[ConfigPoint] = []   # reconfigure decisions seen
        # speculation as a reconfigure axis: under a deep frequency cap
        # the drafter's extra passes stop paying for themselves, so the
        # control plane drops it (like quantization) and restores it when
        # the cap lifts.  None disables the rule.
        self.draft_min_freq = draft_min_freq
        self._stashed_draft: str | None = None
        self.draft_drops = 0

    # -- control-plane side ------------------------------------------------
    def apply_config(self, cfg: ConfigPoint, *, paused: bool = False) -> None:
        """Translate a configurator decision into engine knob turns."""
        knobs = self.engine.knobs
        knobs.freq_scale = float(cfg.freq)
        knobs.max_batch = int(self.batch_for_knob.get(
            cfg.batch, self.engine.n_slots))
        knobs.paused = bool(paused)
        variant = self.variant_for_size.get(cfg.size)
        if variant is not None and variant != knobs.variant:
            self.engine.set_variant(variant)
        if self.draft_min_freq is not None:
            if cfg.freq < self.draft_min_freq:
                if self.engine.draft_name is not None:
                    self._stashed_draft = self.engine.draft_name
                    self.engine.set_drafter(None)
                    self.draft_drops += 1
            elif self._stashed_draft is not None \
                    and self.engine.draft_name is None:
                self.engine.set_drafter(self._stashed_draft)
                self._stashed_draft = None
        self.applied.append(cfg)

    # -- workload side -----------------------------------------------------
    def pump(self, *, now: float, load: float) -> int:
        """Feed demand proportional to the routed ``load`` (nominal-VM
        units) and run scheduler steps; returns decode tokens produced.

        Also measures this tick's decode rate (tokens per wall-second of
        engine stepping, with the simulated frequency knob already folded
        into the step times) so ``measured_goodput`` reflects the engine's
        *current* capacity, not a lifetime average."""
        vocab = self.engine.model.cfg.vocab_size
        for _ in range(int(round(load * self.requests_per_load))):
            self.engine.submit(Request(
                prompt=[int(t) for t in self.rng.integers(
                    0, vocab, self.prompt_len)],
                max_new_tokens=self.max_new_tokens,
                customer=f"bk{self._next_id % 4}", arrival_s=now))
            self._next_id += 1
        wall_before = self.engine.stats.step_time_total
        produced = 0
        for _ in range(self.steps_per_tick):
            if self.engine.knobs.paused and not self.engine.active:
                break   # drained during a reload pause
            produced += self.engine.step(now=now)
        wall = self.engine.stats.step_time_total - wall_before
        # no steps ran (paused-and-drained, or idle) => the instance is
        # serving nothing right now; report that, not the last busy rate
        self._last_rate = produced / wall if wall > 0.0 else 0.0
        return produced

    def measured_goodput(self) -> float:
        """Decode tokens per wall-second over the most recent ``pump``
        window — responds immediately to knob turns (batch/variant change
        tokens-per-step, ``freq_scale`` stretches the step times)."""
        return self._last_rate
