"""Engine-in-the-loop backend: a real serving Engine as a simulated SaaS
server.

``EngineBackend`` binds one ``serving.Engine`` (with its ``EngineKnobs``)
to a server inside ``ClusterSim``.  Each tick the simulator

* mirrors the control plane's ``reconfigure()`` decisions onto the engine —
  a ``ConfigPoint`` becomes ``set_variant`` / ``max_batch`` / ``freq_scale``
  (plus ``paused`` while a reload drains), and
* pumps the engine with requests proportional to the load the router
  assigned to that server, then reports the engine's *measured* goodput
  back into ``ClusterState.measured_goodput``.

This closes the loop that ``profiles.measure_from_engine()`` opened: PR 1
fed engine measurements into the profile tables offline; here the engine
runs live inside the simulated datacenter and the control plane's
decisions land on actual serving knobs.

The backend is telemetry-only with respect to the physics: attaching
engines never changes the simulated thermal/power trajectory, so
simulation results stay reproducible with or without live engines.

Backends work unchanged inside a fleet: ``FleetSim.attach_backend(region,
server, backend)`` binds the engine to one region's cluster, and the
region's own reconfigure decisions keep landing on the engine's knobs
(the fleet layer only redirects demand).  If a fleet migration evicts the
bound server, the backend idles — ``pump`` receives zero load until the
server hosts SaaS again — rather than erroring; rebind to the VM's new
region to follow it across the WAN.
"""
from __future__ import annotations

import numpy as np

from repro.core.faults import fault_pick
from repro.core.profiles import ConfigPoint
from repro.serving.engine import Engine
from repro.serving.request import Request


class EngineBackend:
    """Binds a real ``Engine`` to a simulated SaaS server.

    ``variant_for_size`` maps profile model sizes ("70b"/"13b"/"7b") onto
    engine variant names registered via ``Engine.add_variant``; sizes
    without a mapping leave the variant untouched.  ``batch_for_knob``
    maps the profile's batch axis onto engine ``max_batch`` values
    (default: 1 -> 1, 16 -> half the lanes, 64 -> all lanes).
    """

    #: set on FleetBackend instances; the simulator flushes each distinct
    #: fleet once per tick after every attached backend has pumped
    fleet = None

    def __init__(self, engine: Engine, *,
                 variant_for_size: dict | None = None,
                 batch_for_knob: dict | None = None,
                 requests_per_load: float = 3.0,
                 steps_per_tick: int = 4,
                 prompt_len: int = 6, max_new_tokens: int = 4,
                 seed: int = 0, draft_min_freq: float | None = None,
                 ladder=None, deadline_ms: float | None = None,
                 max_retries: int = 3,
                 shards_for_tp: dict | None = None):
        n = engine.n_slots
        self.engine = engine
        self.variant_for_size = variant_for_size or {}
        unknown = sorted(set(self.variant_for_size.values())
                         - set(engine.variants))
        if unknown:
            raise ValueError(
                f"variant_for_size names variants {unknown} not registered "
                f"on the engine (has {sorted(engine.variants)}); a typo "
                f"here would silently disable model swaps")
        self.batch_for_knob = batch_for_knob or {1: 1, 16: max(1, n // 2),
                                                 64: n}
        # parallelism as a reconfigure axis: map the profile's tp degree
        # onto engine shard counts (``Engine.set_shards``); unmapped tp
        # values leave the shard degree untouched, and a mapping the
        # engine rejects (``can_shard``) is counted, not crashed on
        self.shards_for_tp = shards_for_tp or {}
        self.shard_rejects = 0
        self.requests_per_load = requests_per_load
        self.steps_per_tick = steps_per_tick
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.rng = np.random.default_rng(seed)
        self._next_id = 0
        self._last_rate = 0.0
        self.applied: list[ConfigPoint] = []   # reconfigure decisions seen
        # speculation as a reconfigure axis: under a deep frequency cap
        # the drafter's extra passes stop paying for themselves, so the
        # control plane drops it (like quantization) and restores it when
        # the cap lifts.  None disables the rule.
        self.draft_min_freq = draft_min_freq
        self._stashed_draft: str | None = None
        self.draft_drops = 0
        # resilience: the fault lane (apply_faults) + degradation ladder
        # (tick_ladder), both driven by the simulator's reconfigure phase;
        # `issued` is the zero-silent-loss ledger — every request this
        # backend ever created, audited with faults.audit_requests after
        # a drained run
        self.seed = seed
        self.ladder = ladder          # core.faults.DegradationLadder | None
        self.deadline_ms = deadline_ms   # stamped onto pumped requests
        self.max_retries = max_retries
        self.issued: list[Request] = []
        self.dropped: list[Request] = []   # lost to drop-mode crashes
        self._fault_down = False      # inside a crash window right now
        self._fault_stashed_draft: str | None = None

    # -- control-plane side ------------------------------------------------
    def apply_config(self, cfg: ConfigPoint, *, paused: bool = False) -> None:
        """Translate a configurator decision into engine knob turns."""
        knobs = self.engine.knobs
        knobs.freq_scale = float(cfg.freq)
        knobs.max_batch = int(self.batch_for_knob.get(
            cfg.batch, self.engine.n_slots))
        knobs.paused = bool(paused)
        variant = self.variant_for_size.get(cfg.size)
        if variant is not None and variant != knobs.variant:
            self.engine.set_variant(variant)
        shards = self.shards_for_tp.get(cfg.tp)
        if shards is not None and shards != self.engine.shards:
            if self.engine.can_shard(shards) is None:
                self.engine.set_shards(shards)
            else:
                self.shard_rejects += 1
        if self.draft_min_freq is not None:
            if cfg.freq < self.draft_min_freq:
                if self.engine.draft_name is not None:
                    self._stashed_draft = self.engine.draft_name
                    self.engine.set_drafter(None)
                    self.draft_drops += 1
            elif self._stashed_draft is not None \
                    and self.engine.draft_name is None:
                self.engine.set_drafter(self._stashed_draft)
                self._stashed_draft = None
        self.applied.append(cfg)

    # -- workload side -----------------------------------------------------
    def pump(self, *, now: float, load: float) -> int:
        """Feed demand proportional to the routed ``load`` (nominal-VM
        units) and run scheduler steps; returns decode tokens produced.

        ``now`` is the simulator clock in hours; the engine clock runs in
        simulated seconds (``now * 3600``) so per-request ``deadline_ms``
        has its natural unit.  Nothing consumes the absolute timestamps
        except deadline eviction, and ``measured_goodput`` stays
        wall-clock based, so the conversion is behavior-neutral for
        engines without deadlines.

        Also measures this tick's decode rate (tokens per wall-second of
        engine stepping, with the simulated frequency knob already folded
        into the step times) so ``measured_goodput`` reflects the engine's
        *current* capacity, not a lifetime average."""
        now_s = now * 3600.0
        vocab = self.engine.model.cfg.vocab_size
        for _ in range(int(round(load * self.requests_per_load))):
            # fresh construction, not a copy of an existing Request — the
            # backend attrs just share the field names
            req = Request(  # tapaslint: disable=TL004
                prompt=[int(t) for t in self.rng.integers(
                    0, vocab, self.prompt_len)],
                max_new_tokens=self.max_new_tokens,
                customer=f"bk{self._next_id % 4}", arrival_s=now_s,
                deadline_ms=self.deadline_ms,
                max_retries=self.max_retries)
            self.issued.append(req)
            self.engine.submit(req)
            self._next_id += 1
        wall_before = self.engine.stats.step_time_total
        produced = 0
        for _ in range(self.steps_per_tick):
            if self.engine.offline:
                break   # crashed: nothing steps until restore()
            if self.engine.knobs.paused and not self.engine.active:
                break   # drained during a reload pause
            produced += self.engine.step(now=now_s)
        wall = self.engine.stats.step_time_total - wall_before
        # no steps ran (paused-and-drained, crashed, or idle) => the
        # instance is serving nothing right now; report that, not the
        # last busy rate
        self._last_rate = produced / wall if wall > 0.0 else 0.0
        return produced

    def measured_goodput(self) -> float:
        """Decode tokens per wall-second over the most recent ``pump``
        window — responds immediately to knob turns (batch/variant change
        tokens-per-step, ``freq_scale`` stretches the step times)."""
        return self._last_rate

    # -- resilience side ---------------------------------------------------
    def apply_faults(self, faults: list, *, now_h: float, tick: int,
                     knobs) -> None:
        """Land this tick's active ``EngineFault`` windows on the engine.

        Crash windows are edge-triggered (one crash() per window, one
        restore() when it closes); stuck-slow and drafter failures are
        level-triggered; KV corruption picks one active request per tick
        via ``fault_pick`` so the injection timeline is a pure function
        of (seed, kind, tick) — replay-stable.  ``knobs`` is the run's
        ``ResilienceKnobs``: with recovery off, crashes drop work instead
        of re-queueing it and corruption goes unguarded."""
        eng = self.engine
        kinds = {f.kind for f in faults}
        now_s = now_h * 3600.0
        if "crash" in kinds and not self._fault_down:
            self._fault_down = True
            self.dropped.extend(
                eng.crash(now_s, drop=not knobs.requeue_on_crash))
        elif "crash" not in kinds and self._fault_down:
            self._fault_down = False
            eng.restore()
        slow = [f.slow_factor for f in faults if f.kind == "stuck_slow"]
        eng.slow_factor = max(slow) if slow else 1.0
        if "draft_fail" in kinds:
            if eng.draft_name is not None:
                self._fault_stashed_draft = eng.draft_name
                eng.set_drafter(None)
        elif self._fault_stashed_draft is not None \
                and eng.draft_name is None:
            eng.set_drafter(self._fault_stashed_draft)
            self._fault_stashed_draft = None
        for kind in ("nan_burst", "kv_corrupt"):
            if kind in kinds and eng.active and not eng.offline:
                rids = sorted(eng.active)
                rid = rids[fault_pick(len(rids), kind, tick, self.seed)]
                eng.inject_kv_corruption(rid,
                                         last_block=(kind == "nan_burst"),
                                         arm_guard=knobs.nan_guard)

    def tick_ladder(self, emergency: bool) -> None:
        """Walk the attached degradation ladder one rung (down under an
        emergency, up after a calm stretch); no-op without a ladder or
        while the engine is down."""
        if self.ladder is not None and not self.engine.offline:
            self.ladder.tick(self, emergency)

    def heartbeat(self) -> bool:
        """Liveness probe for the simulator's watchdog."""
        return self.engine.heartbeat()

    def adopt(self, reqs: list) -> None:
        """Accept requests drained off an unhealthy sibling (watchdog
        re-homing).  They keep their identity — the origin backend's
        ``issued`` ledger still audits them."""
        for req in reqs:
            self.engine.submit(req)

    def drain(self, *, now_h: float, max_steps: int = 200) -> int:
        """Run the engine dry after the sim's last tick, advancing the
        clock one simulated second per step so backoff-delayed retries
        release (and overdue deadlines expire).  A backend still inside
        a crash window is restored first — the run is over; what matters
        is that no re-queued request is left in limbo."""
        eng = self.engine
        if eng.offline:
            eng.restore()
        now_s = now_h * 3600.0
        produced = 0
        for _ in range(max_steps):
            if not (eng.queue or eng.active or eng.prefilling
                    or eng._delayed):
                break
            produced += eng.step(now=now_s)
            now_s += 1.0
        return produced


# ---------------------------------------------------------------------------
# fleet of engines: many simulated servers, few real engines
# ---------------------------------------------------------------------------

class EngineFleet:
    """A small pool of real engines backing 100+ simulated SaaS servers.

    All engines are built from ONE ``EngineSpec`` and share one copy of
    the model params (``EngineSpec.build(share=first)`` aliases the
    immutable jax arrays), so the weight footprint is per *fleet*, not
    per simulated server.  Simulated servers attach via
    ``make_backend()``, which round-robins them across the engines.

    The pump is batched: each simulator tick every ``FleetBackend`` only
    *submits* its server's demand (``pump``), and one ``flush()`` per
    fleet then runs each engine's scheduler steps once for all of its
    servers together — one process backs a whole region's SaaS tier on
    measured goodput instead of stepping one engine per server.
    """

    def __init__(self, spec, *, n_engines: int = 2, steps_per_tick: int = 4,
                 backend_kw: dict | None = None, share=None):
        if n_engines < 1:
            raise ValueError(f"n_engines must be >= 1, got {n_engines}")
        self.spec = spec
        first = spec.build(share=share) if share is not None else spec.build()
        self.engines = [first] + [spec.build(share=first)
                                  for _ in range(n_engines - 1)]
        self.steps_per_tick = steps_per_tick
        self.backend_kw = dict(backend_kw or {})
        self.backends: list[FleetBackend] = []
        self.flushes = 0

    def make_backend(self, **kw) -> "FleetBackend":
        """A backend for one more simulated server, assigned round-robin
        to the fleet's engines."""
        i = len(self.backends)
        merged = {**self.backend_kw, **kw}
        merged.setdefault("seed", i)
        bk = FleetBackend(self.engines[i % len(self.engines)],
                          fleet=self, index=i, **merged)
        self.backends.append(bk)
        return bk

    def flush(self, *, now: float) -> int:
        """Run each engine's scheduler steps for this tick and settle the
        per-server measured rates.  The simulator calls this once per
        distinct fleet after every attached backend pumped."""
        self.flushes += 1
        now_s = now * 3600.0
        produced_total = 0
        for eng in self.engines:
            wall_before = eng.stats.step_time_total
            produced = 0
            for _ in range(self.steps_per_tick):
                if eng.offline:
                    break   # crashed: nothing steps until restore()
                if eng.knobs.paused and not eng.active:
                    break   # drained during a reload pause
                produced += eng.step(now=now_s)
            wall = eng.stats.step_time_total - wall_before
            produced_total += produced
            for bk in self.backends:
                if bk.engine is eng:
                    bk._settle(wall)
        return produced_total

    def drain(self, *, now_h: float, max_steps: int = 200) -> int:
        """Run every engine dry after the last tick (one backend per
        engine drives the shared drain)."""
        produced = 0
        seen = set()
        for bk in self.backends:
            if id(bk.engine) not in seen:
                seen.add(id(bk.engine))
                produced += EngineBackend.drain(bk, now_h=now_h,
                                                max_steps=max_steps)
        return produced


class FleetBackend(EngineBackend):
    """An ``EngineBackend`` whose engine is shared with other simulated
    servers through an ``EngineFleet``.

    ``pump`` only submits this server's demand (requests tagged with the
    server's fleet index); the engine steps run once per tick for all
    servers in ``EngineFleet.flush``, which settles each server's
    measured goodput from its own requests' output-token delta over the
    engine's step wall-clock."""

    def __init__(self, engine: Engine, *, fleet: EngineFleet, index: int,
                 **kw):
        super().__init__(engine, **kw)
        self.fleet = fleet
        self.index = index
        self._out_cursor = 0      # output tokens already credited

    def pump(self, *, now: float, load: float) -> int:
        now_s = now * 3600.0
        vocab = self.engine.model.cfg.vocab_size
        for _ in range(int(round(load * self.requests_per_load))):
            # fresh construction, not a copy of an existing Request — the
            # backend attrs just share the field names
            req = Request(  # tapaslint: disable=TL004
                prompt=[int(t) for t in self.rng.integers(
                    0, vocab, self.prompt_len)],
                max_new_tokens=self.max_new_tokens,
                customer=f"srv{self.index}", arrival_s=now_s,
                deadline_ms=self.deadline_ms,
                max_retries=self.max_retries)
            self.issued.append(req)
            self.engine.submit(req)
            self._next_id += 1
        return 0    # tokens are produced (and counted) at flush time

    def _settle(self, wall: float) -> None:
        """Credit this tick's output-token delta against the engine's
        step wall-clock for the tick (shared across the engine's
        servers)."""
        total = sum(len(r.output) for r in self.issued)
        produced = total - self._out_cursor
        self._out_cursor = total
        self._last_rate = produced / wall if wall > 0.0 else 0.0

    def drain(self, *, now_h: float, max_steps: int = 200) -> int:
        produced = super().drain(now_h=now_h, max_steps=max_steps)
        # fold the drained tokens into this server's cursor so a later
        # audit of `issued` matches what was credited
        self._out_cursor = sum(len(r.output) for r in self.issued)
        return produced
