"""Inference request / response records."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

_ids = itertools.count()


@dataclass
class Request:
    prompt: list                 # token ids
    max_new_tokens: int = 32
    customer: str = "anon"       # KV-cache affinity key (paper §4.5 LB rule 1)
    arrival_s: float = 0.0
    req_id: int = field(default_factory=lambda: next(_ids))
    eos_id: int | None = None
    # sampling knobs: temperature <= 0 means exact greedy (argmax); top_k
    # <= 0 disables top-k truncation; seed None derives a deterministic
    # per-request seed from the engine seed + req_id (crc32 idiom)
    temperature: float = 0.0
    top_k: int = 0
    seed: int | None = None

    # filled during serving
    first_token_s: float | None = None
    finish_s: float | None = None
    output: list = field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.finish_s is not None

    def ttft(self) -> float | None:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    def tbt(self) -> float | None:
        """Mean time between output tokens."""
        if self.finish_s is None or len(self.output) < 2:
            return None
        return (self.finish_s - self.first_token_s) / (len(self.output) - 1)
