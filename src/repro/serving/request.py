"""Inference request / response records."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

_ids = itertools.count()

#: terminal outcomes — mutually exclusive and exhaustive (see
#: ``core.faults.REQUEST_OUTCOMES`` / ``audit_requests``): a drained run
#: must leave every submitted request with exactly one of these.
OUTCOMES = ("accepted", "timed_out", "rejected")


@dataclass
class Request:
    prompt: list                 # token ids
    max_new_tokens: int = 32
    customer: str = "anon"       # KV-cache affinity key (paper §4.5 LB rule 1)
    arrival_s: float = 0.0
    req_id: int = field(default_factory=lambda: next(_ids))
    eos_id: int | None = None
    # sampling knobs: temperature <= 0 means exact greedy (argmax); top_k
    # <= 0 disables top-k truncation; seed None derives a deterministic
    # per-request seed from the engine seed + req_id (crc32 idiom)
    temperature: float = 0.0
    top_k: int = 0
    seed: int | None = None
    # resilience knobs: deadline_ms is relative to arrival_s (None == no
    # deadline); max_retries bounds quarantine/crash re-queues before the
    # request is rejected as retry-exhausted.
    deadline_ms: float | None = None
    max_retries: int = 3

    # filled during serving
    first_token_s: float | None = None
    finish_s: float | None = None
    output: list = field(default_factory=list)
    retries: int = 0
    outcome: str | None = None   # one of OUTCOMES once terminal

    def __post_init__(self):
        if self.deadline_ms is not None and self.deadline_ms <= 0.0:
            raise ValueError(
                f"deadline_ms must be None or > 0, got {self.deadline_ms}")
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")

    @property
    def done(self) -> bool:
        return self.finish_s is not None

    @property
    def deadline_s(self) -> float | None:
        """Absolute expiry time on the engine clock (None == never)."""
        if self.deadline_ms is None:
            return None
        return self.arrival_s + self.deadline_ms / 1000.0

    def finish(self, now: float, outcome: str) -> None:
        """Mark terminal exactly once; double-finish is a serving bug."""
        if outcome not in OUTCOMES:
            raise ValueError(
                f"unknown outcome {outcome!r}; expected one of {OUTCOMES}")
        if self.outcome is not None:
            raise RuntimeError(
                f"request {self.req_id} finished twice: "
                f"{self.outcome!r} then {outcome!r}")
        self.finish_s = now
        self.outcome = outcome

    def ttft(self) -> float | None:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    def tbt(self) -> float | None:
        """Mean time between output tokens."""
        if self.finish_s is None or len(self.output) < 2:
            return None
        return (self.finish_s - self.first_token_s) / (len(self.output) - 1)
