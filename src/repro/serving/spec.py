"""One serving construction API.

``EngineSpec`` is the single way to build a serving stack: it turns an
``ArchConfig`` plus a shard count into (mesh, sharded params, paged pool,
``Engine``) in one call, replacing the hand-wired
``build_model``/``init``/``Engine(...)`` chains previously duplicated
across ``examples/``, ``benchmarks/bench_engine.py`` and the backends.

``serving_plan`` is the single mesh entrypoint for serving:
``launch.mesh.make_mesh_for`` + ``models.sharding.mesh_plan`` at
``shards > 1`` (a ``(1, shards)`` ("data", "model") mesh over the first
``shards`` local devices), ``local_plan`` at ``shards = 1`` — so the
shard-count knob is one integer and shard=1 builds byte-identical graphs
to the pre-sharding engine.

``build(share=other_engine)`` aliases another engine's (model, params)
registries instead of re-initialising them — jax arrays are immutable, so
a fleet of engines holds ONE copy of the weights (see
``serving.backend.EngineFleet``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.mesh import make_mesh_for
from repro.models.sharding import ShardPlan, local_plan, mesh_plan
from repro.models.transformer import Model
from repro.serving.engine import Engine, EngineKnobs, shard_compat


def serving_plan(shards: int = 1, **kw) -> ShardPlan:
    """THE serving mesh entrypoint: one integer picks the parallelism.

    ``shards <= 1`` returns a single-device ``local_plan``; otherwise a
    ``(1, shards)`` ("data", "model") mesh over the first ``shards``
    local devices, so the whole decode batch stays on every rank and only
    the paged pool (and TP params) shard."""
    kw.setdefault("param_dtype", jnp.bfloat16)
    if shards <= 1:
        return local_plan(**kw)
    if jax.device_count() < shards:
        raise ValueError(
            f"serving_plan(shards={shards}): only {jax.device_count()} "
            f"devices visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={shards} before "
            f"importing jax, or run on a {shards}-chip slice")
    mesh = make_mesh_for(shards, want_model=shards)
    if mesh.shape["model"] != shards:
        raise ValueError(f"make_mesh_for could not build a model={shards} "
                         f"mesh (got {dict(mesh.shape)})")
    return mesh_plan(mesh, **kw)


@dataclass(frozen=True)
class EngineSpec:
    """Declarative description of one serving engine.

    ``variants`` / ``drafters`` are ``(name, ArchConfig)`` pairs; their
    models are built under the same plan and registered on the engine
    (params keyed off ``seed`` so repeated builds are deterministic).
    """
    cfg: ArchConfig
    shards: int = 1
    max_seq: int = 512
    n_slots: int = 8
    max_batch: int | None = None        # default: n_slots
    block_size: int = 16
    n_blocks: int | None = None
    horizon: int = 1
    prefill_chunk: int | None = None
    prefix_share: bool = False
    spec_k: int = 4
    draft: str | None = None            # None | "ngram" | a drafters name
    ngram: int = 2
    seed: int = 0
    param_dtype: Any = jnp.bfloat16
    variants: tuple = ()                # ((name, ArchConfig), ...)
    drafters: tuple = ()                # ((name, ArchConfig), ...)

    def replace(self, **kw) -> "EngineSpec":
        return dataclasses.replace(self, **kw)

    def plan(self) -> ShardPlan:
        return serving_plan(self.shards, param_dtype=self.param_dtype)

    def validate(self) -> None:
        for name, cfg in (("full", self.cfg), *self.variants, *self.drafters):
            err = shard_compat(self.shards, cfg)
            if err is not None:
                raise ValueError(f"EngineSpec ({name!r}): {err}")

    def _materialize(self, cfg: ArchConfig, plan: ShardPlan, seed: int):
        model = Model(cfg, plan)
        params = model.init(jax.random.PRNGKey(seed))
        if plan.mesh is not None:
            params = jax.device_put(params, model.param_shardings())
        return model, params

    def build(self, *, share: Engine | None = None) -> Engine:
        """Build (mesh, sharded params, pool, Engine) in one call.

        ``share=`` aliases an existing engine's model/param registries
        (it must come from a spec with the same cfg/shards/variants) so N
        engines hold one copy of the weights; each engine still gets its
        own pool and jit bindings."""
        self.validate()
        plan = self.plan()
        if share is not None:
            model, params = share.variants["full"]
        else:
            model, params = self._materialize(self.cfg, plan, self.seed)
        eng = Engine(
            model, params, max_seq=self.max_seq, n_slots=self.n_slots,
            knobs=EngineKnobs(max_batch=self.max_batch or self.n_slots),
            paged=True, block_size=self.block_size, n_blocks=self.n_blocks,
            horizon=self.horizon, prefill_chunk=self.prefill_chunk,
            prefix_share=self.prefix_share, spec_k=self.spec_k,
            draft=self.draft if self.draft in (None, "ngram") else None,
            ngram=self.ngram, seed=self.seed)
        if share is not None:
            for name, (m, p) in share.variants.items():
                if name != "full":
                    eng.add_variant(name, m, p)
            for name, (m, p) in share.drafters.items():
                eng.add_drafter(name, m, p)
        else:
            for i, (name, vcfg) in enumerate(self.variants):
                eng.add_variant(name,
                                *self._materialize(vcfg, plan,
                                                   self.seed + 10 + i))
            for i, (name, dcfg) in enumerate(self.drafters):
                eng.add_drafter(name,
                                *self._materialize(dcfg, plan,
                                                   self.seed + 100 + i))
        if self.draft is not None and self.draft != "ngram":
            eng.set_drafter(self.draft)
        eng.spec = self
        return eng
