"""LLM serving engine: continuous batching over a paged (or slot) KV cache.

One Engine == one SaaS "VM instance" in TAPAS terms.  It exposes the knobs
the Instance Configurator turns (paper Table 1): max batch size, frequency
cap (simulated via a step-time multiplier), model variant (size /
quantization — swap params), and reports goodput (tokens/s within TTFT/TBT
SLOs, SLO = 5x unloaded latency, paper §3.3).

Serving modes:

* ``paged`` (default for plain-GQA models) — KV lives in a global block
  pool (``PagedCachePool``); admission runs *bucketed batched prefill*
  (prompts padded to power-of-two length buckets, one jitted prefill per
  bucket shape instead of one trace per request) and decode walks
  per-request block tables.  When the pool runs out of blocks mid-decode
  the youngest request is preempted and recomputed later (vLLM-style).
* ``slots`` — the legacy contiguous-slot pool, kept for cache families the
  block pool cannot hold (MLA latent, SWA ring, recurrent state) and as
  the ground truth the paged path is tested against.

The paged decode hot path is device-resident end to end:

* **Horizon decode** (``horizon=N``) — greedy sampling, KV append,
  position advance and finished-flag computation are fused into one
  jitted ``lax.scan`` loop (``Model.decode_multi_paged``); the engine
  runs up to N decode steps per host sync and only reads the drained
  ``(tokens, emitted)`` horizon back.  Block-table / position /
  last-token buffers persist on device between launches
  (``PagedCachePool`` mirrors) instead of being re-uploaded every step.
* **Chunked prefill** (``prefill_chunk=C``) — long prompts are split into
  C-token chunks processed one per scheduler step and interleaved with
  decode, so a long prefill never blocks decode TBT for more than one
  chunk (Sarathi-style).
* **Prefix sharing** (``prefix_share=True``) — admission looks the
  prompt's full blocks up in the pool's content-hash index and reuses
  refcounted blocks written by earlier requests (shared system prompts
  are neither recomputed nor double-stored).
"""
from __future__ import annotations

import functools
import heapq
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.drafters import check_draft_pair
from repro.core.traces import _stable_seed
from repro.models.transformer import (SALT_SAMPLE, Model, event_keys,
                                      lane_keys, sample_from_dist,
                                      sampling_dist)
from repro.serving.kvcache import CachePool, PagedCachePool
from repro.serving.request import Request

STEP_WINDOW = 512       # recent step times retained for inspection


@dataclass
class EngineKnobs:
    """The TAPAS-configurable instance settings."""
    max_batch: int = 8
    freq_scale: float = 1.0      # 1.0 = nominal clock; <1 slows step time
    variant: str = "full"        # model-size / quantization variant key
    paused: bool = False         # drained during reconfiguration (§4.3)


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_batches: int = 0     # jitted prefill launches (not requests)
    preemptions: int = 0         # requests requeued for recompute (pool ran
    #                              dry, or displaced by a variant reload)
    variant_swaps: int = 0       # set_variant reloads (may preempt actives)
    shard_swaps: int = 0         # set_shards reconfigures (preempt actives)
    rejected: int = 0            # finished "rejected": contexts that can
    #                              never fit max_seq, or retry-exhausted
    host_syncs: int = 0          # device->host readbacks on the serving path
    decode_syncs: int = 0        # the subset issued by decode launches
    # speculative decode accounting: one verify pass emits a whole
    # accepted run, so tokens-per-pass (and tokens-per-sync) is the
    # speedup speculation buys, not the old one-pass-per-token identity
    draft_tokens: int = 0        # drafts proposed across verify passes
    accepted_tokens: int = 0     # drafts accepted (excludes bonus tokens)
    verify_passes: int = 0       # target verify passes (lane-rounds) run
    # resilience accounting: each counter tracks one recovery mechanism;
    # terminal outcomes live on the Request (mutually exclusive), these
    # count *events*, so retried can exceed the number of requests
    submitted: int = 0           # requests ever handed to submit()
    timed_out: int = 0           # requests evicted past their deadline_ms
    retried: int = 0             # re-queues via the bounded-retry path
    #                              (quarantine / crash recompute) — NOT
    #                              pool-exhaustion preemptions
    retry_exhausted: int = 0     # retry budget burned -> finished rejected
    quarantined: int = 0         # lanes pulled by the NaN/Inf KV guard
    guard_scans: int = 0         # pre-decode corruption scans launched
    crashes: int = 0             # crash() invocations survived
    n_steps: int = 0             # recorded (working) scheduler steps
    step_time_total: float = 0.0  # running sum of freq-scaled step times
    completed: list = field(default_factory=list)
    # recent window only — long-lived engines must not grow without bound
    step_times: deque = field(
        default_factory=lambda: deque(maxlen=STEP_WINDOW))
    _good_acc: dict = field(default_factory=dict, repr=False)

    def record_step(self, dt: float) -> None:
        self.n_steps += 1
        self.step_time_total += dt
        self.step_times.append(dt)

    def goodput(self, *, ttft_slo: float, tbt_slo: float) -> float:
        """Tokens/s over completed requests meeting both SLOs.

        Only requests that finished ``accepted`` count: a request that
        produced tokens, was preempted, and later timed out (or burned
        its retry budget) must not credit those tokens as served — the
        stats-drift bug class the terminal-outcome invariant pins.

        Incremental: each completed request is folded into the per-SLO
        accumulator exactly once, so repeated calls on a long-lived engine
        do not rescan the whole history.
        """
        key = (ttft_slo, tbt_slo)
        idx, good, t_max = self._good_acc.get(key, (0, 0, 1e-9))
        for r in self.completed[idx:]:
            t_max = max(t_max, r.finish_s or 0.0)
            if (r.outcome == "accepted" and (r.ttft() or 0) <= ttft_slo
                    and (r.tbt() or 0) <= tbt_slo):
                good += len(r.output)
        self._good_acc[key] = (len(self.completed), good, t_max)
        return good / t_max

    @property
    def accepted_per_sync(self) -> float:
        """Accepted draft tokens per decode sync — the free tokens each
        host round-trip carried on top of the one-per-pass baseline."""
        return self.accepted_tokens / max(self.decode_syncs, 1)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens the target accepted."""
        return self.accepted_tokens / max(self.draft_tokens, 1)


def shard_compat(shards: int, cfg) -> str | None:
    """Why ``cfg`` cannot serve at ``shards``-way model parallelism, or
    None when it can.

    Sharded serving requires *identity pads* — head / kv-head / vocab /
    ffn counts that divide the shard degree — so params transfer verbatim
    between plans on ``set_shards`` and the KV pool stays at the real
    head count on every rank."""
    if shards <= 1:
        return None
    if cfg.n_kv_heads and cfg.n_kv_heads % shards:
        return (f"{cfg.name}: n_kv_heads={cfg.n_kv_heads} is not divisible "
                f"by shard degree {shards}")
    if cfg.n_heads % shards:
        return (f"{cfg.name}: n_heads={cfg.n_heads} is not divisible by "
                f"shard degree {shards}")
    if cfg.vocab_size % shards:
        return (f"{cfg.name}: vocab_size={cfg.vocab_size} is not divisible "
                f"by shard degree {shards}")
    if cfg.d_ff % shards:
        return (f"{cfg.name}: d_ff={cfg.d_ff} is not divisible by "
                f"shard degree {shards}")
    return None


def _bucket(n: int, lo: int = 16, hi: int | None = None) -> int:
    """Power-of-two prompt-length bucket (bounds distinct prefill shapes).

    ``hi`` clamps the bucket to the cache capacity — a context one past a
    power of two must not round up to a shape that can never be inserted.
    Callers must reject contexts longer than ``hi`` beforehand.
    """
    b = lo
    while b < n:
        b *= 2
    if hi is not None:
        b = min(b, hi)
    return b


class Engine:
    def __init__(self, model: Model, params: Any, *, max_seq: int = 512,
                 n_slots: int = 8, knobs: EngineKnobs | None = None,
                 paged: bool | None = None, block_size: int = 16,
                 n_blocks: int | None = None, horizon: int = 1,
                 prefill_chunk: int | None = None,
                 prefix_share: bool = False, spec_k: int = 4,
                 draft: str | None = None, ngram: int = 2, seed: int = 0):
        self.model = model
        self.variants: dict[str, tuple[Model, Any]] = {"full": (model, params)}
        self.knobs = knobs or EngineKnobs(max_batch=n_slots)
        self.max_seq = max_seq
        self.n_slots = n_slots
        self.block_size = block_size
        self.n_blocks = n_blocks
        self.paged = model.supports_paged if paged is None else paged
        if horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {horizon}")
        self.horizon = horizon
        # prefix sharing rides on the chunked (in-pool) prefill path: a
        # shared head must be skipped, so the suffix is prefilled against
        # the pool; default to one whole-prompt-sized chunk when unset
        if prefix_share and prefill_chunk is None:
            prefill_chunk = max_seq
        self.prefill_chunk = prefill_chunk
        self.prefix_share = prefix_share
        if (prefill_chunk or prefix_share) and not self.paged:
            raise ValueError("chunked prefill / prefix sharing require the "
                             "paged serving mode")
        # speculative decode: ``draft`` picks the proposer ("ngram" =
        # prompt-lookup, or a model drafter registered via add_drafter);
        # spec_k drafts are verified per target pass.  draft=None keeps
        # the plain fused-horizon decode path, graph-for-graph.
        if draft is not None and not self.paged:
            raise ValueError("speculative decoding requires the paged "
                             "serving mode")
        if draft is not None and spec_k < 1:
            raise ValueError(f"spec_k must be >= 1 with a drafter, "
                             f"got {spec_k}")
        if draft is not None and draft != "ngram":
            raise ValueError("model drafters are registered via "
                             "add_drafter()/set_drafter(); the constructor "
                             "only accepts draft='ngram' or None")
        self.spec_k = spec_k
        self.draft_name = draft
        self.ngram = ngram
        self.seed = seed
        self.drafters: dict[str, tuple[Model, Any]] = {}
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}
        self.prefilling: dict[int, Request] = {}
        self._prefill_pos: dict[int, int] = {}
        self._pending_waiter: int | None = None   # req deferred on a
        #                                           pending shared prefill
        # resilience state — all of it inert on the no-fault path: the
        # backoff heap stays empty, deadline eviction is gated on
        # _has_deadlines, and the NaN guard scan only runs while armed,
        # so fault-free streams and host_syncs are byte-identical to a
        # pre-hardening engine
        self.offline = False          # crash()ed and not yet restore()d
        self.slow_factor = 1.0        # stuck-slow fault: step-time stretch
        self.retry_backoff_s = 0.05   # base of the exponential re-queue
        #                               backoff (doubles per retry)
        self._guard_armed = False     # scan KV for NaN/Inf before decode
        self._delayed: list = []      # (not_before_s, seq, req) heap
        self._delay_seq = 0
        self._has_deadlines = False
        self.stats = EngineStats()
        self._bind(model)

    def _make_pool(self) -> None:
        """(Re)create only the KV pool for the current model.  Crash
        recovery goes through here: a restart wipes cache state but keeps
        the jitted entry points — no retrace, just cold KV."""
        if self.paged:
            self.pool: Any = PagedCachePool(
                self.model, self.n_slots, self.max_seq,
                block_size=self.block_size, n_blocks=self.n_blocks)
            if self._spec_on and self.draft_name != "ngram":
                d_model, _ = self.drafters[self.draft_name]
                self.pool.attach_draft(d_model)
        else:
            self.pool = CachePool(self.model, self.n_slots, self.max_seq)

    def _bind(self, model: Model) -> None:
        """(Re)build pool + jitted entry points for the current model."""
        self.model = model
        if self.paged and not model.supports_paged:
            raise ValueError(f"{model.cfg.name} cannot serve paged "
                             f"(attn_kind={model.cfg.attn_kind!r})")
        if self.paged:
            self.pool: Any = PagedCachePool(
                model, self.n_slots, self.max_seq,
                block_size=self.block_size, n_blocks=self.n_blocks)
            self._prefill_jit = jax.jit(model.prefill_ragged)
            self._decode_multi_jit = jax.jit(
                model.decode_multi_paged,
                static_argnames=("num_steps", "max_len"),
                donate_argnums=(1,))
            self._prefill_chunk_jit = jax.jit(model.prefill_chunk_paged,
                                              donate_argnums=(1,))
        else:
            self.pool = CachePool(model, self.n_slots, self.max_seq)
            self._prefill_jit = jax.jit(model.prefill)
            self._decode_jit = jax.jit(model.decode_step)
        self._bind_spec()

    # -- speculative decode (drafter lifecycle) ----------------------------
    @property
    def _spec_on(self) -> bool:
        return self.paged and self.spec_k > 0 and self.draft_name is not None

    def _bind_spec(self) -> None:
        """(Re)build the speculative entry points for the current target
        model and drafter choice."""
        self._decode_spec_jit = None
        self._d_params = None
        self._draft_prefill_jit = None
        self._draft_chunk_jit = None
        if not self._spec_on:
            if self.paged:
                self.pool.detach_draft()
            return
        if self.draft_name == "ngram":
            d_model = None
            self.pool.detach_draft()
        else:
            d_model, d_params = self.drafters[self.draft_name]
            check_draft_pair(self.model.cfg, d_model.cfg)
            self.pool.attach_draft(d_model)
            self._d_params = d_params
            self._draft_prefill_jit = jax.jit(d_model.prefill_ragged)
            self._draft_chunk_jit = jax.jit(d_model.prefill_chunk_paged,
                                            donate_argnums=(1,))
        self._decode_spec_jit = jax.jit(
            functools.partial(Model.decode_spec_paged, self.model, d_model),
            static_argnames=("num_steps", "spec_k", "max_len", "ngram"),
            donate_argnums=(1, 3))

    def add_drafter(self, name: str, model: Model, params: Any) -> None:
        """Register a small same-tokenizer model as a drafter choice
        (pairing is validated: shared vocab + paged-servable)."""
        check_draft_pair(self.model.cfg, model.cfg)
        self.drafters[name] = (model, params)

    def set_drafter(self, name: str | None) -> None:
        """Switch the speculation proposer mid-flight: None (off),
        "ngram" (prompt-lookup), or a registered model drafter.

        In-flight requests keep their target KV — speculation only
        changes how candidate tokens are PROPOSED, never what the target
        accepts, so no preemption is needed.  A freshly attached model
        drafter starts with a cold draft cache; that costs acceptance
        rate until lanes turn over, not correctness.
        """
        if name == self.draft_name:
            return
        if name is not None and name != "ngram" and name not in self.drafters:
            raise KeyError(f"unknown drafter {name!r}")
        self.draft_name = name
        self._bind_spec()

    def _req_seed(self, req: Request) -> int:
        """Per-request deterministic sampling seed: the request's own, or
        a crc32 fold of (engine seed, req_id) — process-stable, so
        sampled replays reproduce across runs (the trace_seed idiom)."""
        if req.seed is not None:
            return int(req.seed) % (2 ** 31)
        return _stable_seed("request", self.seed, req.req_id) % (2 ** 31)

    def _next_from_prefill(self, logits, reqs: list, idx) -> np.ndarray:
        """Each row's first output token from its prefill logits: argmax,
        with sampled rows (temperature > 0) drawn from the warped
        distribution under the request's deterministic key.  Folding the
        token's absolute sequence index keeps resumes replay-stable; the
        all-greedy fast path is byte-identical to the old argmax."""
        v = self.model.cfg.vocab_size
        nxt = np.array(jnp.argmax(logits[:, :v], axis=-1))
        if not any(r.temperature > 0 for r in reqs):
            return nxt
        rows = len(reqs)
        temps = jnp.asarray([r.temperature for r in reqs], jnp.float32)
        tks = jnp.asarray([r.top_k for r in reqs], jnp.int32)
        seeds = jnp.asarray([self._req_seed(r) for r in reqs], jnp.int32)
        dist = sampling_dist(logits[:rows, :v], temps, tks)
        keys = event_keys(lane_keys(seeds),
                          jnp.asarray(idx[:rows], jnp.int32), SALT_SAMPLE)
        nxt[:rows] = np.asarray(sample_from_dist(keys, dist, temps <= 0.0))
        return nxt

    # -- variant management (model-size / quantization knob) --------------
    def add_variant(self, name: str, model: Model, params: Any) -> None:
        self.variants[name] = (model, params)

    def set_variant(self, name: str) -> None:
        """Reload a different model variant (costs a pause, paper §4.3).

        In-flight requests lose their KV state (the new variant's cache is
        a different shape) but are not dropped: they are preempted — blocks
        released, requeued at the front — and recomputed under the new
        variant, exactly like a pool-exhaustion preemption.  Setting the
        already-active variant is a no-op."""
        if name == self.knobs.variant:
            return
        model, params = self.variants[name]
        err = shard_compat(self.shards, model.cfg)
        if err is not None:
            # reject BEFORE preempting anything: an indivisible variant
            # must not cost in-flight work on its way to the ValueError
            raise ValueError(f"set_variant({name!r}) at shard degree "
                             f"{self.shards}: {err}")
        in_flight = set(self.active) | set(self.prefilling)
        if in_flight:
            # reverse-sorted so the front of the queue ends up in rid order
            self._preempt(sorted(in_flight, reverse=True))
        self.knobs.variant = name
        self.stats.variant_swaps += 1
        self._bind(model)

    def variant_compatible(self, name: str) -> bool:
        """Can ``set_variant(name)`` succeed at the current shard degree?
        (The DegradationLadder asks before walking its quantized rung.)"""
        if name not in self.variants:
            return False
        return shard_compat(self.shards, self.variants[name][0].cfg) is None

    @property
    def params(self):
        return self.variants[self.knobs.variant][1]

    # -- shard management (parallelism-degree knob) ------------------------
    @property
    def shards(self) -> int:
        """Current model-parallel degree of the serving plan."""
        plan = self.model.plan
        return plan.tp if plan.paged_pool_sharded(self.model.cfg) else 1

    def can_shard(self, n: int) -> str | None:
        """Why the engine cannot reconfigure to ``n``-way model
        parallelism (None = it can): paged mode, enough local devices,
        and every registered variant/drafter divides cleanly."""
        if n < 1:
            return f"shard degree must be >= 1, got {n}"
        if n == 1:
            return None
        if not self.paged:
            return "sharded serving requires the paged mode"
        if jax.device_count() < n:
            return f"need {n} devices, have {jax.device_count()}"
        for kind, reg in (("variant", self.variants),
                          ("drafter", self.drafters)):
            for name, (m, _) in reg.items():
                err = shard_compat(n, m.cfg)
                if err is not None:
                    return f"{kind} {name!r}: {err}"
        return None

    def set_shards(self, n: int) -> None:
        """Reconfigure the model-parallel degree (costs a pause, like a
        variant reload): preempt in-flight work, rebuild every registered
        model under the new plan, transfer params under the new
        shardings, and rebind.  Raises (without preempting) when
        ``can_shard`` objects."""
        if n == self.shards:
            return
        err = self.can_shard(n)
        if err is not None:
            raise ValueError(f"set_shards({n}): {err}")
        from repro.serving.spec import serving_plan  # local: import cycle
        plan = serving_plan(n, param_dtype=self.model.plan.param_dtype)
        in_flight = set(self.active) | set(self.prefilling)
        if in_flight:
            self._preempt(sorted(in_flight, reverse=True))

        def rebuild(m: Model, p):
            new_m = Model(m.cfg, plan)
            if plan.mesh is not None:
                new_p = jax.device_put(p, new_m.param_shardings())
            else:
                new_p = jax.device_put(p, jax.devices()[0])
            return new_m, new_p

        self.variants = {k: rebuild(m, p)
                         for k, (m, p) in self.variants.items()}
        self.drafters = {k: rebuild(m, p)
                         for k, (m, p) in self.drafters.items()}
        self.stats.shard_swaps += 1
        self._bind(self.variants[self.knobs.variant][0])

    # -- request lifecycle -------------------------------------------------
    def submit(self, req: Request) -> None:
        self.stats.submitted += 1
        if req.deadline_ms is not None:
            self._has_deadlines = True
        self.queue.append(req)

    @staticmethod
    def _context(req: Request) -> list:
        """Prefill context: prompt plus any tokens generated before a
        preemption (recompute-style resume)."""
        return list(req.prompt) + list(req.output)

    def _finish(self, req: Request, now: float, outcome: str) -> None:
        """The single terminal transition: stamp exactly one outcome
        (Request.finish raises on a double-finish), bump its counter,
        and append to the completed log.  Every serving path ends here,
        which is what makes the outcome audit exhaustive."""
        req.finish(now, outcome)
        if outcome == "timed_out":
            self.stats.timed_out += 1
        elif outcome == "rejected":
            self.stats.rejected += 1
        self.stats.completed.append(req)

    def _reject(self, req: Request, now: float) -> None:
        """A context that can never fit the cache (even after recompute
        growth) is finished empty instead of looping through admission."""
        self._finish(req, now, "rejected")

    def _activate(self, req: Request, tok: int, now: float) -> None:
        """Append the prefill token and either activate the request or, if
        it already hit its budget/eos (e.g. resumed right at the limit),
        finish it without occupying a decode lane."""
        req.output.append(tok)
        if req.first_token_s is None:
            req.first_token_s = now
        if self._spec_on:
            lane = self.pool.lane_of[req.req_id]
            self.pool.set_hist_token(lane, int(self.pool.lengths[lane]), tok)
        if (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id)):
            self._finish(req, now, "accepted")
            self.pool.release(req.req_id)
            return
        self.active[req.req_id] = req
        if self.paged:
            self.pool.set_last_token(self.pool.lane_of[req.req_id], tok)

    # -- resilience: deadlines, bounded retry, NaN quarantine, crash -------
    def _release_delayed(self, now: float) -> None:
        """Move backoff-delayed retries whose time has come back into the
        admission queue (no-op while the heap is empty)."""
        while self._delayed and self._delayed[0][0] <= now:
            _, _, req = heapq.heappop(self._delayed)
            self.queue.append(req)

    def _expire_deadlines(self, now: float) -> None:
        """Evict every request past its deadline — queued, backing off,
        prefilling, or active — so expired work never occupies a lane.
        Gated on _has_deadlines: engines that never saw a deadline_ms
        skip this entirely (no-fault parity)."""
        if not self._has_deadlines:
            return

        def expired(r: Request) -> bool:
            return r.deadline_s is not None and now >= r.deadline_s

        if any(expired(r) for r in self.queue):
            kept: deque[Request] = deque()
            for req in self.queue:
                if expired(req):
                    self._finish(req, now, "timed_out")
                else:
                    kept.append(req)
            self.queue = kept
        if any(expired(item[2]) for item in self._delayed):
            kept_d = []
            for item in self._delayed:
                if expired(item[2]):
                    self._finish(item[2], now, "timed_out")
                else:
                    kept_d.append(item)
            self._delayed = kept_d
            heapq.heapify(self._delayed)
        for rid in sorted((rid for rid, r in self.active.items()
                           if expired(r)), reverse=True):
            req = self.active.pop(rid)
            self.pool.release(rid)
            self._finish(req, now, "timed_out")
        for rid in sorted((rid for rid, r in self.prefilling.items()
                           if expired(r)), reverse=True):
            req = self.prefilling.pop(rid)
            del self._prefill_pos[rid]
            self.pool.release(rid)
            self._finish(req, now, "timed_out")

    def _requeue_for_retry(self, req: Request, now: float) -> None:
        """Bounded retry on the recompute path: re-queue with exponential
        backoff, or finish rejected once the budget is burned.  Distinct
        from _preempt — preemptions are scheduler churn (unlimited, no
        backoff), retries are fault recovery (bounded, backed off)."""
        if req.retries >= req.max_retries:
            self.stats.retry_exhausted += 1
            self._finish(req, now, "rejected")
            return
        req.retries += 1
        self.stats.retried += 1
        delay = self.retry_backoff_s * (2.0 ** (req.retries - 1))
        self._delay_seq += 1
        heapq.heappush(self._delayed, (now + delay, self._delay_seq, req))

    def _quarantine_scan(self, now: float) -> int:
        """Pre-decode NaN/Inf sweep over active lanes' KV blocks.  Only
        runs while armed (a fault injection just landed), so the no-fault
        path never pays the scan or its host sync.  Corrupted lanes are
        quarantined: blocks released, request re-queued for recompute via
        the bounded-retry path — the corrupted KV never feeds a decode
        launch, which is why recovered streams match fault-free ones."""
        if not (self._guard_armed and self.paged and self.active):
            return 0
        self._guard_armed = False
        mask = np.zeros(self.pool.n_lanes, bool)
        for rid in self.active:
            mask[self.pool.lane_of[rid]] = True
        bad = self.pool.bad_lanes(mask)
        self.stats.host_syncs += 1
        self.stats.guard_scans += 1
        bad_rids = sorted((rid for rid in self.active
                           if bad[self.pool.lane_of[rid]]), reverse=True)
        for rid in bad_rids:
            req = self.active.pop(rid)
            self.pool.scrub_lane(rid)     # never recycle poisoned blocks
            self.pool.release(rid)
            self.stats.quarantined += 1
            self._requeue_for_retry(req, now)
        return len(bad_rids)

    def inject_kv_corruption(self, rid: int, *, last_block: bool = False,
                             arm_guard: bool = True) -> None:
        """Fault hook: poison one of an active request's KV blocks with
        NaNs (oldest block by default — cold corruption; freshest with
        ``last_block`` — a NaN-logit burst) and arm the guard scan.
        ``arm_guard=False`` models an unguarded engine: the corruption
        stays and the next decode reads it."""
        if not self.paged:
            raise ValueError("KV corruption targets the paged pool")
        if rid not in self.active:
            raise KeyError(f"request {rid} is not active")
        lane = self.pool.lane_of[rid]
        n_written = max(1, int(self.pool.lengths[lane]))
        idx = (n_written - 1) // self.pool.block_size if last_block else 0
        self.pool.corrupt_lane(lane, block_idx=idx)
        if arm_guard:
            self._guard_armed = True

    def crash(self, now: float, *, drop: bool = False) -> list:
        """Simulate process death.  The engine goes offline (step() is a
        no-op until restore()) and all KV state is lost.  With
        ``drop=False`` unfinished work is re-queued for recompute after
        restart; with ``drop=True`` (recovery disabled) every unfinished
        request is returned un-finished — the silent loss the resilience
        audit exists to catch."""
        self.stats.crashes += 1
        self.offline = True
        dropped: list[Request] = []
        for rid in sorted(set(self.active) | set(self.prefilling),
                          reverse=True):
            req = self.active.pop(rid, None)
            if req is None:
                req = self.prefilling.pop(rid)
                del self._prefill_pos[rid]
            if drop:
                dropped.append(req)
            else:
                self.queue.appendleft(req)
        if drop:
            dropped.extend(self.queue)
            self.queue.clear()
            dropped.extend(item[2] for item in self._delayed)
            self._delayed.clear()
        self._pending_waiter = None
        self._prefill_pos.clear()
        self._make_pool()
        return dropped

    def restore(self) -> None:
        """Bring a crashed engine back online (its queue survives; KV was
        already wiped by crash())."""
        self.offline = False

    def heartbeat(self) -> bool:
        """Liveness probe the watchdog polls each tick."""
        return not self.offline

    def take_unfinished(self) -> list:
        """Strip every unfinished request off this engine (watchdog
        drain onto siblings): in-flight KV released, queued and
        backoff-delayed work unhooked.  Returns requests sorted by
        req_id so re-homing is deterministic."""
        out = list(self.queue)
        self.queue.clear()
        out.extend(item[2] for item in self._delayed)
        self._delayed.clear()
        for rid in sorted(set(self.active) | set(self.prefilling),
                          reverse=True):
            req = self.active.pop(rid, None)
            if req is None:
                req = self.prefilling.pop(rid)
                del self._prefill_pos[rid]
            self.pool.release(rid)
            out.append(req)
        self._pending_waiter = None
        return sorted(out, key=lambda r: r.req_id)

    def _admit(self, now: float) -> None:
        if self.paged:
            if self.prefill_chunk:
                self._admit_chunked(now)
            else:
                self._admit_paged(now)
            return
        while (self.queue and self.pool.has_free()
               and len(self.active) < self.knobs.max_batch
               and not self.knobs.paused):
            if len(self._context(self.queue[0])) > self.max_seq - 1:
                self._reject(self.queue.popleft(), now)
                continue
            req = self.queue.popleft()
            prompt = jnp.asarray([self._context(req)], jnp.int32)
            logits, cache = self._prefill_jit(self.params, prompt)
            self.stats.prefill_tokens += prompt.shape[1]
            self.stats.prefill_batches += 1
            tok = int(self._next_from_prefill(
                logits, [req], np.asarray([prompt.shape[1]]))[0])
            self.stats.host_syncs += 1
            self.pool.insert(req.req_id, cache, prompt.shape[1])
            self._activate(req, tok, now)

    def _admit_paged(self, now: float) -> None:
        """Batched admission: drain the queue into length buckets, one
        jitted prefill per bucket shape (not per request)."""
        batch: list[Request] = []
        # reserve lanes/blocks as the batch builds — can_admit alone would
        # double-count the free lists across requests admitted together
        lanes_left = len(self.pool.free_lanes)
        blocks_left = len(self.pool.free_blocks)
        while (self.queue and not self.knobs.paused
               and len(self.active) + len(batch) < self.knobs.max_batch
               and lanes_left > 0):
            ctx_len = len(self._context(self.queue[0]))
            if ctx_len > self.max_seq - 1:
                self._reject(self.queue.popleft(), now)
                continue
            # reserve the first decode append too (an extra block exactly
            # when the context ends on a block boundary)
            need = self.pool.blocks_for(ctx_len + 1)
            if blocks_left < need:
                break
            batch.append(self.queue.popleft())
            lanes_left -= 1
            blocks_left -= need
        if not batch:
            return
        groups: dict[int, list[Request]] = {}
        for req in batch:
            groups.setdefault(
                _bucket(len(self._context(req)), hi=self.max_seq),
                []).append(req)
        for s_bucket, reqs in sorted(groups.items()):
            rows = len(reqs)
            b_pad = _bucket(rows, lo=1)   # batch bucket bounds retraces too
            tokens = np.zeros((b_pad, s_bucket), np.int32)
            lengths = np.ones(b_pad, np.int32)
            for i, req in enumerate(reqs):
                ctx = self._context(req)
                tokens[i, : len(ctx)] = ctx
                lengths[i] = len(ctx)
            logits, cache = self._prefill_jit(
                self.params, jnp.asarray(tokens), jnp.asarray(lengths))
            nxt = self._next_from_prefill(logits, reqs, lengths)
            self.stats.prefill_batches += 1
            self.stats.host_syncs += 1
            d_cache = None
            if self._draft_prefill_jit is not None:
                # drafter KV for the same rows, scattered into the SAME
                # blocks (the draft pool shares this pool's block tables)
                _, d_cache = self._draft_prefill_jit(
                    self._d_params, jnp.asarray(tokens),
                    jnp.asarray(lengths))
            for i, req in enumerate(reqs):
                lane = self.pool.insert(req.req_id, cache, i,
                                        int(lengths[i]))
                if self._spec_on:
                    self.pool.set_hist(lane, self._context(req))
                if d_cache is not None:
                    self.pool.insert_draft(req.req_id, d_cache, i,
                                           int(lengths[i]))
                self.stats.prefill_tokens += int(lengths[i])
                self._activate(req, int(nxt[i]), now)

    def _admit_chunked(self, now: float) -> None:
        """Chunked-prefill admission: claim a lane plus every block the
        context needs (reusing prefix-shared blocks), then let
        ``_prefill_tick`` stream the prompt into the pool one chunk per
        scheduler step, interleaved with decode."""
        while (self.queue and not self.knobs.paused
               and len(self.active) + len(self.prefilling)
               < self.knobs.max_batch):
            ctx = self._context(self.queue[0])
            if len(ctx) > self.max_seq - 1:
                self._reject(self.queue.popleft(), now)
                continue
            shared = self.pool.shared_prefix(ctx) if self.prefix_share \
                else []
            if self.prefix_share and self.pool.pending_shared(
                    ctx, have=len(shared)):
                # an in-flight prefill owns this prompt's next shareable
                # block: wait at the queue head and attach to its copy
                # instead of writing a duplicate (pending claims are
                # released on preemption, so the wait cannot deadlock)
                if self.queue[0].req_id != self._pending_waiter:
                    self._pending_waiter = self.queue[0].req_id
                    self.pool.pending_share_waits += 1
                break
            lane = self.pool.admit_prefill(self.queue[0].req_id, len(ctx),
                                           shared)
            if lane is None:
                break
            req = self.queue.popleft()
            if self._spec_on:
                self.pool.set_hist(lane, ctx)
            self.prefilling[req.req_id] = req
            self._prefill_pos[req.req_id] = \
                len(shared) * self.pool.block_size
            if self.prefix_share:
                self.pool.register_pending(req.req_id, ctx)
        return

    def _prefill_tick(self, now: float) -> int:
        """Advance every in-progress prefill by one chunk (a single jitted
        launch over all prefilling rows, padded to shared buckets)."""
        if not self.prefilling:
            return 0
        reqs = sorted(self.prefilling.values(), key=lambda r: r.req_id)
        ctxs = [self._context(r) for r in reqs]
        takes = [min(self.prefill_chunk,
                     len(ctx) - self._prefill_pos[r.req_id])
                 for r, ctx in zip(reqs, ctxs)]
        c_pad = _bucket(max(takes), lo=min(16, self.prefill_chunk))
        b_pad = _bucket(len(reqs), lo=1)
        tokens = np.zeros((b_pad, c_pad), np.int32)
        starts = np.zeros(b_pad, np.int32)
        lens = np.zeros(b_pad, np.int32)
        tables = np.zeros((b_pad, self.pool.blocks_per_seq), np.int32)
        for i, (req, ctx, take) in enumerate(zip(reqs, ctxs, takes)):
            p = self._prefill_pos[req.req_id]
            tokens[i, :take] = ctx[p:p + take]
            starts[i] = p
            lens[i] = take
            tables[i] = self.pool.block_tables[self.pool.lane_of[req.req_id]]
        logits, self.pool.cache = self._prefill_chunk_jit(
            self.params, self.pool.cache, jnp.asarray(tokens),
            jnp.asarray(starts), jnp.asarray(lens), jnp.asarray(tables))
        if self._draft_chunk_jit is not None:
            # stream the same chunk through the drafter into its pool
            _, self.pool.draft_cache = self._draft_chunk_jit(
                self._d_params, self.pool.draft_cache, jnp.asarray(tokens),
                jnp.asarray(starts), jnp.asarray(lens), jnp.asarray(tables))
        self.stats.prefill_batches += 1
        done_rows = [i for i, (req, ctx, take) in
                     enumerate(zip(reqs, ctxs, takes))
                     if self._prefill_pos[req.req_id] + take == len(ctx)]
        nxt = None
        if done_rows:
            nxt = self._next_from_prefill(
                logits, reqs,
                np.asarray([self._prefill_pos[r.req_id] + t
                            for r, t in zip(reqs, takes)]))
            self.stats.host_syncs += 1
        worked = 0
        for i, (req, ctx, take) in enumerate(zip(reqs, ctxs, takes)):
            self._prefill_pos[req.req_id] += take
            self.stats.prefill_tokens += take
            worked += take
            if self._prefill_pos[req.req_id] == len(ctx):
                rid = req.req_id
                del self.prefilling[rid]
                del self._prefill_pos[rid]
                self.pool.set_length(self.pool.lane_of[rid], len(ctx))
                if self.prefix_share:
                    self.pool.register_prefix(rid, ctx)
                self._activate(req, int(nxt[i]), now)
        return worked

    def _preempt(self, req_ids: list) -> None:
        """Pool ran dry: drop these requests' blocks and requeue them at the
        front for recompute (prompt + generated-so-far become the context)."""
        for rid in req_ids:
            req = self.active.pop(rid, None)
            if req is None:
                req = self.prefilling.pop(rid)
                del self._prefill_pos[rid]
            self.pool.release(rid)
            self.queue.appendleft(req)
            self.stats.preemptions += 1

    def _decode_paged(self, now: float) -> int:
        """Fused horizon decode: one jitted launch runs up to ``horizon``
        steps for every active lane; the host syncs once to drain the
        produced ``(tokens, emitted)`` horizon."""
        budgets = {rid: req.max_new_tokens - len(req.output)
                   for rid, req in self.active.items()}
        # always launch `horizon` steps: the scan skips drained tail steps
        # on-device (lax.cond), so num_steps stays one static value and
        # the decode graph never retraces mid-run
        n_eff = self.horizon
        # allocate append blocks oldest-request-first; when the pool is
        # exhausted the youngest actives are the ones preempted
        victims = self.pool.ensure_append_blocks(
            sorted(self.active), horizon=n_eff, budgets=budgets)
        if victims:
            self._preempt(victims)
        if not self.active:
            return 0
        width = self.pool.n_lanes
        active_mask = np.zeros(width, bool)
        budget_arr = np.zeros(width, np.int32)
        eos_arr = np.full(width, -1, np.int32)
        sampled = any(r.temperature > 0 for r in self.active.values())
        temp_arr = np.zeros(width, np.float32)
        topk_arr = np.zeros(width, np.int32)
        seed_arr = np.zeros(width, np.int32)
        for rid, req in self.active.items():
            lane = self.pool.lane_of[rid]
            active_mask[lane] = True
            budget_arr[lane] = budgets[rid]
            if req.eos_id is not None:
                eos_arr[lane] = req.eos_id
            temp_arr[lane] = req.temperature
            topk_arr[lane] = req.top_k
            seed_arr[lane] = self._req_seed(req)
        # sampling arrays are only passed when some lane needs them, so
        # an all-greedy engine runs the identical pre-sampling graph
        extra = dict(temps=jnp.asarray(temp_arr),
                     top_ks=jnp.asarray(topk_arr),
                     seeds=jnp.asarray(seed_arr)) if sampled else {}
        toks, emitted, _, (tok_f, pos_f, _, _), self.pool.cache = \
            self._decode_multi_jit(
                self.params, self.pool.cache, self.pool.last_tokens_dev(),
                self.pool.positions(), self.pool.tables(),
                jnp.asarray(active_mask), jnp.asarray(budget_arr),
                jnp.asarray(eos_arr), num_steps=n_eff, max_len=self.max_seq,
                **extra)
        toks_h = np.asarray(toks)        # the horizon's single host sync
        em_h = np.asarray(emitted)
        self.stats.host_syncs += 1
        self.stats.decode_syncs += 1
        # the loop's final device state becomes the pool mirror — nothing
        # is re-uploaded next launch; numpy mirrors updated below
        self.pool.adopt_device("positions", pos_f)
        self.pool.adopt_device("last_tokens", tok_f)
        produced = 0
        finished = []
        for rid, req in list(self.active.items()):
            lane = self.pool.lane_of[rid]
            cnt = int(em_h[:, lane].sum())
            req.output.extend(int(t) for t in toks_h[:cnt, lane])
            produced += cnt
            self.pool.lengths[lane] += cnt
            self.pool.last_tokens[lane] = req.output[-1]
            full = int(self.pool.lengths[lane]) + 1 > self.max_seq
            if (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None
                        and req.output[-1] == req.eos_id) or full):
                finished.append(rid)
        for rid in finished:
            self._finish(self.active.pop(rid), now, "accepted")
            self.pool.release(rid)
        self.stats.decode_tokens += produced
        return produced

    def _decode_spec(self, now: float) -> int:
        """Fused speculative decode: each launch runs up to ``horizon``
        verify rounds; every round advances each lane by its accepted
        draft run + 1, so one host sync drains up to
        ``horizon * (spec_k + 1)`` tokens per lane."""
        budgets = {rid: req.max_new_tokens - len(req.output)
                   for rid, req in self.active.items()}
        k = self.spec_k
        # always launch `horizon` rounds: the scan skips exhausted tail
        # rounds on-device (lax.cond), so num_steps stays one static value
        # and the spec graph never retraces mid-run
        n_eff = self.horizon
        # each round may write KV up to spec_k slots past the emitted run,
        # so pad the per-request budgets by spec_k for block reservation
        victims = self.pool.ensure_append_blocks(
            sorted(self.active), horizon=n_eff * (k + 1),
            budgets={rid: b + k for rid, b in budgets.items()})
        if victims:
            self._preempt(victims)
        if not self.active:
            return 0
        width = self.pool.n_lanes
        active_mask = np.zeros(width, bool)
        budget_arr = np.zeros(width, np.int32)
        eos_arr = np.full(width, -1, np.int32)
        temp_arr = np.zeros(width, np.float32)
        topk_arr = np.zeros(width, np.int32)
        seed_arr = np.zeros(width, np.int32)
        for rid, req in self.active.items():
            lane = self.pool.lane_of[rid]
            active_mask[lane] = True
            budget_arr[lane] = budgets[rid]
            if req.eos_id is not None:
                eos_arr[lane] = req.eos_id
            temp_arr[lane] = req.temperature
            topk_arr[lane] = req.top_k
            seed_arr[lane] = self._req_seed(req)
        toks, em, acc, (tok_f, pos_f, _, _), self.pool.cache, \
            self.pool.draft_cache, hist_f = self._decode_spec_jit(
                self.params, self.pool.cache, self._d_params,
                self.pool.draft_cache, self.pool.hist_dev(),
                self.pool.last_tokens_dev(), self.pool.positions(),
                self.pool.tables(), jnp.asarray(active_mask),
                jnp.asarray(budget_arr), jnp.asarray(eos_arr),
                jnp.asarray(temp_arr), jnp.asarray(topk_arr),
                jnp.asarray(seed_arr), num_steps=n_eff, spec_k=k,
                max_len=self.max_seq, ngram=self.ngram)
        toks_h = np.asarray(toks)       # (N, B, K+1) — the single sync
        em_h = np.asarray(em)
        acc_h = np.asarray(acc)         # (N, B) accepted drafts per round
        self.stats.host_syncs += 1
        self.stats.decode_syncs += 1
        self.pool.adopt_device("positions", pos_f)
        self.pool.adopt_device("last_tokens", tok_f)
        self.pool.adopt_device("hist", hist_f)
        produced = 0
        finished = []
        for rid, req in list(self.active.items()):
            lane = self.pool.lane_of[rid]
            em_l = em_h[:, lane, :]                          # (N, K+1)
            # row-major boolean drain preserves round-then-slot order
            new = [int(t) for t in toks_h[:, lane, :][em_l]]
            cnt = len(new)
            # a verify pass ran for this lane iff its slot 0 emitted
            rounds = int(em_l[:, 0].sum())
            self.stats.verify_passes += rounds
            self.stats.draft_tokens += k * rounds
            self.stats.accepted_tokens += int(
                np.minimum(em_l.sum(axis=1), acc_h[:, lane]).sum())
            if cnt:
                base = int(self.pool.lengths[lane])
                self.pool.token_hist[lane, base + 1: base + 1 + cnt] = new
                req.output.extend(new)
                produced += cnt
                self.pool.lengths[lane] += cnt
                self.pool.last_tokens[lane] = req.output[-1]
            full = int(self.pool.lengths[lane]) + 1 > self.max_seq
            if (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None
                        and req.output[-1] == req.eos_id) or full):
                finished.append(rid)
        for rid in finished:
            self._finish(self.active.pop(rid), now, "accepted")
            self.pool.release(rid)
        self.stats.decode_tokens += produced
        return produced

    def _decode_slots(self, now: float) -> int:
        lanes = {rid: self.pool.slot_of[rid] for rid in self.active}
        width = self.pool.n_slots
        tokens = [0] * width
        for rid, req in self.active.items():
            tokens[lanes[rid]] = req.output[-1]
        positions = self.pool.positions()
        logits, self.pool.cache = self._decode_jit(
            self.params, self.pool.cache,
            jnp.asarray(tokens, jnp.int32), positions)
        if any(r.temperature > 0 for r in self.active.values()):
            temps = np.zeros(width, np.float32)
            tks = np.zeros(width, np.int32)
            seeds = np.zeros(width, np.int32)
            for rid, req in self.active.items():
                ln = lanes[rid]
                temps[ln] = req.temperature
                tks[ln] = req.top_k
                seeds[ln] = self._req_seed(req)
            t = jnp.asarray(temps)
            dist = sampling_dist(logits[:, : self.model.cfg.vocab_size],
                                 t, jnp.asarray(tks))
            keys = event_keys(lane_keys(jnp.asarray(seeds)),
                              positions + 1, SALT_SAMPLE)
            nxt = np.asarray(sample_from_dist(keys, dist, t <= 0.0))
        else:
            nxt = np.asarray(
                jnp.argmax(logits[:, : self.model.cfg.vocab_size], axis=-1))
        self.stats.host_syncs += 1
        self.stats.decode_syncs += 1
        produced = 0
        finished = []
        for rid, req in list(self.active.items()):
            ln = lanes[rid]
            tok = int(nxt[ln])
            req.output.append(tok)
            produced += 1
            full = int(self.pool.lengths[ln]) + 1 >= self.max_seq
            if (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id) or full):
                finished.append(rid)
        self.pool.advance(list(lanes.values()))
        for rid in finished:
            self._finish(self.active.pop(rid), now, "accepted")
            self.pool.release(rid)
        self.stats.decode_tokens += produced
        return produced

    def step(self, now: float | None = None) -> int:
        """One scheduler iteration: admit, advance chunked prefills, then
        run one decode launch (a fused ``horizon``-step loop in paged
        mode).  Returns number of decode tokens produced.
        """
        t0 = time.perf_counter()
        now = now if now is not None else t0
        if self.offline:
            return 0
        self._release_delayed(now)
        self._expire_deadlines(now)
        self._quarantine_scan(now)
        self._admit(now)
        prefilled = self._prefill_tick(now) \
            if self.paged and self.prefill_chunk else 0
        produced = 0
        if self.active:
            if self._spec_on:
                produced = self._decode_spec(now)
            elif self.paged:
                produced = self._decode_paged(now)
            else:
                produced = self._decode_slots(now)
        if produced or prefilled:
            # simulated frequency knob: a capped clock stretches wall
            # time; a stuck-slow fault stretches it further
            self.stats.record_step((time.perf_counter() - t0)
                                   * self.slow_factor
                                   / max(self.knobs.freq_scale, 1e-3))
        return produced

    def run(self, *, max_steps: int = 10_000) -> EngineStats:
        steps = 0
        while (self.queue or self.active or self.prefilling
               or self._delayed) and steps < max_steps:
            self.step(now=float(steps))
            steps += 1
        return self.stats

    # -- goodput (paper §3.3) ----------------------------------------------
    def goodput(self, *, ttft_slo: float, tbt_slo: float) -> float:
        """Tokens/s over completed requests meeting both SLOs (times are in
        scheduler-step units when run() supplies logical `now`)."""
        return self.stats.goodput(ttft_slo=ttft_slo, tbt_slo=tbt_slo)
