"""LLM serving engine: continuous batching over a paged (or slot) KV cache.

One Engine == one SaaS "VM instance" in TAPAS terms.  It exposes the knobs
the Instance Configurator turns (paper Table 1): max batch size, frequency
cap (simulated via a step-time multiplier), model variant (size /
quantization — swap params), and reports goodput (tokens/s within TTFT/TBT
SLOs, SLO = 5x unloaded latency, paper §3.3).

Serving modes:

* ``paged`` (default for plain-GQA models) — KV lives in a global block
  pool (``PagedCachePool``); admission runs *bucketed batched prefill*
  (prompts padded to power-of-two length buckets, one jitted prefill per
  bucket shape instead of one trace per request) and decode walks
  per-request block tables.  When the pool runs out of blocks mid-decode
  the youngest request is preempted and recomputed later (vLLM-style).
* ``slots`` — the legacy contiguous-slot pool, kept for cache families the
  block pool cannot hold (MLA latent, SWA ring, recurrent state) and as
  the ground truth the paged path is tested against.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model
from repro.serving.kvcache import CachePool, PagedCachePool
from repro.serving.request import Request


@dataclass
class EngineKnobs:
    """The TAPAS-configurable instance settings."""
    max_batch: int = 8
    freq_scale: float = 1.0      # 1.0 = nominal clock; <1 slows step time
    variant: str = "full"        # model-size / quantization variant key
    paused: bool = False         # drained during reconfiguration (§4.3)


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_batches: int = 0     # jitted prefill launches (not requests)
    preemptions: int = 0         # requests requeued for recompute (pool ran
    #                              dry, or displaced by a variant reload)
    variant_swaps: int = 0       # set_variant reloads (may preempt actives)
    completed: list = field(default_factory=list)
    step_times: list = field(default_factory=list)


def _bucket(n: int, lo: int = 16) -> int:
    """Power-of-two prompt-length bucket (bounds distinct prefill shapes)."""
    b = lo
    while b < n:
        b *= 2
    return b


class Engine:
    def __init__(self, model: Model, params: Any, *, max_seq: int = 512,
                 n_slots: int = 8, knobs: EngineKnobs | None = None,
                 paged: bool | None = None, block_size: int = 16,
                 n_blocks: int | None = None):
        self.model = model
        self.variants: dict[str, tuple[Model, Any]] = {"full": (model, params)}
        self.knobs = knobs or EngineKnobs(max_batch=n_slots)
        self.max_seq = max_seq
        self.n_slots = n_slots
        self.block_size = block_size
        self.n_blocks = n_blocks
        self.paged = model.supports_paged if paged is None else paged
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}
        self.stats = EngineStats()
        self._bind(model)

    def _bind(self, model: Model) -> None:
        """(Re)build pool + jitted entry points for the current model."""
        self.model = model
        if self.paged and not model.supports_paged:
            raise ValueError(f"{model.cfg.name} cannot serve paged "
                             f"(attn_kind={model.cfg.attn_kind!r})")
        if self.paged:
            self.pool: Any = PagedCachePool(
                model, self.n_slots, self.max_seq,
                block_size=self.block_size, n_blocks=self.n_blocks)
            self._prefill_jit = jax.jit(model.prefill_ragged)
            self._decode_jit = jax.jit(model.decode_step_paged,
                                       donate_argnums=(1,))
        else:
            self.pool = CachePool(model, self.n_slots, self.max_seq)
            self._prefill_jit = jax.jit(model.prefill)
            self._decode_jit = jax.jit(model.decode_step)

    # -- variant management (model-size / quantization knob) --------------
    def add_variant(self, name: str, model: Model, params: Any) -> None:
        self.variants[name] = (model, params)

    def set_variant(self, name: str) -> None:
        """Reload a different model variant (costs a pause, paper §4.3).

        In-flight requests lose their KV state (the new variant's cache is
        a different shape) but are not dropped: they are preempted — blocks
        released, requeued at the front — and recomputed under the new
        variant, exactly like a pool-exhaustion preemption.  Setting the
        already-active variant is a no-op."""
        if name == self.knobs.variant:
            return
        model, params = self.variants[name]
        if self.active:
            # reverse-sorted so the front of the queue ends up in rid order
            self._preempt(sorted(self.active, reverse=True))
        self.knobs.variant = name
        self.stats.variant_swaps += 1
        self._bind(model)

    @property
    def params(self):
        return self.variants[self.knobs.variant][1]

    # -- request lifecycle -------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    @staticmethod
    def _context(req: Request) -> list:
        """Prefill context: prompt plus any tokens generated before a
        preemption (recompute-style resume)."""
        return list(req.prompt) + list(req.output)

    def _activate(self, req: Request, tok: int, now: float) -> None:
        """Append the prefill token and either activate the request or, if
        it already hit its budget/eos (e.g. resumed right at the limit),
        finish it without occupying a decode lane."""
        req.output.append(tok)
        if req.first_token_s is None:
            req.first_token_s = now
        if (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id)):
            req.finish_s = now
            self.stats.completed.append(req)
            self.pool.release(req.req_id)
            return
        self.active[req.req_id] = req

    def _admit(self, now: float) -> None:
        if self.paged:
            self._admit_paged(now)
            return
        while (self.queue and self.pool.has_free()
               and len(self.active) < self.knobs.max_batch
               and not self.knobs.paused):
            req = self.queue.pop(0)
            prompt = jnp.asarray([self._context(req)], jnp.int32)
            logits, cache = self._prefill_jit(self.params, prompt)
            self.stats.prefill_tokens += prompt.shape[1]
            self.stats.prefill_batches += 1
            tok = int(jnp.argmax(logits[0, : self.model.cfg.vocab_size]))
            self.pool.insert(req.req_id, cache, prompt.shape[1])
            self._activate(req, tok, now)

    def _admit_paged(self, now: float) -> None:
        """Batched admission: drain the queue into length buckets, one
        jitted prefill per bucket shape (not per request)."""
        batch: list[Request] = []
        # reserve lanes/blocks as the batch builds — can_admit alone would
        # double-count the free lists across requests admitted together
        lanes_left = len(self.pool.free_lanes)
        blocks_left = len(self.pool.free_blocks)
        while (self.queue and not self.knobs.paused
               and len(self.active) + len(batch) < self.knobs.max_batch
               and lanes_left > 0):
            ctx_len = len(self._context(self.queue[0]))
            # reserve the first decode append too (an extra block exactly
            # when the context ends on a block boundary)
            need = self.pool.blocks_for(ctx_len + 1)
            if blocks_left < need:
                break
            batch.append(self.queue.pop(0))
            lanes_left -= 1
            blocks_left -= need
        if not batch:
            return
        groups: dict[int, list[Request]] = {}
        for req in batch:
            groups.setdefault(_bucket(len(self._context(req))), []).append(req)
        for s_bucket, reqs in sorted(groups.items()):
            rows = len(reqs)
            b_pad = _bucket(rows, lo=1)   # batch bucket bounds retraces too
            tokens = np.zeros((b_pad, s_bucket), np.int32)
            lengths = np.ones(b_pad, np.int32)
            for i, req in enumerate(reqs):
                ctx = self._context(req)
                tokens[i, : len(ctx)] = ctx
                lengths[i] = len(ctx)
            logits, cache = self._prefill_jit(
                self.params, jnp.asarray(tokens), jnp.asarray(lengths))
            nxt = jnp.argmax(logits[:, : self.model.cfg.vocab_size], axis=-1)
            self.stats.prefill_batches += 1
            for i, req in enumerate(reqs):
                self.pool.insert(req.req_id, cache, i, int(lengths[i]))
                self.stats.prefill_tokens += int(lengths[i])
                self._activate(req, int(nxt[i]), now)

    def _preempt(self, req_ids: list) -> None:
        """Pool ran dry: drop these requests' blocks and requeue them at the
        front for recompute (prompt + generated-so-far become the context)."""
        for rid in req_ids:
            req = self.active.pop(rid)
            self.pool.release(rid)
            self.queue.insert(0, req)
            self.stats.preemptions += 1

    def step(self, now: float | None = None) -> int:
        """One scheduler iteration: admit + one decode step for all actives.

        Returns number of decode tokens produced.
        """
        t0 = time.perf_counter()
        now = now if now is not None else t0
        self._admit(now)
        if not self.active:
            return 0
        if self.paged:
            # allocate append blocks oldest-request-first; when the pool is
            # exhausted the youngest actives are the ones preempted
            victims = self.pool.ensure_append_blocks(sorted(self.active))
            if victims:
                self._preempt(victims)
            if not self.active:
                return 0
            lanes = {rid: self.pool.lane_of[rid] for rid in self.active}
            width = self.pool.n_lanes
        else:
            lanes = {rid: self.pool.slot_of[rid] for rid in self.active}
            width = self.pool.n_slots
        tokens = [0] * width
        for rid, req in self.active.items():
            tokens[lanes[rid]] = req.output[-1]
        positions = self.pool.positions()
        if self.paged:
            logits, self.pool.cache = self._decode_jit(
                self.params, self.pool.cache,
                jnp.asarray(tokens, jnp.int32), positions, self.pool.tables())
        else:
            logits, self.pool.cache = self._decode_jit(
                self.params, self.pool.cache,
                jnp.asarray(tokens, jnp.int32), positions)
        nxt = jnp.argmax(logits[:, : self.model.cfg.vocab_size], axis=-1)
        produced = 0
        finished = []
        for rid, req in list(self.active.items()):
            ln = lanes[rid]
            tok = int(nxt[ln])
            req.output.append(tok)
            produced += 1
            full = int(self.pool.lengths[ln]) + 1 >= self.max_seq
            if (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id) or full):
                req.finish_s = now
                finished.append(rid)
        self.pool.advance(list(lanes.values()))
        for rid in finished:
            self.stats.completed.append(self.active.pop(rid))
            self.pool.release(rid)
        self.stats.decode_tokens += produced
        # simulated frequency knob: a capped clock stretches wall time
        self.stats.step_times.append((time.perf_counter() - t0)
                                     / max(self.knobs.freq_scale, 1e-3))
        return produced

    def run(self, *, max_steps: int = 10_000) -> EngineStats:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step(now=float(steps))
            steps += 1
        return self.stats

    # -- goodput (paper §3.3) ----------------------------------------------
    def goodput(self, *, ttft_slo: float, tbt_slo: float) -> float:
        """Tokens/s over completed requests meeting both SLOs (times are in
        scheduler-step units when run() supplies logical `now`)."""
        good = 0
        t_max = 1e-9
        for r in self.stats.completed:
            t_max = max(t_max, r.finish_s or 0.0)
            if (r.ttft() or 0) <= ttft_slo and (r.tbt() or 0) <= tbt_slo:
                good += len(r.output)
        return good / t_max
