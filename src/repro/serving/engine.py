"""LLM serving engine: continuous batching over a slot-based KV cache.

One Engine == one SaaS "VM instance" in TAPAS terms.  It exposes the knobs
the Instance Configurator turns (paper Table 1): max batch size, frequency
cap (simulated via a step-time multiplier), model variant (size /
quantization — swap params), and reports goodput (tokens/s within TTFT/TBT
SLOs, SLO = 5x unloaded latency, paper §3.3).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.serving.kvcache import CachePool
from repro.serving.request import Request


@dataclass
class EngineKnobs:
    """The TAPAS-configurable instance settings."""
    max_batch: int = 8
    freq_scale: float = 1.0      # 1.0 = nominal clock; <1 slows step time
    variant: str = "full"        # model-size / quantization variant key
    paused: bool = False         # drained during reconfiguration (§4.3)


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    completed: list = field(default_factory=list)
    step_times: list = field(default_factory=list)


class Engine:
    def __init__(self, model: Model, params: Any, *, max_seq: int = 512,
                 n_slots: int = 8, knobs: EngineKnobs | None = None):
        self.model = model
        self.variants: dict[str, tuple[Model, Any]] = {"full": (model, params)}
        self.knobs = knobs or EngineKnobs(max_batch=n_slots)
        self.pool = CachePool(model, n_slots, max_seq)
        self.max_seq = max_seq
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}
        self.stats = EngineStats()
        self._prefill_jit = jax.jit(model.prefill)
        self._decode_jit = jax.jit(model.decode_step)

    # -- variant management (model-size / quantization knob) --------------
    def add_variant(self, name: str, model: Model, params: Any) -> None:
        self.variants[name] = (model, params)

    def set_variant(self, name: str) -> None:
        """Reloading a different model variant (costs a pause, paper §4.3)."""
        model, params = self.variants[name]
        self.model = model
        self.knobs.variant = name
        self.pool = CachePool(model, self.pool.n_slots, self.max_seq)
        self.active.clear()
        self._prefill_jit = jax.jit(model.prefill)
        self._decode_jit = jax.jit(model.decode_step)

    @property
    def params(self):
        return self.variants[self.knobs.variant][1]

    # -- request lifecycle -------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self, now: float) -> None:
        while (self.queue and self.pool.has_free()
               and len(self.active) < self.knobs.max_batch
               and not self.knobs.paused):
            req = self.queue.pop(0)
            prompt = jnp.asarray([req.prompt], jnp.int32)
            logits, cache = self._prefill_jit(self.params, prompt)
            self.stats.prefill_tokens += len(req.prompt)
            tok = int(jnp.argmax(logits[0, : self.model.cfg.vocab_size]))
            self.pool.insert(req.req_id, cache, len(req.prompt))
            req.output.append(tok)
            req.first_token_s = now
            self.active[req.req_id] = req

    def step(self, now: float | None = None) -> int:
        """One scheduler iteration: admit + one decode step for all actives.

        Returns number of decode tokens produced.
        """
        t0 = time.perf_counter()
        now = now if now is not None else t0
        self._admit(now)
        if not self.active:
            return 0
        slots = {rid: self.pool.slot_of[rid] for rid in self.active}
        tokens = [0] * self.pool.n_slots
        for rid, req in self.active.items():
            tokens[slots[rid]] = req.output[-1]
        positions = self.pool.positions()
        logits, self.pool.cache = self._decode_jit(
            self.params, self.pool.cache,
            jnp.asarray(tokens, jnp.int32), positions)
        nxt = jnp.argmax(logits[:, : self.model.cfg.vocab_size], axis=-1)
        produced = 0
        finished = []
        for rid, req in list(self.active.items()):
            s = slots[rid]
            tok = int(nxt[s])
            req.output.append(tok)
            produced += 1
            full = self.pool.lengths[s] + 1 >= self.max_seq
            if (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id) or full):
                req.finish_s = now
                finished.append(rid)
        self.pool.advance(list(slots.values()))
        for rid in finished:
            self.stats.completed.append(self.active.pop(rid))
            self.pool.release(rid)
        self.stats.decode_tokens += produced
        # simulated frequency knob: a capped clock stretches wall time
        self.stats.step_times.append((time.perf_counter() - t0)
                                     / max(self.knobs.freq_scale, 1e-3))
        return produced

    def run(self, *, max_steps: int = 10_000) -> EngineStats:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step(now=float(steps))
            steps += 1
        return self.stats

    # -- goodput (paper §3.3) ----------------------------------------------
    def goodput(self, *, ttft_slo: float, tbt_slo: float) -> float:
        """Tokens/s over completed requests meeting both SLOs (times are in
        scheduler-step units when run() supplies logical `now`)."""
        good = 0
        t_max = 1e-9
        for r in self.stats.completed:
            t_max = max(t_max, r.finish_s or 0.0)
            if (r.ttft() or 0) <= ttft_slo and (r.tbt() or 0) <= tbt_slo:
                good += len(r.output)
        return good / t_max
