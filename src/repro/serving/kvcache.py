"""KV-cache pools for continuous batching.

Two pools:

``PagedCachePool`` — the production path.  One global block pool per layer
(leaves ``(L, n_blocks, block_size, K, hd)``), a free-list block allocator,
and a per-request block table mapping logical KV blocks to physical pool
blocks (vLLM-style PagedAttention).  Admission writes exactly the blocks a
prompt occupies (one donated-jit scatter — O(blocks touched), never a
whole-tree copy), decode appends allocate blocks on demand, and release
returns blocks to the free list in O(blocks held).  Physical block 0 is a
reserved *parking block*: idle decode lanes point their whole table at it
so a fixed-shape decode batch never reads unowned memory.

``CachePool`` — the legacy slot-based pool.  One contiguous ``max_seq``
cache per slot; insertion is a structural tree surgery on the batch dim.
It remains the fallback for cache families the paged pool cannot hold
(MLA latent, SWA ring, mamba/rwkv state) and the ground truth the paged
engine is tested against.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model


class CachePool:
    def __init__(self, model: Model, n_slots: int, max_seq: int):
        self.model = model
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = model.init_cache(n_slots, max_seq)
        self.lengths = [0] * n_slots          # tokens written per slot
        self.free = list(range(n_slots))
        self.slot_of: dict[int, int] = {}      # req_id -> slot

    def has_free(self) -> bool:
        return bool(self.free)

    def insert(self, req_id: int, prefill_cache: Any, prompt_len: int) -> int:
        """Copy a single-request prefill cache (batch dim 1) into a slot."""
        slot = self.free.pop()

        def put_leaf(dst, src):
            if dst.ndim >= 3 and src.shape[2:] != dst.shape[2:]:
                # sequence-prefix insert (e.g. k: (L,1,S_prompt,K,hd))
                return jax.lax.dynamic_update_slice(
                    dst, src.astype(dst.dtype),
                    (0, slot) + (0,) * (src.ndim - 2))
            return dst.at[:, slot].set(src.astype(dst.dtype)[:, 0])

        self.cache = jax.tree.map(put_leaf, self.cache, prefill_cache)
        self.lengths[slot] = prompt_len
        self.slot_of[req_id] = slot
        return slot

    def release(self, req_id: int) -> None:
        slot = self.slot_of.pop(req_id)
        self.lengths[slot] = 0
        self.free.append(slot)

    def positions(self) -> jnp.ndarray:
        """Next write position per slot (parked slots write at 0, which is
        always overwritten by the next prefill insert)."""
        return jnp.asarray(self.lengths, jnp.int32)

    def advance(self, active_slots: list) -> None:
        for s in active_slots:
            self.lengths[s] += 1


# ---------------------------------------------------------------------------
# paged pool
# ---------------------------------------------------------------------------

@partial(jax.jit, donate_argnums=(0,))
def _paged_insert(pool, prefill, blk_ids, row):
    """Scatter one request's prefill KV into its allocated pool blocks.

    pool leaves: (L, n_blocks, bs, K, hd); prefill leaves (L, B, S_pad, ...);
    blk_ids: (n,) physical ids; row: which batch row of the prefill.
    Only the ``n`` indexed blocks are written — with the pool donated, XLA
    aliases in/out and updates them in place (no copy of untouched blocks).
    """
    def put(dst, src):
        n, bs = blk_ids.shape[0], dst.shape[2]
        seq = jax.lax.dynamic_index_in_dim(src, row, axis=1, keepdims=False)
        need = n * bs
        if seq.shape[1] < need:
            pad = [(0, 0)] * seq.ndim
            pad[1] = (0, need - seq.shape[1])
            seq = jnp.pad(seq, pad)
        seq = seq[:, :need].reshape((dst.shape[0], n, bs) + dst.shape[3:])
        # (L, n, bs, ...) -> scatter along the block axis
        return dst.at[:, blk_ids].set(seq.astype(dst.dtype))

    return jax.tree.map(put, pool, prefill)


class PagedCachePool:
    """Global block-pool KV cache with per-request block tables."""

    def __init__(self, model: Model, n_lanes: int, max_seq: int, *,
                 block_size: int = 16, n_blocks: int | None = None,
                 dtype=jnp.bfloat16):
        self.model = model
        self.n_lanes = n_lanes              # fixed decode-batch width
        self.max_seq = max_seq
        self.block_size = block_size
        self.blocks_per_seq = -(-max_seq // block_size)
        # +1: block 0 is the reserved parking block, never allocated
        self.n_blocks = n_blocks if n_blocks is not None \
            else 1 + n_lanes * self.blocks_per_seq
        self.cache = model.init_paged_cache(self.n_blocks, block_size, dtype)
        self.free_blocks = list(range(self.n_blocks - 1, 0, -1))
        self.free_lanes = list(range(n_lanes - 1, -1, -1))
        self.lane_of: dict[int, int] = {}    # req_id -> lane
        self.blocks_of: dict[int, list] = {}  # req_id -> physical block ids
        self.block_tables = np.zeros((n_lanes, self.blocks_per_seq), np.int32)
        self.lengths = np.zeros(n_lanes, np.int32)  # tokens written per lane

    # -- allocator ---------------------------------------------------------
    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_admit(self, prompt_len: int) -> bool:
        """Lane + blocks for the prompt and its first decode append."""
        return (bool(self.free_lanes)
                and len(self.free_blocks) >= self.blocks_for(prompt_len + 1))

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - 1 - len(self.free_blocks)

    def utilization(self) -> float:
        return self.used_blocks / max(self.n_blocks - 1, 1)

    # -- request lifecycle -------------------------------------------------
    def insert(self, req_id: int, prefill_cache: Any, row: int,
               prompt_len: int) -> int:
        """Admit one request: allocate its prompt blocks and scatter row
        ``row`` of a (possibly batched) prefill cache into them."""
        lane = self.free_lanes.pop()
        n = self.blocks_for(prompt_len)
        assert len(self.free_blocks) >= n, "admission not gated by can_admit"
        blks = [self.free_blocks.pop() for _ in range(n)]
        self.cache = _paged_insert(self.cache, prefill_cache,
                                   jnp.asarray(blks, jnp.int32),
                                   jnp.asarray(row, jnp.int32))
        self.block_tables[lane, :] = 0
        self.block_tables[lane, :n] = blks
        self.lengths[lane] = prompt_len
        self.lane_of[req_id] = lane
        self.blocks_of[req_id] = blks
        return lane

    def ensure_append_blocks(self, req_ids: list) -> list:
        """Make sure each request can write its next token (position
        ``lengths[lane]``); allocate a fresh block at block-boundary
        crossings.  Returns the req_ids that could NOT get a block — the
        engine preempts those (release + recompute later)."""
        victims = []
        for rid in req_ids:
            lane = self.lane_of[rid]
            bi = int(self.lengths[lane]) // self.block_size
            if bi < len(self.blocks_of[rid]):
                continue
            if bi >= self.blocks_per_seq or not self.free_blocks:
                victims.append(rid)
                continue
            blk = self.free_blocks.pop()
            self.blocks_of[rid].append(blk)
            self.block_tables[lane, bi] = blk
        return victims

    def release(self, req_id: int) -> None:
        lane = self.lane_of.pop(req_id)
        self.free_blocks.extend(reversed(self.blocks_of.pop(req_id)))
        self.free_lanes.append(lane)
        self.block_tables[lane, :] = 0       # park the lane on block 0
        self.lengths[lane] = 0

    # -- decode-step views -------------------------------------------------
    def positions(self) -> jnp.ndarray:
        """Next write position per lane (parked lanes write into the
        parking block at offset 0; their output is discarded)."""
        return jnp.asarray(self.lengths, jnp.int32)

    def tables(self) -> jnp.ndarray:
        return jnp.asarray(self.block_tables, jnp.int32)

    def advance(self, active_lanes: list) -> None:
        for ln in active_lanes:
            self.lengths[ln] += 1
