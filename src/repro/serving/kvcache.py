"""KV-cache pools for continuous batching.

Two pools:

``PagedCachePool`` — the production path.  One global block pool per layer
(leaves ``(L, n_blocks, block_size, K, hd)``), a free-list block allocator,
and a per-request block table mapping logical KV blocks to physical pool
blocks (vLLM-style PagedAttention).  Admission writes exactly the blocks a
prompt occupies (one donated-jit scatter — O(blocks touched), never a
whole-tree copy), decode appends allocate blocks on demand, and release
returns blocks to the free list in O(blocks held).  Physical block 0 is a
reserved *parking block*: idle decode lanes point their whole table at it
so a fixed-shape decode batch never reads unowned memory.

Three hot-path extensions ride on the block pool:

* **Persistent device buffers** — ``tables()`` / ``positions()`` /
  ``last_tokens_dev()`` return cached device arrays that are updated
  *incrementally* (donated-jit row/element scatters) as the host-side
  allocator mutates, instead of re-uploading the full ``np -> jnp`` table
  every decode step.  After a fused decode horizon the engine hands the
  loop's final device state straight back via ``adopt_device`` — zero
  re-upload on the steady-state decode path.
* **Refcounted blocks + prefix sharing** — every block carries a
  refcount; full prompt blocks are registered in a content-hash chain
  index (``register_prefix``) so later requests with the same prefix
  (``shared_prefix``) reuse the physical blocks instead of recomputing
  and double-storing them.  Shared blocks are immutable by construction:
  only *full* blocks strictly inside the prompt are ever registered, and
  decode appends always land at positions past the prompt.  Same-wave
  duplicates are deduped too: admission claims its chain keys up front
  (``register_pending``), and a request whose next shareable block is
  owned by an in-flight prefill (``pending_shared``) waits and attaches
  to the owner's blocks once they publish.
* **Horizon-aware append allocation** — ``ensure_append_blocks`` can
  reserve every block a lane may write within an N-step fused decode
  horizon, so the jitted loop never needs a host round-trip to allocate.

``CachePool`` — the legacy slot-based pool.  One contiguous ``max_seq``
cache per slot; insertion is a structural tree surgery on the batch dim.
It remains the fallback for cache families the block pool cannot hold
(MLA latent, SWA ring, mamba/rwkv state) and the ground truth the paged
engine is tested against.
"""
from __future__ import annotations

import hashlib
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model


class CachePool:
    def __init__(self, model: Model, n_slots: int, max_seq: int):
        self.model = model
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = model.init_cache(n_slots, max_seq)
        self.lengths = [0] * n_slots          # tokens written per slot
        self.free = list(range(n_slots))
        self.slot_of: dict[int, int] = {}      # req_id -> slot

    def has_free(self) -> bool:
        return bool(self.free)

    def insert(self, req_id: int, prefill_cache: Any, prompt_len: int) -> int:
        """Copy a single-request prefill cache (batch dim 1) into a slot."""
        slot = self.free.pop()

        def put_leaf(dst, src):
            if dst.ndim >= 3 and src.shape[2:] != dst.shape[2:]:
                # sequence-prefix insert (e.g. k: (L,1,S_prompt,K,hd))
                return jax.lax.dynamic_update_slice(
                    dst, src.astype(dst.dtype),
                    (0, slot) + (0,) * (src.ndim - 2))
            return dst.at[:, slot].set(src.astype(dst.dtype)[:, 0])

        self.cache = jax.tree.map(put_leaf, self.cache, prefill_cache)
        self.lengths[slot] = prompt_len
        self.slot_of[req_id] = slot
        return slot

    def release(self, req_id: int) -> None:
        slot = self.slot_of.pop(req_id)
        self.lengths[slot] = 0
        self.free.append(slot)

    def positions(self) -> jnp.ndarray:
        """Next write position per slot (parked slots write at 0, which is
        always overwritten by the next prefill insert)."""
        return jnp.asarray(self.lengths, jnp.int32)

    def advance(self, active_slots: list) -> None:
        for s in active_slots:
            self.lengths[s] += 1


# ---------------------------------------------------------------------------
# paged pool
# ---------------------------------------------------------------------------

@partial(jax.jit, donate_argnums=(0,))
def _paged_insert(pool, prefill, blk_ids, row):
    """Scatter one request's prefill KV into its allocated pool blocks.

    pool leaves: (L, n_blocks, bs, K, hd); prefill leaves (L, B, S_pad, ...);
    blk_ids: (n,) physical ids; row: which batch row of the prefill.
    Only the ``n`` indexed blocks are written — with the pool donated, XLA
    aliases in/out and updates them in place (no copy of untouched blocks).
    """
    def put(dst, src):
        n, bs = blk_ids.shape[0], dst.shape[2]
        seq = jax.lax.dynamic_index_in_dim(src, row, axis=1, keepdims=False)
        need = n * bs
        if seq.shape[1] < need:
            pad = [(0, 0)] * seq.ndim
            pad[1] = (0, need - seq.shape[1])
            seq = jnp.pad(seq, pad)
        seq = seq[:, :need].reshape((dst.shape[0], n, bs) + dst.shape[3:])
        # (L, n, bs, ...) -> scatter along the block axis
        return dst.at[:, blk_ids].set(seq.astype(dst.dtype))

    return jax.tree.map(put, pool, prefill)


@partial(jax.jit, donate_argnums=(0,))
def _poison_block(pool, blk):
    """Overwrite one physical block with NaNs in every leaf (fault
    injection: simulated KV memory corruption).  Donated like the other
    pool scatters — only the indexed block is touched."""
    return jax.tree.map(lambda dst: dst.at[:, blk].set(jnp.nan), pool)


@partial(jax.jit, donate_argnums=(0,))
def _zero_blocks(pool, blk_ids):
    """Scrub the indexed physical blocks back to zero (quarantine
    cleanup).  A freed NaN block reused as a decode *append* block is
    only partially overwritten, and masked attention still folds the
    residue in as ``0 * NaN`` — so poisoned blocks must be scrubbed to
    the pool's pristine (zero) state before re-entering the free list."""
    return jax.tree.map(lambda dst: dst.at[:, blk_ids].set(0), pool)


@jax.jit
def _bad_lane_scan(pool, tables, lengths, mask):
    """Per-lane NaN/Inf detector over the *written* KV positions.

    Gathers each lane's logical blocks (leaf ``(L, n_blocks, bs, ...)``
    via ``tables (B, P)`` -> ``(L, B, P, bs, ...)``) and reduces
    is-not-finite over everything but the lane axis.  Positions at or
    past ``lengths`` are ignored: append blocks reused from the free
    list may carry stale NaNs from a previously quarantined lane in
    slots decode has not written yet, and those are never read by
    attention — flagging them would be a false quarantine.
    """
    n_p = tables.shape[1]

    def leaf_bad(leaf):
        bs = leaf.shape[2]
        g = leaf[:, tables]                       # (L, B, P, bs, ...)
        # isfinite reads bf16 directly — upcasting the gathered view first
        # doubled this scan's peak footprint for identical results
        # (bf16 -> f32 is exact), per the iraudit f32_out_bytes budget
        bad = ~jnp.isfinite(g)
        bad = bad.any(axis=tuple(range(4, bad.ndim)))   # (L, B, P, bs)
        bad = bad.any(axis=0)                           # (B, P, bs)
        pos = (jnp.arange(n_p)[None, :, None] * bs
               + jnp.arange(bs)[None, None, :])         # (1, P, bs)
        valid = pos < lengths[:, None, None]
        return (bad & valid).any(axis=(1, 2))           # (B,)

    lanes_bad = jnp.stack(
        [leaf_bad(leaf) for leaf in jax.tree.leaves(pool)])
    return lanes_bad.any(axis=0) & mask


def _dev_i32(v) -> jnp.ndarray:
    """Explicit upload of a host int scalar.  The incremental mirror
    helpers below are jitted; handing them a bare Python int is an
    *implicit* host-to-device transfer on every lane touch — a per-step
    sync on real accelerators, and the thing
    ``jax.transfer_guard("disallow")`` (the hot-path test guard) trips
    on.  ``device_put`` is an explicit, sanctioned transfer."""
    return jax.device_put(np.int32(v))


@partial(jax.jit, donate_argnums=(0,))
def _mirror_update(arr, idx, val):
    """The single donated choke point for every incremental mirror
    scatter.  ``idx`` is a tuple of int32 scalars: ``(lane,)`` with a row
    (or, on a 1-D mirror, scalar) ``val`` rewrites one row/element;
    ``(lane, pos)`` rewrites one cell.  Each arity is its own jit cache
    entry of this one function, so per-shard mirrors don't multiply the
    helper surface."""
    return arr.at[idx].set(val)


def _chain_key(prev: bytes, tokens) -> bytes:
    """Collision-resistant running hash over block-sized token chunks."""
    h = hashlib.blake2b(prev, digest_size=16)
    h.update(np.asarray(tokens, np.int64).tobytes())
    return h.digest()


class PagedCachePool:
    """Global block-pool KV cache with per-request block tables."""

    def __init__(self, model: Model, n_lanes: int, max_seq: int, *,
                 block_size: int = 16, n_blocks: int | None = None,
                 dtype=jnp.bfloat16):
        self.model = model
        self.n_lanes = n_lanes              # fixed decode-batch width
        self.max_seq = max_seq
        self.block_size = block_size
        self.blocks_per_seq = -(-max_seq // block_size)
        # +1: block 0 is the reserved parking block, never allocated
        self.n_blocks = n_blocks if n_blocks is not None \
            else 1 + n_lanes * self.blocks_per_seq
        # a sharded pool stripes contiguous block-id ranges across the
        # model axis; round up so every rank owns an equal stripe (the
        # extra blocks just enlarge the free list)
        self.shards = model.plan.tp \
            if model.plan.paged_pool_sharded(model.cfg) else 1
        if self.n_blocks % self.shards:
            self.n_blocks += self.shards - self.n_blocks % self.shards
        self.cache = model.init_paged_cache(self.n_blocks, block_size, dtype)
        self.free_blocks = list(range(self.n_blocks - 1, 0, -1))
        self.free_lanes = list(range(n_lanes - 1, -1, -1))
        self.lane_of: dict[int, int] = {}    # req_id -> lane
        self.blocks_of: dict[int, list] = {}  # req_id -> physical block ids
        self.block_tables = np.zeros((n_lanes, self.blocks_per_seq), np.int32)
        self.lengths = np.zeros(n_lanes, np.int32)  # tokens written per lane
        self.last_tokens = np.zeros(n_lanes, np.int32)  # next decode input
        # refcounts + prefix-sharing index; the index is a multimap of the
        # LIVE physical copies of each content chunk
        self.ref = np.zeros(self.n_blocks, np.int32)
        self.prefix_index: dict[bytes, list] = {}
        self.key_of: dict[int, bytes] = {}   # phys block -> its chain key
        self.shared_block_hits = 0           # blocks reused via the index
        # pending-share dedup: chain keys an in-flight prefill will publish
        # once it completes.  A same-wave request with the same prompt head
        # waits for the owner instead of writing its own copy (without this
        # two requests admitted together both prefill an identical head).
        self.pending_index: dict[bytes, int] = {}   # chain key -> req_id
        self.pending_of: dict[int, list] = {}       # req_id -> its keys
        # distinct admissions that deferred to attach to an in-flight
        # prefill (incremented by the engine once per waiting request,
        # not per poll)
        self.pending_share_waits = 0
        # speculative decode: per-lane sequence history (token_hist[l, i] =
        # i-th sequence token; width max_seq + 1 so the token AT max_seq's
        # write position still has a slot) + optional drafter KV pool
        self.token_hist = np.zeros((n_lanes, max_seq + 1), np.int32)
        self.draft_model: Model | None = None
        self.draft_cache: Any = None
        # persistent device mirrors, updated incrementally
        self._dev: dict[str, Any] = {}
        self._dirty = {"tables", "positions", "last_tokens", "hist"}

    # -- device mirrors ----------------------------------------------------
    def _host_of(self, name: str):
        return {"tables": self.block_tables, "positions": self.lengths,
                "last_tokens": self.last_tokens,
                "hist": self.token_hist}[name]

    def _device(self, name: str) -> jnp.ndarray:
        if name in self._dirty or name not in self._dev:
            self._dev[name] = jnp.asarray(self._host_of(name),
                                          dtype=jnp.int32)
            self._dirty.discard(name)
        return self._dev[name]

    def mirror_write(self, name: str, lane: int,
                     pos: int | None = None) -> None:
        """Mirror one host-side mutation into the persistent device copy.

        The numpy host array is the source of truth and must already hold
        the new value; this replays row ``lane`` (or cell ``(lane, pos)``)
        through the one donated ``_mirror_update`` choke point.  A mirror
        that does not exist yet (or is already dirty) is just marked dirty
        and rebuilt whole on next access."""
        if name not in self._dev or name in self._dirty:
            self._dirty.add(name)
            return
        host = self._host_of(name)
        if pos is None:
            val = host[lane]
            val = _dev_i32(val) if np.ndim(val) == 0 else \
                jax.device_put(np.ascontiguousarray(val, np.int32))
            idx = (_dev_i32(lane),)
        else:
            val = _dev_i32(host[lane, pos])
            idx = (_dev_i32(lane), _dev_i32(pos))
        self._dev[name] = _mirror_update(self._dev[name], idx, val)

    def adopt_device(self, name: str, arr: jnp.ndarray) -> None:
        """Install a device array produced by the fused decode loop as the
        new mirror (the caller keeps the numpy host state in sync)."""
        self._dev[name] = arr
        self._dirty.discard(name)

    # -- allocator ---------------------------------------------------------
    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_admit(self, prompt_len: int) -> bool:
        """Lane + blocks for the prompt and its first decode append."""
        return (bool(self.free_lanes)
                and len(self.free_blocks) >= self.blocks_for(prompt_len + 1))

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - 1 - len(self.free_blocks)

    def utilization(self) -> float:
        return self.used_blocks / max(self.n_blocks - 1, 1)

    # -- prefix sharing ----------------------------------------------------
    def shared_prefix(self, tokens: list) -> list:
        """Physical blocks already holding a prefix of ``tokens``.

        Walks the content-hash chain over full block-sized chunks and
        returns the longest run of registered blocks.  At least one token
        is always left unshared (capped at ``(len - 1) // block_size``
        blocks) so the admitting request still prefills something and has
        last-token logits to sample its first output from.
        """
        out = []
        key = b""
        for i in range((len(tokens) - 1) // self.block_size):
            chunk = tokens[i * self.block_size:(i + 1) * self.block_size]
            key = _chain_key(key, chunk)
            copies = self.prefix_index.get(key)
            if not copies:
                break
            out.append(copies[-1])
        return out

    def register_prefix(self, req_id: int, tokens: list) -> None:
        """Publish a request's full, immutable prompt blocks in the prefix
        index (decode appends land strictly past ``len(tokens)``, so every
        full block inside the prompt is frozen)."""
        blks = self.blocks_of[req_id]
        key = b""
        for i in range(len(tokens) // self.block_size):
            chunk = tokens[i * self.block_size:(i + 1) * self.block_size]
            key = _chain_key(key, chunk)
            if blks[i] in self.key_of:
                continue                     # this copy already registered
            self.prefix_index.setdefault(key, []).append(blks[i])
            self.key_of[blks[i]] = key
        self._clear_pending(req_id)

    # -- pending-share dedup -----------------------------------------------
    def register_pending(self, req_id: int, tokens: list) -> None:
        """Claim the chain keys this admission will publish when its
        prefill completes, so identical same-wave prompt heads wait and
        attach instead of each writing their own copy.  First claimant
        wins; keys already live in ``prefix_index`` need no claim."""
        keys = []
        key = b""
        for i in range(len(tokens) // self.block_size):
            chunk = tokens[i * self.block_size:(i + 1) * self.block_size]
            key = _chain_key(key, chunk)
            if key not in self.pending_index and key not in self.prefix_index:
                self.pending_index[key] = req_id
                keys.append(key)
        if keys:
            self.pending_of[req_id] = keys

    def pending_shared(self, tokens: list, *, have: int) -> bool:
        """True when another in-flight prefill owns the *next* shareable
        block of this prompt (block index ``have``, the first one past
        what ``shared_prefix`` already found) — the caller should defer
        admission until the owner publishes and the head becomes
        attachable."""
        n_full = (len(tokens) - 1) // self.block_size
        if have >= n_full:
            return False
        key = b""
        for i in range(have + 1):
            chunk = tokens[i * self.block_size:(i + 1) * self.block_size]
            key = _chain_key(key, chunk)
        return key in self.pending_index

    def _clear_pending(self, req_id: int) -> None:
        for key in self.pending_of.pop(req_id, ()):
            if self.pending_index.get(key) == req_id:
                del self.pending_index[key]

    # -- request lifecycle -------------------------------------------------
    def insert(self, req_id: int, prefill_cache: Any, row: int,
               prompt_len: int) -> int:
        """Admit one request: allocate its prompt blocks and scatter row
        ``row`` of a (possibly batched) prefill cache into them."""
        lane = self.free_lanes.pop()
        n = self.blocks_for(prompt_len)
        assert len(self.free_blocks) >= n, "admission not gated by can_admit"
        blks = [self.free_blocks.pop() for _ in range(n)]
        self.ref[blks] = 1
        self.cache = _paged_insert(self.cache, prefill_cache,
                                   jax.device_put(np.asarray(blks, np.int32)),
                                   _dev_i32(row))
        self.block_tables[lane, :] = 0
        self.block_tables[lane, :n] = blks
        self.lengths[lane] = prompt_len
        self.lane_of[req_id] = lane
        self.blocks_of[req_id] = blks
        self.mirror_write("tables", lane)
        self.mirror_write("positions", lane)
        return lane

    def admit_prefill(self, req_id: int, ctx_len: int,
                      shared_blocks: list | None = None) -> int | None:
        """Chunked-prefill admission: allocate a lane plus every block the
        context and its first decode append need, reusing refcounted
        ``shared_blocks`` (from ``shared_prefix``) for the prompt head.

        The pool's KV is written later, chunk by chunk, by the jitted
        ``prefill_chunk_paged`` scatter; ``lengths`` starts at the shared
        length (the only tokens already valid in the pool).  Returns the
        lane, or None when lanes/blocks are exhausted.
        """
        shared = list(shared_blocks or [])
        need_new = self.blocks_for(ctx_len + 1) - len(shared)
        if not self.free_lanes or len(self.free_blocks) < need_new:
            return None
        lane = self.free_lanes.pop()
        blks = shared + [self.free_blocks.pop() for _ in range(need_new)]
        for b in shared:
            self.ref[b] += 1
        self.ref[blks[len(shared):]] = 1
        self.shared_block_hits += len(shared)
        self.block_tables[lane, :] = 0
        self.block_tables[lane, : len(blks)] = blks
        self.lengths[lane] = len(shared) * self.block_size
        self.lane_of[req_id] = lane
        self.blocks_of[req_id] = blks
        self.mirror_write("tables", lane)
        self.mirror_write("positions", lane)
        return lane

    def ensure_append_blocks(self, req_ids: list, *, horizon: int = 1,
                             budgets: dict | None = None) -> list:
        """Make sure each request can write every token it may produce in
        the next ``horizon`` fused decode steps (positions ``lengths`` ..
        ``lengths + steps - 1``, ``steps`` capped by the per-request
        ``budgets`` and ``max_seq``); allocate fresh blocks at boundary
        crossings.  Returns the req_ids that could NOT get a block — the
        engine preempts those (release + recompute later)."""
        victims = []
        for rid in req_ids:
            lane = self.lane_of[rid]
            steps = horizon if budgets is None else \
                max(1, min(horizon, budgets.get(rid, horizon)))
            target = min(int(self.lengths[lane]) + steps, self.max_seq)
            need = self.blocks_for(target)
            blks = self.blocks_of[rid]
            grew = False
            while len(blks) < need:
                if len(blks) >= self.blocks_per_seq or not self.free_blocks:
                    victims.append(rid)
                    break
                blk = self.free_blocks.pop()
                self.ref[blk] = 1
                self.block_tables[lane, len(blks)] = blk
                blks.append(blk)
                grew = True
            if grew:
                self.mirror_write("tables", lane)
        return victims

    def release(self, req_id: int) -> None:
        # a preempted/failed prefill must free its pending claims, or the
        # requests waiting on it would deadlock at the queue head
        self._clear_pending(req_id)
        lane = self.lane_of.pop(req_id)
        for b in reversed(self.blocks_of.pop(req_id)):
            self.ref[b] -= 1
            if self.ref[b] == 0:
                self.free_blocks.append(b)
                key = self.key_of.pop(b, None)
                if key is not None:
                    copies = self.prefix_index[key]
                    copies.remove(b)
                    if not copies:
                        del self.prefix_index[key]
        self.free_lanes.append(lane)
        self.block_tables[lane, :] = 0       # park the lane on block 0
        self.lengths[lane] = 0
        self.mirror_write("tables", lane)
        self.mirror_write("positions", lane)

    # -- decode-step views -------------------------------------------------
    def positions(self) -> jnp.ndarray:
        """Next write position per lane (parked lanes write into the
        parking block at offset 0; their output is discarded)."""
        return self._device("positions")

    def tables(self) -> jnp.ndarray:
        return self._device("tables")

    def last_tokens_dev(self) -> jnp.ndarray:
        """Per-lane next decode input token, device-resident."""
        return self._device("last_tokens")

    def set_length(self, lane: int, n: int) -> None:
        self.lengths[lane] = n
        self.mirror_write("positions", lane)

    # -- fault injection + NaN guard ----------------------------------------
    def corrupt_lane(self, lane: int, *, block_idx: int = 0) -> None:
        """Poison the lane's ``block_idx``-th logical block with NaNs
        (fault injection).  Refusing the parking block keeps parked lanes
        clean — every idle lane aliases physical block 0."""
        phys = int(self.block_tables[lane, block_idx])
        if phys == 0:
            raise ValueError(
                f"lane {lane} block {block_idx} is the parking block — "
                f"the lane holds no data there to corrupt")
        self.cache = _poison_block(self.cache, _dev_i32(phys))

    def bad_lanes(self, mask) -> np.ndarray:
        """Which masked lanes hold NaN/Inf anywhere in their written KV.
        One jitted scan + one host readback (the caller accounts the
        sync); runs only when the engine's guard is armed."""
        out = _bad_lane_scan(self.cache, self.tables(), self.positions(),
                             jax.device_put(np.asarray(mask, bool)))
        return np.asarray(out)

    def scrub_lane(self, req_id: int) -> None:
        """Zero every block a quarantined request holds, so the blocks
        re-enter the free list in the pool's pristine state.  Shared
        sharers of a poisoned block are quarantined by the same scan
        (their tables alias the same physical block), so scrubbing under
        them is safe."""
        blks = self.blocks_of[req_id]
        ids = jax.device_put(np.asarray(blks, np.int32))
        self.cache = _zero_blocks(self.cache, ids)
        if self.draft_cache is not None:
            self.draft_cache = _zero_blocks(self.draft_cache, ids)

    def set_last_token(self, lane: int, tok: int) -> None:
        self.last_tokens[lane] = tok
        self.mirror_write("last_tokens", lane)

    # -- speculative decode: sequence history + drafter KV ------------------
    def hist_dev(self) -> jnp.ndarray:
        """Per-lane sequence history, device-resident (B, max_seq + 1)."""
        return self._device("hist")

    def set_hist(self, lane: int, tokens: list) -> None:
        """Install a lane's known sequence tokens (the prefill context).
        The fused spec loop appends emissions on device and hands the
        result back via ``adopt_device('hist', ...)``."""
        row = np.zeros(self.token_hist.shape[1], np.int32)
        row[: len(tokens)] = tokens
        self.token_hist[lane] = row
        self.mirror_write("hist", lane)

    def set_hist_token(self, lane: int, pos: int, tok: int) -> None:
        self.token_hist[lane, pos] = tok
        self.mirror_write("hist", lane, pos)

    def attach_draft(self, model: Model, dtype=jnp.bfloat16) -> None:
        """Allocate a drafter KV pool with the SAME block geometry, so the
        drafter rides this pool's block tables and allocator: every block
        id resolves to the request's slots in both caches at once."""
        self.draft_model = model
        self.draft_cache = model.init_paged_cache(self.n_blocks,
                                                  self.block_size, dtype)

    def detach_draft(self) -> None:
        self.draft_model = None
        self.draft_cache = None

    def insert_draft(self, req_id: int, prefill_cache: Any, row: int,
                     prompt_len: int) -> None:
        """Scatter the DRAFTER's prefill KV for an already-admitted
        request into the drafter pool at the request's existing blocks."""
        blks = self.blocks_of[req_id][: self.blocks_for(prompt_len)]
        self.draft_cache = _paged_insert(self.draft_cache, prefill_cache,
                                         jnp.asarray(blks, jnp.int32),
                                         jnp.asarray(row, jnp.int32))
