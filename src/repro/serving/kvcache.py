"""Slot-based KV-cache pool for continuous batching.

One pre-allocated decode cache (leaves stacked (L, SLOTS, ...)); prefill
results for a single request are inserted into a free slot; freed slots are
recycled.  Works for every cache family (GQA k/v, MLA latent, SWA ring,
mamba/rwkv state) because insertion is a structural tree surgery on the
batch dim (+ sequence prefix where one exists).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.transformer import Model


class CachePool:
    def __init__(self, model: Model, n_slots: int, max_seq: int):
        self.model = model
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = model.init_cache(n_slots, max_seq)
        self.lengths = [0] * n_slots          # tokens written per slot
        self.free = list(range(n_slots))
        self.slot_of: dict[int, int] = {}      # req_id -> slot

    def has_free(self) -> bool:
        return bool(self.free)

    def insert(self, req_id: int, prefill_cache: Any, prompt_len: int) -> int:
        """Copy a single-request prefill cache (batch dim 1) into a slot."""
        slot = self.free.pop()

        def put_leaf(dst, src):
            if dst.ndim >= 3 and src.shape[2:] != dst.shape[2:]:
                # sequence-prefix insert (e.g. k: (L,1,S_prompt,K,hd))
                return jax.lax.dynamic_update_slice(
                    dst, src.astype(dst.dtype),
                    (0, slot) + (0,) * (src.ndim - 2))
            return dst.at[:, slot].set(src.astype(dst.dtype)[:, 0])

        self.cache = jax.tree.map(put_leaf, self.cache, prefill_cache)
        self.lengths[slot] = prompt_len
        self.slot_of[req_id] = slot
        return slot

    def release(self, req_id: int) -> None:
        slot = self.slot_of.pop(req_id)
        self.lengths[slot] = 0
        self.free.append(slot)

    def positions(self) -> jnp.ndarray:
        """Next write position per slot (parked slots write at 0, which is
        always overwritten by the next prefill insert)."""
        return jnp.asarray([self.lengths[s] if self.lengths[s] else 0
                            for s in range(self.n_slots)], jnp.int32)

    def advance(self, active_slots: list) -> None:
        for s in active_slots:
            self.lengths[s] += 1
