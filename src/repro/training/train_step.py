"""Training step factory: loss -> grads -> AdamW, pjit-ready."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    grad_accum: int = 1) -> Callable:
    """``grad_accum`` > 1 splits the global batch into microbatches scanned
    sequentially — activation memory scales down by the accumulation factor
    at identical FLOPs (the standard large-batch memory lever)."""

    def train_step(params, opt_state, inputs, labels):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(model.loss)(params, inputs, labels)
        else:
            a = grad_accum
            b = inputs.shape[0]
            assert b % a == 0, (b, a)
            xs = (inputs.reshape(a, b // a, *inputs.shape[1:]),
                  labels.reshape(a, b // a, *labels.shape[1:]))

            def micro(acc, xi):
                inp, lab = xi
                li, gi = jax.value_and_grad(model.loss)(params, inp, lab)
                acc = jax.tree.map(lambda s, g: s + g / a, acc, gi)
                return acc, li

            g0 = jax.tree.map(jnp.zeros_like, params)
            grads, losses = jax.lax.scan(micro, g0, xs)
            loss = jnp.mean(losses)
        grads = model.canonicalize_grads(grads)  # padded-head/kv-copy exactness
        params, opt_state, gnorm = adamw_update(opt_cfg, grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, inputs, labels):
        return model.loss(params, inputs, labels)

    return eval_step


__all__ = ["make_train_step", "make_eval_step", "init_opt_state", "AdamWConfig"]
