"""Deterministic synthetic token pipeline (zipf-ish LM data).

Checkpointable: the iterator state is just (seed, step); resuming from a
checkpoint replays the exact same batch sequence.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0


class TokenPipeline:
    """Markov-ish synthetic stream so next-token loss is learnable."""

    def __init__(self, cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.step = step
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # sparse transition preference: each token has 4 likely successors
        self._succ = rng.integers(0, v, size=(v, 4))

    def state(self) -> dict:
        return {"seed": self.cfg.seed, "step": self.step}

    def next_batch(self):
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, self.step))
        b = np.empty((cfg.batch, cfg.seq_len + 1), np.int32)
        b[:, 0] = rng.integers(0, cfg.vocab_size, cfg.batch)
        explore = rng.random((cfg.batch, cfg.seq_len)) < 0.15
        choice = rng.integers(0, 4, (cfg.batch, cfg.seq_len))
        randtok = rng.integers(0, cfg.vocab_size, (cfg.batch, cfg.seq_len))
        for t in range(cfg.seq_len):
            succ = self._succ[b[:, t], choice[:, t]]
            b[:, t + 1] = np.where(explore[:, t], randtok[:, t], succ)
        self.step += 1
        inputs = jnp.asarray(b[:, :-1])
        labels = jnp.asarray(b[:, 1:])
        return inputs, labels

    def next_embed_batch(self, d_model: int):
        """Frame-embedding batch for encoder archs (modality stub)."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), self.step)
        self.step += 1
        k1, k2 = jax.random.split(key)
        x = jax.random.normal(k1, (cfg.batch, cfg.seq_len, d_model), jnp.bfloat16)
        labels = jax.random.randint(k2, (cfg.batch, cfg.seq_len), 0, cfg.vocab_size)
        return x, labels
