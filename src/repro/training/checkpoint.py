"""Sharded checkpointing with atomic commits (fault-tolerance substrate).

Layout:  <dir>/step_<N>/
            manifest.json        step, flat leaf index, config hash
            shard_<host>.npz     one file per host (this container: host 0)
         <dir>/LATEST            committed step pointer (atomic rename)

On restore, leaves are device_put with the *target* shardings, so a resume
onto a different mesh (elastic shrink/grow) re-shards transparently.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def config_hash(obj: Any) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


def save_checkpoint(ckpt_dir: str | pathlib.Path, step: int, tree: Any,
                    *, meta: dict | None = None) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    tmp = pathlib.Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_"))
    try:
        arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
        np.savez(tmp / "shard_0.npz", **arrays)
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": str(treedef),
            "meta": meta or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        final = ckpt_dir / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # LATEST pointer via atomic replace
    ptr = ckpt_dir / "LATEST"
    tmp_ptr = ckpt_dir / ".LATEST.tmp"
    tmp_ptr.write_text(str(step))
    os.replace(tmp_ptr, ptr)
    return final


def latest_step(ckpt_dir: str | pathlib.Path) -> int | None:
    ptr = pathlib.Path(ckpt_dir) / "LATEST"
    if not ptr.exists():
        return None
    return int(ptr.read_text().strip())


def restore_checkpoint(ckpt_dir: str | pathlib.Path, template: Any,
                       *, step: int | None = None,
                       shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``template``; optionally re-shard."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "shard_0.npz")
    leaves, treedef = _flatten(template)
    assert manifest["n_leaves"] == len(leaves), "checkpoint/model mismatch"
    out = []
    shard_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
                    if shardings is not None else [None] * len(leaves))
    for i, (tmpl, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = data[f"leaf_{i}"]
        x = jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)
        out.append(x.astype(tmpl.dtype) if hasattr(tmpl, "dtype") else x)
    return jax.tree.unflatten(treedef, out), manifest
