"""AdamW with linear-warmup cosine decay — pure JAX, sharding-transparent.

Optimizer state mirrors the parameter pytree (same shapes, same shardings),
so ZeRO-style FSDP sharding of params automatically shards m/v too.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def init_opt_state(params: Params) -> dict:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: AdamWConfig, grads: Params, opt_state: dict,
                 params: Params):
    """Returns (new_params, new_opt_state, grad_norm)."""
    step = opt_state["step"] + 1
    lr = _schedule(cfg, step.astype(jnp.float32))
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
