"""iraudit cost pass: per-entrypoint budget rows from the compiled HLO.

Two families of numbers, chosen for how they are gated:

* **execution costs** from ``analysis/hlo_cost.py`` over the optimized
  HLO — FLOPs and HBM-traffic bytes with while-loop trip counts
  multiplied through.  These depend on XLA's fusion choices, so the
  budget gate gives them a small relative tolerance (and the CI lane
  pins jax/jaxlib).
* **structural metrics** straight off the jaxpr — op census, closure
  constants, f32 surface, peak-live estimate, arg/out bytes,
  donated-vs-aliased leaf counts.  Exact integers, gated exactly.

The roofline view (``analysis/roofline.py``) consumes the same
flops/bytes pair, so a budget row doubles as a per-entrypoint roofline
point when planning kernel work.
"""
from __future__ import annotations

from repro.analysis.hlo_cost import analyze_hlo
from repro.analysis.iraudit.jaxprs import (const_census, f32_out_bytes,
                                           op_census, peak_live_bytes)
from repro.analysis.iraudit.jaxpr_pass import hlo_aliased_params
from repro.analysis.iraudit.registry import EntryAudit


def _leaf_bytes(leaves) -> int:
    total = 0
    for leaf in leaves:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n * leaf.dtype.itemsize
    return total


def cost_metrics(audit: EntryAudit) -> dict:
    """One budget row: every gated metric for one entrypoint."""
    hlo = analyze_hlo(audit.hlo)
    census = op_census(audit.jaxpr)
    const_count, const_bytes, _ = const_census(audit.jaxpr)
    return {
        "flops": float(hlo["flops"]),
        "bytes": float(hlo["bytes"]),
        "coll_bytes": float(hlo["coll_bytes"]),
        "peak_live_bytes": int(peak_live_bytes(audit.jaxpr)),
        "arg_bytes": _leaf_bytes(audit.arg_leaves),
        "out_bytes": _leaf_bytes(audit.out_leaves),
        "n_eqns": int(sum(census.values())),
        "f32_out_bytes": int(f32_out_bytes(audit.jaxpr)),
        "const_count": int(const_count),
        "const_bytes": int(const_bytes),
        "donated_leaves": len(audit.donated_idx),
        "aliased_leaves": len(hlo_aliased_params(audit.hlo)),
        "census": census,
    }
