"""Shared jaxpr-walking utilities for the iraudit passes.

Everything here is *structural*: counts and byte sizes read straight off
the (closed) jaxpr, never multiplied by loop trip counts — that keeps the
numbers exact and jax-version-stable, which is what golden snapshots and
exact budget gates need.  Trip-count-aware costs live in the HLO pass
(``analysis/hlo_cost.py``).
"""
from __future__ import annotations

from collections import Counter
from typing import Iterator

import numpy as np
from jax import core as jcore


def _sub_jaxprs(eqn) -> Iterator[jcore.Jaxpr]:
    """Yield every Jaxpr nested in an eqn's params (scan/while/cond/pjit
    bodies, pallas_call kernels, custom_*_call — anything jaxpr-valued)."""
    for v in eqn.params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for x in vals:
            if isinstance(x, jcore.ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, jcore.Jaxpr):
                yield x


def iter_eqns(jaxpr: jcore.Jaxpr, *, depth: int = 0):
    """Depth-first walk over every eqn, recursing into sub-jaxprs.

    Yields ``(eqn, depth)``; depth 0 is the entry jaxpr itself, so a
    caller can restrict a check to the top level when sub-graphs (e.g.
    Pallas kernel bodies) play by different rules.
    """
    for eqn in jaxpr.eqns:
        yield eqn, depth
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, depth=depth + 1)


def op_census(closed: jcore.ClosedJaxpr) -> dict:
    """Structural primitive census: ``{primitive_name: count}`` over the
    whole jaxpr including nested bodies (each body counted once, not per
    trip — golden-snapshot stable)."""
    c: Counter = Counter()
    for eqn, _ in iter_eqns(closed.jaxpr):
        c[eqn.primitive.name] += 1
    return dict(sorted(c.items()))


def _itemsize(dtype) -> int:
    try:
        return np.dtype(dtype).itemsize
    except TypeError:
        # extended dtypes (PRNG keys): key<fry> carries 2 x uint32
        return getattr(dtype, "itemsize", 8)


def _aval_bytes(aval) -> int:
    dtype = getattr(aval, "dtype", None)
    shape = getattr(aval, "shape", None)
    if dtype is None or shape is None:
        return 0
    n = 1
    for d in shape:
        if not isinstance(d, int):   # symbolic dims: not used on these paths
            return 0
        n *= d
    return n * _itemsize(dtype)


def _var_bytes(v) -> int:
    return 0 if isinstance(v, jcore.Literal) else _aval_bytes(v.aval)


def const_census(closed: jcore.ClosedJaxpr) -> tuple[int, int, list]:
    """Closure-captured constants of the traced entrypoint.

    Returns ``(count, total_bytes, rows)`` with one ``(dtype, shape,
    bytes)`` row per const, largest first.  Every const here is a buffer
    jit re-uploads alongside the arguments — the dynamic counterpart of
    tapaslint TL008.
    """
    rows = []
    for c in closed.consts:
        arr = np.asarray(c)
        rows.append((str(arr.dtype), tuple(arr.shape),
                     int(arr.size * arr.dtype.itemsize)))
    rows.sort(key=lambda r: (-r[2], r[0], r[1]))
    return len(rows), sum(r[2] for r in rows), rows


def f32_out_bytes(closed: jcore.ClosedJaxpr) -> int:
    """Structural bytes of every f32/f64 eqn output in the graph (nested
    bodies included, counted once).  A creep detector: bf16-configured
    graphs hold a small, deliberate f32 surface (softmax scores, sampling
    distributions, kernel accumulators) and this pins its size."""
    wide = (np.dtype(np.float32), np.dtype(np.float64))
    total = 0
    for eqn, _ in iter_eqns(closed.jaxpr):
        for v in eqn.outvars:
            dt = getattr(v.aval, "dtype", None)
            try:
                is_wide = dt is not None and np.dtype(dt) in wide
            except TypeError:      # extended dtypes (PRNG keys)
                is_wide = False
            if is_wide:
                total += _var_bytes(v)
    return total


def peak_live_bytes(closed: jcore.ClosedJaxpr) -> int:
    """Deterministic peak-live estimate from jaxpr liveness.

    Linear scan of each jaxpr's eqns tracking live defined values (args +
    consts + not-yet-dead outputs); at an eqn with a nested body the
    body's own peak is stacked on top of the caller's live set.  This is
    an upper-bound proxy (no aliasing/donation credit, buffers die at
    last textual use), but it is exact arithmetic over the IR — stable
    enough to gate exactly, unlike XLA's allocator-dependent numbers.
    """
    def walk(jaxpr: jcore.Jaxpr) -> int:
        last_use: dict = {}
        for i, eqn in enumerate(jaxpr.eqns):
            for v in eqn.invars:
                if not isinstance(v, jcore.Literal):
                    last_use[v] = i
        for v in jaxpr.outvars:
            if not isinstance(v, jcore.Literal):
                last_use[v] = len(jaxpr.eqns)
        live = {v: _var_bytes(v)
                for v in (*jaxpr.invars, *jaxpr.constvars)}
        cur = sum(live.values())
        peak = cur
        for i, eqn in enumerate(jaxpr.eqns):
            for v in eqn.outvars:
                if v not in live:
                    live[v] = _var_bytes(v)
                    cur += live[v]
            inner = max((walk(sub) for sub in _sub_jaxprs(eqn)), default=0)
            peak = max(peak, cur + inner)
            for v in list(live):
                if last_use.get(v, -1) <= i:
                    cur -= live.pop(v)
        return peak

    return walk(closed.jaxpr)
