"""iraudit — jaxpr/HLO-level static audit of the jitted serving hot paths.

Where ``repro.analysis.lint`` (tapaslint) checks *source* patterns, this
package checks the *compiled* artifacts: every registered hot-path
entrypoint is traced under abstract shapes (no params materialised, no
kernels executed) and two analysis passes run over the result:

* the **jaxpr invariant pass** (``jaxpr_pass``) — forbidden primitives
  (host callbacks, mid-trace ``device_put``), donation declared-vs-
  consumed verification against the compiled module's
  ``input_output_alias`` table, dtype discipline (f32 creeping into a
  bf16-configured graph), and a closure-constant census with a
  per-entrypoint byte cap;
* the **HLO cost pass** (``hlo_pass``) — FLOPs / bytes-accessed via
  ``analysis/hlo_cost.py`` over the optimized HLO (while-loop trip counts
  multiplied through), an op census and a peak-live-bytes estimate from
  jaxpr liveness, emitted as per-entrypoint budget rows.

``benchmarks/BUDGET_ir.json`` pins the budget rows; ``scripts/iraudit.py``
gates CI on both the invariants and the budgets (``budget.py`` holds the
comparison tolerances and the added/removed-primitive census diff).
"""
from repro.analysis.iraudit.budget import (budget_row, census_diff,
                                           check_budgets, load_budgets,
                                           write_budgets)
from repro.analysis.iraudit.hlo_pass import cost_metrics
from repro.analysis.iraudit.jaxpr_pass import (INVARIANTS, IRFinding,
                                               run_invariants)
from repro.analysis.iraudit.registry import (AuditContext, EntryAudit,
                                             Entrypoint, ENTRYPOINTS,
                                             ENTRYPOINTS_BY_NAME,
                                             audit_entry, audit_all)

__all__ = ["AuditContext", "EntryAudit", "Entrypoint", "ENTRYPOINTS",
           "ENTRYPOINTS_BY_NAME",
           "INVARIANTS", "IRFinding", "audit_entry", "audit_all",
           "budget_row", "census_diff", "check_budgets", "cost_metrics",
           "load_budgets", "run_invariants", "write_budgets"]
