"""Budget pinning + drift gate for the iraudit cost pass.

``benchmarks/BUDGET_ir.json`` is the checked-in contract: one row of cost
metrics per registered entrypoint plus a meta block recording the
jax/jaxlib versions and audit geometry the numbers were taken under.
``check_budgets`` mirrors the ``scripts/check_bench.py`` philosophy —
named metric, expected vs got, tolerance in the message — with one
addition: op-census drift reports an added/removed/changed primitive
diff, not a bare mismatch.

Tolerances: XLA-fusion-dependent metrics (flops / bytes / peak-live) get
a small relative band; structural metrics (census, consts, f32 surface,
donation counts) are exact integers and gated exactly.  The numbers are
only stable under the pinned toolchain, so a version skew is itself a
failure — re-record under the pin rather than chasing phantom drift.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jaxlib

# metric -> relative tolerance; everything else in a row is exact
REL_TOL = {"flops": 0.02, "bytes": 0.02, "peak_live_bytes": 0.05}
EXACT = ("coll_bytes", "arg_bytes", "out_bytes", "n_eqns", "f32_out_bytes",
         "const_count", "const_bytes", "donated_leaves", "aliased_leaves")

DEFAULT_BUDGETS = Path(__file__).resolve().parents[4] / "benchmarks" \
    / "BUDGET_ir.json"


def budget_row(metrics: dict) -> dict:
    """The subset of a cost row that gets pinned (all of it, today)."""
    return dict(metrics)


def meta_block(ctx) -> dict:
    return {
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "config": ctx.config_name + "-smoke",
        "geometry": {
            "n_lanes": ctx.n_lanes, "max_seq": ctx.max_seq,
            "block_size": ctx.block_size, "n_blocks": ctx.n_blocks,
            "horizon": ctx.horizon, "chunk": ctx.chunk,
            "bucket": ctx.bucket,
        },
    }


def load_budgets(path: Path | str = DEFAULT_BUDGETS) -> dict:
    with open(path) as f:
        return json.load(f)


def write_budgets(rows: dict, ctx, path: Path | str = DEFAULT_BUDGETS) -> None:
    payload = {"meta": meta_block(ctx), "entries": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")


def census_diff(pinned: dict, got: dict) -> str:
    """Human-readable primitive diff: 'added scatter(+2); removed
    pure_callback; changed dot_general 12->14'."""
    added = [f"{k}(+{v})" for k, v in sorted(got.items()) if k not in pinned]
    removed = [f"{k}(-{v})" for k, v in sorted(pinned.items())
               if k not in got]
    changed = [f"{k} {pinned[k]}->{got[k]}" for k in sorted(pinned)
               if k in got and pinned[k] != got[k]]
    parts = []
    if added:
        parts.append("added " + ", ".join(added))
    if removed:
        parts.append("removed " + ", ".join(removed))
    if changed:
        parts.append("changed " + ", ".join(changed))
    return "; ".join(parts) or "identical"


def check_budgets(current: dict, pinned_payload: dict) -> list:
    """Compare current rows against the pinned file; returns problem
    strings (empty = within budget).  ``current``: {entry: metrics}."""
    problems = []
    meta = pinned_payload.get("meta", {})
    ver = (meta.get("jax"), meta.get("jaxlib"))
    here = (jax.__version__, jaxlib.__version__)
    if ver != here:
        problems.append(
            f"toolchain skew: budgets recorded under jax {ver[0]} / jaxlib "
            f"{ver[1]}, running {here[0]} / {here[1]} — numbers are only "
            f"comparable under the pin (CI installs the pinned pair); "
            f"re-record with --update-budgets under that toolchain")
        return problems
    pinned = pinned_payload.get("entries", {})
    for name in sorted(set(pinned) | set(current)):
        if name not in current:
            problems.append(f"{name}: pinned in BUDGET_ir.json but not "
                            f"registered (stale budget row — re-record)")
            continue
        if name not in pinned:
            problems.append(f"{name}: registered but has no budget row — "
                            f"record it with --update-budgets")
            continue
        got, want = current[name], pinned[name]
        for key, tol in REL_TOL.items():
            g, w = float(got[key]), float(want[key])
            if abs(g - w) > tol * max(abs(w), 1.0):
                problems.append(
                    f"{name}: {key} {g:.6g} vs budget {w:.6g} "
                    f"(|Δ| > {tol:.0%})")
        for key in EXACT:
            if int(got[key]) != int(want[key]):
                problems.append(
                    f"{name}: {key} {got[key]} vs budget {want[key]} "
                    f"(exact)")
        if got["census"] != want["census"]:
            problems.append(
                f"{name}: op census drift — "
                f"{census_diff(want['census'], got['census'])}")
    return problems
