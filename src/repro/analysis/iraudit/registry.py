"""Registry of jitted hot-path entrypoints, traced under abstract shapes.

Each :class:`Entrypoint` names one serving hot path and knows how to
rebuild its *exact* jit binding — same donation declaration, same static
arguments — over a fixed smoke-scale geometry (llama2-7b smoke config,
bf16 params, 3 lanes, ``max_seq`` 64, ``block_size`` 8: the engine-test
defaults, so budget numbers stay tiny and meaningful).  Tracing uses
``ShapeDtypeStruct`` avals throughout: no parameters are materialised and
no kernels execute; ``audit_entry`` only traces, lowers and compiles for
CPU, then hands the jaxpr + optimized HLO to the analysis passes.

The pool helpers (``_paged_insert`` & co.) are audited through the very
jitted objects serving calls — a drifted donation declaration in
``serving/kvcache.py`` shows up here, not in a copy.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model, local_plan
from repro.models.transformer import Model


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


class AuditContext:
    """Smoke-scale serving geometry shared by every registered entrypoint.

    ``shards > 1`` audits the same geometry under a ``(1, shards)``
    ("data", "model") mesh — the sharded paged pool + LSE-combined decode
    paths — and needs that many visible devices (``scripts/iraudit.py``
    forces a 4-device CPU view; entries carry ``min_devices`` so
    single-device test sessions skip them)."""

    def __init__(self, config_name: str = "llama2-7b", *, n_lanes: int = 3,
                 max_seq: int = 64, block_size: int = 8, horizon: int = 4,
                 chunk: int = 16, bucket: int = 16, shards: int = 1):
        self.config_name = config_name
        self.n_lanes = n_lanes
        self.max_seq = max_seq
        self.block_size = block_size
        self.horizon = horizon
        self.chunk = chunk
        self.bucket = bucket
        self.shards = shards
        self.blocks_per_seq = max_seq // block_size
        self.n_blocks = n_lanes * self.blocks_per_seq + 1   # + parking block
        if self.n_blocks % max(shards, 1):
            self.n_blocks += shards - self.n_blocks % shards
        self.cfg = get_config(config_name).smoke_config()
        if shards > 1:
            from repro.serving.spec import serving_plan
            plan = serving_plan(shards)
        else:
            plan = local_plan(param_dtype=jnp.bfloat16)
        self.model = build_model(self.cfg, plan)
        self.params = jax.eval_shape(self.model.init, jax.random.PRNGKey(0))
        self.cache = jax.eval_shape(
            lambda: self.model.init_paged_cache(self.n_blocks,
                                                self.block_size))
        # stacked prefill cache for one ragged bucket (feeds _paged_insert)
        self.prefill_cache = jax.eval_shape(
            self.model.prefill_ragged, self.params,
            _sds((n_lanes, bucket), jnp.int32),
            _sds((n_lanes,), jnp.int32))[1]

    # -- common abstract operands ------------------------------------------
    def lane_i32(self):
        return _sds((self.n_lanes,), jnp.int32)

    def tables(self):
        return _sds((self.n_lanes, self.blocks_per_seq), jnp.int32)

    def decode_state(self):
        """(tokens, positions, block_tables) for the decode entrypoints."""
        return self.lane_i32(), self.lane_i32(), self.tables()

    def horizon_state(self):
        """active/budgets/eos_ids masks for the fused horizons."""
        return (_sds((self.n_lanes,), jnp.bool_), self.lane_i32(),
                self.lane_i32())

    def sampling_state(self):
        return (_sds((self.n_lanes,), jnp.float32), self.lane_i32(),
                self.lane_i32())

    def hist(self):
        return _sds((self.n_lanes, self.max_seq + 1), jnp.int32)

    def kv_pool_leaf(self):
        """(n_blocks, bs, K, hd) of one layer's K pool leaf."""
        return self.cache["attn"]["k"].shape[1:]


@dataclass(frozen=True)
class Entrypoint:
    """One audited hot path.

    ``build(ctx)`` returns ``(jitted_fn, args, kwargs)`` — the jitted
    callable with its real donation/static declarations, plus abstract
    operands.  ``f32_dot_ok`` marks entries whose graphs *deliberately*
    run f32 matmuls (the Pallas kernel bodies upcast q/k/v for
    flash-attention numerics); everything else must keep dot inputs in
    the configured compute dtype.  ``const_cap_bytes`` bounds the closure
    constants jit re-uploads per call.
    """
    name: str
    kind: str                    # "model" | "pool" | "kernel"
    build: Callable[[AuditContext], tuple]
    donate: tuple = ()           # documented declaration (ground truth is
                                 # read back off the traced args_info)
    f32_dot_ok: bool = False
    const_cap_bytes: int = 2048
    doc: str = ""
    min_devices: int = 1         # mesh entries need this many visible devices


@dataclass
class EntryAudit:
    """Trace + compile artifacts for one entrypoint, input to the passes."""
    entry: Entrypoint
    jaxpr: Any                   # ClosedJaxpr
    hlo: str                     # optimized (compiled) HLO text
    arg_leaves: list             # flat ShapeDtypeStructs of the call args
    donated_idx: tuple           # flat arg-leaf indices declared donated
    out_leaves: list = field(default_factory=list)


# ---------------------------------------------------------------------------
# builders — one per hot path, mirroring the engine's jit bindings exactly
# ---------------------------------------------------------------------------

def _b_decode_step(ctx: AuditContext):
    fn = jax.jit(ctx.model.decode_step_paged, donate_argnums=(1,))
    tok, pos, tab = ctx.decode_state()
    return fn, (ctx.params, ctx.cache, tok, pos, tab), {}


def _multi_args(ctx: AuditContext, sampled: bool):
    tok, pos, tab = ctx.decode_state()
    active, budgets, eos = ctx.horizon_state()
    args = (ctx.params, ctx.cache, tok, pos, tab, active, budgets, eos)
    kwargs = dict(num_steps=ctx.horizon, max_len=ctx.max_seq)
    if sampled:
        temps, top_ks, seeds = ctx.sampling_state()
        kwargs.update(temps=temps, top_ks=top_ks, seeds=seeds)
    return args, kwargs


def _b_decode_multi(ctx: AuditContext, *, sampled: bool = False):
    fn = jax.jit(ctx.model.decode_multi_paged,
                 static_argnames=("num_steps", "max_len"),
                 donate_argnums=(1,))
    args, kwargs = _multi_args(ctx, sampled)
    return fn, args, kwargs


def _b_decode_spec(ctx: AuditContext, *, spec_k: int):
    # engine binding: partial over (self, drafter); ngram drafter => None
    fn = jax.jit(
        functools.partial(Model.decode_spec_paged, ctx.model, None),
        static_argnames=("num_steps", "spec_k", "max_len", "ngram"),
        donate_argnums=(1, 3))
    tok, pos, tab = ctx.decode_state()
    active, budgets, eos = ctx.horizon_state()
    temps, top_ks, seeds = ctx.sampling_state()
    args = (ctx.params, ctx.cache, None, None, ctx.hist(), tok, pos, tab,
            active, budgets, eos, temps, top_ks, seeds)
    return fn, args, dict(num_steps=ctx.horizon, spec_k=spec_k,
                          max_len=ctx.max_seq, ngram=2)


def _b_prefill_ragged(ctx: AuditContext):
    fn = jax.jit(ctx.model.prefill_ragged)
    return fn, (ctx.params, _sds((ctx.n_lanes, ctx.bucket), jnp.int32),
                ctx.lane_i32()), {}


def _b_prefill_chunk(ctx: AuditContext):
    fn = jax.jit(ctx.model.prefill_chunk_paged, donate_argnums=(1,))
    return fn, (ctx.params, ctx.cache,
                _sds((ctx.n_lanes, ctx.chunk), jnp.int32), ctx.lane_i32(),
                ctx.lane_i32(), ctx.tables()), {}


def _b_paged_insert(ctx: AuditContext):
    from repro.serving.kvcache import _paged_insert
    n = -(-ctx.bucket // ctx.block_size)
    return _paged_insert, (ctx.cache, ctx.prefill_cache,
                           _sds((n,), jnp.int32), _sds((), jnp.int32)), {}


def _b_mirror_row(ctx: AuditContext):
    # the single donated mirror-update choke point, at row arity (the
    # block-table adopt path): arr.at[(lane,)].set(row)
    from repro.serving.kvcache import _mirror_update
    return _mirror_update, (ctx.tables(), (_sds((), jnp.int32),),
                            _sds((ctx.blocks_per_seq,), jnp.int32)), {}


@functools.lru_cache(maxsize=None)
def _mesh_ctx(shards: int) -> AuditContext:
    """One cached mesh-geometry context per shard degree (construction
    requires >= ``shards`` visible devices, so it is deferred to build
    time and only reached when ``min_devices`` admits the entry)."""
    return AuditContext(shards=shards)


def _b_decode_step_mesh(_ctx, *, shards: int):
    return _b_decode_step(_mesh_ctx(shards))


def _b_prefill_chunk_mesh(_ctx, *, shards: int):
    return _b_prefill_chunk(_mesh_ctx(shards))


def _b_bad_lane_scan(ctx: AuditContext):
    from repro.serving.kvcache import _bad_lane_scan
    return _bad_lane_scan, (ctx.cache, ctx.tables(), ctx.lane_i32(),
                            _sds((ctx.n_lanes,), jnp.bool_)), {}


def _b_kernel_decode(ctx: AuditContext):
    from repro.kernels import ops
    n_blocks, bs, K, hd = ctx.kv_pool_leaf()
    h_pad = ctx.model.plan.h_pad(ctx.cfg)
    pool = _sds((n_blocks, bs, K, hd), jnp.bfloat16)
    q = _sds((ctx.n_lanes, h_pad, hd), jnp.bfloat16)
    return ops.paged_decode_attention, (q, pool, pool, ctx.tables(),
                                        ctx.lane_i32()), dict(interpret=True)


def _b_kernel_prefill(ctx: AuditContext):
    from repro.kernels import ops
    n_blocks, bs, K, hd = ctx.kv_pool_leaf()
    h_pad = ctx.model.plan.h_pad(ctx.cfg)
    pool = _sds((n_blocks, bs, K, hd), jnp.bfloat16)
    q = _sds((ctx.n_lanes, ctx.chunk, h_pad, hd), jnp.bfloat16)
    return ops.paged_prefill_attention, (q, pool, pool, ctx.tables(),
                                         ctx.lane_i32()), dict(interpret=True)


ENTRYPOINTS: tuple = (
    Entrypoint("decode_step_paged", "model", _b_decode_step, donate=(1,),
               doc="single-token paged decode (the horizon's inner step)"),
    Entrypoint("decode_multi_paged_h4", "model",
               functools.partial(_b_decode_multi, sampled=False),
               donate=(1,), doc="fused greedy horizon, num_steps=4"),
    Entrypoint("decode_multi_sampled_h4", "model",
               functools.partial(_b_decode_multi, sampled=True),
               donate=(1,),
               doc="fused horizon with temperature/top-k/seed lanes"),
    Entrypoint("decode_spec_paged_k1", "model",
               functools.partial(_b_decode_spec, spec_k=1), donate=(1, 3),
               doc="speculative horizon, n-gram drafts, K=1"),
    Entrypoint("decode_spec_paged_k4", "model",
               functools.partial(_b_decode_spec, spec_k=4), donate=(1, 3),
               doc="speculative horizon, n-gram drafts, K=4"),
    Entrypoint("prefill_ragged_b16", "model", _b_prefill_ragged,
               doc="batched ragged prefill at bucket 16"),
    Entrypoint("prefill_chunk_paged_c16", "model", _b_prefill_chunk,
               donate=(1,), doc="chunked paged prefill, chunk 16"),
    Entrypoint("pool_paged_insert", "pool", _b_paged_insert, donate=(0,),
               doc="scatter one prefilled request into its pool blocks"),
    Entrypoint("pool_mirror_row", "pool", _b_mirror_row, donate=(0,),
               doc="donated mirror-update choke point, row arity "
                   "(block-table adopt path)"),
    Entrypoint("pool_bad_lane_scan", "pool", _b_bad_lane_scan,
               doc="NaN/Inf quarantine sweep over written KV positions"),
    Entrypoint("kernel_paged_decode", "kernel", _b_kernel_decode,
               f32_dot_ok=True,
               doc="Pallas paged flash-decode (interpret mode)"),
    Entrypoint("kernel_paged_prefill", "kernel", _b_kernel_prefill,
               f32_dot_ok=True,
               doc="Pallas paged prefill kernel (interpret mode)"),
    # mesh geometries: the same decode/prefill hot paths under a
    # (data=1, model=2) mesh — per-shard paged attention + LSE combine,
    # with coll_bytes as a live budget column (the name carries the mesh
    # shape so budget rows per geometry stay distinct)
    Entrypoint("decode_step_paged@1x2", "model",
               functools.partial(_b_decode_step_mesh, shards=2),
               donate=(1,), min_devices=2,
               doc="paged decode under a 1x2 mesh (sharded pool, "
                   "LSE-combined)"),
    Entrypoint("prefill_chunk_paged_c16@1x2", "model",
               functools.partial(_b_prefill_chunk_mesh, shards=2),
               donate=(1,), min_devices=2,
               doc="chunked paged prefill under a 1x2 mesh"),
)

ENTRYPOINTS_BY_NAME = {e.name: e for e in ENTRYPOINTS}


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------

def audit_entry(entry: Entrypoint, ctx: AuditContext) -> EntryAudit:
    """Trace, lower and compile one entrypoint; no numerics run."""
    fn, args, kwargs = entry.build(ctx)
    traced = fn.trace(*args, **kwargs)
    lowered = traced.lower()
    hlo = lowered.compile().as_text()
    info_leaves = jax.tree.leaves(traced.args_info)
    donated = tuple(i for i, a in enumerate(info_leaves)
                    if getattr(a, "donated", False))
    arg_leaves = [jax.ShapeDtypeStruct(a.shape, a.dtype)
                  for a in info_leaves]
    out_leaves = [x for x in jax.tree.leaves(traced.out_info)
                  if hasattr(x, "shape")]
    return EntryAudit(entry=entry, jaxpr=traced.jaxpr, hlo=hlo,
                      arg_leaves=arg_leaves, donated_idx=donated,
                      out_leaves=out_leaves)


def audit_all(ctx: AuditContext | None = None,
              names: list | None = None) -> list:
    """Audit every registered entrypoint (or the named subset), in
    registry order."""
    ctx = ctx or AuditContext()
    picked = ENTRYPOINTS if not names else tuple(
        ENTRYPOINTS_BY_NAME[n] for n in names)
    return [audit_entry(e, ctx) for e in picked]
