"""iraudit invariant pass: checks over the traced jaxpr + compiled HLO.

Four invariants, each with an ``IRxxx`` code (mirroring tapaslint's
``TLxxx`` so ``scripts/iraudit.py --explain IR002`` works the same way):

IR001  no forbidden primitives on a hot path
IR002  every declared donation is consumed (buffer actually aliased)
IR003  dtype discipline: no f32/f64 matmul inputs in a bf16 graph
IR004  closure-constant census under the per-entry byte cap

There is deliberately no waiver mechanism: a finding either gets fixed or
the entry's declaration (e.g. ``f32_dot_ok`` for the Pallas kernel
bodies) is changed in the registry, in review, next to the reason.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

from repro.analysis.hlo_cost import HloModuleCost
from repro.analysis.iraudit.jaxprs import const_census, iter_eqns
from repro.analysis.iraudit.registry import EntryAudit

# Host round-trips and transfers have no business inside a decode horizon:
# one callback inside a lax.scan body is a per-step host sync on real
# accelerators, exactly the class TAPAS's ms-scale envelope cannot absorb.
FORBIDDEN_PRIMS = {
    "pure_callback": "host callback inside a jitted hot path",
    "io_callback": "host I/O callback inside a jitted hot path",
    "debug_callback": "debug callback (jax.debug.*) left in a hot path",
    "infeed": "host infeed in a hot path",
    "outfeed": "host outfeed in a hot path",
    "device_put": "mid-trace device_put (host constant uploaded per call)",
}

INVARIANTS = {
    "IR001": ("forbidden-primitive", """\
The jaxpr contains a primitive that forces a host round-trip (callbacks,
infeed/outfeed) or a mid-trace transfer (device_put).  Inside a fused
decode horizon each of these is a per-step host sync: the 5.6x host-sync
reduction the horizon exists for silently evaporates, and on TPU the
runtime stalls the pipeline.  Fix: compute the value on device, pass it
as an argument, or hoist the transfer out of the traced function.
No waivers — serving hot paths must be clean."""),
    "IR002": ("donation-unconsumed", """\
An argument declared in ``donate_argnums`` was NOT aliased into the
outputs by XLA (missing from the compiled module's input_output_alias
table).  The donation silently degrades to a copy: for the paged KV pool
that doubles peak memory on every decode launch, which is precisely what
donation was declared to avoid.  Usual causes: dtype/shape mismatch
between the donated input and the output it should alias, or the donated
buffer not flowing to any output at all.  Fix the graph (or drop the
false declaration) — do not waive it."""),
    "IR003": ("dtype-discipline", """\
A matmul (dot_general) in a bf16-configured graph takes f32/f64 inputs.
Accumulating in f32 (``preferred_element_type``) is deliberate and fine;
*feeding* f32 operands doubles the MXU-side bandwidth and usually means
an upcast leaked in (a ``.astype`` lost, an f32 softmax output fed
straight into the PV matmul).  Entries whose kernels upcast by design
(Pallas flash-attention bodies) opt out via ``f32_dot_ok`` in the
registry, in review."""),
    "IR004": ("closure-constant-cap", """\
The traced function closes over more constant bytes than its registry
cap.  Closure constants are baked into the executable AND re-uploaded
alongside the arguments at dispatch; a big captured table (np.ndarray,
list of floats) is re-sent every call — the dynamic twin of tapaslint
TL008.  Fix: pass the array as an argument, or compute it inside the
trace from scalars.  If the constant is genuinely tiny and fixed (rope
frequencies), raise the entry's cap in the registry, in review."""),
    "IR005": ("budget-drift", """\
A cost metric moved outside its tolerance against the checked-in
``benchmarks/BUDGET_ir.json`` (or the op census changed shape).  This is
how an accidental broadcast blowup, a dead computation, or a lost
donation shows up before any TPU time is spent.  If the change is
intended, re-record with ``scripts/iraudit.py --update-budgets`` and
commit the diff — reviewers then see the cost delta next to the code
that caused it."""),
}

_ALIAS_RE = re.compile(r"input_output_alias=\{")


@dataclass(frozen=True)
class IRFinding:
    entry: str       # entrypoint name
    code: str        # IRxxx
    message: str

    def __str__(self) -> str:
        return f"{self.code} {self.entry}: {self.message}"


def hlo_aliased_params(hlo: str) -> set:
    """Flat parameter indices aliased to outputs, from the module header's
    ``input_output_alias={ {out}: (param, {}, may-alias), ... }`` table."""
    m = _ALIAS_RE.search(hlo)
    if not m:
        return set()
    depth, i = 1, m.end()
    while i < len(hlo) and depth:
        depth += (hlo[i] == "{") - (hlo[i] == "}")
        i += 1
    body = hlo[m.end():i - 1]
    return {int(p) for p in re.findall(r"\(\s*(\d+)\s*,", body)}


def hlo_entry_param_count(hlo: str) -> int:
    mod = HloModuleCost(hlo)
    instrs = mod.computations.get(mod.entry, [])
    return sum(1 for i in instrs if i.opcode == "parameter")


def _check_forbidden(audit: EntryAudit) -> list:
    found = []
    for eqn, _ in iter_eqns(audit.jaxpr.jaxpr):
        name = eqn.primitive.name
        if name in FORBIDDEN_PRIMS:
            found.append(IRFinding(
                audit.entry.name, "IR001",
                f"{name}: {FORBIDDEN_PRIMS[name]}"))
    return found


def _check_donation(audit: EntryAudit) -> list:
    declared = set(audit.donated_idx)
    if not declared:
        return []
    n_params = hlo_entry_param_count(audit.hlo)
    if n_params != len(audit.arg_leaves):
        # jit pruned unused args — index spaces differ; a pruned *donated*
        # arg cannot be aliased, so report the discrepancy head-on.
        return [IRFinding(
            audit.entry.name, "IR002",
            f"compiled entry has {n_params} params for "
            f"{len(audit.arg_leaves)} traced arg leaves (unused args "
            f"pruned?) — donated buffers cannot be verified; make every "
            f"donated arg reach an output")]
    aliased = hlo_aliased_params(audit.hlo)
    out = []
    for i in sorted(declared - aliased):
        leaf = audit.arg_leaves[i]
        out.append(IRFinding(
            audit.entry.name, "IR002",
            f"donated arg leaf {i} ({leaf.dtype}{list(leaf.shape)}) is "
            f"not aliased into any output — donation degraded to a copy"))
    return out


def _check_dtypes(audit: EntryAudit) -> list:
    if audit.entry.f32_dot_ok:
        return []
    wide = (np.dtype(np.float32), np.dtype(np.float64))
    out = []
    for eqn, _ in iter_eqns(audit.jaxpr.jaxpr):
        if eqn.primitive.name != "dot_general":
            continue
        bad = [str(v.aval.dtype) for v in eqn.invars
               if getattr(v.aval, "dtype", None) is not None
               and np.dtype(v.aval.dtype) in wide]
        if bad:
            shapes = " x ".join(
                f"{v.aval.dtype}{list(v.aval.shape)}" for v in eqn.invars)
            out.append(IRFinding(
                audit.entry.name, "IR003",
                f"dot_general with wide inputs ({shapes}) in a "
                f"bf16-configured graph"))
    return out


def _check_consts(audit: EntryAudit) -> list:
    count, total, rows = const_census(audit.jaxpr)
    if total <= audit.entry.const_cap_bytes:
        return []
    head = ", ".join(f"{dt}{list(sh)}={b}B" for dt, sh, b in rows[:4])
    return [IRFinding(
        audit.entry.name, "IR004",
        f"{count} closure constants totalling {total}B exceed the "
        f"{audit.entry.const_cap_bytes}B cap ({head})")]


def run_invariants(audit: EntryAudit) -> list:
    """All IR001-IR004 findings for one audited entrypoint."""
    return (_check_forbidden(audit) + _check_donation(audit)
            + _check_dtypes(audit) + _check_consts(audit))
