"""Mini HLO-text cost analyzer with while-loop trip-count multiplication.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, which makes it
useless for scan-over-layers models (every layer lives in one loop body).
This module parses the optimized (SPMD-partitioned, per-device) HLO text
and computes:

  * flops  — dots: 2 * result_elems * contracted_elems; elementwise ops:
    result elems (counted inside fusion bodies too);
  * bytes  — HBM-traffic proxy: operand + result bytes at fusion/dot/copy/
    collective boundaries (fusion-internal ops are VMEM-resident, not
    counted), matching HloCostAnalysis conventions;
  * collective bytes — operand bytes per collective kind;

with every while body multiplied by its ``known_trip_count`` backend config
(nested loops multiply through).  Values are per partition (= per chip).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e4m3": 1, "f8e5m2": 1, "f8e3m4": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[="\{:\s]+n["\s:]+"?(\d+)')
_CALL_ATTR_RE = re.compile(r"(?:calls|body)=%([\w.\-]+)")
_COND_ATTR_RE = re.compile(r"condition=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_APPLY_RE = re.compile(
    r"(?:true_computation|false_computation|to_apply)=%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

# ops that move no data / cost nothing
_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "add-dependency", "partition-id", "replica-id",
         "opt-barrier"}


def _shape_info(type_text: str) -> tuple[int, int]:
    """Return (bytes, elems) summed over every shape token in the text."""
    total_b = 0
    total_e = 0
    for m in _SHAPE_RE.finditer(type_text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_b, total_e


@dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    operands: list
    attrs: str
    result_bytes: int = 0
    result_elems: int = 0


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_count: float = 0.0
    unknown_loops: int = 0

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k in COLLECTIVES:
            self.coll[k] += mult * other.coll[k]
        self.coll_count += mult * other.coll_count
        self.unknown_loops += other.unknown_loops

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


def _split_instruction(line: str) -> Instr | None:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    # rhs = "TYPE opcode(operands), attrs"; TYPE may be a (tuple, ...)
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        result_type = rhs[: i + 1]
        rest = rhs[i + 1:].strip()
    else:
        sp = rhs.find(" ")
        result_type = rhs[:sp]
        rest = rhs[sp + 1:].strip()
    pi = rest.find("(")
    if pi < 0:
        return None
    opcode = rest[:pi].strip()
    depth = 0
    end = pi
    for i in range(pi, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            end = i
            break
    operand_text = rest[pi + 1: end]
    attrs = rest[end + 1:]
    # operands print as "%name" or typed "f32[3,4]{1,0} %name" (XLA uses the
    # typed form in SPMD-partitioned modules); keep only the name so symtab
    # lookups — and with them collective/operand byte counting — resolve
    operands = [o.strip().rsplit(" ", 1)[-1].lstrip("%")
                for o in _split_top_commas(operand_text)]
    rb, re_ = _shape_info(result_type)
    return Instr(name, opcode, result_type, [o for o in operands if o],
                 attrs, rb, re_)


def _split_top_commas(s: str) -> list:
    parts = []
    depth = 0
    cur = []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return parts


class HloModuleCost:
    def __init__(self, hlo_text: str):
        self.computations: dict = {}
        self.entry: str | None = None
        self._parse(hlo_text)
        self._memo: dict = {}

    def _parse(self, text: str) -> None:
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            hdr = _COMP_HDR_RE.match(line)
            if hdr and "->" in line and line.endswith("{"):
                cur = hdr.group(1)
                self.computations[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            ins = _split_instruction(line)
            if ins is not None:
                self.computations[cur].append(ins)
        if self.entry is None and self.computations:
            self.entry = next(reversed(self.computations))

    # ------------------------------------------------------------------
    def cost(self) -> Cost:
        return self._comp_cost(self.entry, count_bytes=True)

    def _comp_cost(self, comp: str, *, count_bytes: bool) -> Cost:
        key = (comp, count_bytes)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        instrs = self.computations.get(comp, [])
        symtab = {i.name: i for i in instrs}
        for ins in instrs:
            total.add(self._instr_cost(ins, symtab, count_bytes=count_bytes))
        self._memo[key] = total
        return total

    def _operand_bytes(self, ins: Instr, symtab: dict) -> int:
        b = 0
        for op in ins.operands:
            src = symtab.get(op)
            if src is not None:
                b += src.result_bytes
        return b

    def _fusion_operand_bytes(self, ins: Instr, symtab: dict,
                              inner_name: str | None) -> int:
        """Operand bytes for a fusion, slice-aware: a parameter that is only
        consumed through (dynamic-)slices/gathers inside the fusion is
        charged at the sliced size, not the full operand (e.g. the per-layer
        dynamic-slice of scan-stacked weights)."""
        inner = self.computations.get(inner_name or "", [])
        if not inner:
            return self._operand_bytes(ins, symtab)
        param_of = {}  # inner instr name -> operand index
        for iins in inner:
            if iins.opcode == "parameter" and iins.operands:
                try:
                    param_of[iins.name] = int(iins.operands[0])
                except ValueError:
                    pass
        sliced_bytes: dict = {}
        full_use: set = set()
        for iins in inner:
            if iins.opcode in ("dynamic-slice", "slice", "gather"):
                src = iins.operands[0] if iins.operands else None
                if src in param_of:
                    idx = param_of[src]
                    sliced_bytes[idx] = sliced_bytes.get(idx, 0) + iins.result_bytes
                    continue
            for opnd in iins.operands:
                if opnd in param_of:
                    full_use.add(param_of[opnd])
        total = 0
        for i, opnd in enumerate(ins.operands):
            src = symtab.get(opnd)
            if src is None:
                continue
            if i in sliced_bytes and i not in full_use:
                total += min(sliced_bytes[i], src.result_bytes)
            else:
                total += src.result_bytes
        return total

    def _instr_cost(self, ins: Instr, symtab: dict, *, count_bytes: bool) -> Cost:
        c = Cost()
        op = ins.opcode
        if op in _FREE:
            return c
        if op == "while":
            trips = 1
            m = _TRIP_RE.search(ins.attrs)
            if m:
                trips = int(m.group(1))
            else:
                c.unknown_loops += 1
            body = _CALL_ATTR_RE.search(ins.attrs)
            cond = _COND_ATTR_RE.search(ins.attrs)
            if body:
                c.add(self._comp_cost(body.group(1), count_bytes=count_bytes), trips)
            if cond:
                c.add(self._comp_cost(cond.group(1), count_bytes=count_bytes), trips)
            return c
        if op in ("call", "conditional", "async-start"):
            m = _CALL_ATTR_RE.search(ins.attrs)
            if m:
                c.add(self._comp_cost(m.group(1), count_bytes=count_bytes))
            # lax.cond lowers to conditional(..., branch_computations={..})
            # (or legacy true_/false_computation); plain calls use to_apply.
            # Branches are mutually exclusive, so charge the costliest one —
            # the upper bound a budget wants.  Before this, conditional
            # bodies were skipped entirely, zeroing out any graph whose hot
            # loop sits behind a cond (both fused decode horizons do this).
            bm = _BRANCHES_RE.search(ins.attrs)
            branches = ([b.strip().lstrip("%")
                         for b in bm.group(1).split(",") if b.strip()]
                        if bm else [])
            branches += _APPLY_RE.findall(ins.attrs)
            if branches:
                costs = [self._comp_cost(b, count_bytes=count_bytes)
                         for b in branches]
                c.add(max(costs, key=lambda x: (x.flops, x.bytes)))
            return c
        base = op.replace("-start", "")
        if base in COLLECTIVES and not op.endswith("-done"):
            ob = self._operand_bytes(ins, symtab)
            c.coll[base] += ob
            c.coll_count += 1
            if count_bytes:
                c.bytes += ob + ins.result_bytes
            return c
        if op.endswith("-done"):
            return c
        if op == "fusion":
            m = _CALL_ATTR_RE.search(ins.attrs)
            inner_name = m.group(1) if m else None
            if inner_name:
                inner = self._comp_cost(inner_name, count_bytes=False)
                c.flops += inner.flops
            if count_bytes:
                c.bytes += (self._fusion_operand_bytes(ins, symtab, inner_name)
                            + ins.result_bytes)
            return c
        if op == "dot":
            k_elems = 1
            m = _CONTRACT_RE.search(ins.attrs)
            lhs = symtab.get(ins.operands[0]) if ins.operands else None
            if m and lhs is not None:
                lhs_dims = []
                sm = _SHAPE_RE.search(lhs.result_type)
                if sm and sm.group(2):
                    lhs_dims = [int(d) for d in sm.group(2).split(",")]
                for d in (m.group(1).split(",") if m.group(1) else []):
                    di = int(d)
                    if di < len(lhs_dims):
                        k_elems *= lhs_dims[di]
            c.flops += 2.0 * ins.result_elems * k_elems
            if count_bytes:
                c.bytes += self._operand_bytes(ins, symtab) + ins.result_bytes
            return c
        if op in ("convolution",):
            # not used by this code base; fall back to result-sized cost
            c.flops += 2.0 * ins.result_elems
            if count_bytes:
                c.bytes += self._operand_bytes(ins, symtab) + ins.result_bytes
            return c
        if op in ("slice", "dynamic-slice", "gather"):
            # output-driven reads: only the sliced/gathered region moves
            if count_bytes:
                c.bytes += 2 * ins.result_bytes
            return c
        if op == "dynamic-update-slice":
            # in-place (aliased) update: read+write the update region only
            upd = symtab.get(ins.operands[1]) if len(ins.operands) > 1 else None
            if count_bytes and upd is not None:
                c.bytes += 2 * upd.result_bytes
            return c
        if op == "scatter":
            upd = symtab.get(ins.operands[-1]) if ins.operands else None
            ub = upd.result_bytes if upd else ins.result_bytes
            c.flops += upd.result_elems if upd else 0
            if count_bytes:
                c.bytes += 3 * ub  # read dst region + read updates + write
            return c
        if op in ("copy", "copy-start", "transpose", "reshape", "broadcast",
                  "iota", "concatenate", "pad", "reverse", "sort",
                  "rng-bit-generator", "custom-call", "reduce",
                  "reduce-window", "select-and-scatter", "map"):
            if op in ("reduce", "reduce-window", "map", "sort"):
                # one flop per input element is the usual convention
                c.flops += sum(symtab[o].result_elems for o in ins.operands
                               if o in symtab)
            if count_bytes:
                c.bytes += self._operand_bytes(ins, symtab) + ins.result_bytes
            return c
        # generic elementwise (add/multiply/exp/...)
        c.flops += ins.result_elems
        if count_bytes:
            c.bytes += self._operand_bytes(ins, symtab) + ins.result_bytes
        return c


def loop_breakdown(hlo_text: str, top: int = 12) -> list:
    """Per-while-loop and top-collective attribution (for §Perf).

    Returns rows: {'kind': 'loop'|'collective', 'name', 'trips'/'bytes',
    'flops', 'bytes', 'coll_bytes', 'op_name' hint}.
    """
    mod = HloModuleCost(hlo_text)
    rows = []

    def walk(comp: str, mult: float):
        for ins in mod.computations.get(comp, []):
            if ins.opcode == "while":
                m = _TRIP_RE.search(ins.attrs)
                trips = int(m.group(1)) if m else 1
                body = _CALL_ATTR_RE.search(ins.attrs)
                if body:
                    c = mod._comp_cost(body.group(1), count_bytes=True)
                    hint = ""
                    hm = re.search(r'op_name="([^"]+)"', ins.attrs)
                    if hm:
                        hint = hm.group(1)
                    rows.append({
                        "kind": "loop", "name": ins.name, "trips": trips,
                        "mult": mult, "flops": mult * trips * c.flops,
                        "bytes": mult * trips * c.bytes,
                        "coll_bytes": mult * trips * c.coll_bytes,
                        "op_name": hint,
                    })
                    walk(body.group(1), mult * trips)
            base = ins.opcode.replace("-start", "")
            if base in COLLECTIVES and not ins.opcode.endswith("-done"):
                symtab = {i.name: i for i in mod.computations[comp]}
                ob = sum(symtab[o].result_bytes for o in ins.operands
                         if o in symtab)
                hint = ""
                hm = re.search(r'op_name="([^"]+)"', ins.attrs)
                if hm:
                    hint = hm.group(1)
                rows.append({
                    "kind": base, "name": ins.name, "mult": mult,
                    "coll_bytes": mult * ob, "bytes_one": ob, "op_name": hint,
                })

    walk(mod.entry, 1.0)
    colls = sorted((r for r in rows if r["kind"] != "loop"),
                   key=lambda r: -r["coll_bytes"])[:top]
    loops = [r for r in rows if r["kind"] == "loop"]
    return loops + colls


def analyze_hlo(hlo_text: str) -> dict:
    """Convenience wrapper -> plain dict."""
    cost = HloModuleCost(hlo_text).cost()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "coll_bytes": cost.coll_bytes,
        "coll_breakdown": {k: v for k, v in cost.coll.items() if v},
        "coll_count": cost.coll_count,
        "unknown_trip_loops": cost.unknown_loops,
    }
