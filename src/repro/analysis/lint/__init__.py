"""tapaslint — repo-specific static analysis for TAPAS invariants.

Stdlib-only (the CI lint lane runs without jax/numpy installed): the
runtime guards live in ``repro.analysis.lint.runtime`` and are imported
separately by test code.
"""
from repro.analysis.lint.framework import (Finding, ModuleContext, Registry,
                                           Rule, collect_files,
                                           diff_baseline, format_baseline,
                                           lint_sources, load_baseline)
from repro.analysis.lint.rules import ALL_RULES, RULES_BY_CODE

__all__ = ["Finding", "ModuleContext", "Registry", "Rule", "ALL_RULES",
           "RULES_BY_CODE", "collect_files", "diff_baseline",
           "format_baseline", "lint_sources", "load_baseline"]
