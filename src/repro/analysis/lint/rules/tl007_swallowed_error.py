"""TL007 — swallowed error: no silent except-pass in the serving/control
planes."""
from __future__ import annotations

import ast

from repro.analysis.lint.framework import Rule

EXPLAIN = """\
TL007 swallowed error — fault-handling code must never discard failures
silently.

The resilience layer's core guarantee is zero *silently* lost requests:
every admitted request ends in exactly one terminal outcome (accepted /
timed_out / rejected), and ``faults.audit_requests`` fails the bench if
one vanishes.  A bare ``except:`` — or an ``except Exception: pass`` —
in the serving or control plane is how requests vanish: the crash that
should have re-queued the batch is eaten, the stats counters never move,
and the audit has nothing to point at.

Flags, in ``serving/`` and ``core/``:
  * bare ``except:`` handlers (always — they also eat KeyboardInterrupt
    and the watchdog's own failures);
  * ``except Exception`` / ``except BaseException`` (alone or inside a
    tuple) whose body does nothing but ``pass`` / ``...`` / ``continue``.

Narrow handlers (``except KeyError: pass``) stay legal — catching a
*specific* expected failure and moving on is a decision, not a leak.

Fix: catch the narrowest exception that is actually expected, or record
the failure (counter bump, re-queue, log) before continuing.  A genuinely
intentional broad swallow can be annotated
``# tapaslint: disable=TL007``.
"""

_BROAD = {"Exception", "BaseException"}


def _names(node: ast.AST | None):
    """Exception-class names referenced by an ``except`` type expression."""
    if node is None:
        return []
    exprs = node.elts if isinstance(node, ast.Tuple) else [node]
    out = []
    for e in exprs:
        if isinstance(e, ast.Name):
            out.append(e.id)
        elif isinstance(e, ast.Attribute):   # builtins.Exception etc.
            out.append(e.attr)
    return out


def _swallows(body: list) -> bool:
    """True when the handler body does nothing observable."""
    for stmt in body:
        if isinstance(stmt, (ast.Pass, ast.Continue)):
            continue
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
            continue   # `...` or a bare docstring
        return False
    return True


class SwallowedErrorRule(Rule):
    code = "TL007"
    name = "swallowed-error"
    scopes = ("src/repro/serving", "src/repro/core")
    EXPLAIN = EXPLAIN

    def check(self, ctx):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                yield from self.emit(
                    ctx, node,
                    "bare 'except:' swallows every failure (including "
                    "KeyboardInterrupt); catch the narrowest expected "
                    "exception and record the rest")
                continue
            broad = sorted(set(_names(node.type)) & _BROAD)
            if broad and _swallows(node.body):
                yield from self.emit(
                    ctx, node,
                    f"'except {broad[0]}' with a do-nothing body discards "
                    "failures the resilience audit depends on; narrow the "
                    "type or record the failure before continuing")
