"""TL008 — host-constant hazard: no per-call ``np.*`` construction or
closure-captured numpy/list constants inside traced functions."""
from __future__ import annotations

import ast

from repro.analysis.lint.framework import Rule

EXPLAIN = """\
TL008 host-constant hazard — traced code must not manufacture host
arrays.

A ``np.asarray``/``np.arange``/list-literal constant used inside a
jitted function is captured as a *closure constant*: it gets baked into
the executable AND re-uploaded alongside the arguments at every
dispatch.  At decode rates (one launch per fused horizon) that is a
recurring host->device transfer the profile never attributes to you —
and if the value differs between calls it silently retraces instead
(TL003's cousin).  ``scripts/iraudit.py`` measures the same hazard
dynamically: IR004 caps the closure-constant bytes of every registered
hot path, and the ``const_bytes`` budget row pins them.

Flags, inside traced functions only:
  * ``np.<ctor>(...)`` calls (arange/zeros/ones/full/linspace/eye/
    concatenate/stack/...) — per-call host construction.  ``np.array``/
    ``np.asarray`` are deliberately NOT here: on a traced value they
    *concretize* it, which is TL002's host-sync finding;
  * reads of module-level names bound to an ``np.<ctor>(...)`` result or
    a numeric list/tuple literal — the captured-constant form.

Fix: build the value with ``jnp.*`` inside the trace (it becomes a
device constant, folded at compile time), pass it as an argument, or —
for genuinely tiny fixed tables like rope frequencies — keep it and
raise the entry's cap in the iraudit registry, in review.  ``np.*`` in
host-side code (setup, mirrors, benches) is fine and unflagged.
"""

#: pure constructors: flagged per call inside traced code.  array/asarray
#: belong to TL002 there (coercion = host sync), but still mark a
#: module-level binding as a captured host constant.
_NP_CTORS = {"arange", "zeros", "ones", "full",
             "linspace", "logspace", "eye", "empty", "identity",
             "zeros_like", "ones_like", "full_like", "concatenate",
             "stack", "meshgrid", "tri", "tril", "triu", "loadtxt"}
_NP_MODULE_CTORS = _NP_CTORS | {"array", "asarray"}


def _numpy_aliases(tree: ast.AST) -> set:
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    names.add(a.asname or "numpy")
    return names


def _is_numeric_literal_seq(value: ast.AST) -> bool:
    """A (possibly nested) list/tuple literal of numbers."""
    if isinstance(value, (ast.List, ast.Tuple)):
        return bool(value.elts) and all(
            _is_numeric_literal_seq(e) or (
                isinstance(e, ast.Constant)
                and isinstance(e.value, (int, float, complex))
                and not isinstance(e.value, bool))
            for e in value.elts)
    return False


class NpConstRule(Rule):
    code = "TL008"
    name = "host-constant"
    scopes = ("src/repro/serving", "src/repro/models", "src/repro/kernels")
    EXPLAIN = EXPLAIN

    def _np_ctor_call(self, node: ast.Call, np_names: set,
                      ctors: set = _NP_CTORS) -> str | None:
        f = node.func
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
                and f.value.id in np_names and f.attr in ctors:
            return f"{f.value.id}.{f.attr}"
        return None

    def _module_constants(self, ctx, np_names: set) -> dict:
        """Module-level ``NAME = np.ctor(...)`` / numeric-literal-seq
        bindings: name -> short description."""
        consts = {}
        for stmt in ctx.tree.body:
            if isinstance(stmt, ast.Assign):
                targets, value = stmt.targets, stmt.value
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                targets, value = [stmt.target], stmt.value
            else:
                continue
            desc = None
            if isinstance(value, ast.Call):
                ctor = self._np_ctor_call(value, np_names, _NP_MODULE_CTORS)
                if ctor is not None:
                    desc = f"{ctor}(...)"
            elif _is_numeric_literal_seq(value):
                desc = "numeric list/tuple literal"
            if desc is None:
                continue
            for t in targets:
                if isinstance(t, ast.Name):
                    consts[t.id] = desc
        return consts

    def check(self, ctx):
        np_names = _numpy_aliases(ctx.tree)
        traced = ctx.traced_functions
        mod_consts = self._module_constants(ctx, np_names)
        for node in ast.walk(ctx.tree):
            fn = ctx.enclosing_function(node)
            if fn is None or fn not in traced:
                continue
            if isinstance(node, ast.Call) and np_names:
                ctor = self._np_ctor_call(node, np_names)
                if ctor is not None:
                    yield from self.emit(
                        ctx, node,
                        f"{ctor}(...) inside a traced function builds a "
                        "host constant per call (re-uploaded at every "
                        "dispatch; IR004's census is the dynamic check) — "
                        "use jnp.* or pass it as an argument")
            elif isinstance(node, ast.Name) \
                    and isinstance(node.ctx, ast.Load) \
                    and node.id in mod_consts:
                yield from self.emit(
                    ctx, node,
                    f"module constant '{node.id}' ({mod_consts[node.id]}) "
                    "captured by a traced function: baked into the "
                    "executable and re-sent per dispatch — make it a jnp "
                    "constant inside the trace or an explicit argument")
