"""TL001 — determinism: no unseeded or process-varying entropy sources."""
from __future__ import annotations

import ast

from repro.analysis.lint.framework import Rule

EXPLAIN = """\
TL001 determinism — every random draw and every string-keyed seed must be
process-stable.

Motivating bug (PR 2): trace phases were seeded with ``hash(customer)``;
``hash(str)`` is randomized per interpreter process (PYTHONHASHSEED), so
the same simulation seed produced different thermal trajectories on every
run.  Fixed with crc32 (``repro.core.traces._stable_seed``) — which is
what this rule points you at.

Flags:
  * stdlib ``random.*`` calls (module-global RNG — unseeded AND shared);
  * ``np.random.<fn>(...)`` legacy module-global draws (``np.random.seed``
    included: it mutates global state under every other caller);
  * ``np.random.default_rng()`` with no seed argument;
  * ``hash(...)`` — use ``repro.core.traces._stable_seed`` /
    ``zlib.crc32`` for anything that feeds a seed, key or bucket;
  * iterating directly over ``set(...)`` / set literals / frozenset in
    ``for``/comprehensions — set order is hash-order; wrap in
    ``sorted(...)`` before it can touch scheduling decisions.

Fix: draw from ``np.random.default_rng(seed)`` where ``seed`` derives
from config / ``repro.core.traces.trace_seed(seed, namespace)``.
"""


class DeterminismRule(Rule):
    code = "TL001"
    name = "determinism"
    EXPLAIN = EXPLAIN

    def check(self, ctx):
        stdlib_random = False
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                stdlib_random |= any(a.name == "random" and a.asname is None
                                     for a in node.names)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                yield from self._check_call(ctx, node, stdlib_random)
            elif isinstance(node, (ast.For, ast.comprehension)):
                it = node.iter
                if self._is_set_expr(it):
                    yield from self.emit(
                        ctx, it if isinstance(node, ast.comprehension)
                        else node,
                        "iteration over a set is hash-order-dependent; "
                        "wrap in sorted(...) before order can leak into "
                        "scheduling")

    @staticmethod
    def _is_set_expr(node) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("set", "frozenset"):
            return True
        # `a | b` over set(...) builds — the common union-then-iterate shape
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
            return (DeterminismRule._is_set_expr(node.left)
                    or DeterminismRule._is_set_expr(node.right))
        return False

    def _check_call(self, ctx, node, stdlib_random):
        chain = ctx._call_chain(node.func)
        if len(chain) >= 2 and chain[-2:-1] == ["random"] \
                and chain[0] in ("np", "numpy"):
            fn = chain[-1]
            if fn == "default_rng":
                if not node.args and not node.keywords:
                    yield from self.emit(
                        ctx, node,
                        "np.random.default_rng() without a seed is "
                        "entropy-seeded; derive the seed from config "
                        "(traces.trace_seed)")
            elif fn not in ("Generator", "BitGenerator", "PCG64",
                            "Philox", "SeedSequence"):
                yield from self.emit(
                    ctx, node,
                    f"np.random.{fn}() uses the legacy module-global RNG; "
                    "use np.random.default_rng(seed) with a config-derived "
                    "seed (traces.trace_seed)")
        elif stdlib_random and len(chain) == 2 and chain[0] == "random":
            yield from self.emit(
                ctx, node,
                f"stdlib random.{chain[1]}() draws from the shared "
                "module-global RNG; use np.random.default_rng(seed) "
                "(traces.trace_seed)")
        elif chain == ["hash"]:
            yield from self.emit(
                ctx, node,
                "hash() is randomized per process (PYTHONHASHSEED); use "
                "traces._stable_seed / zlib.crc32 for seeds and keys")
