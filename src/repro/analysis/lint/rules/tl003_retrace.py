"""TL003 — retrace hazard: no per-call-varying shapes or Python branches
on runtime values inside jitted code."""
from __future__ import annotations

import ast

from repro.analysis.lint.framework import Rule

EXPLAIN = """\
TL003 retrace hazard — a jitted graph must be one graph.

Motivating bug (PR 6): the fused decode horizon passed
``num_steps=min(horizon, max_remaining_budget)`` as a jit static arg; the
shrinking tail re-specialized (recompiled) the whole scan mid-measurement,
so step-time benches measured the compiler, not the model.  Fixed by
always launching ``horizon`` steps and parking drained rounds on device
with ``lax.cond``.

Flags:
  * Python ``if``/``while`` inside a traced function whose test reads a
    runtime parameter of that function (branching on a tracer either
    raises ConcretizationError or — when the value is concrete at trace
    time, e.g. a shape-dependent int — bakes a per-call specialization).
    Tests on statics (``self``/``cfg``/``params``/``num_steps``/...),
    ``x is None`` checks, ``isinstance`` checks and ``len(...)``/
    ``.shape``/``.ndim``/``.dtype`` probes are allowed: those are
    trace-time constants.
  * call sites of jitted entry points (``self._*_jit(...)``) passing a
    *computed* expression (min/max/arithmetic/len) to a known static
    kwarg (``num_steps``/``max_len``/``spec_k``/``ngram``/``horizon``):
    each distinct value is a fresh compile — pass a stable knob and mask
    the tail on device instead.

Fix: replace the Python branch with ``jnp.where``/``lax.cond``, and pin
static kwargs to engine-lifetime constants.
"""

#: statics commonly threaded through this repo's traced functions
_STATIC_NAMES = {"self", "cls", "cfg", "plan", "params", "config",
                 "num_steps", "max_len", "spec_k", "ngram", "horizon",
                 "block_size", "kwargs", "kw"}
_STATIC_KWARGS = {"num_steps", "max_len", "spec_k", "ngram", "horizon"}
_STATIC_PROBES = {"shape", "ndim", "dtype", "size"}


class RetraceRule(Rule):
    code = "TL003"
    name = "retrace-hazard"
    scopes = ("src/repro/serving", "src/repro/models", "src/repro/kernels")
    EXPLAIN = EXPLAIN

    def check(self, ctx):
        traced = ctx.traced_functions
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.If, ast.While)):
                fn = ctx.enclosing_function(node)
                if fn is None or fn not in traced:
                    continue
                name = self._runtime_name_in_test(node.test, fn)
                if name is not None:
                    kind = "while" if isinstance(node, ast.While) else "if"
                    yield from self.emit(
                        ctx, node,
                        f"Python `{kind}` on runtime value '{name}' inside "
                        "a traced function retraces per value (or raises "
                        "on a tracer); use jnp.where / lax.cond")
            elif isinstance(node, ast.Call):
                yield from self._check_static_kwargs(ctx, node)

    # -- data-dependent branch test ---------------------------------------
    @classmethod
    def _runtime_name_in_test(cls, test: ast.AST, fn) -> str | None:
        """First runtime (non-static) parameter of ``fn`` the test reads
        outside an allowed probe context, or None."""
        a = fn.args
        all_params = a.posonlyargs + a.args + a.kwonlyargs
        params = {x.arg for x in all_params}
        if a.vararg:
            params.add(a.vararg.arg)
        # params annotated as Python scalars (bool/int/float/str) are
        # trace-time statics by repo convention (static_argnames /
        # closure flags like `causal: bool`); runtime values are arrays
        static_annotated = set()
        for x in all_params:
            if x.annotation is not None:
                try:
                    ann = ast.unparse(x.annotation)
                except Exception:  # pragma: no cover
                    ann = ""
                if ann.split("|")[0].strip() in ("bool", "int", "float",
                                                 "str"):
                    static_annotated.add(x.arg)
        runtime = params - _STATIC_NAMES - static_annotated
        if not runtime:
            return None
        # `x is None` / `x is not None` / isinstance(...) guards are
        # trace-time structure checks, not value branches
        if isinstance(test, ast.Compare) and len(test.ops) == 1 \
                and isinstance(test.ops[0], (ast.Is, ast.IsNot)):
            return None
        if isinstance(test, ast.Call):
            chain_last = test.func.attr \
                if isinstance(test.func, ast.Attribute) else \
                (test.func.id if isinstance(test.func, ast.Name) else "")
            if chain_last in ("isinstance", "hasattr", "callable"):
                return None
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            return cls._runtime_name_in_test(test.operand, fn)
        if isinstance(test, ast.BoolOp):
            for v in test.values:
                hit = cls._runtime_name_in_test(v, fn)
                if hit is not None:
                    return hit
            return None
        allowed: set[int] = set()
        for sub in ast.walk(test):
            # len(x), x.shape/.ndim/.dtype/.size: static under trace
            if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                    and sub.func.id == "len":
                allowed.update(id(n) for n in ast.walk(sub))
            elif isinstance(sub, ast.Attribute) \
                    and sub.attr in _STATIC_PROBES:
                allowed.update(id(n) for n in ast.walk(sub))
        for sub in ast.walk(test):
            if isinstance(sub, ast.Name) and sub.id in runtime \
                    and id(sub) not in allowed:
                return sub.id
        return None

    # -- per-call-varying static kwargs at jit call sites ------------------
    def _check_static_kwargs(self, ctx, node: ast.Call):
        if not isinstance(node.func, ast.Attribute) \
                or not node.func.attr.endswith("_jit"):
            return
        for kw in node.keywords:
            if kw.arg not in _STATIC_KWARGS:
                continue
            if self._varies_per_call(kw.value):
                yield from self.emit(
                    ctx, node,
                    f"static kwarg {kw.arg}= computed per call "
                    f"({ast.unparse(kw.value)}): every distinct value "
                    "recompiles the graph mid-run (the PR 6 shrinking-"
                    "tail bug); pass a stable knob and mask the tail "
                    "on device")

    @staticmethod
    def _varies_per_call(value: ast.AST) -> bool:
        """A computed expression (min/len/arithmetic) rather than a
        constant, plain name, or attribute read."""
        if isinstance(value, (ast.Constant, ast.Name)):
            return False
        if isinstance(value, ast.Attribute):
            return False                       # self.horizon etc.
        return True
