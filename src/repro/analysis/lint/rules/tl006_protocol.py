"""TL006 — protocol conformance for control-plane policy classes."""
from __future__ import annotations

import ast

from repro.analysis.lint.framework import Rule

EXPLAIN = """\
TL006 protocol conformance — a policy that implements *most* of
``ControlPolicy``/``FleetPolicy`` passes ``isinstance`` checks it should
fail.

The control-plane protocols are ``runtime_checkable``, which only checks
method *presence* by name — a policy missing ``release`` (or taking
``(self, state)`` where the sim calls ``(self, state, server)``) imports
cleanly, drives most of a drill, then dies mid-run on the first VM
departure, wasting a whole debugging cycle on what is a signature typo.

Detection: every class that defines at least half of a scanned
``Protocol``'s methods (or names the protocol in its bases) is treated as
an implementor and must:
  * define *every* protocol method, and
  * match each method's positional parameter names (extra trailing
    parameters are allowed only with defaults; ``**kwargs`` absorbs
    anything).

Fix: implement the full surface; stubs that intentionally do nothing
should still exist (``return None``) so the contract stays checkable.
"""


class ProtocolConformanceRule(Rule):
    code = "TL006"
    name = "protocol-conformance"
    EXPLAIN = EXPLAIN

    def check(self, ctx):
        protocols = ctx.registry.protocols
        if not protocols:
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            base_names = {n for b in node.bases
                          for n in ctx._call_chain(b)}
            if "Protocol" in base_names:
                continue                      # the protocol itself
            methods = {s.name: s for s in node.body
                       if isinstance(s, ast.FunctionDef)}
            for pname, proto in protocols.items():
                declared = pname in base_names
                overlap = len(set(proto.methods) & set(methods))
                # all-but-one: adapters legitimately share a couple of
                # hook names with a protocol; a class one method short of
                # the full surface is the bug shape worth catching
                needed = max(2, len(proto.methods) - 1)
                if not declared and overlap < needed:
                    continue
                missing = sorted(set(proto.methods) - set(methods))
                if missing:
                    yield from self.emit(
                        ctx, node,
                        f"class {node.name} implements {overlap}/"
                        f"{len(proto.methods)} of {pname} but is missing "
                        f"{', '.join(missing)}; runtime_checkable "
                        "isinstance would only fail mid-drill")
                for mname, proto_args in proto.methods.items():
                    impl = methods.get(mname)
                    if impl is None:
                        continue
                    yield from self._check_signature(
                        ctx, impl, pname, mname, proto_args)

    def _check_signature(self, ctx, impl: ast.FunctionDef, pname, mname,
                         proto_args):
        a = impl.args
        if a.kwarg is not None:
            return                            # **kwargs absorbs anything
        impl_args = [x.arg for x in a.posonlyargs + a.args
                     if x.arg not in ("self", "cls")]
        n = len(proto_args)
        if a.vararg is not None and len(impl_args) <= n:
            return                            # *args covers the tail
        if impl_args[:n] != proto_args:
            yield from self.emit(
                ctx, impl,
                f"{mname}({', '.join(impl_args)}) does not match "
                f"{pname}.{mname}({', '.join(proto_args)}); the sim "
                "calls positionally — rename/reorder to the protocol")
            return
        full = [x.arg for x in a.posonlyargs + a.args]
        defaults_start = len(full) - len(a.defaults)
        self_off = len(full) - len(impl_args)      # 0 or 1 (self/cls)
        for i, extra in enumerate(impl_args[n:]):
            if self_off + n + i < defaults_start:
                yield from self.emit(
                    ctx, impl,
                    f"{mname} adds required parameter '{extra}' beyond "
                    f"{pname}.{mname}; give it a default (the sim will "
                    "never pass it)")
