"""TL002 — host-sync leak: no tracer-to-host coercion on the hot path."""
from __future__ import annotations

import ast

from repro.analysis.lint.framework import Rule

EXPLAIN = """\
TL002 host-sync leak — device values must not be coerced to host scalars
inside serving/model hot-path code.

The decode hot path is engineered around ONE host sync per drained
horizon (PR 3: 5.6x sync reduction); a single stray ``.item()`` /
``float(tracer)`` / ``np.asarray(jit_output)`` re-serializes the device
stream and silently costs the whole batch a round-trip — or, inside a
traced function, raises ConcretizationError only on the untraced branch
nobody tested.

Flags, inside functions that run under a jax trace (``@jit``-decorated,
passed to ``jax.jit``/``lax.scan``/``lax.cond``/..., named like a
``decode_*``/``prefill_*``/kernel entry point, or nested in one):
  * ``x.item()``, ``x.tolist()``;
  * ``float(x)`` / ``int(x)`` / ``bool(x)`` on a non-constant argument;
  * ``np.asarray(x)`` / ``np.array(x)`` — the result silently leaves the
    traced graph;
  * ``jax.device_get(x)``.

Outside traced functions (engine scheduler code in ``serving/``), only
``.item()``/``.tolist()`` are flagged: per-element readbacks hide in stats
paths, whereas one batched ``np.asarray`` per horizon is the sanctioned
sync idiom (and is counted in ``EngineStats.host_syncs``).

Fix: keep the value on device (mask/where), or batch the readback at the
horizon boundary and account it in ``stats.host_syncs``.  Genuinely cold
readbacks can be annotated ``# tapaslint: disable=TL002``.
"""

_COERCERS = ("float", "int", "bool")


class HostSyncRule(Rule):
    code = "TL002"
    name = "host-sync-leak"
    scopes = ("src/repro/serving", "src/repro/models", "src/repro/kernels")
    EXPLAIN = EXPLAIN

    def check(self, ctx):
        traced = ctx.traced_functions
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = ctx.enclosing_function(node)
            in_trace = fn is not None and fn in traced
            chain = ctx._call_chain(node.func)
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ("item", "tolist") \
                    and not node.args:
                yield from self.emit(
                    ctx, node,
                    f".{node.func.attr}() forces a device->host sync per "
                    "element; batch the readback (one np.asarray per "
                    "horizon) and count it in stats.host_syncs")
                continue
            if not in_trace:
                continue
            if chain[-1:] == ["device_get"]:
                yield from self.emit(
                    ctx, node, "jax.device_get inside a traced function "
                    "breaks out of the graph; return the value instead")
            elif len(chain) == 2 and chain[0] in ("np", "numpy") \
                    and chain[1] in ("asarray", "array"):
                yield from self.emit(
                    ctx, node,
                    f"np.{chain[1]}() on a traced value concretizes it "
                    "(host sync / ConcretizationError); use jnp inside "
                    "traced code")
            elif chain in (["float"], ["int"], ["bool"]) and node.args \
                    and not isinstance(node.args[0], ast.Constant):
                yield from self.emit(
                    ctx, node,
                    f"{chain[0]}() on a traced value concretizes it; keep "
                    "it a jnp scalar (or read it back at the horizon "
                    "boundary)")
