"""Rule registry: one instance per TL rule, in code order."""
from repro.analysis.lint.rules.tl001_determinism import DeterminismRule
from repro.analysis.lint.rules.tl002_host_sync import HostSyncRule
from repro.analysis.lint.rules.tl003_retrace import RetraceRule
from repro.analysis.lint.rules.tl004_dataclass_copy import DataclassCopyRule
from repro.analysis.lint.rules.tl005_units import UnitSuffixRule
from repro.analysis.lint.rules.tl006_protocol import ProtocolConformanceRule
from repro.analysis.lint.rules.tl007_swallowed_error import SwallowedErrorRule
from repro.analysis.lint.rules.tl008_np_const import NpConstRule

ALL_RULES = [
    DeterminismRule(),
    HostSyncRule(),
    RetraceRule(),
    DataclassCopyRule(),
    UnitSuffixRule(),
    ProtocolConformanceRule(),
    SwallowedErrorRule(),
    NpConstRule(),
]

RULES_BY_CODE = {r.code: r for r in ALL_RULES}

__all__ = ["ALL_RULES", "RULES_BY_CODE"]
