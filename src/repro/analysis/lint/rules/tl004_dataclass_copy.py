"""TL004 — dataclass-copy completeness: modified copies must carry every
field (or use dataclasses.replace)."""
from __future__ import annotations

import ast

from repro.analysis.lint.framework import Rule

EXPLAIN = """\
TL004 dataclass-copy completeness — a hand-rolled "copy with tweaks" of a
config dataclass silently resets every field it forgets.

Motivating bug (PR 5): ``scale_datacenter`` rebuilt ``DCConfig`` field by
field and omitted ``power_provision_frac``/``airflow_provision_frac`` —
custom-provisioned regions quietly reverted to the defaults, skewing
every planner sweep over them until a drill surfaced it.

Detection: a constructor call ``X(...)`` where ``X`` is a repo dataclass
and at least two keyword arguments are verbatim field reads off one
source object (``f=src.f``) is a copy; the rule then requires every field
of ``X`` to appear as a keyword (positional args count positionally).
Missing fields are listed in the message.

Fix: ``dataclasses.replace(src, changed=...)`` — it fails loudly on
unknown fields and can never drop one.  (Adding a field to the dataclass
later keeps working, which the hand-rolled copy never does.)
"""


class DataclassCopyRule(Rule):
    code = "TL004"
    name = "dataclass-copy"
    EXPLAIN = EXPLAIN

    def check(self, ctx):
        specs = ctx.registry.dataclasses
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            cname = None
            if isinstance(node.func, ast.Name):
                cname = node.func.id
            elif isinstance(node.func, ast.Attribute):
                cname = node.func.attr
            spec = specs.get(cname or "")
            if spec is None:
                continue
            kw_named = {kw.arg for kw in node.keywords if kw.arg}
            has_splat = any(kw.arg is None for kw in node.keywords)
            # copy-shaped: >=2 kwargs are `field=<src>.field` off one obj
            src_counts: dict[str, int] = {}
            for kw in node.keywords:
                if kw.arg and isinstance(kw.value, ast.Attribute) \
                        and kw.value.attr == kw.arg \
                        and isinstance(kw.value.value, ast.Name):
                    src = kw.value.value.id
                    src_counts[src] = src_counts.get(src, 0) + 1
            if not src_counts or max(src_counts.values()) < 2:
                continue
            if has_splat:
                continue                     # X(**asdict(src), ...) is total
            covered = kw_named | set(spec.fields[:len(node.args)])
            missing = [f for f in spec.fields if f not in covered]
            if missing:
                src = max(src_counts, key=src_counts.get)
                yield from self.emit(
                    ctx, node,
                    f"field-by-field copy of {cname} drops "
                    f"{', '.join(missing)} (silently reset to defaults — "
                    f"the scale_datacenter bug); use "
                    f"dataclasses.replace({src}, ...)")
