"""TL005 — unit-suffix discipline for physical quantities in core/."""
from __future__ import annotations

import ast
import re

from repro.analysis.lint.framework import Rule

EXPLAIN = """\
TL005 unit-suffix discipline — ``core/`` carries real physics; names carry
the units.

The thermal/power model mixes watts, CFM, degrees C, hours, seconds,
fractions-of-provisioned and kWh in one dataflow.  The repo convention is
a unit suffix on every field and variable holding a physical quantity:

    suffix   unit                       examples
    ------   ------------------------   --------------------------------
    _w       watts                      idle_power_w, peak_power_w
    _kw      kilowatts                  (reserved; convert at the edge)
    _kwh     kilowatt-hours             energy_kwh
    _c       degrees Celsius            gpu_temp_limit_c, t_outside_c
    _ms      milliseconds               wan_rtt_ms, rtt_budget_ms
    _s       seconds                    finish_s, first_token_s
    _h       hours                      now_h, horizon_h, arrival_h
    _frac    fraction of provisioned    power_provision_frac
    _cfm     cubic feet / minute        airflow_idle_cfm
    _kg      kilograms (CO2)            carbon_kg

Flags:
  * ``+``/``-``/comparison between names carrying *different* unit
    suffixes (``x_c + y_w`` is meaningless; ``x_ms + y_s`` and
    ``x_w + y_kw`` are scale bugs).  ``*``/``/`` are exempt — they
    legitimately form new units.
  * dataclass fields in ``core/`` whose name says physical quantity
    (power/temp/energy/airflow/rtt/latency) but carries no unit suffix —
    dimensionless knobs end in ``_scale``/``_frac``/``_headroom``/
    ``_weight``/``_index``/``_quantile`` instead.

Fix: rename to carry the unit, or convert explicitly at the boundary
(and name the converted value with its new suffix).
"""

_SUFFIX_RE = re.compile(r"_(w|kw|kwh|c|ms|s|h|frac|cfm|kg)$")
#: suffix -> dimension; mixing inside a dimension is a *scale* bug,
#: across dimensions a *meaning* bug — both flagged.
_DIMENSION = {"w": "power", "kw": "power", "kwh": "energy",
              "c": "temperature", "ms": "time", "s": "time", "h": "time",
              "frac": "fraction", "cfm": "airflow", "kg": "mass"}
_QUANTITY_RE = re.compile(
    r"(^|_)(power|temp|energy|airflow|rtt|latency)(_|$)")
_DIMENSIONLESS_RE = re.compile(
    r"_(scale|headroom|weight|index|quantile|kind|name|id|"
    r"events|rows|mask|count|cap)$")
#: annotations that can hold a bare physical scalar/array; fields typed
#: as model objects (PowerModel, ThermalModel, ...) carry their own units
_NUMERIC_ANN_RE = re.compile(
    r"^(float|int|(np|jnp|numpy)\.ndarray|jnp\.Array)")


def _unit_of(node: ast.AST) -> str | None:
    """Unit suffix of a name/attribute operand, if any."""
    name = None
    if isinstance(node, ast.Name):
        name = node.id
    elif isinstance(node, ast.Attribute):
        name = node.attr
    if name is None:
        return None
    m = _SUFFIX_RE.search(name)
    return m.group(1) if m else None


class UnitSuffixRule(Rule):
    code = "TL005"
    name = "unit-suffix"
    scopes = ("src/repro/core",)
    EXPLAIN = EXPLAIN

    def check(self, ctx):
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.BinOp) \
                    and isinstance(node.op, (ast.Add, ast.Sub)):
                yield from self._check_pair(ctx, node, node.left,
                                            node.right)
            elif isinstance(node, ast.Compare):
                operands = [node.left, *node.comparators]
                for a, b in zip(operands, operands[1:]):
                    yield from self._check_pair(ctx, node, a, b)
            elif isinstance(node, ast.ClassDef):
                yield from self._check_fields(ctx, node)

    def _check_pair(self, ctx, node, left, right):
        ul, ur = _unit_of(left), _unit_of(right)
        if ul is None or ur is None or ul == ur:
            return
        dl, dr = _DIMENSION[ul], _DIMENSION[ur]
        what = f"different scales of {dl}" if dl == dr else \
            f"{dl} with {dr}"
        yield from self.emit(
            ctx, node,
            f"arithmetic mixes _{ul} and _{ur} ({what}); convert "
            "explicitly and name the result with its unit")

    def _check_fields(self, ctx, node: ast.ClassDef):
        is_dc = any("dataclass" in ctx._call_chain(
            d.func if isinstance(d, ast.Call) else d)
            for d in node.decorator_list)
        if not is_dc:
            return
        for stmt in node.body:
            if not (isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)):
                continue
            name = stmt.target.id
            if _SUFFIX_RE.search(name) or _DIMENSIONLESS_RE.search(name):
                continue
            try:
                ann = ast.unparse(stmt.annotation)
            except Exception:  # pragma: no cover - unparse never fails here
                ann = ""
            if not _NUMERIC_ANN_RE.match(ann):
                continue
            if _QUANTITY_RE.search(name):
                yield from self.emit(
                    ctx, stmt,
                    f"field '{name}' holds a physical quantity but has "
                    "no unit suffix (_w/_kw/_kwh/_c/_ms/_s/_h/_frac/"
                    "_cfm/_kg); name the unit or a dimensionless role "
                    "(_scale/_frac/_headroom)")
