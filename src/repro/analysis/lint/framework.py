"""tapaslint: AST framework for repo-specific invariant checking.

Every rule here is derived from a bug this repo actually shipped (see the
rule modules' ``EXPLAIN`` texts and README "Static analysis & invariants").
The framework is deliberately stdlib-only — the CI lint lane runs it
without installing jax/numpy — and deals in three currencies:

* ``Finding`` — one violation, keyed for the baseline by
  ``(rule, path, enclosing symbol, message)`` and *not* by line number, so
  unrelated edits above a grandfathered finding don't churn the baseline.
* suppression — ``# tapaslint: disable=TL002`` (or ``disable=all``) on the
  flagged line or the enclosing ``def``/``class`` line silences a finding
  at the source; ``# tapaslint: disable-file=TL005`` anywhere in the file
  silences a rule for the whole module.
* baseline — a checked-in multiset of grandfathered finding keys
  (``scripts/tapaslint_baseline.txt``).  CI fails on any finding *not* in
  the baseline; stale baseline entries are reported so the file shrinks as
  defects are fixed.

Rules see a ``ModuleContext`` (per file: source, AST, parent links,
qualified names, traced-function detection) plus a ``Registry`` built in a
first pass over the whole file set (dataclass field lists and Protocol
method signatures — rules TL004/TL006 need cross-module knowledge).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Finding:
    rule: str          # "TL001"
    path: str          # repo-relative posix path
    line: int          # 1-based, for humans; not part of the baseline key
    message: str
    symbol: str = ""   # enclosing def/class qualname ("" == module level)

    def key(self) -> str:
        """Line-independent identity used by the baseline."""
        return f"{self.rule} {self.path}::{self.symbol} {self.message}"

    def render(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{self.path}:{self.line}: {self.rule} {self.message}{sym}"


_SUPPRESS_RE = re.compile(r"#\s*tapaslint:\s*disable=([A-Za-z0-9,]+)")
_SUPPRESS_FILE_RE = re.compile(r"#\s*tapaslint:\s*disable-file=([A-Za-z0-9,]+)")


def _codes(match: re.Match) -> set[str]:
    return {c.strip().upper() for c in match.group(1).split(",") if c.strip()}


class ModuleContext:
    """One parsed module plus the lazy per-module analyses rules share."""

    def __init__(self, path: str, source: str, registry: "Registry"):
        self.path = path                       # repo-relative posix path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.registry = registry
        self._parents: dict[ast.AST, ast.AST] | None = None
        self._traced: set[ast.AST] | None = None
        self._line_suppress: dict[int, set[str]] = {}
        self._file_suppress: set[str] = set()
        for i, ln in enumerate(self.lines, start=1):
            m = _SUPPRESS_FILE_RE.search(ln)
            if m:
                self._file_suppress |= _codes(m)
                continue
            m = _SUPPRESS_RE.search(ln)
            if m:
                self._line_suppress[i] = _codes(m)

    # -- tree plumbing -----------------------------------------------------
    @property
    def parents(self) -> dict[ast.AST, ast.AST]:
        if self._parents is None:
            self._parents = {}
            for node in ast.walk(self.tree):
                for child in ast.iter_child_nodes(node):
                    self._parents[child] = node
        return self._parents

    def ancestors(self, node: ast.AST):
        cur = self.parents.get(node)
        while cur is not None:
            yield cur
            cur = self.parents.get(cur)

    def qualname(self, node: ast.AST) -> str:
        """Dotted name of the innermost enclosing def/class (incl. node)."""
        parts: list[str] = []
        for n in [node, *self.ancestors(node)]:
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
                parts.append(n.name)
        return ".".join(reversed(parts))

    def enclosing_function(self, node: ast.AST):
        for n in [node, *self.ancestors(node)]:
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return n
        return None

    # -- traced-function detection (shared by TL002/TL003) -----------------
    TRACE_WRAPPERS = {"jit", "pmap", "vmap", "grad", "value_and_grad",
                      "scan", "cond", "while_loop", "fori_loop", "switch",
                      "checkpoint", "remat", "pallas_call"}
    #: method-name shapes that are traced by callers in *other* modules
    #: (the engine jits ``Model.decode_*``/``prefill_*``; kernels are
    #:  pallas bodies) — static reachability without whole-program analysis.
    HOT_NAME_RE = re.compile(
        r"^(decode_|prefill_|gqa_prefill|block_|_flash|_paged|.*_kernel$)")

    def _call_chain(self, func: ast.AST) -> list[str]:
        parts: list[str] = []
        while isinstance(func, ast.Attribute):
            parts.append(func.attr)
            func = func.value
        if isinstance(func, ast.Name):
            parts.append(func.id)
        return list(reversed(parts))

    @property
    def traced_functions(self) -> set[ast.AST]:
        """FunctionDefs that (transitively) run under a jax trace: wrapped
        in jit/scan/cond/..., named like a known hot-path entry point, or
        nested inside either."""
        if self._traced is not None:
            return self._traced
        traced: set[ast.AST] = set()
        by_name: dict[str, list[ast.AST]] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                by_name.setdefault(node.name, []).append(node)
                for dec in node.decorator_list:
                    names = set(self._call_chain(
                        dec.func if isinstance(dec, ast.Call) else dec))
                    # @jax.jit, @functools.partial(jax.jit, ...), @jit
                    if names & self.TRACE_WRAPPERS:
                        traced.add(node)
                    if "partial" in names and isinstance(dec, ast.Call):
                        for arg in dec.args:
                            if set(self._call_chain(arg)) \
                                    & self.TRACE_WRAPPERS:
                                traced.add(node)
                if self.HOT_NAME_RE.match(node.name):
                    traced.add(node)
        # functions passed (by name) into jit/scan/cond/... call sites
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = self._call_chain(node.func)
            if not (set(chain) & self.TRACE_WRAPPERS):
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                for ref in ast.walk(arg):
                    if isinstance(ref, ast.Name) and ref.id in by_name:
                        traced.update(by_name[ref.id])
        # closure: defs nested inside traced defs are traced
        changed = True
        while changed:
            changed = False
            for fns in by_name.values():
                for fn in fns:
                    if fn in traced:
                        continue
                    for anc in self.ancestors(fn):
                        if anc in traced:
                            traced.add(fn)
                            changed = True
                            break
        self._traced = traced
        return traced

    # -- suppression -------------------------------------------------------
    def suppressed(self, rule: str, node: ast.AST) -> bool:
        if rule in self._file_suppress or "ALL" in self._file_suppress:
            return True
        cand_lines = {getattr(node, "lineno", 0)}
        fn = self.enclosing_function(node)
        if fn is not None:
            cand_lines.add(fn.lineno)
        for n in self.ancestors(node):
            if isinstance(n, ast.ClassDef):
                cand_lines.add(n.lineno)
                break
        for ln in cand_lines:
            codes = self._line_suppress.get(ln, set())
            if rule in codes or "ALL" in codes:
                return True
        return False

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule=rule, path=self.path,
                       line=getattr(node, "lineno", 0), message=message,
                       symbol=self.qualname(node))


@dataclass
class ProtocolSpec:
    name: str
    path: str
    methods: dict = field(default_factory=dict)  # name -> [arg names] (no self)


@dataclass
class DataclassSpec:
    name: str
    path: str
    fields: list = field(default_factory=list)   # declaration order
    frozen: bool = False


class Registry:
    """Cross-module facts collected in pass 1 (before any rule runs)."""

    def __init__(self):
        self.dataclasses: dict[str, DataclassSpec] = {}
        self.protocols: dict[str, ProtocolSpec] = {}

    def collect(self, ctx: ModuleContext) -> None:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            deco_names = set()
            frozen = False
            for dec in node.decorator_list:
                chain = ctx._call_chain(
                    dec.func if isinstance(dec, ast.Call) else dec)
                deco_names.update(chain)
                if isinstance(dec, ast.Call) and "dataclass" in chain:
                    for kw in dec.keywords:
                        if kw.arg == "frozen" and isinstance(
                                kw.value, ast.Constant):
                            frozen = bool(kw.value.value)
            base_names = {n for b in node.bases for n in ctx._call_chain(b)}
            if "dataclass" in deco_names:
                fields = [s.target.id for s in node.body
                          if isinstance(s, ast.AnnAssign)
                          and isinstance(s.target, ast.Name)
                          and not s.target.id.startswith("_")]
                if fields:
                    self.dataclasses[node.name] = DataclassSpec(
                        node.name, ctx.path, fields, frozen)
            if "Protocol" in base_names:
                spec = ProtocolSpec(node.name, ctx.path)
                for s in node.body:
                    if isinstance(s, ast.FunctionDef) \
                            and not s.name.startswith("_"):
                        args = [a.arg for a in s.args.args
                                if a.arg != "self"]
                        spec.methods[s.name] = args
                if spec.methods:
                    self.protocols[node.name] = spec


class Rule:
    """Base class: subclasses set ``code``/``name``/``EXPLAIN`` and
    implement ``check(ctx) -> iterator of Finding``."""

    code = "TL000"
    name = "base"
    EXPLAIN = ""
    #: repo-relative path prefixes the rule applies to ("" == everywhere)
    scopes: tuple = ("",)

    def applies(self, path: str) -> bool:
        return any(path.startswith(s) for s in self.scopes)

    def check(self, ctx: ModuleContext):
        raise NotImplementedError
        yield  # pragma: no cover

    def emit(self, ctx: ModuleContext, node: ast.AST, message: str):
        """Yield a finding unless suppressed at the source."""
        if not ctx.suppressed(self.code, node):
            yield ctx.finding(self.code, node, message)


# ---------------------------------------------------------------------------
# driving
# ---------------------------------------------------------------------------

def lint_sources(files: dict, rules: list | None = None) -> list[Finding]:
    """Lint in-memory sources: ``{repo-relative-path: source}``.

    Two passes: collect the cross-module registry, then run every rule
    over every module it scopes to.  Files that fail to parse yield a
    single TL000 syntax finding instead of aborting the run.
    """
    if rules is None:
        from repro.analysis.lint.rules import ALL_RULES
        rules = ALL_RULES
    registry = Registry()
    contexts: list[ModuleContext] = []
    findings: list[Finding] = []
    for path in sorted(files):
        try:
            ctx = ModuleContext(path, files[path], registry)
        except SyntaxError as e:
            findings.append(Finding("TL000", path, e.lineno or 0,
                                    f"syntax error: {e.msg}"))
            continue
        registry.collect(ctx)
        contexts.append(ctx)
    for ctx in contexts:
        for rule in rules:
            if rule.applies(ctx.path):
                findings.extend(rule.check(ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def collect_files(root, paths) -> dict:
    """Read every ``*.py`` under ``paths`` (relative to ``root``) into a
    ``{relative-posix-path: source}`` dict, skipping caches/results."""
    import pathlib
    root = pathlib.Path(root)
    out: dict[str, str] = {}
    for p in paths:
        base = root / p
        candidates = [base] if base.is_file() else sorted(base.rglob("*.py"))
        for f in candidates:
            rel = f.relative_to(root).as_posix()
            if "__pycache__" in rel or rel.startswith("benchmarks/results"):
                continue
            out[rel] = f.read_text()
    return out


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

def load_baseline(path) -> list[str]:
    import pathlib
    p = pathlib.Path(path)
    if not p.exists():
        return []
    return [ln.strip() for ln in p.read_text().splitlines()
            if ln.strip() and not ln.startswith("#")]


def diff_baseline(findings: list[Finding], baseline: list[str]):
    """Multiset-match finding keys against the baseline.

    Returns ``(new_findings, matched_keys, stale_keys)``: findings whose
    key is not grandfathered, the keys that matched, and baseline entries
    that no longer correspond to any finding (fixed — remove them)."""
    from collections import Counter
    remaining = Counter(baseline)
    new: list[Finding] = []
    matched: list[str] = []
    for f in findings:
        k = f.key()
        if remaining.get(k, 0) > 0:
            remaining[k] -= 1
            matched.append(k)
        else:
            new.append(f)
    stale = list((+remaining).elements())
    return new, matched, stale


def format_baseline(findings: list[Finding]) -> str:
    lines = [
        "# tapaslint baseline — grandfathered findings (CI fails on any",
        "# finding NOT listed here).  Regenerate after fixing an entry:",
        "#   PYTHONPATH=src python scripts/tapaslint.py --update-baseline",
        "# One key per line: '<rule> <path>::<symbol> <message>'.",
    ]
    lines += sorted(f.key() for f in findings)
    return "\n".join(lines) + "\n"
