"""Runtime teeth for the tapaslint invariants.

The static rules (TL002 host-sync, TL003 retrace) catch the *shapes* of
hot-path bugs; this module catches the *behavior* at test time:

* :func:`no_implicit_transfers` — ``jax.transfer_guard("disallow")``.
  Any host value (Python scalar, list, np array) flowing implicitly into
  jitted code raises.  Explicit ``jax.device_put`` / ``np.asarray`` of a
  device array stay sanctioned, so the engine's one-per-horizon readback
  and the kvcache's ``_dev_i32`` uploads pass while an accidental
  per-step upload trips.  (On the CPU backend device-to-host is
  zero-copy and unguarded; host-to-device still trips, which is the
  direction per-step leaks take.)
* :func:`no_leaked_tracers` — ``jax.checking_leaks()``: a tracer
  escaping its trace (stashed on ``self``, returned through a closure)
  raises at the leak site instead of as a deferred ConcretizationError.
* :func:`hot_path_guard` — both at once; what the marked kernel /
  engine-hot-path test modules run under (see ``tests/conftest.py``).
* :func:`retrace_budget` — asserts the jit compile-cache grew by at most
  ``budget`` entries across a region (the PR 6 shrinking-tail bug
  recompiled the fused scan every round; budget 0 over a drained run is
  the regression fence).

Unlike the rest of ``repro.analysis.lint`` (stdlib-only so the CI lint
lane can run it without jax), this module imports jax and is imported
separately, by tests.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Iterator

import jax
import numpy as np

__all__ = ["no_implicit_transfers", "no_leaked_tracers", "hot_path_guard",
           "sanctioned_readback", "cache_size", "jit_entries",
           "retrace_budget"]


@contextlib.contextmanager
def no_implicit_transfers() -> Iterator[None]:
    """Raise on any implicit host<->device transfer inside the block."""
    with jax.transfer_guard("disallow"):
        yield


@contextlib.contextmanager
def no_leaked_tracers() -> Iterator[None]:
    """Raise at the leak site if a tracer escapes its trace."""
    with jax.checking_leaks():
        yield


@contextlib.contextmanager
def hot_path_guard() -> Iterator[None]:
    """Transfer guard + leak check: the full hot-path discipline."""
    with jax.checking_leaks(), jax.transfer_guard("disallow"):
        yield


def sanctioned_readback(x: Any) -> np.ndarray:
    """Deliberate device->host sync, exempt from an enclosing guard.

    The serving engine budgets exactly one readback per fused horizon
    (``EngineStats.host_syncs``); code making that sanctioned sync under
    a guard routes it through here so the guard keeps teeth everywhere
    else.
    """
    with jax.transfer_guard("allow"):
        return np.asarray(jax.device_get(x))


def cache_size(fn: Any) -> int | None:
    """Compile-cache entry count of a jitted callable (None if the
    jax version does not expose it — the budget check then skips)."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except Exception:  # pragma: no cover - defensive against jax churn
        return None


def jit_entries(obj: Any) -> dict[str, Any]:
    """The live jitted entry points of an object, by attribute name.

    The serving engine binds its compiled functions as ``*_jit``
    attributes; this collects the non-None ones so a test can fence all
    of them at once: ``retrace_budget(*jit_entries(eng).values())``.
    """
    out: dict[str, Any] = {}
    for name in dir(obj):
        if not name.endswith("_jit"):
            continue
        fn = getattr(obj, name)
        if fn is not None and hasattr(fn, "_cache_size"):
            out[name] = fn
    return out


@contextlib.contextmanager
def retrace_budget(*jitted: Any, budget: int = 0,
                   names: Callable[[Any], str] = repr) -> Iterator[None]:
    """Assert each jitted callable compiles at most ``budget`` new graphs
    inside the block.

    Run warmup (one call per live shape bucket) *before* entering; a
    steady-state region should then hold at delta 0.  A positive delta
    means some call argument re-specialized the graph mid-run — the
    exact failure mode the fused decode horizon had in PR 6.
    """
    before = [cache_size(f) for f in jitted]
    yield
    over = []
    for f, b in zip(jitted, before):
        a = cache_size(f)
        if b is None or a is None:
            continue
        if a - b > budget:
            over.append(f"{names(f)}: +{a - b} compiles (budget {budget})")
    if over:
        raise AssertionError(
            "retrace budget exceeded — a static argument or shape varied "
            "per call inside the fenced region:\n  " + "\n  ".join(over))
