"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms, in seconds, per (arch x shape x mesh):

    compute    = HLO_FLOPs_total      / (chips * peak_FLOPs)
    memory     = HLO_bytes_total      / (chips * HBM_bw)
    collective = collective_bytes_dev / link_bw        (per-chip link bytes)

``cost_analysis()`` on the SPMD-partitioned module reports *per-partition*
flops/bytes; collective bytes are parsed from the optimized HLO text
(operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), which is also per-partition.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field


@dataclass(frozen=True)
class HWSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12  # per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    link_bw: float = 50e9            # bytes/s per ICI link
    hbm_bytes: float = 16e9          # capacity per chip


HW = HWSpec()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  f32[16,128]{1,0}   or  bf16[2,8,128]
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op (per-partition module).

    Returns {op_kind: bytes, ..., 'total': bytes, 'count': n}.
    """
    out: dict = {k: 0 for k in _COLLECTIVES}
    count = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result-side: "%x = f32[..] all-reduce(f32[..] %y, ...)"
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z\-]+)[.\d]*\(", stripped)
        if not m:
            continue
        kind = m.group(1)
        # normalise fused/start variants: all-reduce-start, all-gather-start...
        base = kind.replace("-start", "").replace("-done", "")
        if base not in _COLLECTIVES:
            continue
        if kind.endswith("-done"):
            continue  # operands of -done are the -start result; skip double count
        count += 1
        # operand types are inline inside the call parens
        args = stripped[m.end():]
        depth = 1
        end = 0
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        args = args[:end]
        for dm in _SHAPE_RE.finditer(args):
            out[base] += _shape_bytes(dm.group(1), dm.group(2))
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["count"] = count
    return out


def model_flops(n_params_active: int, shape_kind: str, tokens: int) -> float:
    """6*N*D for train, 2*N*D for prefill, 2*N*B for decode (per step)."""
    if shape_kind == "train":
        return 6.0 * n_params_active * tokens
    return 2.0 * n_params_active * tokens


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_breakdown: dict = field(default_factory=dict)
    model_flops_total: float = 0.0
    peak_mem_per_dev: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / HW.peak_flops_bf16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_dev / HW.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / HW.link_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_dev * self.chips
        return self.model_flops_total / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term-bound step time that is useful
        compute: (MODEL_FLOPS / chips / peak) / max(terms)."""
        ideal = self.model_flops_total / self.chips / HW.peak_flops_bf16
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        return ideal / bound if bound else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops_total,
            "hlo_flops_total": self.flops_per_dev * self.chips,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_mem_per_dev_gb": self.peak_mem_per_dev / 1e9,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "coll_breakdown": self.coll_breakdown,
        }


def roofline_terms(*, arch: str, shape: str, mesh: str, chips: int,
                   cost: dict, hlo_text: str, model_flops_total: float,
                   peak_mem: float) -> RooflineReport:
    """Three-term roofline from the compiled per-partition HLO.

    Uses analysis/hlo_cost.py (while-loop trip counts multiplied through);
    ``cost`` (XLA's own cost_analysis) is kept by the caller for reference
    but NOT used directly — it counts loop bodies once.
    """
    from repro.analysis.hlo_cost import analyze_hlo
    parsed = analyze_hlo(hlo_text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        flops_per_dev=float(parsed["flops"]),
        bytes_per_dev=float(parsed["bytes"]),
        coll_bytes_per_dev=float(parsed["coll_bytes"]),
        coll_breakdown=parsed["coll_breakdown"],
        model_flops_total=model_flops_total,
        peak_mem_per_dev=peak_mem,
    )
