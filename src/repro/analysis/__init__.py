from repro.analysis.roofline import (
    HW,
    RooflineReport,
    collective_bytes_from_hlo,
    model_flops,
    roofline_terms,
)

__all__ = ["HW", "RooflineReport", "collective_bytes_from_hlo",
           "model_flops", "roofline_terms"]
