"""State-space / linear-recurrence layers: Mamba (hymba branch) and RWKV6.

Train/prefill use chunked scans: an outer ``lax.scan`` over time chunks
carries the recurrent state, keeping HLO size O(1) in sequence length and
temporaries bounded; the Mamba inner chunk uses an associative scan
(work-efficient on TPU), RWKV6 uses an in-chunk sequential scan (the Pallas
``rwkv6_wkv`` kernel is the TPU fast path; see kernels/).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.sharding import ShardPlan

Params = dict[str, Any]


# ===========================================================================
# Mamba (selective SSM) — used as the parallel branch in hymba
# ===========================================================================

def _dt_rank(cfg: ArchConfig) -> int:
    return max(16, cfg.d_model // 16)


def init_mamba(key, cfg: ArchConfig, plan: ShardPlan) -> Params:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr = _dt_rank(cfg)
    dt = plan.param_dtype
    ks = jax.random.split(key, 6)
    return {
        "w_in": L.dense_init(ks[0], (d, 2, di), dtype=dt),  # x branch + gate z
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, di)) * 0.1).astype(dt),
        "w_bcdt": L.dense_init(ks[2], (di, dtr + 2 * n), dtype=dt),
        "w_dt": L.dense_init(ks[3], (dtr, di), dtype=dt),
        "dt_bias": jnp.full((di,), -4.6, dt),  # softplus^-1(0.01)
        "a_log": jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))).astype(dt),
        "d_skip": jnp.ones((di,), dt),
        "w_out": L.dense_init(ks[5], (di, d), dtype=dt),
    }


def mamba_axes(cfg: ArchConfig, plan: ShardPlan) -> Params:
    return {
        "w_in": ("embed", None, "d_inner"),
        "conv_w": ("conv", "d_inner"),
        "w_bcdt": ("d_inner", None),
        "w_dt": (None, "d_inner"),
        "dt_bias": ("d_inner",),
        "a_log": ("d_inner", "state"),
        "d_skip": ("d_inner",),
        "w_out": ("d_inner", "embed"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, x_prev: jax.Array | None = None):
    """Depthwise causal conv over time. x: (B, S, di); w: (cw, di).

    ``x_prev``: (B, cw-1, di) left context (decode/chunk carry); zeros if None.
    Returns (y (B, S, di), new left-context (B, cw-1, di)).
    """
    cw = w.shape[0]
    if x_prev is None:
        x_prev = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([x_prev, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    return y, xp[:, -(cw - 1):]


def _selective_scan_chunk(a, b, h0):
    """a, b: (B, C, di, n) decay / input; h0: (B, di, n). Returns (h_seq, h_last)."""
    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2
    a_cum, b_cum = jax.lax.associative_scan(op, (a, b), axis=1)
    h = a_cum * h0[:, None] + b_cum
    return h, h[:, -1]


def mamba_forward(p: Params, x: jax.Array, cfg: ArchConfig, plan: ShardPlan,
                  state: Params | None = None, *, chunk: int = 256):
    """x: (B, S, d) -> (y (B, S, d), new state). Train/prefill path."""
    dt = plan.compute_dtype
    B, S, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    dtr = _dt_rank(cfg)
    xz = jnp.einsum("bsd,dci->bsci", x, p["w_in"].astype(dt))
    xin, z = xz[:, :, 0], xz[:, :, 1]
    xin = plan.constrain(xin, ("batch", "seq", "d_inner"), cfg)
    conv_prev = state["conv"] if state is not None else None
    xc, conv_new = _causal_conv(xin, p["conv_w"].astype(dt), conv_prev)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(dt)
    bcdt = jnp.einsum("bsi,ir->bsr", xc, p["w_bcdt"].astype(dt))
    dt_lo, Bs, Cs = jnp.split(bcdt, [dtr, dtr + n], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_lo, p["w_dt"].astype(dt)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))  # (B, S, di)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, n)
    h0 = (state["ssm"].astype(jnp.float32) if state is not None
          else jnp.zeros((B, di, n), jnp.float32))

    c = min(chunk, S)
    while S % c:
        c //= 2
    nchunks = S // c
    a_all = jnp.exp(delta[..., None] * A)  # (B, S, di, n)
    b_all = (delta[..., None] * Bs[:, :, None, :].astype(jnp.float32)
             * xc[..., None].astype(jnp.float32))
    ar = a_all.reshape(B, nchunks, c, di, n).transpose(1, 0, 2, 3, 4)
    br = b_all.reshape(B, nchunks, c, di, n).transpose(1, 0, 2, 3, 4)

    def body(h, inp):
        ai, bi = inp
        hseq, hlast = _selective_scan_chunk(ai, bi, h)
        return hlast, hseq

    h_last, hs = jax.lax.scan(body, h0, (ar, br))
    h_seq = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, di, n)
    y = jnp.einsum("bsin,bsn->bsi", h_seq.astype(jnp.float32),
                   Cs.astype(jnp.float32))
    y = (y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)).astype(dt)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(dt))
    new_state = {"conv": conv_new, "ssm": h_last.astype(jnp.float32)}
    return plan.constrain(out, ("batch", "seq", "embed_act"), cfg), new_state


def mamba_decode(p: Params, x: jax.Array, state: Params, cfg: ArchConfig,
                 plan: ShardPlan):
    """x: (B, d) single token; state: {'conv': (B, cw-1, di), 'ssm': (B, di, n)}."""
    y, new_state = mamba_forward(p, x[:, None], cfg, plan, state, chunk=1)
    return y[:, 0], new_state


def init_mamba_state(cfg: ArchConfig, plan: ShardPlan, batch: int,
                     dtype=jnp.bfloat16):
    di, n = cfg.d_inner, cfg.ssm_state
    s = {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, n), jnp.float32),
    }
    ax = {"conv": ("batch", "conv", "d_inner"), "ssm": ("batch", "d_inner", "state")}
    return s, ax


# ===========================================================================
# RWKV6 (Finch): data-dependent decay, token-shift, wkv recurrence
# ===========================================================================

def init_rwkv_tmix(key, cfg: ArchConfig, plan: ShardPlan) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    h_pad = plan.h_pad(cfg)
    hdim = h_pad * hd
    lw = cfg.rwkv_lora_w
    dt = plan.param_dtype
    ks = jax.random.split(key, 12)
    names = ["r", "k", "v", "w", "g"]
    p = {
        "mu_base": jnp.full((d,), 0.5, dt),
        "mu": jnp.stack([jnp.full((d,), 0.5, dt)] * 5),  # (5, d) per r/k/v/w/g
        "lora_a": (jax.random.normal(ks[0], (5, d, 32)) * 0.01).astype(dt),
        "lora_b": (jax.random.normal(ks[1], (5, 32, d)) * 0.01).astype(dt),
        "w_r": L.dense_init(ks[2], (d, h_pad, hd), dtype=dt),
        "w_k": L.dense_init(ks[3], (d, h_pad, hd), dtype=dt),
        "w_v": L.dense_init(ks[4], (d, h_pad, hd), dtype=dt),
        "w_g": L.dense_init(ks[5], (d, h_pad, hd), dtype=dt),
        "w_o": L.dense_init(ks[6], (h_pad, hd, d), in_axis=1, dtype=dt),
        "decay_base": jnp.full((h_pad, hd), -6.0, dt),
        "decay_a": (jax.random.normal(ks[7], (d, lw)) * 0.01).astype(dt),
        "decay_b": (jax.random.normal(ks[8], (lw, h_pad, hd)) * 0.01).astype(dt),
        "u_bonus": (jax.random.normal(ks[9], (h_pad, hd)) * 0.1).astype(dt),
        "ln_x": jnp.ones((h_pad, hd), dt),
    }
    del names
    return p


def rwkv_tmix_axes(cfg: ArchConfig, plan: ShardPlan) -> Params:
    return {
        "mu_base": ("embed",),
        "mu": (None, "embed"),
        "lora_a": (None, "embed", None),
        "lora_b": (None, None, "embed"),
        "w_r": ("embed", "heads", "qk_dim"),
        "w_k": ("embed", "heads", "qk_dim"),
        "w_v": ("embed", "heads", "qk_dim"),
        "w_g": ("embed", "heads", "qk_dim"),
        "w_o": ("heads", "qk_dim", "embed"),
        "decay_base": ("heads", "qk_dim"),
        "decay_a": ("embed", "lora"),
        "decay_b": ("lora", "heads", "qk_dim"),
        "u_bonus": ("heads", "qk_dim"),
        "ln_x": ("heads", "qk_dim"),
    }


def _rwkv_mix(p, x, x_prev):
    """ddlerp token-shift: returns (B, S, 5, d) mixed inputs for r/k/v/w/g."""
    dt = x.dtype
    xx = x_prev - x  # (B, S, d)
    base = x + xx * p["mu_base"].astype(dt)
    lo = jnp.tanh(jnp.einsum("bsd,cdr->bscr", base, p["lora_a"].astype(dt)))
    dyn = jnp.einsum("bscr,crd->bscd", lo, p["lora_b"].astype(dt))
    mixes = p["mu"].astype(dt)[None, None] + dyn  # (B, S, 5, d)
    return x[:, :, None, :] + xx[:, :, None, :] * mixes


def _wkv_chunk(r, k, v, w, u, s0):
    """Sequential wkv within a chunk.

    r,k,v,w: (B, C, H, hd) — w is per-step decay in (0,1);
    u: (H, hd); s0: (B, H, hd, hd). Returns (y (B,C,H,hd), s_last).
    """
    def step(s, inp):
        rt, kt, vt, wt = inp  # (B, H, hd)
        at = kt[..., :, None] * vt[..., None, :]  # (B, H, hdk, hdv)
        bonus = (u[None] * kt)[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + bonus)
        s = wt[..., :, None] * s + at
        return s, y

    rs, ks_, vs, ws = (t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    s_last, ys = jax.lax.scan(step, s0, (rs, ks_, vs, ws))
    return ys.transpose(1, 0, 2, 3), s_last


def rwkv_tmix_forward(p: Params, x: jax.Array, cfg: ArchConfig, plan: ShardPlan,
                      state: Params | None = None, *, chunk: int = 64):
    """RWKV6 time-mix. x: (B, S, d) -> (y, new_state)."""
    dt = plan.compute_dtype
    B, S, d = x.shape
    h_pad, hd = plan.h_pad(cfg), cfg.head_dim
    x_last = state["shift"] if state is not None else jnp.zeros((B, 1, d), dt)
    x_prev = jnp.concatenate([x_last, x[:, :-1]], axis=1)
    mixed = _rwkv_mix(p, x, x_prev)  # (B, S, 5, d)
    xr, xk, xv, xw, xg = (mixed[:, :, i] for i in range(5))
    r = jnp.einsum("bsd,dhk->bshk", xr, p["w_r"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", xk, p["w_k"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", xv, p["w_v"].astype(dt))
    g = jnp.einsum("bsd,dhk->bshk", xg, p["w_g"].astype(dt))
    dlo = jnp.einsum("bsd,dr->bsr", xw, p["decay_a"].astype(dt))
    dw = p["decay_base"].astype(jnp.float32) + jnp.einsum(
        "bsr,rhk->bshk", dlo, p["decay_b"].astype(dt)).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dw))  # (B, S, H, hd) in (0, 1)
    r = plan.constrain(r, ("batch", "seq", "heads", None), cfg)
    k = plan.constrain(k, ("batch", "seq", "heads", None), cfg)
    v = plan.constrain(v, ("batch", "seq", "heads", None), cfg)

    s0 = (state["wkv"] if state is not None
          else jnp.zeros((B, h_pad, hd, hd), jnp.float32))
    c = min(chunk, S)
    while S % c:
        c //= 2
    n = S // c
    u = p["u_bonus"].astype(jnp.float32)

    def body(s, inp):
        rc, kc, vc, wc = inp
        y, s = _wkv_chunk(rc.astype(jnp.float32), kc.astype(jnp.float32),
                          vc.astype(jnp.float32), wc, u, s)
        return s, y

    resh = lambda t: t.reshape(B, n, c, h_pad, hd).transpose(1, 0, 2, 3, 4)
    s_last, ys = jax.lax.scan(body, s0, (resh(r), resh(k), resh(v), resh(w.astype(jnp.float32))))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, h_pad, hd)
    # per-head group norm + gate
    y = L.rms_norm(y.astype(dt), p["ln_x"])
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(dt)
    out = jnp.einsum("bshk,hkd->bsd", y, p["w_o"].astype(dt))
    new_state = {"shift": x[:, -1:].astype(dt), "wkv": s_last}
    return plan.constrain(out, ("batch", "seq", "embed_act"), cfg), new_state


def init_rwkv_cmix(key, cfg: ArchConfig, plan: ShardPlan) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    dt = plan.param_dtype
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_r": jnp.full((d,), 0.5, dt),
        "w_k": L.dense_init(ks[0], (d, f), dtype=dt),
        "w_v": L.dense_init(ks[1], (f, d), dtype=dt),
        "w_r": L.dense_init(ks[2], (d, d), dtype=dt),
    }


def rwkv_cmix_axes(cfg: ArchConfig, plan: ShardPlan) -> Params:
    return {
        "mu_k": ("embed",),
        "mu_r": ("embed",),
        "w_k": ("embed", "ffn"),
        "w_v": ("ffn", "embed"),
        "w_r": ("embed", "embed_act"),
    }


def rwkv_cmix_forward(p: Params, x: jax.Array, cfg: ArchConfig, plan: ShardPlan,
                      state: Params | None = None):
    """RWKV channel-mix FFN with token shift. x: (B, S, d)."""
    dt = plan.compute_dtype
    B, S, d = x.shape
    x_last = state["shift"] if state is not None else jnp.zeros((B, 1, d), dt)
    x_prev = jnp.concatenate([x_last, x[:, :-1]], axis=1)
    xx = x_prev - x
    xk = x + xx * p["mu_k"].astype(dt)
    xr = x + xx * p["mu_r"].astype(dt)
    h = jnp.einsum("bsd,df->bsf", xk, p["w_k"].astype(dt))
    h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(dt)
    kv = jnp.einsum("bsf,fd->bsd", h, p["w_v"].astype(dt))
    rgate = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, p["w_r"].astype(dt)).astype(jnp.float32)).astype(dt)
    out = rgate * kv
    new_state = {"shift": x[:, -1:].astype(dt)}
    return plan.constrain(out, ("batch", "seq", "embed_act"), cfg), new_state


def init_rwkv_state(cfg: ArchConfig, plan: ShardPlan, batch: int,
                    dtype=jnp.bfloat16):
    h_pad, hd = plan.h_pad(cfg), cfg.head_dim
    s = {
        "tmix": {
            "shift": jnp.zeros((batch, 1, cfg.d_model), dtype),
            "wkv": jnp.zeros((batch, h_pad, hd, hd), jnp.float32),
        },
        "cmix": {"shift": jnp.zeros((batch, 1, cfg.d_model), dtype)},
    }
    ax = {
        "tmix": {"shift": ("batch", None, "embed_act"),
                 "wkv": ("batch", "heads", "qk_dim", None)},
        "cmix": {"shift": ("batch", None, "embed_act")},
    }
    return s, ax
