"""Model assembly: per-family blocks + scan-over-layers + Model API.

``jax.lax.scan`` over stacked per-layer parameters keeps HLO size (and
compile time on this 1-core container) O(1) in depth.  The same block
functions serve train, prefill and decode; decode uses the shard_map cores
from attention.py / moe.py.

Cross-entropy runs inside shard_map over the vocab-sharded unembedding with
a checkpointed chunk scan, so the (T, V) logits are never materialised
globally (V_loc chunks only) — this is what keeps gemma's 256k vocab inside
HBM at train time.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.sharding import ShardPlan, shard_map_or_call

Params = dict[str, Any]
NEG_INF = -1e30
MOE_AUX_WEIGHT = 0.01


def _norm(x, w, cfg: ArchConfig):
    if cfg.norm_kind == "layer":
        return L.layer_norm(x, w["scale"], w["bias"])
    return L.rms_norm(x, w["scale"], plus_one=cfg.norm_plus_one)


def _norm_init(cfg: ArchConfig, dt) -> Params:
    scale = jnp.zeros if cfg.norm_plus_one else jnp.ones
    p = {"scale": scale((cfg.d_model,), dt)}
    if cfg.norm_kind == "layer":
        p["bias"] = jnp.zeros((cfg.d_model,), dt)
    return p


def _norm_axes(cfg: ArchConfig) -> Params:
    p = {"scale": ("embed_act",)}
    if cfg.norm_kind == "layer":
        p["bias"] = ("embed_act",)
    return p


# ---------------------------------------------------------------------------
# per-layer init / axes
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ArchConfig, plan: ShardPlan) -> Params:
    dt = plan.param_dtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"norm1": _norm_init(cfg, dt), "norm2": _norm_init(cfg, dt)}
    if cfg.rwkv:
        p["tmix"] = S.init_rwkv_tmix(k1, cfg, plan)
        p["cmix"] = S.init_rwkv_cmix(k2, cfg, plan)
        return p
    if cfg.attn_kind == "mla":
        p["attn"] = A.init_mla(k1, cfg, plan)
    else:
        p["attn"] = A.init_gqa(k1, cfg, plan)
    if cfg.family == "hybrid":
        p["mamba"] = S.init_mamba(k2, cfg, plan)
    if cfg.n_experts:
        p["moe"] = M.init_moe(k3, cfg, plan)
    elif cfg.mlp_kind == "gelu2":
        p["mlp"] = L.gelu_mlp_init(k4, cfg.d_model, cfg.d_ff, dtype=dt)
    else:
        p["mlp"] = L.mlp_init(k4, cfg.d_model, cfg.d_ff, dtype=dt)
    return p


def layer_axes(cfg: ArchConfig, plan: ShardPlan) -> Params:
    ax: Params = {"norm1": _norm_axes(cfg), "norm2": _norm_axes(cfg)}
    if cfg.rwkv:
        ax["tmix"] = S.rwkv_tmix_axes(cfg, plan)
        ax["cmix"] = S.rwkv_cmix_axes(cfg, plan)
        return ax
    if cfg.attn_kind == "mla":
        ax["attn"] = A.mla_axes(cfg, plan)
    else:
        ax["attn"] = A.gqa_axes(cfg, plan)
    if cfg.family == "hybrid":
        ax["mamba"] = S.mamba_axes(cfg, plan)
    if cfg.n_experts:
        ax["moe"] = M.moe_axes(cfg, plan)
    elif cfg.mlp_kind == "gelu2":
        ax["mlp"] = {"w_in": ("embed", "ffn"), "b_in": ("ffn",),
                     "w_out": ("ffn", "embed"), "b_out": ("embed_act",)}
    else:
        ax["mlp"] = {"w_gate": ("embed", "ffn"), "w_up": ("embed", "ffn"),
                     "w_down": ("ffn", "embed")}
    return ax


# ---------------------------------------------------------------------------
# block forward (train / prefill): x (B, S, d)
# ---------------------------------------------------------------------------

def block_forward(x, lp: Params, positions, cfg: ArchConfig, plan: ShardPlan,
                  *, want_cache: bool, state: Params | None = None):
    """Returns (x, cache_or_None, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.rwkv:
        st = state or {}
        y, tmix_state = S.rwkv_tmix_forward(lp["tmix"], _norm(x, lp["norm1"], cfg),
                                            cfg, plan, st.get("tmix"))
        x = x + y
        y, cmix_state = S.rwkv_cmix_forward(lp["cmix"], _norm(x, lp["norm2"], cfg),
                                            cfg, plan, st.get("cmix"))
        x = x + y
        cache = {"tmix": tmix_state, "cmix": cmix_state} if want_cache else None
        return x, cache, aux

    h = _norm(x, lp["norm1"], cfg)
    if cfg.attn_kind == "mla":
        attn_out, attn_cache = A.mla_forward(lp["attn"], h, positions, cfg, plan,
                                             want_cache=want_cache)
    else:
        attn_out, attn_cache = A.gqa_forward(lp["attn"], h, positions, cfg, plan,
                                             want_cache=want_cache)
    if cfg.family == "hybrid":
        st = state or {}
        mamba_out, mamba_state = S.mamba_forward(lp["mamba"], h, cfg, plan,
                                                 st.get("mamba"))
        x = x + 0.5 * (attn_out + mamba_out)
    else:
        x = x + attn_out
        mamba_state = None

    h = _norm(x, lp["norm2"], cfg)
    if cfg.n_experts:
        y, aux = M.moe_ffn(lp["moe"], h, cfg, plan)
    elif cfg.mlp_kind == "gelu2":
        y = L.gelu_mlp(h, {k: v.astype(plan.compute_dtype) for k, v in lp["mlp"].items()})
        y = plan.constrain(y, ("batch", "seq", "embed_act"), cfg)
    else:
        y = L.glu_mlp(h, {k: v.astype(plan.compute_dtype) for k, v in lp["mlp"].items()},
                      activation=cfg.activation)
        y = plan.constrain(y, ("batch", "seq", "embed_act"), cfg)
    x = x + y

    cache = None
    if want_cache:
        cache = {"attn": attn_cache}
        if mamba_state is not None:
            cache["mamba"] = mamba_state
    return x, cache, aux


# ---------------------------------------------------------------------------
# block decode: x (B, d), per-layer cache
# ---------------------------------------------------------------------------

def block_decode(x, lp: Params, lc: Params, positions, cfg: ArchConfig,
                 plan: ShardPlan):
    """Returns (x, new_cache)."""
    if cfg.rwkv:
        x3 = x[:, None]
        y, tmix_state = S.rwkv_tmix_forward(lp["tmix"], _norm(x3, lp["norm1"], cfg),
                                            cfg, plan, lc["tmix"])
        x3 = x3 + y
        y, cmix_state = S.rwkv_cmix_forward(lp["cmix"], _norm(x3, lp["norm2"], cfg),
                                            cfg, plan, lc["cmix"])
        x3 = x3 + y
        return x3[:, 0], {"tmix": tmix_state, "cmix": cmix_state}

    h = _norm(x, lp["norm1"], cfg)
    if cfg.attn_kind == "mla":
        attn_out, attn_cache = A.mla_decode(lp["attn"], h, lc["attn"], positions,
                                            cfg, plan)
    else:
        attn_out, attn_cache = A.gqa_decode(lp["attn"], h, lc["attn"], positions,
                                            cfg, plan)
    if cfg.family == "hybrid":
        mamba_out, mamba_state = S.mamba_decode(lp["mamba"], h, lc["mamba"], cfg, plan)
        x = x + 0.5 * (attn_out + mamba_out)
    else:
        x = x + attn_out
        mamba_state = None

    h = _norm(x, lp["norm2"], cfg)
    if cfg.n_experts:
        y, _ = M.moe_ffn(lp["moe"], h[:, None], cfg, plan)
        y = y[:, 0]
    elif cfg.mlp_kind == "gelu2":
        y = L.gelu_mlp(h, {k: v.astype(plan.compute_dtype) for k, v in lp["mlp"].items()})
    else:
        y = L.glu_mlp(h, {k: v.astype(plan.compute_dtype) for k, v in lp["mlp"].items()},
                      activation=cfg.activation)
    x = x + y
    new_cache = {"attn": attn_cache}
    if mamba_state is not None:
        new_cache["mamba"] = mamba_state
    return x, new_cache


def block_prefill_paged(x, lp: Params, lc: Params, starts, lengths,
                        block_tables, cfg: ArchConfig, plan: ShardPlan):
    """Chunked-prefill variant of ``block_forward`` over the paged pool.

    x: (B, C, d) — one chunk of C prompt tokens per row starting at
    absolute position ``starts[b]``; lc holds this layer's slice of the
    global block pool.  The attention scatter/gather goes through the
    per-sequence block table, so the chunk sees all previously written
    context (earlier chunks, shared prefix blocks) plus itself causally.
    """
    h = _norm(x, lp["norm1"], cfg)
    attn_out, attn_cache = A.gqa_prefill_paged(lp["attn"], h, lc["attn"],
                                               starts, lengths, block_tables,
                                               cfg, plan)
    x = x + attn_out
    h = _norm(x, lp["norm2"], cfg)
    if cfg.n_experts:
        y, _ = M.moe_ffn(lp["moe"], h, cfg, plan)
    elif cfg.mlp_kind == "gelu2":
        y = L.gelu_mlp(h, {k: v.astype(plan.compute_dtype) for k, v in lp["mlp"].items()})
        y = plan.constrain(y, ("batch", "seq", "embed_act"), cfg)
    else:
        y = L.glu_mlp(h, {k: v.astype(plan.compute_dtype) for k, v in lp["mlp"].items()},
                      activation=cfg.activation)
        y = plan.constrain(y, ("batch", "seq", "embed_act"), cfg)
    x = x + y
    return x, {"attn": attn_cache}


def block_decode_paged(x, lp: Params, lc: Params, positions, block_tables,
                       cfg: ArchConfig, plan: ShardPlan):
    """Paged-pool variant of ``block_decode`` (plain-GQA families only).

    lc holds this layer's slice of the global block pool; the attention
    write/gather goes through the per-sequence block table.
    """
    h = _norm(x, lp["norm1"], cfg)
    attn_out, attn_cache = A.gqa_decode_paged(lp["attn"], h, lc["attn"],
                                              positions, block_tables,
                                              cfg, plan)
    x = x + attn_out
    h = _norm(x, lp["norm2"], cfg)
    if cfg.n_experts:
        y, _ = M.moe_ffn(lp["moe"], h[:, None], cfg, plan)
        y = y[:, 0]
    elif cfg.mlp_kind == "gelu2":
        y = L.gelu_mlp(h, {k: v.astype(plan.compute_dtype) for k, v in lp["mlp"].items()})
    else:
        y = L.glu_mlp(h, {k: v.astype(plan.compute_dtype) for k, v in lp["mlp"].items()},
                      activation=cfg.activation)
    x = x + y
    return x, {"attn": attn_cache}


# ---------------------------------------------------------------------------
# vocab-sharded embedding / loss
# ---------------------------------------------------------------------------

def _embed_core(axis, table, ids):
    v_loc = table.shape[0]
    off = (jax.lax.axis_index(axis) * v_loc) if axis is not None else 0
    local = ids - off
    valid = (local >= 0) & (local < v_loc)
    e = jnp.take(table, jnp.clip(local, 0, v_loc - 1), axis=0)
    e = jnp.where(valid[..., None], e, 0)
    if axis is not None:
        e = jax.lax.psum(e, axis)
    return e


def embed_lookup(table, ids, cfg: ArchConfig, plan: ShardPlan):
    dp = plan.dp_axes if plan.dp_axes else None
    specs_in = (P("model", None), P(dp, None) if ids.ndim == 2 else P(dp))
    out = P(dp, None, None) if ids.ndim == 2 else P(dp, None)
    return shard_map_or_call(plan, _embed_core, specs_in, out,
                             table.astype(plan.compute_dtype), ids)


def _xent_core(axis, x, w_u, labels, *, vocab_size: int, n_chunks: int):
    """Chunked, checkpointed cross-entropy on a vocab shard.

    x: (T_loc, d); w_u: (d, V_loc); labels: (T_loc,). Returns summed loss.
    """
    t = x.shape[0]
    v_loc = w_u.shape[1]
    off = (jax.lax.axis_index(axis) * v_loc) if axis is not None else 0
    cols = off + jnp.arange(v_loc)
    col_valid = cols < vocab_size
    chunk = t // n_chunks

    @jax.checkpoint
    def chunk_loss(xc, lc):
        logits = jnp.einsum("td,dv->tv", xc, w_u,
                            preferred_element_type=jnp.float32)
        logits = jnp.where(col_valid[None, :], logits, NEG_INF)
        # stability shift: stop_gradient BEFORE pmax (pmax has no JVP rule;
        # the shift cancels in the gradient anyway)
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
        if axis is not None:
            m = jax.lax.pmax(m, axis)
        se = jnp.sum(jnp.exp(logits - m[:, None]), axis=-1)
        if axis is not None:
            se = jax.lax.psum(se, axis)
        lse = jnp.log(se) + m
        lab_local = lc - off
        lab_valid = (lab_local >= 0) & (lab_local < v_loc)
        lab_logit = jnp.take_along_axis(
            logits, jnp.clip(lab_local, 0, v_loc - 1)[:, None], axis=1)[:, 0]
        lab_logit = jnp.where(lab_valid, lab_logit, 0.0)
        if axis is not None:
            lab_logit = jax.lax.psum(lab_logit, axis)
        return jnp.sum(lse - lab_logit)

    def body(acc, inp):
        xc, lc = inp
        return acc + chunk_loss(xc, lc), None

    xs = x.reshape(n_chunks, chunk, -1)
    ls = labels.reshape(n_chunks, chunk)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return total


def sharded_xent(x, w_u, labels, cfg: ArchConfig, plan: ShardPlan,
                 *, n_chunks: int = 8):
    """Mean next-token loss; x: (B, S, d), labels: (B, S)."""
    B, Sq, d = x.shape
    t = B * Sq
    dp = plan.dp_axes if plan.dp_axes else None
    xf = x.reshape(t, d)
    lf = labels.reshape(t)
    while n_chunks > 1 and t // max(plan.dp, 1) % n_chunks:
        n_chunks //= 2

    def core(axis, xc, wc, lc):
        s = _xent_core(axis, xc, wc, lc, vocab_size=cfg.vocab_size,
                       n_chunks=n_chunks)
        if axis is not None and dp is not None:
            s = jax.lax.psum(s, dp)  # sum per-data-shard partials
        return s

    in_specs = (P(dp, None), P(None, "model"), P(dp))
    total = shard_map_or_call(plan, core, in_specs, P(), xf,
                              w_u.astype(plan.compute_dtype), lf)
    return total / t


# ---------------------------------------------------------------------------
# sampling / speculative-decode helpers
# ---------------------------------------------------------------------------

# Salts folded into per-lane PRNG keys so every sampling event at one
# sequence index draws from a distinct stream.  Keys fold the ABSOLUTE
# sequence index of the token being decided, which makes streams
# replay-stable across preemption and horizon re-splits.
SALT_SAMPLE = 0   # non-speculative draws (scan step / first prefill token)
SALT_DRAFT = 1    # drafter proposal draws
SALT_ACCEPT = 2   # rejection-sampling accept uniforms
SALT_BONUS = 3    # residual / bonus draws after the accepted prefix


def lane_keys(seeds):
    """Per-lane base PRNG keys from int32 seeds: (B,) -> (B, 2) uint32."""
    return jax.vmap(jax.random.PRNGKey)(seeds)


def event_keys(base_keys, seq_idx, salt):
    """``fold(fold(base, seq_idx), salt)`` per lane.

    base_keys: (B, 2); seq_idx: (B,) or (B, Q) absolute sequence index of
    the token the event decides.  Returns keys of seq_idx.shape + (2,).
    """
    seq_idx = jnp.asarray(seq_idx, jnp.uint32)
    salt_arr = jnp.full(seq_idx.shape, salt, jnp.uint32)
    if seq_idx.ndim == 2:
        keys = jnp.broadcast_to(base_keys[:, None, :],
                                seq_idx.shape + (base_keys.shape[-1],))
        fold = jax.vmap(jax.vmap(jax.random.fold_in))
    else:
        keys = base_keys
        fold = jax.vmap(jax.random.fold_in)
    return fold(fold(keys, seq_idx), salt_arr)


def uniform_lanes(keys):
    """One U[0, 1) draw per key; keys: (..., 2) raw PRNG key data."""
    flat = keys.reshape(-1, keys.shape[-1])
    u = jax.vmap(jax.random.uniform)(flat)
    return u.reshape(keys.shape[:-1])


def sampling_dist(logits, temps, top_ks):
    """Per-lane warped sampling distribution over the real vocab.

    logits: (..., V) already sliced to the real vocab; temps/top_ks:
    (...,).  Lanes with ``temps <= 0`` get a ONE-HOT argmax distribution,
    so the single rejection-sampling path degenerates bit-exactly to
    greedy acceptance; ``top_ks <= 0`` disables top-k truncation.
    Returns float32 probs.
    """
    logits = logits.astype(jnp.float32)
    V = logits.shape[-1]
    greedy = temps <= 0.0
    top = jnp.sort(logits, axis=-1)[..., ::-1]
    kth = jnp.take_along_axis(
        top, jnp.clip(top_ks - 1, 0, V - 1)[..., None], axis=-1)
    keep = (logits >= kth) | (top_ks <= 0)[..., None]
    t = jnp.where(greedy, 1.0, jnp.maximum(temps, 1e-6))[..., None]
    probs = jax.nn.softmax(jnp.where(keep, logits / t, NEG_INF), axis=-1)
    one_hot = jax.nn.one_hot(jnp.argmax(logits, axis=-1), V,
                             dtype=jnp.float32)
    return jnp.where(greedy[..., None], one_hot, probs)


def sample_from_dist(keys, probs, greedy):
    """One token per lane from ``probs``; greedy lanes take the argmax
    EXACTLY (categorical over a one-hot is only almost-surely the argmax).

    keys: (..., 2); probs: (..., V); greedy: (...,) bool -> (...) int32.
    """
    flat_k = keys.reshape(-1, keys.shape[-1])
    flat_p = probs.reshape(-1, probs.shape[-1])
    drawn = jax.vmap(
        lambda k, p: jax.random.categorical(k, jnp.log(p + 1e-30)))(
            flat_k, flat_p).reshape(probs.shape[:-1])
    return jnp.where(greedy, jnp.argmax(probs, axis=-1),
                     drawn).astype(jnp.int32)


def rejection_choose(base_keys, pos_eff, drafts, q_dists, p_dists, greedy,
                     n_valid):
    """Standard speculative rejection sampling, vectorised per lane.

    drafts: (B, K) proposal tokens with proposal dists ``q_dists``
    (B, K, V); ``p_dists``: (B, K+1, V) target dists for every slot.
    Draft j is accepted iff ``u_j * q_j(d_j) < p_j(d_j)`` with u_j ~
    U[0, 1) keyed on the token's absolute slot index (SALT_ACCEPT); the
    token at the first rejected slot is drawn from the renormalised
    residual ``max(p - q, 0)`` (SALT_BONUS), falling back to p when the
    residual vanishes (q == p); slot K has q = 0, so its "residual" is
    the plain bonus draw from p.  The emitted-token marginal at every
    consumed slot equals p exactly; greedy lanes (one-hot dists) accept
    iff the draft is the argmax and correct with the argmax.

    Returns ``(n_acc (B,) accepted-prefix length, capped at
    max(n_valid - 1, 0) so the bonus slot stays in range, cand_out
    (B, K+1) the would-be emitted token per slot)``.
    """
    B, spec_k = drafts.shape
    K1 = spec_k + 1
    V = p_dists.shape[-1]
    p_d = jnp.take_along_axis(p_dists[:, :spec_k],
                              drafts[..., None], axis=2)[..., 0]
    q_d = jnp.take_along_axis(q_dists, drafts[..., None], axis=2)[..., 0]
    slot_idx = pos_eff[:, None] + 1 + jnp.arange(spec_k)[None, :]
    u = uniform_lanes(event_keys(base_keys, slot_idx, SALT_ACCEPT))
    accept = u * q_d < p_d
    n_acc = jnp.cumprod(accept.astype(jnp.int32), axis=1).sum(axis=1)
    n_acc = jnp.minimum(n_acc, jnp.maximum(n_valid - 1, 0))

    q_ext = jnp.concatenate([q_dists, jnp.zeros((B, 1, V), jnp.float32)],
                            axis=1)
    resid = jnp.maximum(p_dists - q_ext, 0.0)
    rsum = resid.sum(axis=-1, keepdims=True)
    resid = jnp.where(rsum > 1e-9, resid / jnp.maximum(rsum, 1e-30),
                      p_dists)
    emit_idx = pos_eff[:, None] + 1 + jnp.arange(K1)[None, :]
    corr = sample_from_dist(event_keys(base_keys, emit_idx, SALT_BONUS),
                            resid, jnp.broadcast_to(greedy[:, None], (B, K1)))
    j = jnp.arange(K1)[None, :]
    d_ext = jnp.concatenate([drafts, jnp.zeros((B, 1), jnp.int32)], axis=1)
    cand_out = jnp.where(j < n_acc[:, None], d_ext, corr)
    return n_acc, cand_out


def ngram_propose(hist, positions, *, k: int, n: int = 2):
    """Prompt-lookup drafting (drafter-free speculation).

    Match the n-token suffix ending at ``positions`` against every earlier
    window of the sequence history and propose the k tokens that followed
    the MOST RECENT match; with no match (or too little history) repeat
    the current last token.  hist: (B, S) int32, ``hist[b, i]`` = i-th
    sequence token, valid through ``positions[b]``; returns (B, k) int32.
    """
    B, S = hist.shape
    last = jnp.take_along_axis(hist, jnp.clip(positions, 0, S - 1)[:, None],
                               axis=1)
    nw = S - n
    if nw <= 0:
        return jnp.broadcast_to(last, (B, k)).astype(jnp.int32)
    windows = jnp.stack([hist[:, j:nw + j] for j in range(n)], axis=-1)
    suf_idx = jnp.clip(positions[:, None] - (n - 1) + jnp.arange(n)[None, :],
                       0, S - 1)
    suffix = jnp.take_along_axis(hist, suf_idx, axis=1)
    starts = jnp.arange(nw)
    match = jnp.all(windows == suffix[:, None, :], axis=-1)
    # the window must END strictly before the suffix itself (start <=
    # pos - n), which also keeps its continuation a known token
    match &= starts[None, :] <= positions[:, None] - n
    best = jnp.max(jnp.where(match, starts[None, :], -1), axis=1)
    cont_idx = jnp.minimum(best[:, None] + n + jnp.arange(k)[None, :],
                           positions[:, None])
    drafts = jnp.take_along_axis(hist, jnp.clip(cont_idx, 0, S - 1), axis=1)
    return jnp.where(best[:, None] >= 0, drafts, last).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    """One assigned architecture bound to a shard plan."""

    def __init__(self, cfg: ArchConfig, plan: ShardPlan):
        self.cfg = cfg
        self.plan = plan

    # ----- params -----
    def init(self, rng) -> Params:
        cfg, plan = self.cfg, self.plan
        dt = plan.param_dtype
        k_embed, k_layers, k_out = jax.random.split(rng, 3)
        v_pad = plan.v_pad(cfg)
        p: Params = {}
        if cfg.input_kind == "tokens":
            emb = L.embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype=dt)
            p["embed"] = jnp.pad(emb, ((0, v_pad - cfg.vocab_size), (0, 0)))
        keys = jax.random.split(k_layers, cfg.num_layers)
        p["layers"] = jax.vmap(lambda k: init_layer(k, cfg, plan))(keys)
        p["final_norm"] = _norm_init(cfg, dt)
        if not (cfg.tie_embeddings and cfg.input_kind == "tokens"):
            w = L.dense_init(k_out, (cfg.d_model, cfg.vocab_size), dtype=dt)
            p["unembed"] = jnp.pad(w, ((0, 0), (0, v_pad - cfg.vocab_size)))
        return p

    def param_axes(self) -> Params:
        cfg, plan = self.cfg, self.plan
        ax: Params = {}
        if cfg.input_kind == "tokens":
            ax["embed"] = ("vocab", "embed")
        lax_ = layer_axes(cfg, plan)
        ax["layers"] = jax.tree.map(lambda a: ("layers",) + a, lax_,
                                    is_leaf=lambda x: isinstance(x, tuple))
        ax["final_norm"] = _norm_axes(cfg)
        if not (cfg.tie_embeddings and cfg.input_kind == "tokens"):
            ax["unembed"] = ("embed", "vocab")
        return ax

    def param_shardings(self):
        return self.plan.tree_shardings(self.param_axes(), self.cfg)

    def _unembed_w(self, params: Params):
        if self.cfg.tie_embeddings and self.cfg.input_kind == "tokens":
            return params["embed"].T
        return params["unembed"]

    # ----- input embedding -----
    def _embed_inputs(self, params, inputs):
        cfg, plan = self.cfg, self.plan
        if cfg.input_kind == "embeds":
            x = inputs.astype(plan.compute_dtype)
        else:
            x = embed_lookup(params["embed"], inputs, cfg, plan)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), plan.compute_dtype)
        return plan.constrain(x, ("batch", "seq", "embed_act"), cfg)

    # ----- forward (train/prefill trunk) -----
    def _trunk(self, params, x, positions, *, want_cache: bool):
        cfg, plan = self.cfg, self.plan

        def body(carry, lp):
            x, aux = carry
            x, cache, aux_l = block_forward(x, lp, positions, cfg, plan,
                                            want_cache=want_cache)
            return (x, aux + aux_l), cache

        if plan.remat == "full":
            body = jax.checkpoint(body)
        (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        params["layers"])
        x = _norm(x, params["final_norm"], cfg)
        return x, caches, aux

    def loss(self, params, inputs, labels):
        cfg, plan = self.cfg, self.plan
        x = self._embed_inputs(params, inputs)
        Sq = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(Sq)[None, :], x.shape[:2])
        x, _, aux = self._trunk(params, x, positions, want_cache=False)
        ce = sharded_xent(x, self._unembed_w(params), labels, cfg, plan)
        return ce + MOE_AUX_WEIGHT * aux

    def logits(self, params, inputs):
        """Full-sequence logits (small inputs / tests only)."""
        cfg, plan = self.cfg, self.plan
        x = self._embed_inputs(params, inputs)
        Sq = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(Sq)[None, :], x.shape[:2])
        x, _, _ = self._trunk(params, x, positions, want_cache=False)
        return self._head(params, x)

    def _head(self, params, x):
        cfg, plan = self.cfg, self.plan
        w = self._unembed_w(params).astype(plan.compute_dtype)
        logits = jnp.einsum("...d,dv->...v", x, w)
        cols = jnp.arange(logits.shape[-1])
        return jnp.where(cols < cfg.vocab_size, logits, NEG_INF)

    # ----- serving -----
    @property
    def supports_paged(self) -> bool:
        """Paged KV serving needs plain GQA attention (MLA latent, SWA ring
        and mamba/rwkv recurrent state keep the slot-based pool)."""
        cfg = self.cfg
        return (not cfg.rwkv and cfg.family != "hybrid"
                and cfg.attn_kind == "gqa" and cfg.causal
                and cfg.input_kind == "tokens")

    def prefill(self, params, inputs):
        """Returns (last-token logits (B, V_pad), cache stacked over layers)."""
        cfg, plan = self.cfg, self.plan
        x = self._embed_inputs(params, inputs)
        Sq = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(Sq)[None, :], x.shape[:2])
        x, caches, _ = self._trunk(params, x, positions, want_cache=True)
        logits = self._head(params, x[:, -1])
        return logits, caches

    def prefill_ragged(self, params, inputs, lengths):
        """Batched prefill over right-padded prompts of one bucket shape.

        inputs: (B, S_bucket) token ids, row b valid for its first
        lengths[b] tokens; returns logits at each row's true last token
        (B, V_pad) + the stacked cache.  Padded tail positions attend only
        causally so rows' valid prefixes are exact; their cache entries are
        garbage past lengths[b] and masked downstream by position.
        """
        cfg, plan = self.cfg, self.plan
        x = self._embed_inputs(params, inputs)
        Sq = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(Sq)[None, :], x.shape[:2])
        x, caches, _ = self._trunk(params, x, positions, want_cache=True)
        last = jnp.take_along_axis(
            x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        return self._head(params, last), caches

    def decode_step(self, params, cache, tokens, positions):
        """One token per sequence. tokens: (B,), positions: (B,)."""
        cfg, plan = self.cfg, self.plan
        if cfg.input_kind == "embeds":
            raise ValueError(f"{cfg.name} is encoder-only: no decode step")
        x = embed_lookup(params["embed"], tokens, cfg, plan)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), plan.compute_dtype)

        def body(x, inp):
            lp, lc = inp
            x, new_lc = block_decode(x, lp, lc, positions, cfg, plan)
            return x, new_lc

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        x = _norm(x, params["final_norm"], cfg)
        logits = self._head(params, x)
        return logits, new_cache

    def decode_step_paged(self, params, cache, tokens, positions,
                          block_tables):
        """One token per lane over the paged pool.  tokens/positions: (B,);
        block_tables: (B, T) physical block ids per lane."""
        cfg, plan = self.cfg, self.plan
        x = embed_lookup(params["embed"], tokens, cfg, plan)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), plan.compute_dtype)

        def body(x, inp):
            lp, lc = inp
            x, new_lc = block_decode_paged(x, lp, lc, positions, block_tables,
                                           cfg, plan)
            return x, new_lc

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        x = _norm(x, params["final_norm"], cfg)
        logits = self._head(params, x)
        return logits, new_cache

    def decode_multi_paged(self, params, cache, tokens, positions,
                           block_tables, active, budgets, eos_ids,
                           num_steps: int, max_len: int,
                           temps=None, top_ks=None, seeds=None):
        """Fused multi-step greedy decode over the paged pool.

        Runs ``num_steps`` decode iterations inside one jitted
        ``lax.scan`` horizon — embed, trunk, greedy sampling (argmax), KV
        append, position advance and finished-flag computation all stay on
        device; the host only reads ``(out_tokens, emitted)`` when the
        horizon drains (one sync per N steps instead of per step).

        tokens/positions: (B,) per-lane state at entry; active: (B,) bool
        decode mask (parked / still-prefilling lanes False); budgets: (B,)
        tokens each lane may still produce; eos_ids: (B,) int32 (-1 = no
        eos).  Lanes that finish mid-horizon are steered to the parking
        block (position 0, table row 0) so they never touch live blocks.
        Blocks for every position a lane can reach within the horizon must
        be allocated before entry (``PagedCachePool.ensure_append_blocks``
        with the same horizon).

        With ``temps``/``top_ks``/``seeds`` (all (B,)) set, sampling
        replaces argmax per lane: each step's token is drawn from the
        temperature/top-k-warped distribution with a key folded from the
        lane seed and the token's absolute sequence index (replay-stable);
        lanes with ``temps[b] <= 0`` still take the exact argmax.  The
        default ``None`` builds the identical graph as before.

        Returns ``(out_tokens (N, B), emitted (N, B) bool — token [i, b]
        valid iff emitted, last_logits (B, V_pad), (tokens, positions,
        active, budgets) final state, cache)``.
        """
        cfg, plan = self.cfg, self.plan
        v_pad = params["embed"].shape[0] if "embed" in params else \
            self._unembed_w(params).shape[1]
        logits0 = jnp.zeros((tokens.shape[0], v_pad), plan.compute_dtype)
        base_keys = lane_keys(seeds) if temps is not None else None

        def one_step(carry, _):
            # dead tail steps (every lane drained) skip the model at
            # runtime, so the engine can always launch `horizon` steps —
            # one jit variant — without paying for the unused tail
            return jax.lax.cond(jnp.any(carry[3]), live_step, parked_step,
                                carry)

        def parked_step(carry):
            B = carry[1].shape[0]
            return carry, (jnp.zeros((B,), jnp.int32),
                           jnp.zeros((B,), bool))

        def live_step(carry):
            cache, tokens, positions, active, budgets, _ = carry
            pos_eff = jnp.where(active, positions, 0)
            bt_eff = jnp.where(active[:, None], block_tables, 0)
            logits, cache = self.decode_step_paged(
                params, cache, tokens, pos_eff, bt_eff)
            if temps is None:
                nxt = jnp.argmax(logits[:, : cfg.vocab_size],
                                 axis=-1).astype(jnp.int32)
            else:
                dist = sampling_dist(logits[:, : cfg.vocab_size], temps,
                                     top_ks)
                keys = event_keys(base_keys, positions + 1, SALT_SAMPLE)
                nxt = sample_from_dist(keys, dist, temps <= 0.0)
            emitted = active
            budgets = budgets - emitted.astype(jnp.int32)
            done = emitted & ((budgets <= 0) | (nxt == eos_ids)
                              | (positions + 1 >= max_len))
            tokens = jnp.where(emitted, nxt, tokens)
            positions = positions + emitted.astype(jnp.int32)
            active = active & ~done
            carry = (cache, tokens, positions, active, budgets,
                     logits.astype(logits0.dtype))
            return carry, (nxt, emitted)

        carry0 = (cache, tokens, positions, active, budgets, logits0)
        (cache, tokens, positions, active, budgets, last_logits), \
            (out_tokens, emitted) = jax.lax.scan(
                one_step, carry0, None, length=num_steps)
        return (out_tokens, emitted, last_logits,
                (tokens, positions, active, budgets), cache)

    def decode_verify_paged(self, params, cache, tokens, positions,
                            block_tables, n_valid):
        """Target forward over Q candidate tokens per lane in ONE paged
        pass (speculative verify): the current input token plus K drafts,
        token i at absolute position ``positions[b] + i``.

        The Q candidates fold into the lane axis — candidate i becomes a
        pseudo-lane at position ``positions[b] + i`` sharing lane b's
        block-table row — and run through ``decode_step_paged`` verbatim.
        All Q keys scatter into the pool before the (position-masked)
        attention gather, so candidate i sees candidates < i causally;
        because every op is the exact decode-step graph (just a larger
        batch) the per-position logits are BITWISE equal to sequential
        single-token decode — greedy speculative streams match plain
        decode exactly, not just to tolerance.  (The standalone
        multi-query kernel ``ops.paged_verify_attention`` computes the
        same attention in one prefill-style pass; it is kept as the
        general-purpose form but differs from the decode kernel by bf16
        ulps, which is why the model path folds instead.)

        Tokens at index >= ``n_valid[b]`` are routed to the parking block
        (their logits are garbage — callers must ignore them).  Returns
        (logits (B, Q, V_pad) — ``logits[b, i]`` predicts position
        ``positions[b] + i + 1`` — and the cache).
        """
        B, Q = tokens.shape
        j = jnp.arange(Q, dtype=jnp.int32)
        valid = j[None, :] < n_valid[:, None]                        # (B, Q)
        pos = jnp.where(valid, positions[:, None] + j[None, :], 0)
        tab = jnp.where(valid[..., None], block_tables[:, None, :], 0)
        logits, new_cache = self.decode_step_paged(
            params, cache, tokens.reshape(B * Q), pos.reshape(B * Q),
            tab.reshape(B * Q, tab.shape[-1]))
        return logits.reshape(B, Q, logits.shape[-1]), new_cache

    def decode_spec_paged(self, drafter, params, cache, d_params, d_cache,
                          hist, tokens, positions, block_tables, active,
                          budgets, eos_ids, temps, top_ks, seeds, *,
                          num_steps: int, spec_k: int, max_len: int,
                          ngram: int = 2):
        """Fused speculative decode over the paged pool.

        Each of ``num_steps`` rounds proposes ``spec_k`` draft tokens per
        lane — from ``drafter`` (a paired smaller Model whose paged cache
        ``d_cache`` shares this pool's block tables) or, when ``drafter``
        is None, by n-gram prompt-lookup over the sequence history
        ``hist`` — then verifies all spec_k + 1 positions in ONE target
        pass (``decode_verify_paged``) and advances each lane by its
        accepted prefix plus one corrected/bonus token via standard
        rejection sampling (accept draft d iff ``u * q(d) < p(d)``;
        residual ``max(p - q, 0)`` renormalised on rejection; bonus from
        p on full acceptance).  Greedy lanes (``temps <= 0``) use one-hot
        distributions, so acceptance degenerates to exact argmax
        agreement and the emitted stream is bit-identical to
        ``decode_multi_paged``.

        Rejected tails need no KV rollback: position p is always the
        next-write slot, so a stale slot is rewritten the moment that
        position is consumed again as an input token; positions, budgets
        and the history only advance by emitted tokens.  Blocks for the
        worst case (spec_k + 1 writes per round) must be pre-allocated
        (``ensure_append_blocks`` with the padded horizon).

        Returns ``(out_tokens (N, B, spec_k+1), emitted (N, B, spec_k+1)
        bool, n_acc (N, B) accepted drafts per round, (tokens, positions,
        active, budgets) final state, cache, d_cache, hist)``.
        """
        cfg, plan = self.cfg, self.plan
        B = tokens.shape[0]
        K1 = spec_k + 1
        base_keys = lane_keys(seeds)
        greedy = temps <= 0.0
        hist_w = hist.shape[1]

        def one_round(carry, _):
            # once every lane has drained its budget the remaining rounds
            # of the fixed-length scan skip the model entirely (lax.cond
            # executes one branch at runtime), so the engine can launch a
            # constant number of rounds — one jit variant, no retraces —
            # without paying for dead tail rounds
            return jax.lax.cond(jnp.any(carry[5]), live_round, parked_round,
                                carry)

        def parked_round(carry):
            return carry, (jnp.zeros((B, K1), jnp.int32),
                           jnp.zeros((B, K1), bool),
                           jnp.zeros((B,), jnp.int32))

        def live_round(carry):
            cache, d_cache, hist, tokens, positions, active, budgets = carry
            pos_eff = jnp.where(active, positions, 0)
            bt_eff = jnp.where(active[:, None], block_tables, 0)

            # ---- propose spec_k draft tokens + their proposal dists q
            if drafter is None:
                drafts = ngram_propose(hist, pos_eff, k=spec_k, n=ngram)
                q_dists = jax.nn.one_hot(drafts, cfg.vocab_size,
                                         dtype=jnp.float32)
                new_d_cache = d_cache
            else:
                def d_step(dc, j):
                    d_cache, cur = dc
                    p_j = jnp.minimum(pos_eff + j, max_len - 1)
                    lg, d_cache = drafter.decode_step_paged(
                        d_params, d_cache, cur, p_j, bt_eff)
                    qd = sampling_dist(lg[:, : cfg.vocab_size], temps,
                                       top_ks)
                    keys = event_keys(base_keys, pos_eff + j + 1,
                                      SALT_DRAFT)
                    nxt = sample_from_dist(keys, qd, greedy)
                    return (d_cache, nxt), (nxt, qd)

                (new_d_cache, d_last), (drafts, q_dists) = jax.lax.scan(
                    d_step, (d_cache, tokens), jnp.arange(spec_k))
                # backfill d_K so the drafter cache has no hole next round
                p_b = jnp.minimum(pos_eff + spec_k, max_len - 1)
                _, new_d_cache = drafter.decode_step_paged(
                    d_params, new_d_cache, d_last, p_b, bt_eff)
                drafts = drafts.transpose(1, 0)
                q_dists = q_dists.transpose(1, 0, 2)

            # ---- verify all spec_k + 1 positions in one target pass
            cand_in = jnp.concatenate([tokens[:, None], drafts], axis=1)
            n_valid = jnp.where(active,
                                jnp.clip(max_len - pos_eff, 0, K1), 0)
            logits, cache = self.decode_verify_paged(
                params, cache, cand_in, pos_eff, bt_eff, n_valid)
            p_dists = sampling_dist(
                logits[..., : cfg.vocab_size],
                jnp.broadcast_to(temps[:, None], (B, K1)),
                jnp.broadcast_to(top_ks[:, None], (B, K1)))

            # ---- rejection-sample the accepted prefix + correction/bonus
            n_acc, cand_out = rejection_choose(
                base_keys, pos_eff, drafts, q_dists, p_dists, greedy,
                n_valid)
            j = jnp.arange(K1)[None, :]

            # ---- stop flags, replicating decode_multi_paged's per-step
            # semantics: token slot j is this round's (j+1)-th emission
            stop = ((budgets[:, None] - (j + 1) <= 0)
                    | (cand_out == eos_ids[:, None])
                    | (pos_eff[:, None] + j + 1 >= max_len))
            stopped_before = (jnp.cumsum(stop.astype(jnp.int32), axis=1)
                              - stop.astype(jnp.int32)) > 0
            emit = active[:, None] & (j <= n_acc[:, None]) & ~stopped_before
            done = jnp.any(emit & stop, axis=1)
            m = emit.sum(axis=1)

            # ---- advance lane state by the emitted run
            last_tok = jnp.take_along_axis(
                cand_out, jnp.maximum(m - 1, 0)[:, None], axis=1)[:, 0]
            tokens = jnp.where(m > 0, last_tok, tokens)
            positions = positions + m
            budgets = budgets - m
            active = active & ~done
            upd_idx = jnp.where(emit, pos_eff[:, None] + j + 1, hist_w)
            hist = jax.vmap(
                lambda h, i, v: h.at[i].set(v, mode="drop"))(
                    hist, upd_idx, cand_out)

            carry = (cache, new_d_cache, hist, tokens, positions, active,
                     budgets)
            return carry, (cand_out, emit, n_acc)

        carry0 = (cache, d_cache, hist, tokens, positions, active, budgets)
        (cache, d_cache, hist, tokens, positions, active, budgets), \
            (out_tokens, emitted, n_accs) = jax.lax.scan(
                one_round, carry0, None, length=num_steps)
        return (out_tokens, emitted, n_accs,
                (tokens, positions, active, budgets), cache, d_cache, hist)

    def prefill_chunk_paged(self, params, cache, tokens, starts, lengths,
                            block_tables):
        """One chunked-prefill step over the paged pool.

        tokens: (B, C) — the next C context tokens per prefilling row, row
        b valid for its first lengths[b] tokens; starts: (B,) absolute
        position of tokens[:, 0] (tokens before ``starts`` — earlier
        chunks or prefix-shared blocks — must already sit in the pool).
        Returns (logits at each row's last valid chunk token (B, V_pad),
        cache).  Rows admitted mid-way through a longer prompt simply call
        this again with ``starts`` advanced; decode TBT is never blocked
        for longer than one chunk.
        """
        cfg, plan = self.cfg, self.plan
        x = self._embed_inputs(params, tokens)

        def body(x, inp):
            lp, lc = inp
            x, new_lc = block_prefill_paged(x, lp, lc, starts, lengths,
                                            block_tables, cfg, plan)
            return x, new_lc

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        x = _norm(x, params["final_norm"], cfg)
        last = jnp.take_along_axis(
            x, jnp.maximum(lengths - 1, 0)[:, None, None].astype(jnp.int32),
            axis=1)[:, 0]
        return self._head(params, last), new_cache

    # ----- grads -----
    def canonicalize_grads(self, grads: Params) -> Params:
        """Zero pad-head grads / tie padded-kv-copy grads so the padded model
        stays exactly equivalent to the published architecture."""
        cfg, plan = self.cfg, self.plan
        H, h_pad = cfg.n_heads, plan.h_pad(cfg)
        lay = dict(grads["layers"])

        def zero_tail(w, axis):
            idx = [slice(None)] * w.ndim
            idx[axis] = slice(H, None)
            return w.at[tuple(idx)].set(0)

        if cfg.rwkv and h_pad != H:
            t = dict(lay["tmix"])
            for name in ("w_r", "w_k", "w_v", "w_g"):
                t[name] = zero_tail(t[name], 2)
            t["w_o"] = zero_tail(t["w_o"], 1)
            t["decay_b"] = zero_tail(t["decay_b"], 2)
            for name in ("decay_base", "u_bonus", "ln_x"):
                t[name] = zero_tail(t[name], 1)
            lay["tmix"] = t
        elif "attn" in lay:
            if cfg.attn_kind == "mla":
                if h_pad != H:
                    a = dict(lay["attn"])
                    for name in ("w_uq", "w_uk", "w_uv"):
                        a[name] = zero_tail(a[name], 2)
                    a["w_o"] = zero_tail(a["w_o"], 1)
                    lay["attn"] = a
            else:
                lay["attn"] = A.canonicalize_gqa_grads(lay["attn"], cfg, plan)
        out = dict(grads)
        out["layers"] = lay
        return out

    # ----- cache -----
    def init_cache(self, batch: int, seq_len: int, dtype=jnp.bfloat16):
        cfg, plan = self.cfg, self.plan
        single, _ = self._cache_template(batch, seq_len, dtype)
        return jax.tree.map(
            lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), single)

    def init_paged_cache(self, n_blocks: int, block_size: int,
                         dtype=jnp.bfloat16):
        """Layer-stacked paged KV pool: leaves (L, n_blocks, bs, K, hd).
        Under a sharded plan each leaf is laid out over the mesh (the
        ``kv_blocks`` axis stripes physical block ids across ranks)."""
        cfg, plan = self.cfg, self.plan
        c, _ = A.init_paged_attn_cache(cfg, plan, n_blocks, block_size, dtype)
        cache = jax.tree.map(
            lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype),
            {"attn": c})
        sh = self.paged_cache_shardings()
        if sh is not None:
            cache = jax.device_put(cache, sh)
        return cache

    def paged_cache_axes(self):
        """Logical axes of the layer-stacked paged pool leaves."""
        _, ax = A.init_paged_attn_cache(self.cfg, self.plan,
                                        max(self.plan.tp, 1), 1, jnp.bfloat16)
        return jax.tree.map(lambda a: ("layers",) + a, {"attn": ax},
                            is_leaf=lambda x: isinstance(x, tuple))

    def paged_cache_shardings(self):
        if self.plan.mesh is None:
            return None
        return self.plan.tree_shardings(self.paged_cache_axes(), self.cfg)

    def cache_axes(self):
        _, ax = self._cache_template(1, 8, jnp.bfloat16)
        return jax.tree.map(lambda a: ("layers",) + a, ax,
                            is_leaf=lambda x: isinstance(x, tuple))

    def cache_shardings(self):
        return self.plan.tree_shardings(self.cache_axes(), self.cfg)

    def _cache_template(self, batch, seq_len, dtype):
        cfg, plan = self.cfg, self.plan
        if cfg.rwkv:
            return S.init_rwkv_state(cfg, plan, batch, dtype)
        c, ax = A.init_attn_cache(cfg, plan, batch, seq_len, dtype)
        cache = {"attn": c}
        axes = {"attn": ax}
        if cfg.family == "hybrid":
            ms, max_ = S.init_mamba_state(cfg, plan, batch, dtype)
            cache["mamba"] = ms
            axes["mamba"] = max_
        return cache, axes


def build_model(name_or_cfg, plan: ShardPlan) -> Model:
    from repro.configs import get_config
    cfg = name_or_cfg if isinstance(name_or_cfg, ArchConfig) else get_config(name_or_cfg)
    return Model(cfg, plan)
