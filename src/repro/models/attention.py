"""Attention for every assigned family: GQA (+qk_norm), MLA, SWA, encoder.

Train/prefill use an XLA-native flash-equivalent: a statically unrolled
q-chunk loop that only materialises (chunk x klen) score blocks, giving
exact causal FLOPs and bounded VMEM-sized temporaries (this mirrors what the
Pallas ``flash_attention`` kernel does on real TPUs; see kernels/).

Decode uses sequence-sharded flash-decode: the KV cache is sharded along
the sequence dim over the ``model`` axis, every device computes a partial
softmax over its KV slice for *all* heads, and partials are combined with
the LSE trick via psum (collective bytes per layer: O(B*H*hd), tiny).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.sharding import ShardPlan, shard_map_or_call

Params = dict[str, Any]
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init + logical axes
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ArchConfig, plan: ShardPlan) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    h_pad, k = plan.h_pad(cfg), cfg.n_kv_heads
    dt = plan.param_dtype
    ks = jax.random.split(key, 4)
    w_q = L.dense_init(ks[0], (d, cfg.n_heads, hd), dtype=dt)
    w_q = jnp.pad(w_q, ((0, 0), (0, h_pad - cfg.n_heads), (0, 0)))
    w_o = L.dense_init(ks[3], (cfg.n_heads, hd, d), in_axis=1, dtype=dt)
    w_o = jnp.pad(w_o, ((0, h_pad - cfg.n_heads), (0, 0), (0, 0)))
    w_k = L.dense_init(ks[1], (d, k, hd), dtype=dt)
    w_v = L.dense_init(ks[2], (d, k, hd), dtype=dt)
    if plan.kv_padded(cfg):
        copies = plan.k_pad(cfg) // k  # slot j <-> real head j // copies
        w_k = jnp.repeat(w_k, copies, axis=1)
        w_v = jnp.repeat(w_v, copies, axis=1)
    p = {"w_q": w_q, "w_k": w_k, "w_v": w_v, "w_o": w_o}
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def canonicalize_gqa_grads(g: Params, cfg: ArchConfig, plan: ShardPlan) -> Params:
    """Keep padded params exactly equivalent to the unpadded model:
    zero pad-q-head grads; average (tie) padded kv-copy grads.

    Grad arrays are layer-stacked: leading 'layers' dim.
    """
    g = dict(g)
    H, h_pad = cfg.n_heads, plan.h_pad(cfg)
    if h_pad != H:
        g["w_q"] = g["w_q"].at[:, :, H:, :].set(0)
        g["w_o"] = g["w_o"].at[:, H:, :, :].set(0)
    if plan.kv_padded(cfg):
        k = cfg.n_kv_heads
        copies = plan.k_pad(cfg) // k
        for name in ("w_k", "w_v"):
            w = g[name]  # (L, d, K_pad, hd); slot j <-> real j // copies
            shp = w.shape
            w = w.reshape(shp[0], shp[1], k, copies, shp[3])
            w = jnp.broadcast_to(w.mean(axis=3, keepdims=True), w.shape)
            g[name] = w.reshape(shp)
    return g


def gqa_axes(cfg: ArchConfig, plan: ShardPlan) -> Params:
    ax = {
        "w_q": ("embed", "heads", "qk_dim"),
        "w_k": ("embed", "kv_heads", "qk_dim"),
        "w_v": ("embed", "kv_heads", "qk_dim"),
        "w_o": ("heads", "qk_dim", "embed"),
    }
    if cfg.qk_norm:
        ax["q_norm"] = ("qk_dim",)
        ax["k_norm"] = ("qk_dim",)
    return ax


def init_mla(key, cfg: ArchConfig, plan: ShardPlan) -> Params:
    d = cfg.d_model
    h_pad = plan.h_pad(cfg)
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, ropeD, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = plan.param_dtype
    ks = jax.random.split(key, 7)

    def padh(w, axis):
        pad = [(0, 0)] * w.ndim
        pad[axis] = (0, h_pad - cfg.n_heads)
        return jnp.pad(w, pad)

    return {
        "w_dq": L.dense_init(ks[0], (d, rq), dtype=dt),
        "w_uq": padh(L.dense_init(ks[1], (rq, cfg.n_heads, nope + ropeD), dtype=dt), 1),
        "w_dkv": L.dense_init(ks[2], (d, rkv), dtype=dt),
        "w_kr": L.dense_init(ks[3], (d, ropeD), dtype=dt),
        "w_uk": padh(L.dense_init(ks[4], (rkv, cfg.n_heads, nope), dtype=dt), 1),
        "w_uv": padh(L.dense_init(ks[5], (rkv, cfg.n_heads, vd), dtype=dt), 1),
        "w_o": padh(L.dense_init(ks[6], (cfg.n_heads, vd, d), in_axis=1, dtype=dt), 0),
        "q_norm": jnp.ones((rq,), dt),
        "kv_norm": jnp.ones((rkv,), dt),
    }


def mla_axes(cfg: ArchConfig, plan: ShardPlan) -> Params:
    return {
        "w_dq": ("embed", "lora"),
        "w_uq": ("lora", "heads", "qk_dim"),
        "w_dkv": ("embed", "lora"),
        "w_kr": ("embed", "qk_dim"),
        "w_uk": ("lora", "heads", "qk_dim"),
        "w_uv": ("lora", "heads", "v_dim"),
        "w_o": ("heads", "v_dim", "embed"),
        "q_norm": ("lora",),
        "kv_norm": ("lora",),
    }


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def kv_index(cfg: ArchConfig, h_pad: int, k_pad: int | None = None) -> jnp.ndarray:
    """Constant q-head -> kv-slot map; pad heads point at slot 0.

    With padded kv (k_pad == tp > n_kv) the map is h * k_pad // n_heads,
    which is monotone and shard-aligned (slot j holds a copy of real head
    j * n_kv // k_pad; see DESIGN.md §3).
    """
    k = k_pad or cfg.n_kv_heads
    if k == cfg.n_kv_heads:
        idx = [h * cfg.n_kv_heads // cfg.n_heads for h in range(cfg.n_heads)]
    else:
        idx = [h * k // cfg.n_heads for h in range(cfg.n_heads)]
    idx += [0] * (h_pad - cfg.n_heads)
    return jnp.asarray(idx, jnp.int32)


def _expand_kv(k: jax.Array, kv_idx: jax.Array, n_heads: int) -> jax.Array:
    """(..., K, hd) -> (..., H, hd) via constant-index gather (GQA)."""
    if k.shape[-2] == n_heads:
        return k
    return jnp.take(k, kv_idx, axis=-2)


def _pick_chunk(b_loc: int, h_loc: int, s: int, budget: int) -> int:
    """Largest power-of-two chunk whose fp32 score block fits the budget."""
    c = 1024
    while c > 128 and b_loc * h_loc * c * s * 4 > budget:
        c //= 2
    while s % c:
        c //= 2
    return max(c, 1)


def _attn_block(q, k, v, mask, scale):
    """One (chunk x klen) attention block; fp32 softmax."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


# ---------------------------------------------------------------------------
# train / prefill attention cores
# ---------------------------------------------------------------------------

def causal_attention(q, k, v, *, scale: float, plan: ShardPlan,
                     cfg: ArchConfig) -> jax.Array:
    """Flash-style causal attention: nested scans over uniform (cq x ck)
    tiles with online softmax.

    Uniform tile shapes let XLA reuse one score buffer across every scan
    step (the unrolled growing-klen variant kept O(S/c) distinct buffers
    live and blew past HBM at 32k).  Above-diagonal tiles are masked, not
    skipped — the XLA path pays ~2x causal attention FLOPs; the Pallas
    ``flash_attention`` kernel (kernels/) skips them with @pl.when on TPU.
    """
    B, S, H, D = q.shape
    Dv = v.shape[-1]  # MLA: value head dim differs from the qk dim
    if S <= 1024:
        mask = (jnp.arange(S)[None, :] <= jnp.arange(S)[:, None])[None, None]
        return _attn_block(q, k, v, mask, scale)
    if plan.attn_exact_causal:
        return _causal_pair_scan(q, k, v, scale=scale, c=plan.attn_cq)
    cq = ck = plan.attn_cq
    while S % cq:
        cq //= 2
    ck = cq
    nq, nk = S // cq, S // ck
    qs = q.reshape(B, nq, cq, H, D).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(B, nk, ck, H, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, ck, H, Dv).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_i):
        qi, i = qi_i  # (B, cq, H, D)

        def k_step(carry, kv_j):
            m, l, acc = carry
            kj, vj, j = kv_j
            s = jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            qpos = i * cq + jnp.arange(cq)
            kpos = j * ck + jnp.arange(ck)
            mask = (kpos[None, :] <= qpos[:, None])[None, None]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, H, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, H, cq, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), (ks, vs, jnp.arange(nk)))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, o.transpose(0, 2, 1, 3).astype(q.dtype)  # (B, cq, H, D)

    _, outs = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Dv)


def _causal_pair_scan(q, k, v, *, scale: float, c: int) -> jax.Array:
    """Exact-FLOPs flash attention: one scan over the n(n+1)/2 lower-triangle
    (q-block, k-block) pairs — above-diagonal tiles are never read or
    computed, unlike the masked nested scan (§Perf iteration 1).

    Pairs are ordered row-major (i ascending, j = 0..i), so the running
    (m, l, acc) carry resets at j == 0 and row i's output is complete at the
    diagonal; the out buffer is updated every step and the diagonal write
    (the last one per row) wins.
    """
    B, S, H, D = q.shape
    Dv = v.shape[-1]
    while S % c:
        c //= 2
    n = S // c
    i_idx = jnp.asarray([i for i in range(n) for _ in range(i + 1)])
    j_idx = jnp.asarray([j for i in range(n) for j in range(i + 1)])

    def step(carry, ij):
        m, l, acc, out = carry
        i, j = ij
        reset = (j == 0)
        m = jnp.where(reset, NEG_INF, m)
        l = jnp.where(reset, 0.0, l)
        acc = jnp.where(reset, 0.0, acc)
        qi = jax.lax.dynamic_slice_in_dim(q, i * c, c, 1)
        kj = jax.lax.dynamic_slice_in_dim(k, j * c, c, 1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * c, c, 1)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        qpos = i * c + jnp.arange(c)
        kpos = j * c + jnp.arange(c)
        mask = (kpos[None, :] <= qpos[:, None])[None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        o = (acc / jnp.maximum(l, 1e-30)[..., None]).transpose(0, 2, 1, 3)
        out = jax.lax.dynamic_update_slice_in_dim(out, o.astype(out.dtype),
                                                  i * c, 1)
        return (m_new, l, acc, out), None

    m0 = jnp.full((B, H, c), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, c), jnp.float32)
    a0 = jnp.zeros((B, H, c, Dv), jnp.float32)
    out0 = jnp.zeros((B, S, H, Dv), q.dtype)
    (_, _, _, out), _ = jax.lax.scan(step, (m0, l0, a0, out0),
                                     (i_idx, j_idx))
    return out


def encoder_attention(q, k, v, *, scale: float, plan: ShardPlan,
                      cfg: ArchConfig) -> jax.Array:
    """Bidirectional attention (encoder-only archs); scan over q chunks."""
    B, S, H, D = q.shape
    b_loc = max(B // max(plan.dp, 1), 1)
    h_loc = max(H // max(plan.tp, 1), 1)
    chunk = _pick_chunk(b_loc, h_loc, S, plan.attn_temp_budget)
    n = S // chunk
    if n == 1:
        return _attn_block(q, k, v, jnp.bool_(True)[None, None, None, None], scale)
    qs = q.reshape(B, n, chunk, H, D).transpose(1, 0, 2, 3, 4)

    def body(_, qi):
        return None, _attn_block(qi, k, v, jnp.bool_(True)[None, None, None, None], scale)

    _, o = jax.lax.scan(body, None, qs)
    return o.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)


def swa_attention(q, k, v, *, window: int, scale: float, plan: ShardPlan,
                  cfg: ArchConfig) -> jax.Array:
    """Banded (sliding-window) causal attention, O(S * window)."""
    B, S, H, D = q.shape
    if S <= window:  # window covers everything: plain causal is identical
        return causal_attention(q, k, v, scale=scale, plan=plan, cfg=cfg)
    chunk = min(max(window, 128), S)
    while S % chunk:
        chunk //= 2
    if S <= window + chunk or S <= 2048:
        # small enough: one explicit causal+window masked block
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(S)[None, :]
        mask = ((kpos <= qpos) & (kpos > qpos - window))[None, None]
        return _attn_block(q, k, v, mask, scale)
    n = S // chunk
    win = window + chunk  # each q chunk sees [i*chunk - window, i*chunk + chunk)
    # gather k windows: idx[i, t] = i*chunk - window + t (clamped; masked below)
    base = jnp.arange(n)[:, None] * chunk
    idx = base + jnp.arange(-window, chunk)[None, :]
    valid_idx = idx >= 0
    idx_c = jnp.clip(idx, 0, S - 1)
    kw = jnp.take(k, idx_c, axis=1)  # (B, n, win, Hk, D)
    vw = jnp.take(v, idx_c, axis=1)
    qs = q.reshape(B, n, chunk, H, D)
    qpos = base[:, :, None] + jnp.arange(chunk)[None, None, :]  # (1? n, chunk)
    qpos = (jnp.arange(n)[:, None] * chunk + jnp.arange(chunk)[None, :])
    kpos = idx  # (n, win)
    causal = kpos[:, None, :] <= qpos[:, :, None]
    inwin = kpos[:, None, :] > qpos[:, :, None] - window
    mask = (causal & inwin & valid_idx[:, None, :])[None, :, None]  # (1,n,1,chunk,win)
    s = jnp.einsum("bnqhd,bnkhd->bnhqk", qs, kw,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnhqk,bnkhd->bnqhd", p.astype(vw.dtype), vw)
    return o.reshape(B, S, H, D)


# ---------------------------------------------------------------------------
# GQA forward (train / prefill)
# ---------------------------------------------------------------------------

def gqa_forward(p: Params, x: jax.Array, positions: jax.Array,
                cfg: ArchConfig, plan: ShardPlan, *, want_cache: bool):
    """x: (B, S, d) -> (out (B, S, d), cache | None)."""
    dt = plan.compute_dtype
    h_pad = plan.h_pad(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"].astype(dt))
    k = jnp.einsum("bsd,dgk->bsgk", x, p["w_k"].astype(dt))
    v = jnp.einsum("bsd,dgk->bsgk", x, p["w_v"].astype(dt))
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"])
        k = L.rms_norm(k, p["k_norm"])
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    q = plan.constrain(q, ("batch", "seq", "heads", None), cfg)
    kv_ax = "kv_heads"
    k = plan.constrain(k, ("batch", "seq", kv_ax, None), cfg)
    v = plan.constrain(v, ("batch", "seq", kv_ax, None), cfg)
    cache = None
    if want_cache:
        k_out, v_out = k, v
        if plan.kv_padded(cfg):
            # dedup padded copies: slot r*copies is copy-0 of real head r
            copies = plan.k_pad(cfg) // cfg.n_kv_heads
            k_out, v_out = k[:, :, ::copies], v[:, :, ::copies]
        if cfg.attn_kind == "swa" and cfg.window:
            # ring-buffer tail: slot (p % W) holds position p, p in [S-W, S)
            S = k_out.shape[1]
            W = min(cfg.window, S)
            tail = jnp.arange(S - W, S)
            slot = tail % W
            k_ring = jnp.zeros((k_out.shape[0], W) + k_out.shape[2:], k_out.dtype)
            v_ring = jnp.zeros_like(k_ring)
            k_ring = k_ring.at[:, slot].set(k_out[:, S - W:])
            v_ring = v_ring.at[:, slot].set(v_out[:, S - W:])
            cache = {"k": k_ring, "v": v_ring}
        else:
            cache = {
                "k": plan.constrain(k_out, ("batch", "cache_seq", "kv_cache_heads", None), cfg),
                "v": plan.constrain(v_out, ("batch", "cache_seq", "kv_cache_heads", None), cfg),
            }
    idx = kv_index(cfg, h_pad, plan.k_pad(cfg))
    ke = _expand_kv(k, idx, h_pad)
    ve = _expand_kv(v, idx, h_pad)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    if not cfg.causal:
        o = encoder_attention(q, ke, ve, scale=scale, plan=plan, cfg=cfg)
    elif cfg.attn_kind == "swa" and cfg.window:
        o = swa_attention(q, ke, ve, window=cfg.window, scale=scale, plan=plan, cfg=cfg)
    else:
        o = causal_attention(q, ke, ve, scale=scale, plan=plan, cfg=cfg)
    out = jnp.einsum("bshk,hkd->bsd", o, p["w_o"].astype(dt))
    return plan.constrain(out, ("batch", "seq", "embed_act"), cfg), cache


# ---------------------------------------------------------------------------
# MLA forward (train / prefill)
# ---------------------------------------------------------------------------

def mla_forward(p: Params, x: jax.Array, positions: jax.Array,
                cfg: ArchConfig, plan: ShardPlan, *, want_cache: bool):
    dt = plan.compute_dtype
    nope, ropeD = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    # --- queries (low-rank) ---
    cq = L.rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(dt)), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(dt))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    # --- latent kv ---
    ckv = L.rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(dt)), p["kv_norm"])
    kr = L.apply_rope(jnp.einsum("bsd,dk->bsk", x, p["w_kr"].astype(dt))[:, :, None, :],
                      positions, cfg.rope_theta)[:, :, 0]  # (B,S,ropeD), shared
    cache = None
    if want_cache:
        cache = {
            "ckv": plan.constrain(ckv, ("batch", "cache_seq", None), cfg),
            "kr": plan.constrain(kr, ("batch", "cache_seq", None), cfg),
        }
    # --- expand latent to per-head k/v (prefill-optimal form) ---
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"].astype(dt))
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uv"].astype(dt))
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr[:, :, None, :], k_nope.shape[:2] + (k_nope.shape[2], ropeD))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    qf = plan.constrain(qf, ("batch", "seq", "heads", None), cfg)
    k = plan.constrain(k, ("batch", "seq", "heads", None), cfg)
    v = plan.constrain(v, ("batch", "seq", "heads", None), cfg)
    scale = 1.0 / math.sqrt(nope + ropeD)
    o = causal_attention(qf, k, v, scale=scale, plan=plan, cfg=cfg)
    out = jnp.einsum("bshk,hkd->bsd", o, p["w_o"].astype(dt))
    return plan.constrain(out, ("batch", "seq", "embed_act"), cfg), cache


# ---------------------------------------------------------------------------
# decode: sequence-sharded flash-decode
# ---------------------------------------------------------------------------

def _flash_decode_core(axis, q, k_cache, v_cache, k_new, v_new, positions,
                       kv_idx, scale):
    """Runs per-device on an S-shard of the cache.

    q: (B, H, hd) full heads; k_cache/v_cache: (B, S_loc, K, hd);
    k_new/v_new: (B, K, hd); positions: (B,).  Returns (o, k_cache, v_cache).
    """
    B, S_loc = k_cache.shape[0], k_cache.shape[1]
    off = (jax.lax.axis_index(axis) * S_loc) if axis is not None else 0
    local = positions - off
    valid_w = (local >= 0) & (local < S_loc)
    safe = jnp.clip(local, 0, S_loc - 1)
    bidx = jnp.arange(B)
    old_k = k_cache[bidx, safe]
    old_v = v_cache[bidx, safe]
    k_cache = k_cache.at[bidx, safe].set(
        jnp.where(valid_w[:, None, None], k_new, old_k))
    v_cache = v_cache.at[bidx, safe].set(
        jnp.where(valid_w[:, None, None], v_new, old_v))

    ke = _expand_kv(k_cache, kv_idx, q.shape[1])  # (B, S_loc, H, hd)
    ve = _expand_kv(v_cache, kv_idx, q.shape[1])
    s = jnp.einsum("bhd,bshd->bhs", q, ke,
                   preferred_element_type=jnp.float32) * scale
    kpos = off + jnp.arange(S_loc)
    mask = kpos[None, None, :] <= positions[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)  # (B, H)
    if axis is not None:
        m = jax.lax.pmax(m, axis)
    pexp = jnp.exp(s - m[..., None])
    l = jnp.sum(pexp, axis=-1)
    num = jnp.einsum("bhs,bshd->bhd", pexp.astype(ve.dtype), ve,
                     preferred_element_type=jnp.float32)
    if axis is not None:
        l = jax.lax.psum(l, axis)
        num = jax.lax.psum(num, axis)
    o = num / jnp.maximum(l, 1e-30)[..., None]
    return o.astype(q.dtype), k_cache, v_cache


def _decode_qkv(p: Params, x: jax.Array, positions: jax.Array,
                cfg: ArchConfig, plan: ShardPlan):
    """Shared one-token GQA projection: q (B, H, hd) + the new token's
    real-head k/v (B, K, hd), with qk_norm/rope/padded-copy-drop applied."""
    dt = plan.compute_dtype
    q = jnp.einsum("bd,dhk->bhk", x, p["w_q"].astype(dt))
    k_new = jnp.einsum("bd,dgk->bgk", x, p["w_k"].astype(dt))
    v_new = jnp.einsum("bd,dgk->bgk", x, p["w_v"].astype(dt))
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"])
        k_new = L.rms_norm(k_new, p["k_norm"])
    q = L.apply_rope(q[:, None], positions[:, None], cfg.rope_theta)[:, 0]
    k_new = L.apply_rope(k_new[:, None], positions[:, None], cfg.rope_theta)[:, 0]
    if plan.kv_padded(cfg):
        # decode caches store real heads; drop padded copies of the new token
        copies = plan.k_pad(cfg) // cfg.n_kv_heads
        k_new, v_new = k_new[:, ::copies], v_new[:, ::copies]
    return q, k_new, v_new


def gqa_decode(p: Params, x: jax.Array, cache: Params, positions: jax.Array,
               cfg: ArchConfig, plan: ShardPlan):
    """x: (B, d) one token per sequence -> (out (B, d), new cache)."""
    dt = plan.compute_dtype
    h_pad = plan.h_pad(cfg)
    q, k_new, v_new = _decode_qkv(p, x, positions, cfg, plan)
    idx = kv_index(cfg, h_pad)
    scale = 1.0 / math.sqrt(cfg.head_dim)

    if cfg.attn_kind == "swa" and cfg.window:
        return _swa_decode(p, q, k_new, v_new, cache, positions, cfg, plan, idx, scale)

    dp = plan.dp_axes if plan.dp_axes else None
    in_specs = (P(dp, None, None), P(dp, "model", None, None),
                P(dp, "model", None, None), P(dp, None, None),
                P(dp, None, None), P(dp))
    out_specs = (P(dp, None, None), P(dp, "model", None, None),
                 P(dp, "model", None, None))
    o, k_c, v_c = shard_map_or_call(
        plan, lambda ax, *a: _flash_decode_core(ax, *a, kv_idx=idx, scale=scale),
        in_specs, out_specs, q, cache["k"], cache["v"], k_new, v_new, positions)
    out = jnp.einsum("bhk,hkd->bd", o, p["w_o"].astype(dt))
    return plan.constrain(out, ("batch", "embed_act"), cfg), {"k": k_c, "v": v_c}


def _swa_decode(p, q, k_new, v_new, cache, positions, cfg, plan, kv_idx, scale):
    """Ring-buffer sliding-window decode; window cache replicated over model."""
    dt = plan.compute_dtype
    W = cache["k"].shape[1]
    B = q.shape[0]
    bidx = jnp.arange(B)
    slot = positions % W
    k_c = cache["k"].at[bidx, slot].set(k_new)
    v_c = cache["v"].at[bidx, slot].set(v_new)
    ke = _expand_kv(k_c, kv_idx, q.shape[1])
    ve = _expand_kv(v_c, kv_idx, q.shape[1])
    s = jnp.einsum("bhd,bshd->bhs", q, ke,
                   preferred_element_type=jnp.float32) * scale
    slots = jnp.arange(W)
    valid = (slots[None, :] <= positions[:, None]) | (positions[:, None] >= W)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhs,bshd->bhd", prob.astype(ve.dtype), ve)
    out = jnp.einsum("bhk,hkd->bd", o.astype(dt), p["w_o"].astype(dt))
    return plan.constrain(out, ("batch", "embed_act"), cfg), {"k": k_c, "v": v_c}


def paged_attention(q, k_pool, v_pool, block_tables, positions, *,
                    scale: float, kv_idx: jax.Array) -> jax.Array:
    """Decode attention over a paged KV pool (XLA gather path).

    q: (B, H, hd); k_pool/v_pool: (n_blocks, bs, K, hd);
    block_tables: (B, T) physical block ids; positions: (B,).

    This is the XLA-native counterpart of the Pallas
    ``kernels/paged_decode_attention.py`` kernel: the per-sequence logical
    view is gathered from the pool through the block table, then masked by
    position.  On TPU the kernel resolves the same gather in its BlockSpec
    index map and never materialises the view.
    """
    B, H = q.shape[:2]
    bs, K = k_pool.shape[1], k_pool.shape[2]
    T = block_tables.shape[1]
    k = k_pool[block_tables].reshape(B, T * bs, K, -1)
    v = v_pool[block_tables].reshape(B, T * bs, K, -1)
    ke = _expand_kv(k, kv_idx, H)
    ve = _expand_kv(v, kv_idx, H)
    s = jnp.einsum("bhd,bshd->bhs", q, ke,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(T * bs)[None, None, :] <= positions[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhs,bshd->bhd", prob.astype(ve.dtype), ve,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def paged_prefill_attention(q, k_pool, v_pool, block_tables, starts, *,
                            scale: float, kv_idx: jax.Array) -> jax.Array:
    """Chunk-prefill attention over a paged KV pool (XLA gather path).

    q: (B, C, H, hd) — a chunk of C query tokens per sequence whose first
    token sits at absolute position ``starts[b]``; k_pool/v_pool:
    (n_blocks, bs, K, hd); block_tables: (B, T).  The chunk's own KV must
    already be written into the pool (see ``gqa_prefill_paged``), so one
    gather serves both the cached context and the within-chunk causal
    part: position kpos is visible to chunk token c iff
    kpos <= starts + c.  On TPU the Pallas counterpart
    (``kernels/paged_decode_attention.paged_prefill_attention``) resolves
    the gather in its BlockSpec index map instead.
    """
    B, C, H = q.shape[:3]
    bs, K = k_pool.shape[1], k_pool.shape[2]
    T = block_tables.shape[1]
    k = k_pool[block_tables].reshape(B, T * bs, K, -1)
    v = v_pool[block_tables].reshape(B, T * bs, K, -1)
    ke = _expand_kv(k, kv_idx, H)
    ve = _expand_kv(v, kv_idx, H)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, ke,
                   preferred_element_type=jnp.float32) * scale
    qpos = starts[:, None] + jnp.arange(C)[None, :]            # (B, C)
    mask = jnp.arange(T * bs)[None, None, :] <= qpos[:, :, None]
    s = jnp.where(mask[:, None], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", prob.astype(ve.dtype), ve,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def _paged_decode_core(axis, q, k_pool, v_pool, block_tables, positions,
                       k_new, v_new, *, scale, kv_idx):
    """Per-shard paged decode on a block-stripe of the pool, LSE-combined.

    Rank r owns physical blocks ``[r*nb_loc, (r+1)*nb_loc)``: the new
    token's KV scatter uses an out-of-range sentinel with ``mode="drop"``
    so exactly the owning rank writes, the gather masks unowned table
    entries, and the softmax merges across ranks via the same
    max/sum-reduce (pmax/psum) idiom as ``_flash_decode_core``.

    q: (B, H, hd); k_pool/v_pool: (nb_loc, bs, K, hd) local stripe;
    block_tables: (B, T) *global* block ids; k_new/v_new: (B, K, hd).
    """
    B, H = q.shape[:2]
    nb_loc, bs, K = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    off = (jax.lax.axis_index(axis) * nb_loc) if axis is not None else 0
    blk = jnp.take_along_axis(block_tables, (positions // bs)[:, None],
                              axis=1)[:, 0]
    o_in_b = positions % bs
    local_b = blk - off
    owned = (local_b >= 0) & (local_b < nb_loc)
    safe_b = jnp.where(owned, local_b, nb_loc)     # OOB on unowned -> dropped
    k_pool = k_pool.at[safe_b, o_in_b].set(k_new.astype(k_pool.dtype),
                                           mode="drop")
    v_pool = v_pool.at[safe_b, o_in_b].set(v_new.astype(v_pool.dtype),
                                           mode="drop")
    T = block_tables.shape[1]
    local_t = block_tables - off
    t_owned = (local_t >= 0) & (local_t < nb_loc)  # (B, T)
    safe_t = jnp.clip(local_t, 0, nb_loc - 1)
    k = k_pool[safe_t].reshape(B, T * bs, K, -1)
    v = v_pool[safe_t].reshape(B, T * bs, K, -1)
    ke = _expand_kv(k, kv_idx, H)
    ve = _expand_kv(v, kv_idx, H)
    s = jnp.einsum("bhd,bshd->bhs", q, ke,
                   preferred_element_type=jnp.float32) * scale
    mask = (jnp.arange(T * bs)[None, None, :] <= positions[:, None, None]) \
        & jnp.repeat(t_owned, bs, axis=1)[:, None, :]
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    if axis is not None:
        m = jax.lax.pmax(m, axis)
    pexp = jnp.exp(s - m[..., None])
    l = jnp.sum(pexp, axis=-1)
    num = jnp.einsum("bhs,bshd->bhd", pexp.astype(ve.dtype), ve,
                     preferred_element_type=jnp.float32)
    if axis is not None:
        l = jax.lax.psum(l, axis)
        num = jax.lax.psum(num, axis)
    o = num / jnp.maximum(l, 1e-30)[..., None]
    return o.astype(q.dtype), k_pool, v_pool


def _paged_prefill_core(axis, q, k_pool, v_pool, block_tables, starts,
                        lengths, k, v, *, scale, kv_idx):
    """Per-shard chunk prefill on a block-stripe of the pool, LSE-combined.

    q: (B, C, H, hd); k/v: (B, C, K, hd) the chunk's new KV (rope applied,
    real heads); the scatter-then-gather ordering inside the core keeps
    within-chunk causal attention exact on the rank that owns each block.
    """
    B, C, H = q.shape[:3]
    nb_loc, bs, K = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    off = (jax.lax.axis_index(axis) * nb_loc) if axis is not None else 0
    positions = starts[:, None] + jnp.arange(C)[None, :]
    valid = jnp.arange(C)[None, :] < lengths[:, None]
    safe_pos = jnp.where(valid, positions, 0)
    blk = jnp.take_along_axis(block_tables, safe_pos // bs, axis=1)
    blk = jnp.where(valid, blk, 0)
    o_in_b = jnp.where(valid, safe_pos % bs, 0)
    local_b = blk - off
    owned_w = valid & (local_b >= 0) & (local_b < nb_loc)
    safe_b = jnp.where(owned_w, local_b, nb_loc)   # OOB on unowned -> dropped
    k_pool = k_pool.at[safe_b.reshape(-1), o_in_b.reshape(-1)].set(
        k.reshape(B * C, K, -1).astype(k_pool.dtype), mode="drop")
    v_pool = v_pool.at[safe_b.reshape(-1), o_in_b.reshape(-1)].set(
        v.reshape(B * C, K, -1).astype(v_pool.dtype), mode="drop")
    T = block_tables.shape[1]
    local_t = block_tables - off
    t_owned = (local_t >= 0) & (local_t < nb_loc)
    safe_t = jnp.clip(local_t, 0, nb_loc - 1)
    kk = k_pool[safe_t].reshape(B, T * bs, K, -1)
    vv = v_pool[safe_t].reshape(B, T * bs, K, -1)
    ke = _expand_kv(kk, kv_idx, H)
    ve = _expand_kv(vv, kv_idx, H)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, ke,
                   preferred_element_type=jnp.float32) * scale
    kmask = (jnp.arange(T * bs)[None, None, :] <= positions[:, :, None]) \
        & jnp.repeat(t_owned, bs, axis=1)[:, None, :]
    s = jnp.where(kmask[:, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                        # (B, H, C)
    if axis is not None:
        m = jax.lax.pmax(m, axis)
    pexp = jnp.exp(s - m[..., None])
    l = jnp.sum(pexp, axis=-1)
    num = jnp.einsum("bhqk,bkhd->bqhd", pexp.astype(ve.dtype), ve,
                     preferred_element_type=jnp.float32)
    if axis is not None:
        l = jax.lax.psum(l, axis)
        num = jax.lax.psum(num, axis)
    o = num / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return o.astype(q.dtype), k_pool, v_pool


def gqa_prefill_paged(p: Params, x: jax.Array, cache: Params,
                      starts: jax.Array, lengths: jax.Array,
                      block_tables: jax.Array, cfg: ArchConfig,
                      plan: ShardPlan):
    """Chunked-prefill step over the paged pool: project a chunk of C
    tokens, scatter its KV into the owned blocks, then attend through the
    block table (cached context + within-chunk causal in one gather).

    x: (B, C, d); starts: (B,) absolute position of x[:, 0]; lengths: (B,)
    valid tokens per row (ragged tails).  Invalid positions are routed to
    the reserved parking block 0, whose contents are never read unmasked.
    """
    dt = plan.compute_dtype
    h_pad = plan.h_pad(cfg)
    B, C = x.shape[:2]
    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"].astype(dt))
    k = jnp.einsum("bsd,dgk->bsgk", x, p["w_k"].astype(dt))
    v = jnp.einsum("bsd,dgk->bsgk", x, p["w_v"].astype(dt))
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"])
        k = L.rms_norm(k, p["k_norm"])
    positions = starts[:, None] + jnp.arange(C)[None, :]
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if plan.kv_padded(cfg):
        copies = plan.k_pad(cfg) // cfg.n_kv_heads
        k, v = k[:, :, ::copies], v[:, :, ::copies]
    idx = kv_index(cfg, h_pad)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    if plan.paged_pool_sharded(cfg):
        dp = plan.dp_axes if plan.dp_axes else None
        tp = plan.tp_axis
        in_specs = (P(dp, None, None, None), P(tp, None, None, None),
                    P(tp, None, None, None), P(dp, None), P(dp), P(dp),
                    P(dp, None, None, None), P(dp, None, None, None))
        out_specs = (P(dp, None, None, None), P(tp, None, None, None),
                     P(tp, None, None, None))
        o, k_c, v_c = shard_map_or_call(
            plan,
            lambda ax, *a: _paged_prefill_core(ax, *a, scale=scale, kv_idx=idx),
            in_specs, out_specs, q, cache["k"], cache["v"], block_tables,
            starts, lengths, k, v)
    else:
        bs = cache["k"].shape[1]
        K = cache["k"].shape[2]
        valid = jnp.arange(C)[None, :] < lengths[:, None]
        safe_pos = jnp.where(valid, positions, 0)
        blk = jnp.take_along_axis(block_tables, safe_pos // bs, axis=1)
        blk = jnp.where(valid, blk, 0)
        off = jnp.where(valid, safe_pos % bs, 0)
        k_c = cache["k"].at[blk.reshape(-1), off.reshape(-1)].set(
            k.reshape(B * C, K, -1).astype(cache["k"].dtype))
        v_c = cache["v"].at[blk.reshape(-1), off.reshape(-1)].set(
            v.reshape(B * C, K, -1).astype(cache["v"].dtype))
        o = paged_prefill_attention(q, k_c, v_c, block_tables, starts,
                                    scale=scale, kv_idx=idx)
    out = jnp.einsum("bshk,hkd->bsd", o, p["w_o"].astype(dt))
    return plan.constrain(out, ("batch", "seq", "embed_act"), cfg), \
        {"k": k_c, "v": v_c}


def gqa_decode_paged(p: Params, x: jax.Array, cache: Params,
                     positions: jax.Array, block_tables: jax.Array,
                     cfg: ArchConfig, plan: ShardPlan):
    """Paged-pool decode step: write the new token's KV into its block,
    attend through the block table.  x: (B, d) -> (out (B, d), new cache).

    The write touches exactly one (block, offset) slot per sequence —
    O(active sequences), independent of pool size — and under jit with a
    donated cache XLA updates the pool in place.
    """
    dt = plan.compute_dtype
    h_pad = plan.h_pad(cfg)
    q, k_new, v_new = _decode_qkv(p, x, positions, cfg, plan)
    idx = kv_index(cfg, h_pad)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    if plan.paged_pool_sharded(cfg):
        dp = plan.dp_axes if plan.dp_axes else None
        tp = plan.tp_axis
        in_specs = (P(dp, None, None), P(tp, None, None, None),
                    P(tp, None, None, None), P(dp, None), P(dp),
                    P(dp, None, None), P(dp, None, None))
        out_specs = (P(dp, None, None), P(tp, None, None, None),
                     P(tp, None, None, None))
        o, k_c, v_c = shard_map_or_call(
            plan,
            lambda ax, *a: _paged_decode_core(ax, *a, scale=scale, kv_idx=idx),
            in_specs, out_specs, q, cache["k"], cache["v"], block_tables,
            positions, k_new, v_new)
    else:
        bs = cache["k"].shape[1]
        blk = jnp.take_along_axis(block_tables, (positions // bs)[:, None],
                                  axis=1)[:, 0]
        off = positions % bs
        k_c = cache["k"].at[blk, off].set(k_new.astype(cache["k"].dtype))
        v_c = cache["v"].at[blk, off].set(v_new.astype(cache["v"].dtype))
        o = paged_attention(q, k_c, v_c, block_tables, positions,
                            scale=scale, kv_idx=idx)
    out = jnp.einsum("bhk,hkd->bd", o, p["w_o"].astype(dt))
    return plan.constrain(out, ("batch", "embed_act"), cfg), {"k": k_c, "v": v_c}


def _mla_decode_core(axis, qc, qr, ckv, kr, c_new, kr_new, positions, scale):
    """Absorbed MLA flash-decode on an S-shard of the latent cache.

    qc: (B, H, R) absorbed nope-query; qr: (B, H, ropeD);
    ckv: (B, S_loc, R); kr: (B, S_loc, ropeD).
    """
    B, S_loc = ckv.shape[0], ckv.shape[1]
    off = (jax.lax.axis_index(axis) * S_loc) if axis is not None else 0
    local = positions - off
    valid_w = (local >= 0) & (local < S_loc)
    safe = jnp.clip(local, 0, S_loc - 1)
    bidx = jnp.arange(B)
    ckv = ckv.at[bidx, safe].set(jnp.where(valid_w[:, None], c_new, ckv[bidx, safe]))
    kr = kr.at[bidx, safe].set(jnp.where(valid_w[:, None], kr_new, kr[bidx, safe]))
    s = (jnp.einsum("bhr,bsr->bhs", qc, ckv, preferred_element_type=jnp.float32)
         + jnp.einsum("bhp,bsp->bhs", qr, kr, preferred_element_type=jnp.float32)) * scale
    kpos = off + jnp.arange(S_loc)
    mask = kpos[None, None, :] <= positions[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    if axis is not None:
        m = jax.lax.pmax(m, axis)
    pexp = jnp.exp(s - m[..., None])
    l = jnp.sum(pexp, axis=-1)
    num = jnp.einsum("bhs,bsr->bhr", pexp.astype(ckv.dtype), ckv,
                     preferred_element_type=jnp.float32)
    if axis is not None:
        l = jax.lax.psum(l, axis)
        num = jax.lax.psum(num, axis)
    o = num / jnp.maximum(l, 1e-30)[..., None]  # (B, H, R) latent output
    return o.astype(qc.dtype), ckv, kr


def mla_decode(p: Params, x: jax.Array, cache: Params, positions: jax.Array,
               cfg: ArchConfig, plan: ShardPlan):
    dt = plan.compute_dtype
    nope, ropeD = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = L.rms_norm(jnp.einsum("bd,dr->br", x, p["w_dq"].astype(dt)), p["q_norm"])
    q = jnp.einsum("br,rhk->bhk", cq, p["w_uq"].astype(dt))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope[:, None], positions[:, None], cfg.rope_theta)[:, 0]
    # absorb W_uk into the query: q_c = q_nope . W_uk  -> (B, H, R)
    qc = jnp.einsum("bhn,rhn->bhr", q_nope, p["w_uk"].astype(dt))
    c_new = L.rms_norm(jnp.einsum("bd,dr->br", x, p["w_dkv"].astype(dt)), p["kv_norm"])
    kr_new = L.apply_rope(jnp.einsum("bd,dk->bk", x, p["w_kr"].astype(dt))[:, None, None, :],
                          positions[:, None], cfg.rope_theta)[:, 0, 0]
    scale = 1.0 / math.sqrt(nope + ropeD)
    dp = plan.dp_axes if plan.dp_axes else None
    in_specs = (P(dp, None, None), P(dp, None, None),
                P(dp, "model", None), P(dp, "model", None),
                P(dp, None), P(dp, None), P(dp))
    out_specs = (P(dp, None, None), P(dp, "model", None), P(dp, "model", None))
    o, ckv_c, kr_c = shard_map_or_call(
        plan, lambda ax, *a: _mla_decode_core(ax, *a, scale=scale),
        in_specs, out_specs, qc, q_rope, cache["ckv"], cache["kr"],
        c_new, kr_new, positions)
    # un-absorb: latent output -> per-head v -> output projection
    ov = jnp.einsum("bhr,rhv->bhv", o, p["w_uv"].astype(dt))
    out = jnp.einsum("bhv,hvd->bd", ov, p["w_o"].astype(dt))
    return plan.constrain(out, ("batch", "embed_act"), cfg), {"ckv": ckv_c, "kr": kr_c}


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------

def init_paged_attn_cache(cfg: ArchConfig, plan: ShardPlan, n_blocks: int,
                          block_size: int, dtype=jnp.bfloat16):
    """Per-layer paged KV pool (GQA families only): one global block pool
    shared by every sequence, indexed through per-request block tables."""
    if cfg.rwkv or cfg.family == "hybrid" or cfg.attn_kind != "gqa":
        raise ValueError(f"{cfg.name}: paged KV cache requires plain GQA "
                         f"attention (got attn_kind={cfg.attn_kind!r})")
    c = {
        "k": jnp.zeros((n_blocks, block_size, cfg.n_kv_heads, cfg.head_dim),
                       dtype),
        "v": jnp.zeros((n_blocks, block_size, cfg.n_kv_heads, cfg.head_dim),
                       dtype),
    }
    if plan.paged_pool_sharded(cfg) and n_blocks % plan.tp:
        raise ValueError(f"paged pool of {n_blocks} blocks does not divide "
                         f"the {plan.tp}-way model axis; round n_blocks up")
    ax = {"k": ("kv_blocks", None, "kv_cache_heads", None),
          "v": ("kv_blocks", None, "kv_cache_heads", None)}
    return c, ax


def init_attn_cache(cfg: ArchConfig, plan: ShardPlan, batch: int, seq_len: int,
                    dtype=jnp.bfloat16):
    """Per-layer (unstacked) cache arrays + logical axes."""
    if cfg.attn_kind == "mla":
        c = {
            "ckv": jnp.zeros((batch, seq_len, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, seq_len, cfg.qk_rope_head_dim), dtype),
        }
        ax = {"ckv": ("batch", "cache_seq", None), "kr": ("batch", "cache_seq", None)}
        return c, ax
    if cfg.attn_kind == "swa" and cfg.window:
        w = min(cfg.window, seq_len)
        c = {
            "k": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.head_dim), dtype),
        }
        ax = {"k": ("batch", "window", "kv_cache_heads", None),
              "v": ("batch", "window", "kv_cache_heads", None)}
        return c, ax
    c = {
        "k": jnp.zeros((batch, seq_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, seq_len, cfg.n_kv_heads, cfg.head_dim), dtype),
    }
    ax = {"k": ("batch", "cache_seq", "kv_cache_heads", None),
          "v": ("batch", "cache_seq", "kv_cache_heads", None)}
    return c, ax
