"""Core layer primitives shared by every architecture.

All functions are pure; parameters are plain dict pytrees.  A parallel
"logical axes" pytree (see sharding.py) names every parameter dimension so
the launcher can map logical axes -> mesh axes.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, scale: float = 1.0, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[in_axis]
    std = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    """RMSNorm; ``plus_one`` uses the Gemma convention w <- (1 + w)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:
        w = 1.0 + w
    return (x * w).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, *,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def glu_mlp(x: jax.Array, p: Params, *, activation: str = "silu") -> jax.Array:
    """Gated MLP: act(x Wg) * (x Wu) Wd.  Gemma uses gelu (GeGLU)."""
    dtype = x.dtype
    gate = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(dtype))
    up = jnp.einsum("...d,df->...f", x, p["w_up"].astype(dtype))
    if activation == "silu":
        act = jax.nn.silu(gate.astype(jnp.float32)).astype(dtype)
    elif activation == "gelu":
        act = jax.nn.gelu(gate.astype(jnp.float32), approximate=True).astype(dtype)
    else:  # pragma: no cover - config error
        raise ValueError(activation)
    return jnp.einsum("...f,fd->...d", act * up, p["w_down"].astype(dtype))


def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype=dtype),
    }


def gelu_mlp(x: jax.Array, p: Params) -> jax.Array:
    """Plain 2-layer GELU MLP (HuBERT / classic transformer encoders)."""
    dtype = x.dtype
    h = jnp.einsum("...d,df->...f", x, p["w_in"].astype(dtype)) + p["b_in"].astype(dtype)
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(dtype)
    return jnp.einsum("...f,fd->...d", h, p["w_out"].astype(dtype)) + p["b_out"].astype(dtype)


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "w_in": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(k2, (d_ff, d_model), dtype=dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def take_embedding(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Embedding lookup; XLA SPMD handles a vocab-sharded gather."""
    return jnp.take(table, ids, axis=0)
