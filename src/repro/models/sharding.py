"""Logical-axis sharding: map parameter/activation dimensions to mesh axes.

Every parameter pytree has a parallel "axes" pytree of tuples naming each
dimension (e.g. ``("embed", "heads", "qk_dim")``).  ``ShardPlan`` holds the
mesh + rules; ``spec_for``/``tree_shardings`` turn axes into NamedShardings.

TP dims ("heads", "ffn", "vocab", "experts", "d_inner", "kv_heads" where
divisible) shard over the ``model`` axis; training additionally FSDP-shards
"embed" over ``data`` (ZeRO-3 via GSPMD).  Head/expert/vocab counts that do
not divide the TP degree are zero-padded (see DESIGN.md §3).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import pad_to_multiple

if hasattr(jax, "shard_map"):                       # jax >= 0.5
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
else:                                               # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}


@dataclass(frozen=True)
class ShardPlan:
    mesh: Any = None                     # jax.sharding.Mesh or None (single host)
    tp_axis: str | None = None           # "model"
    dp_axes: tuple = ()                  # ("data",) or ("pod", "data")
    fsdp: bool = False                   # shard "embed" over data (training)
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: str = "none"                  # none | full
    attn_temp_budget: int = 512 * 2**20  # bytes budget for score temporaries
    # --- hillclimb knobs (see EXPERIMENTS.md §Perf) ---
    seq_shard_activations: bool = False  # Megatron-style sequence parallelism
    quantize_serve: bool = False         # int8 weights for serve (w8a8 knob)
    kv_pad_enabled: bool = True          # pad kv heads to TP (kills replicated
    #                                      kv-proj compute; off for decode to
    #                                      keep the KV cache at real head count)
    attn_exact_causal: bool = False      # pair-scan: skip above-diagonal tiles
    #                                      (exact causal FLOPs + reads)
    attn_cq: int = 512                   # attention tile size (q and k)
    shard_paged_pool: bool = True        # shard the paged KV block pool over
    #                                      the model axis (LSE-combined decode)

    @property
    def tp(self) -> int:
        if self.mesh is None or self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]

    @property
    def dp(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    # ---------------- padded dims ----------------
    def h_pad(self, cfg: ArchConfig) -> int:
        return pad_to_multiple(cfg.n_heads, self.tp)

    def kv_padded(self, cfg: ArchConfig) -> bool:
        """Pad kv heads to TP degree (shard-aligned copies)?  Legal when the
        GQA group structure aligns with head sharding (see DESIGN.md §3)."""
        k, h, tp = cfg.n_kv_heads, cfg.n_heads, self.tp
        return (self.kv_pad_enabled and cfg.attn_kind in ("gqa", "swa")
                and 0 < k < tp and tp % k == 0 and h % tp == 0)

    def k_pad(self, cfg: ArchConfig) -> int:
        """Effective kv-head count after padding."""
        return self.tp if self.kv_padded(cfg) else cfg.n_kv_heads

    def kv_sharded(self, cfg: ArchConfig) -> bool:
        return cfg.n_kv_heads > 0 and (cfg.n_kv_heads % self.tp == 0
                                       or self.kv_padded(cfg))

    def paged_pool_sharded(self, cfg: ArchConfig | None = None) -> bool:
        """Shard the paged block pool's ``n_blocks`` axis over ``model``?

        The pool shards by *blocks* (rank r owns a contiguous stripe of
        physical block ids), not by heads, so it holds for any kv-head
        count — the per-shard attention masks unowned blocks and an LSE
        max/sum combine merges the partials (see
        ``attention._paged_decode_core``)."""
        return (self.shard_paged_pool and self.mesh is not None
                and self.tp_axis is not None and self.tp > 1)

    def e_pad(self, cfg: ArchConfig) -> int:
        return pad_to_multiple(cfg.n_experts, self.tp) if cfg.n_experts else 0

    def v_pad(self, cfg: ArchConfig) -> int:
        return pad_to_multiple(cfg.vocab_size, self.tp)

    # ---------------- logical -> mesh rules ----------------
    def rules(self, cfg: ArchConfig) -> dict:
        tp = self.tp_axis
        return {
            "batch": self.dp_axes if self.dp_axes else None,
            "seq": None,
            "embed": self.dp_axes[-1] if (self.fsdp and self.dp_axes) else None,
            "embed_act": None,           # activation d_model dim: never sharded
            "vocab": tp,
            "ffn": tp,
            "heads": tp,
            "kv_heads": tp if self.kv_sharded(cfg) else None,
            # decode caches shard along cache_seq; their head dim stays whole
            "kv_cache_heads": None,
            # paged pools shard along physical block ids (stripe per rank)
            "kv_blocks": tp if self.paged_pool_sharded() else None,
            "experts": tp,
            "d_inner": tp,
            "cache_seq": tp,             # decode KV cache sharded along sequence
            "window": None,
            "qk_dim": None,
            "v_dim": None,
            "lora": None,
            "state": None,
            "conv": None,
            None: None,
        }

    def spec_for(self, axes: tuple, cfg: ArchConfig) -> P:
        r = self.rules(cfg)
        return P(*(r.get(a) for a in axes))

    def sharding_for(self, axes: tuple, cfg: ArchConfig):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec_for(axes, cfg))

    def tree_shardings(self, axes_tree, cfg: ArchConfig):
        """Map an axes pytree (tuples at leaves) to NamedShardings."""
        return jax.tree.map(lambda ax: self.sharding_for(ax, cfg), axes_tree,
                            is_leaf=lambda x: isinstance(x, tuple))

    def constrain(self, x: jax.Array, axes: tuple, cfg: ArchConfig) -> jax.Array:
        """Activation sharding constraint; no-op without a mesh."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec_for(axes, cfg)))


def local_plan(**kw) -> ShardPlan:
    """Single-device plan (smoke tests, examples)."""
    return ShardPlan(mesh=None, tp_axis=None, dp_axes=(), **kw)


def mesh_plan(mesh: Mesh, *, fsdp: bool = False, **kw) -> ShardPlan:
    axes = mesh.axis_names
    dp_axes = tuple(a for a in axes if a in ("pod", "data"))
    tp_axis = "model" if "model" in axes else None
    return ShardPlan(mesh=mesh, tp_axis=tp_axis, dp_axes=dp_axes, fsdp=fsdp, **kw)


def shard_map_or_call(plan: ShardPlan, fn, in_specs, out_specs, *args):
    """Run ``fn`` under shard_map when a mesh is present, else directly.

    ``fn`` receives ``axis`` (the TP axis name or None) as first argument so
    collectives become no-ops on a single device.
    """
    if plan.mesh is None or plan.tp_axis is None:
        return fn(None, *args)
    mapped = _shard_map(
        partial(fn, plan.tp_axis), mesh=plan.mesh,
        in_specs=in_specs, out_specs=out_specs, **_SHARD_MAP_KW)
    return mapped(*args)
