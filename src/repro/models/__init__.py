from repro.models.sharding import ShardPlan, local_plan, mesh_plan
from repro.models.transformer import Model, build_model

__all__ = ["Model", "build_model", "ShardPlan", "local_plan", "mesh_plan"]
