"""Mixture-of-Experts with expert parallelism over the ``model`` mesh axis.

Dispatch is sort-based (no O(T*E) one-hot) with a fixed per-expert capacity;
the whole block (router -> dispatch -> expert GEMMs -> combine) runs inside
``shard_map``: tokens are local to each data shard, experts are sharded over
``model``, and the only collective is one psum of the (T_loc, d) output per
MoE layer — identical in shape to the Megatron row-parallel all-reduce.

Expert counts not divisible by the TP degree are padded (granite 40 -> 48)
with pad experts masked to -inf in the router, so they are never selected.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.sharding import ShardPlan, shard_map_or_call

Params = dict[str, Any]
NEG_INF = -1e30


def _quantize_experts(w: jax.Array):
    """Symmetric per-(expert, out-channel) int8 quantization."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    w_q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127)
    return w_q.astype(jnp.int8), scale.astype(jnp.float32)


def init_moe(key, cfg: ArchConfig, plan: ShardPlan) -> Params:
    d, f = cfg.d_model, cfg.expert_d_ff
    e_pad = plan.e_pad(cfg)
    dt = plan.param_dtype
    ks = jax.random.split(key, 4)

    def pad_e(w):
        return jnp.pad(w, ((0, e_pad - cfg.n_experts),) + ((0, 0),) * (w.ndim - 1))

    p = {
        "router": L.dense_init(ks[0], (d, cfg.n_experts), dtype=jnp.float32),
        "w_gate": pad_e(L.dense_init(ks[1], (cfg.n_experts, d, f), in_axis=1, dtype=dt)),
        "w_up": pad_e(L.dense_init(ks[2], (cfg.n_experts, d, f), in_axis=1, dtype=dt)),
        "w_down": pad_e(L.dense_init(ks[3], (cfg.n_experts, f, d), in_axis=1, dtype=dt)),
    }
    if plan.quantize_serve:
        # TAPAS quantization knob: expert weights stored int8 + scales
        # (the serve-time memory-bound lever; see kernels/int8_matmul.py)
        for name in ("w_gate", "w_up", "w_down"):
            w_q, s = _quantize_experts(p.pop(name))
            p[name + "_q"] = w_q
            p[name + "_s"] = s
    return p


def moe_axes(cfg: ArchConfig, plan: ShardPlan) -> Params:
    # experts shard over `model` (EP); per-expert ffn dim stays whole — a
    # second `model` entry would collide with the expert sharding
    ax = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", None),
        "w_up": ("experts", "embed", None),
        "w_down": ("experts", None, "embed"),
    }
    if plan.quantize_serve:
        for name in ("w_gate", "w_up", "w_down"):
            base = ax.pop(name)
            ax[name + "_q"] = base
            ax[name + "_s"] = ("experts", None, None)
    return ax


def _capacity(t_loc: int, cfg: ArchConfig) -> int:
    full = t_loc * cfg.top_k
    if full <= 4096:
        return full  # decode / tiny batches: zero drops
    return -(-int(full * cfg.capacity_factor) // cfg.n_experts)


def _moe_core(axis, x, router_w, w_gate, w_up, w_down, *, cfg: ArchConfig,
              e_pad: int, capacity: int, activation: str):
    """Local MoE on one (data, model) shard.

    x: (T_loc, d) tokens (replicated over model within the data shard);
    w_*: (E_loc, ...) this device's experts. Returns (y (T_loc, d), aux loss).
    """
    T, d = x.shape
    k = cfg.top_k
    E = cfg.n_experts
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E) real experts only
    topv, topi = jax.lax.top_k(probs, k)
    if cfg.router_renorm:
        topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    # aux load-balancing loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,)).at[topi.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * jax.lax.stop_gradient(ce))

    # ---- sort-based dispatch (index math on (T*k,) vectors) ----
    flat_e = topi.reshape(-1)  # (T*k,)
    flat_g = topv.reshape(-1)
    src_tok = jnp.arange(T * k) // k
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e_pad))
    pos_in_e = jnp.arange(T * k) - starts[sorted_e]
    keep = pos_in_e < capacity
    slot = jnp.where(keep, sorted_e * capacity + pos_in_e, e_pad * capacity)
    buf_src = jnp.full((e_pad * capacity + 1,), T, jnp.int32).at[slot].set(
        jnp.where(keep, src_tok[order], T))[:-1]
    buf_gate = jnp.zeros((e_pad * capacity + 1,)).at[slot].set(
        jnp.where(keep, flat_g[order], 0.0))[:-1]

    # ---- local expert slice ----
    e_loc = w_gate.shape[0]
    if axis is not None:
        shard = jax.lax.axis_index(axis)
        lo = shard * e_loc * capacity
        buf_src = jax.lax.dynamic_slice_in_dim(buf_src, lo, e_loc * capacity)
        buf_gate = jax.lax.dynamic_slice_in_dim(buf_gate, lo, e_loc * capacity)

    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xg = x_pad[buf_src].reshape(e_loc, capacity, d)
    gate = jnp.einsum("ecd,edf->ecf", xg, w_gate)
    up = jnp.einsum("ecd,edf->ecf", xg, w_up)
    if activation == "gelu":
        act = jax.nn.gelu(gate.astype(jnp.float32), approximate=True).astype(xg.dtype)
    else:
        act = jax.nn.silu(gate.astype(jnp.float32)).astype(xg.dtype)
    out = jnp.einsum("ecf,efd->ecd", act * up, w_down)
    out = out * buf_gate.reshape(e_loc, capacity, 1).astype(out.dtype)
    y = jnp.zeros((T + 1, d), out.dtype).at[buf_src].add(
        out.reshape(e_loc * capacity, d))[:T]
    if axis is not None:
        y = jax.lax.psum(y, axis)
        aux = jax.lax.pmean(aux, axis)
    return y, aux


def moe_ffn(p: Params, x: jax.Array, cfg: ArchConfig, plan: ShardPlan):
    """x: (B, S, d) -> (y (B, S, d), aux). Runs in shard_map over (dp, model)."""
    dt = plan.compute_dtype
    B, S, d = x.shape
    t_loc = (B // max(plan.dp, 1)) * S
    e_pad = plan.e_pad(cfg)
    cap = _capacity(t_loc, cfg)
    dp = plan.dp_axes if plan.dp_axes else None
    quant = plan.quantize_serve and "w_gate_q" in p

    if quant:
        weights = (p["w_gate_q"], p["w_gate_s"], p["w_up_q"], p["w_up_s"],
                   p["w_down_q"], p["w_down_s"])
        w_specs = (P("model", None, None), P("model", None, None)) * 3
    else:
        weights = (p["w_gate"].astype(dt), p["w_up"].astype(dt),
                   p["w_down"].astype(dt))
        w_specs = (P("model", None, None),) * 3

    def core(axis, xf, rw, *ws):
        if quant:
            # dequantize the local expert slice int8 -> compute dtype; HBM
            # reads are the int8 arrays (half of bf16)
            wg = (ws[0].astype(jnp.float32) * ws[1]).astype(dt)
            wu = (ws[2].astype(jnp.float32) * ws[3]).astype(dt)
            wd = (ws[4].astype(jnp.float32) * ws[5]).astype(dt)
        else:
            wg, wu, wd = ws
        y, aux = _moe_core(axis, xf, rw, wg, wu, wd, cfg=cfg, e_pad=e_pad,
                           capacity=cap, activation=cfg.activation)
        if axis is not None and dp is not None:
            aux = jax.lax.pmean(aux, dp)
        return y, aux

    xf = x.reshape(B * S, d).astype(dt)
    in_specs = (P(dp, None), P(None, None)) + w_specs
    out_specs = (P(dp, None), P())
    y, aux = shard_map_or_call(
        plan, core, in_specs, out_specs, xf, p["router"], *weights)
    y = y.reshape(B, S, d)
    return plan.constrain(y, ("batch", "seq", "embed_act"), cfg), aux
