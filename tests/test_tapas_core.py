"""TAPAS control-plane behaviour: thermal/power models (Eqs. 1-4),
allocator rules, router filtering, configurator, failures, oversubscription."""
import numpy as np
import pytest

from repro.core import profiles as P
from repro.core.allocator import AllocatorState, BaselineAllocator, TapasAllocator
from repro.core.configurator import InstanceConfigurator
from repro.core.datacenter import Datacenter, DCConfig, scale_datacenter
from repro.core.power import PowerModel, capping_factors
from repro.core.router import BaselineRouter, TapasRouter
from repro.core.simulator import (BASELINE, TAPAS, ClusterSim, FailureEvent,
                                  SimConfig)
from repro.core.thermal import ThermalModel
from repro.core.traces import VMSpec, generate_workload, iaas_util


@pytest.fixture(scope="module")
def dc():
    return Datacenter(DCConfig(n_rows=4, racks_per_row=5, servers_per_rack=4))


@pytest.fixture(scope="module")
def thermal(dc):
    return ThermalModel.calibrate(dc)


@pytest.fixture(scope="module")
def power(dc):
    return PowerModel.calibrate(dc)


# ---------------- Eq. 1-3 ----------------

def test_inlet_floor_below_15C(thermal):
    """Cooling holds the 18 °C floor when it's cold outside (humidity)."""
    cold = np.asarray(thermal.inlet_temp(5.0, 0.5))
    colder = np.asarray(thermal.inlet_temp(-10.0, 0.5))
    np.testing.assert_allclose(cold, colder)
    assert (cold >= 18.0).all()


def test_inlet_monotone_in_outside_and_load(thermal):
    t1 = np.asarray(thermal.inlet_temp(18.0, 0.2))
    t2 = np.asarray(thermal.inlet_temp(24.0, 0.2))
    t3 = np.asarray(thermal.inlet_temp(24.0, 0.9))
    assert (t2 > t1).all()
    assert (t3 > t2).all()


def test_inlet_compressed_above_25C(thermal):
    """Mechanical assist kicks in: slope above 25 °C < slope in 15-25 °C."""
    s_mid = np.asarray(thermal.inlet_temp(24.0, 0.5)) - \
        np.asarray(thermal.inlet_temp(23.0, 0.5))
    s_hot = np.asarray(thermal.inlet_temp(33.0, 0.5)) - \
        np.asarray(thermal.inlet_temp(32.0, 0.5))
    assert (s_hot < s_mid + 1e-6).all()


def test_gpu_temp_heterogeneity(dc, thermal):
    """Per-server spread up to ~10 °C; even chips cooler (Fig. 8/9)."""
    inlet = np.asarray(thermal.inlet_temp(30.0, 0.7))
    t = np.asarray(thermal.gpu_temp(inlet, np.ones((dc.n_servers, 8))))
    spread = t.max(axis=1) - t.min(axis=1)
    assert spread.max() > 8.0
    even = t[:, ::2].mean()
    odd = t[:, 1::2].mean()
    assert even < odd


def test_gpu_temp_inversion(dc, thermal):
    inlet = np.asarray(thermal.inlet_temp(28.0, 0.5))
    u = np.asarray(thermal.max_util_for_temp(inlet, 85.0))
    t = np.asarray(thermal.gpu_temp(inlet, np.repeat(u[:, None], 8, 1)))
    assert (t.max(axis=1) <= 85.0 + 1e-3).all()


def test_airflow_linear_bounds(thermal):
    a0 = float(np.asarray(thermal.airflow(np.asarray([0.0])))[0])
    a1 = float(np.asarray(thermal.airflow(np.asarray([1.0])))[0])
    assert a0 == pytest.approx(thermal.airflow_idle_cfm)
    assert a1 == pytest.approx(thermal.airflow_max_cfm)


# ---------------- Eq. 4 ----------------

def test_power_idle_and_peak(dc, power):
    p0 = np.asarray(power.server_power(np.zeros((dc.n_servers, 8))))
    p1 = np.asarray(power.server_power(np.ones((dc.n_servers, 8))))
    assert (p0 >= 0.9 * dc.cfg.hw.idle_power_w).all()
    assert (p1 <= 1.1 * dc.cfg.hw.peak_power_w).all()
    assert (p1 > p0).all()


def test_power_inversion(dc, power):
    budget = 0.7 * dc.cfg.hw.peak_power_w
    u = np.asarray(power.max_util_for_power(budget))
    p = np.asarray(power.server_power(np.repeat(u[:, None], 8, 1)))
    assert (p <= budget * 1.02).all()


def test_capping_brings_rows_under_limit(dc, power):
    util = np.full((dc.n_servers, 8), 0.95)
    p = np.asarray(power.server_power(util))
    limits = dc.row_sum(p) * 0.8  # force 25% overshoot
    f = np.asarray(capping_factors(dc, p, limits, power))
    assert (f < 1.0).any()
    p2 = np.asarray(power.server_power(util * f[:, None]))
    assert (dc.row_sum(p2) <= limits * 1.1).all()


# ---------------- allocator ----------------

def test_allocator_prefers_cool_for_iaas(dc, thermal, power):
    st = AllocatorState.empty(dc, thermal, power)
    alloc = TapasAllocator(seed=0)
    groups = alloc._temp_groups(st)
    vm = VMSpec(0, "iaas", "custA", 0.0, 100.0, 1.0)
    srv = alloc.place(st, vm)
    assert groups[srv] == 0  # coldest third


def test_allocator_saas_safe_servers_only(dc, thermal, power):
    st = AllocatorState.empty(dc, thermal, power)
    alloc = TapasAllocator(seed=0)
    vm = VMSpec(1, "saas", "ep0", 0.0, 100.0, 1.0)
    srv = alloc.place(st, vm)
    t_pred = alloc._peak_temp(st, 0.95)
    if (t_pred <= thermal.gpu_limit - 1.0).any():
        assert t_pred[srv] <= thermal.gpu_limit - 1.0 + 1e-6


def test_allocator_fills_cluster(dc, thermal, power):
    st = AllocatorState.empty(dc, thermal, power)
    alloc = BaselineAllocator(seed=0)
    placed = 0
    for i in range(dc.n_servers + 5):
        vm = VMSpec(i, "iaas", "c", 0.0, 1.0, 0.5)
        if alloc.place(st, vm) is not None:
            placed += 1
    assert placed == dc.n_servers  # never double-books


# ---------------- router ----------------

def test_router_conservation():
    r = TapasRouter()
    cap = np.asarray([1.0, 1.0, 1.0, 1.0])
    risk = np.asarray([0.0, 0.2, 0.9, 0.1])
    d = r.route(2.5, cap, risk)
    assert d.load.sum() + d.unserved == pytest.approx(2.5)
    assert (d.load <= cap + 1e-9).all()
    assert (d.load >= 0).all()


def test_router_avoids_risky_when_possible():
    r = TapasRouter()
    cap = np.asarray([1.0, 1.0, 1.0, 1.0])
    risk = np.asarray([0.9, 0.0, 0.0, 0.0])
    d = r.route(2.0, cap, risk)
    assert d.load[0] == pytest.approx(0.0)  # headroom elsewhere sufficed
    assert d.unserved == pytest.approx(0.0)


def test_router_spills_to_risky_before_dropping():
    r = TapasRouter()
    cap = np.asarray([1.0, 1.0])
    risk = np.asarray([0.9, 0.9])
    d = r.route(1.5, cap, risk)
    assert d.load.sum() == pytest.approx(1.5)  # perf beats risk if queueing


def test_baseline_router_uniform():
    r = BaselineRouter()
    d = r.route(2.0, np.ones(4), np.zeros(4))
    np.testing.assert_allclose(d.load, 0.5)


# ---------------- configurator ----------------

def test_configurator_respects_caps():
    c = InstanceConfigurator()
    st = c.decide(0, power_cap=0.7, temp_cap=0.7)
    assert st.entry.power_frac <= 0.7 + 1e-9
    assert st.entry.temp_frac <= 0.7 + 1e-9
    assert st.entry.quality >= 1.0 - 1e-9  # no quality loss outside emergency


def test_configurator_reload_is_last_resort():
    c = InstanceConfigurator()
    st0 = c.decide(0, power_cap=1.0, temp_cap=1.35)
    st = c.decide(0, power_cap=0.85, temp_cap=1.0)
    # a frequency/batch tweak (no reload) must be preferred when feasible
    assert not st.current.needs_reload_from(st0.current)


def test_configurator_emergency_trades_quality():
    c = InstanceConfigurator()
    # tight caps AND real load to sustain: no-reload 70b configs can't hold
    # the goodput, so the emergency engages a smaller/quantized variant
    st = c.decide(1, power_cap=0.35, temp_cap=0.6, emergency=True,
                  min_goodput=1.2)
    assert st.entry.power_frac <= 0.35 + 1e-9
    assert st.entry.quality < 1.0  # smaller/quantized model engaged
    assert st.entry.goodput >= 1.0  # throughput held (paper Table 2)


def test_pareto_frontier_is_subset_and_nondominated():
    entries = P.build_profile()
    front = P.pareto_frontier(entries)
    assert 0 < len(front) <= len(entries)
    for e in front:
        for o in entries:
            dominates = (o.goodput >= e.goodput and o.power_frac <= e.power_frac
                         and o.temp_frac <= e.temp_frac and o.quality >= e.quality
                         and (o.goodput, o.power_frac, o.temp_frac, o.quality)
                         != (e.goodput, e.power_frac, e.temp_frac, e.quality))
            assert not dominates


# ---------------- end-to-end policies ----------------

@pytest.fixture(scope="module")
def sim_pair():
    # stressed operating point (peak hours covered): TAPAS's advantage only
    # exists under pressure — when idle it deliberately uses warm headroom
    dc_cfg = DCConfig(n_rows=4, racks_per_row=5, servers_per_rack=4)
    kw = dict(dc=dc_cfg, horizon_h=18.0, tick_min=10.0, seed=2,
              occupancy=0.95, demand_scale=0.98)
    base = ClusterSim(SimConfig(policy=BASELINE, **kw)).run()
    tap = ClusterSim(SimConfig(policy=TAPAS, **kw)).run()
    return base, tap


def test_tapas_reduces_peaks(sim_pair):
    base, tap = sim_pair
    # direction must hold under stress; calibrated magnitudes are validated
    # in benchmarks/ (Fig. 19/20)
    assert tap.thermal_events <= base.thermal_events
    if base.thermal_events > 0:
        assert tap.max_gpu_temp_c.max() <= base.max_gpu_temp_c.max() + 0.5


def test_tapas_preserves_service(sim_pair):
    base, tap = sim_pair
    assert tap.unserved_frac <= max(0.05, base.unserved_frac + 0.02)
    assert tap.mean_quality >= 0.97  # no quality loss under normal operation


def test_ups_failure_drill_caps_capacity():
    dc_cfg = DCConfig(n_rows=4, racks_per_row=5, servers_per_rack=4)
    ev = FailureEvent(kind="ups", start_h=6.0, end_h=8.0)
    base = ClusterSim(SimConfig(dc=dc_cfg, horizon_h=10.0, tick_min=10.0,
                                seed=3, policy=BASELINE,
                                failures=(ev,))).run()
    clean = ClusterSim(SimConfig(dc=dc_cfg, horizon_h=10.0, tick_min=10.0,
                                 seed=3, policy=BASELINE)).run()
    # baseline must cap more during a UPS failure than without one
    assert base.power_events >= clean.power_events
    assert base.iaas_perf_impact >= clean.iaas_perf_impact


def test_oversubscription_scaling():
    cfg = DCConfig(n_rows=4, racks_per_row=5, servers_per_rack=4)
    scaled = scale_datacenter(cfg, 0.4)
    assert scaled.n_servers > cfg.n_servers
    dc0, dc1 = Datacenter(cfg), Datacenter(scaled)
    # provisioned envelopes unchanged by oversubscription
    np.testing.assert_allclose(dc1.prov_row_power_w, dc0.prov_row_power_w,
                               rtol=1e-6)


def test_traces_deterministic():
    w1 = generate_workload(n_servers=40, horizon_h=24.0, seed=5)
    w2 = generate_workload(n_servers=40, horizon_h=24.0, seed=5)
    assert [v.vm_id for v in w1.vms] == [v.vm_id for v in w2.vms]
    v = w1.vms[-1]
    t = np.arange(0, 24.0, 0.5)
    np.testing.assert_allclose(iaas_util(v, t, seed=5), iaas_util(v, t, seed=5))
