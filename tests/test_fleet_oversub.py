"""Fleet oversubscription planning + carbon/price-aware steering:
``max_safe_oversubscription`` edge cases, carbon/cost trace helpers,
``PriceShock`` scenario plumbing, the router's cost-chasing path, fleet
energy accounting, and ``FleetOversubPlanner`` determinism."""
import numpy as np
import pytest

from repro.core.datacenter import DCConfig
from repro.core.fleet import (FleetConfig, FleetSim, FleetState,
                              GlobalTapasRouter, RegionSpec,
                              cost_aware_knobs)
from repro.core.oversubscribe import (FleetOversubPlanner,
                                      max_safe_oversubscription)
from repro.core.risk import energy_cost_index, thermally_comparable
from repro.core.scenario import FailureEvent, PriceShock, Scenario
from repro.core.simulator import TAPAS, ClusterSim, SimConfig
from repro.core.traces import carbon_intensity


def _row(ratio, policy="p", thermal_pct=0.0, power_pct=0.0):
    return {"oversub": ratio, "policy": policy,
            "thermal_capped_pct": thermal_pct, "power_capped_pct": power_pct,
            "unserved_pct": 0.0}


# ---------------------------------------------------------------------------
# max_safe_oversubscription edge cases
# ---------------------------------------------------------------------------

def test_max_safe_empty_sweep():
    assert max_safe_oversubscription([], "p") == 0.0
    # rows exist but none for the requested policy
    assert max_safe_oversubscription([_row(0.2, "other")], "p") == 0.0


def test_max_safe_single_point():
    assert max_safe_oversubscription([_row(0.3)], "p") == 0.3
    assert max_safe_oversubscription([_row(0.3, thermal_pct=5.0)], "p") == 0.0


def test_max_safe_all_points_unsafe():
    rows = [_row(r, power_pct=10.0) for r in (0.0, 0.2, 0.4)]
    assert max_safe_oversubscription(rows, "p") == 0.0


def test_max_safe_non_monotone_rows_stay_contiguous():
    """A failing middle point caps the answer even when a higher ratio
    happens to look safe again — the sweep is walked in ratio order, not
    cherry-picked."""
    rows = [_row(0.0), _row(0.2, thermal_pct=5.0), _row(0.4)]
    assert max_safe_oversubscription(rows, "p") == 0.0
    rows = [_row(0.0), _row(0.1), _row(0.2, thermal_pct=5.0), _row(0.4)]
    assert max_safe_oversubscription(rows, "p") == 0.1
    # row order in the list is irrelevant (sorted internally)
    assert max_safe_oversubscription(rows[::-1], "p") == 0.1


def test_max_safe_budget_boundary_inclusive():
    # capped exactly at the budget is safe (<= semantics)
    rows = [_row(0.0), _row(0.2, thermal_pct=0.7)]
    assert max_safe_oversubscription(rows, "p", cap_budget=0.007) == 0.2


# ---------------------------------------------------------------------------
# carbon trace + cost helpers
# ---------------------------------------------------------------------------

def test_carbon_intensity_deterministic_and_bounded():
    t = np.arange(0, 48, 0.25)
    a = carbon_intensity(t, seed=3, namespace="east")
    b = carbon_intensity(t, seed=3, namespace="east")
    assert np.array_equal(a, b)
    assert (a >= 0.3).all() and (a <= 1.8).all()
    assert a.std() > 0.05          # genuinely diurnal, not flat


def test_carbon_intensity_namespaced():
    t = np.arange(0, 24, 0.5)
    east = carbon_intensity(t, seed=3, namespace="east")
    west = carbon_intensity(t, seed=3, namespace="west")
    assert not np.allclose(east, west)
    other_seed = carbon_intensity(t, seed=4, namespace="east")
    assert not np.allclose(east, other_seed)


def test_energy_cost_index_blend():
    assert energy_cost_index(2.0, 0.5, carbon_weight=0.0) == 2.0
    assert energy_cost_index(2.0, 0.5, carbon_weight=1.0) == 0.5
    assert energy_cost_index(2.0, 0.5, carbon_weight=0.5) == 1.25
    with pytest.raises(ValueError, match="carbon_weight"):
        energy_cost_index(1.0, 1.0, carbon_weight=1.5)


def test_thermally_comparable_band():
    assert thermally_comparable(0.2, 0.3, band=0.15, threshold=0.45)
    assert not thermally_comparable(0.2, 0.4, band=0.15, threshold=0.45)
    assert not thermally_comparable(0.5, 0.46, band=0.15, threshold=0.45)
    # a cooler destination is always inside the band
    assert thermally_comparable(0.4, 0.1, band=0.15, threshold=0.45)


# ---------------------------------------------------------------------------
# PriceShock scenario plumbing
# ---------------------------------------------------------------------------

def test_price_shock_validation():
    with pytest.raises(ValueError, match="scale"):
        PriceShock(start_h=0.0, end_h=1.0, scale=0.0)
    with pytest.raises(ValueError, match="inverted"):
        PriceShock(start_h=2.0, end_h=1.0, scale=1.5)
    with pytest.raises(ValueError, match="region"):
        PriceShock(start_h=0.0, end_h=1.0, scale=1.5, region="")


def test_price_scale_accessor_and_region_scoping():
    scen = Scenario((
        PriceShock(start_h=1.0, end_h=3.0, scale=2.0, region="east"),
        PriceShock(start_h=2.0, end_h=4.0, scale=1.5),      # fleet-wide
    ))
    assert scen.price_scale(0.5, "east") == 1.0
    assert scen.price_scale(1.5, "east") == 2.0
    assert scen.price_scale(2.5, "east") == 3.0             # compounds
    assert scen.price_scale(2.5, "west") == 1.5
    assert scen.price_scale(3.5, "east") == 1.5


def test_price_shock_never_reaches_clusters():
    scen = Scenario((
        PriceShock(start_h=0.0, end_h=1.0, scale=2.0, region="east"),
        FailureEvent(kind="cooling", start_h=0.0, end_h=1.0, region="east"),
    ))
    east = scen.for_region("east")
    assert {type(ev).__name__ for ev in east.events} == {"FailureEvent"}
    # and a single-cluster sim rejects one outright
    with pytest.raises(ValueError, match="fleet-level"):
        ClusterSim(SimConfig(
            dc=DCConfig(n_rows=2, racks_per_row=3, servers_per_rack=2),
            scenario=Scenario((PriceShock(start_h=0.0, end_h=1.0,
                                          scale=2.0),))))


# ---------------------------------------------------------------------------
# cost-chasing route path (synthetic FleetState, no simulation)
# ---------------------------------------------------------------------------

def _fleet_state(risk, price, carbon, headroom, *, rtt=10.0, pen=0.002,
                 emergency=()):
    names = sorted(risk)
    return FleetState(
        tick=0, now_h=0.0, regions=dict.fromkeys(names), specs={},
        rtt_ms={(a, b): (0.0 if a == b else rtt)
                for a in names for b in names},
        risk=risk, emergency={n: n in emergency for n in names},
        capacity=dict.fromkeys(names, 10.0), headroom=headroom,
        demand={}, price=price, carbon=carbon, wan_penalty_per_ms=pen)


def test_cost_steering_moves_toward_cheap_clean_region():
    fleet = _fleet_state(
        risk={"coal": 0.15, "hydro": 0.2},
        price={"coal": 1.4, "hydro": 0.6},
        carbon={"coal": 1.4, "hydro": 0.5},
        headroom={"coal": 1.0, "hydro": 6.0})
    router = GlobalTapasRouter(cost_aware_knobs())
    shares = router.route_region(fleet, "ep", {"coal": 4.0, "hydro": 1.0})
    assert shares["coal"]["hydro"] > 0.0
    assert shares["coal"]["coal"] == pytest.approx(
        1.0 - shares["coal"]["hydro"])
    # the cheap region keeps its own demand home
    assert shares["hydro"] == {"hydro": 1.0}
    # default knobs leave cost-chasing off entirely
    default = GlobalTapasRouter()
    assert default.route_region(fleet, "ep", {"coal": 4.0, "hydro": 1.0}) \
        == {"coal": {"coal": 1.0}, "hydro": {"hydro": 1.0}}


def test_cost_steering_respects_thermal_band_and_emergency():
    hot_dest = _fleet_state(
        risk={"coal": 0.1, "hydro": 0.35},      # 0.25 riskier > band 0.15
        price={"coal": 1.4, "hydro": 0.6},
        carbon={"coal": 1.4, "hydro": 0.5},
        headroom={"coal": 1.0, "hydro": 6.0})
    router = GlobalTapasRouter(cost_aware_knobs())
    shares = router.route_region(hot_dest, "ep", {"coal": 4.0, "hydro": 1.0})
    assert shares["coal"] == {"coal": 1.0}
    emergency_dest = _fleet_state(
        risk={"coal": 0.15, "hydro": 0.2},
        price={"coal": 1.4, "hydro": 0.6},
        carbon={"coal": 1.4, "hydro": 0.5},
        headroom={"coal": 1.0, "hydro": 6.0}, emergency=("hydro",))
    shares = GlobalTapasRouter(cost_aware_knobs()).route_region(
        emergency_dest, "ep", {"coal": 4.0, "hydro": 1.0})
    assert shares["coal"] == {"coal": 1.0}


def test_cost_steering_hysteresis_releases_slowly():
    """When the price advantage shrinks into the +-margin dead band, the
    steered share keeps landing on the break-even dest and *ramps* home
    (decaying each tick); a hard reversal snaps home immediately."""
    cheap = dict(price={"coal": 1.4, "hydro": 0.6},
                 carbon={"coal": 1.4, "hydro": 0.5})
    # hydro barely cheaper: inside the dead band (gain ~1% < margin 8%)
    meh = dict(price={"coal": 1.0, "hydro": 0.97},
               carbon={"coal": 1.0, "hydro": 0.97})
    # hydro now far costlier: a hard reversal
    reversed_ = dict(price={"coal": 1.0, "hydro": 1.5},
                     carbon={"coal": 1.0, "hydro": 1.5})
    risk = {"coal": 0.15, "hydro": 0.2}
    head = {"coal": 1.0, "hydro": 6.0}
    demands = {"coal": 4.0, "hydro": 1.0}
    router = GlobalTapasRouter(cost_aware_knobs())
    engaged = router.route_region(
        _fleet_state(risk=risk, headroom=head, **cheap), "ep", demands)
    moved = engaged["coal"]["hydro"]
    assert moved > 0.0
    # advantage gone (dead band): the share still lands, decaying
    for _ in range(3):
        shares = router.route_region(
            _fleet_state(risk=risk, headroom=head, **meh), "ep", demands)
        now = shares["coal"].get("hydro", 0.0)
        assert 0.0 < now < moved        # ramps, never snaps
        moved = now
    for _ in range(30):
        router.route_region(
            _fleet_state(risk=risk, headroom=head, **meh), "ep", demands)
    assert ("ep", "coal") not in router._cost
    # hard reversal: demand returns home at once
    router.route_region(_fleet_state(risk=risk, headroom=head, **cheap),
                        "ep", demands)
    shares = router.route_region(
        _fleet_state(risk=risk, headroom=head, **reversed_), "ep", demands)
    assert shares["coal"] == {"coal": 1.0}


def test_cost_steering_capped_by_destination_headroom():
    fleet = _fleet_state(
        risk={"coal": 0.15, "hydro": 0.2},
        price={"coal": 1.4, "hydro": 0.6},
        carbon={"coal": 1.4, "hydro": 0.5},
        headroom={"coal": 1.0, "hydro": 0.8})
    router = GlobalTapasRouter(cost_aware_knobs(cost_shift_max=0.9))
    shares = router.route_region(fleet, "ep", {"coal": 10.0, "hydro": 1.0})
    moved = shares["coal"]["hydro"] * 10.0
    assert moved <= 0.9 * 0.8 + 1e-9


# ---------------------------------------------------------------------------
# fleet energy accounting + planner (simulation-backed)
# ---------------------------------------------------------------------------

SMALL = DCConfig(n_rows=2, racks_per_row=4, servers_per_rack=1)


def _tiny_cfg(scenario=None, price=2.0, **kw):
    return FleetConfig(
        regions=(RegionSpec("solo", dc=SMALL, power_price_scale=price),),
        horizon_h=4.0, tick_min=30.0, seed=0, policy=TAPAS,
        scenario=scenario, **kw)


@pytest.mark.slow
def test_fleet_energy_accounting_consistent():
    res = FleetSim(_tiny_cfg()).run()
    assert res.energy_kwh > 0.0
    assert res.energy_kwh == pytest.approx(
        sum(r.energy_kwh for r in res.regions.values()), rel=1e-9)
    # constant price, no shocks: cost is exactly price x energy
    assert res.energy_cost_kwh == pytest.approx(2.0 * res.energy_kwh, rel=1e-9)
    # carbon integrates the bounded intensity trace
    assert 0.3 * res.energy_kwh <= res.carbon_kg <= 1.8 * res.energy_kwh
    assert res.blended_cost(0.0) == pytest.approx(res.energy_cost_kwh)
    assert res.blended_cost(1.0) == pytest.approx(res.carbon_kg)


@pytest.mark.slow
def test_price_shock_raises_cost_not_energy():
    shock = Scenario((PriceShock(start_h=1.0, end_h=3.0, scale=3.0),))
    calm = FleetSim(_tiny_cfg()).run()
    shocked = FleetSim(_tiny_cfg(scenario=shock)).run()
    # prices never touch the physics...
    assert shocked.energy_kwh == pytest.approx(calm.energy_kwh, rel=1e-9)
    # ...but the bill integrates the spike
    assert shocked.energy_cost_kwh > calm.energy_cost_kwh


def test_planner_validates_inputs():
    with pytest.raises(TypeError, match="FleetConfig"):
        FleetOversubPlanner(SimConfig())
    with pytest.raises(ValueError, match="region"):
        FleetOversubPlanner(FleetConfig(regions=()))
    with pytest.raises(ValueError, match="ratio grid"):
        FleetOversubPlanner(_tiny_cfg(), ratios=())
    with pytest.raises(ValueError, match="cap_budget"):
        FleetOversubPlanner(_tiny_cfg(), cap_budget=0.0)


@pytest.mark.slow
def test_planner_grid_without_zero_and_unsafe_floor():
    """A grid that omits 0.0 must not crash when even its first ratio is
    unsafe (the 0.0 floor from max_safe_oversubscription is not a grid
    point): the coordinated plan snaps to the grid floor and reports
    itself unsafe."""
    starved = DCConfig(n_rows=2, racks_per_row=4, servers_per_rack=1,
                       power_provision_frac=0.25)
    cfg = FleetConfig(
        regions=(RegionSpec("solo", dc=starved),),
        horizon_h=4.0, tick_min=30.0, seed=0, policy=TAPAS, occupancy=0.95,
        demand_scale=1.0)
    plan = FleetOversubPlanner(cfg, ratios=(0.25, 0.5)).plan()
    assert plan.isolated["solo"] == 0.0        # the max_safe floor
    assert plan.coordinated["solo"] == 0.25    # snapped onto the grid
    assert not plan.coordinated_safe


@pytest.mark.slow
def test_planner_same_seed_identical_plan():
    def mk():
        regions = (RegionSpec("east", dc=SMALL, wan_rtt_ms=10.0),
                   RegionSpec("west", dc=SMALL, wan_rtt_ms=20.0))
        return FleetConfig(regions=regions, horizon_h=4.0, tick_min=30.0,
                           seed=5, policy=TAPAS, occupancy=0.9)

    plans = [FleetOversubPlanner(mk(), ratios=(0.0, 0.25)).plan()
             for _ in range(2)]
    assert plans[0].summary() == plans[1].summary()
    assert plans[0].rows == plans[1].rows
    assert plans[0].trials == plans[1].trials
    # grid membership: every planned ratio is a grid point
    for plan in plans:
        assert set(plan.isolated.values()) <= {0.0, 0.25}
        assert set(plan.coordinated.values()) <= {0.0, 0.25}
