"""Fault injection + graceful degradation: event validation, terminal-
outcome invariants, NaN-guard quarantine, crash/requeue recovery,
deadline eviction, ladder walk/unwind, stale-telemetry steering, and
replay determinism of a full fault drill.

Fast tests (event/Request/ladder/router plumbing) are numpy/stdlib-only;
engine- and sim-level tests drive live jitted engines and are marked
``slow`` like the other engine-in-the-loop suites."""
import types

import numpy as np
import pytest

from repro.core.datacenter import DCConfig
from repro.core.faults import (ENGINE_FAULT_KINDS, DegradationLadder,
                               EngineFault, ResilienceKnobs, SensorDropout,
                               audit_requests, fault_pick, recovery_off)
from repro.core.fleet import FleetKnobs, FleetState, GlobalTapasRouter
from repro.core.scenario import FailureEvent, Scenario
from repro.core.simulator import TAPAS, ClusterSim, SimConfig
from repro.serving.request import Request


# ---------------------------------------------------------------------------
# events + knobs: construction-time validation, scenario accessors
# ---------------------------------------------------------------------------

def test_engine_fault_validation():
    ok = EngineFault(kind="crash", start_h=1.0, end_h=2.0, server=3)
    assert ok.active(1.0) and ok.active(1.99) and not ok.active(2.0)
    with pytest.raises(ValueError, match="kind"):
        EngineFault(kind="meltdown", start_h=0.0, end_h=1.0)
    with pytest.raises(ValueError, match="window"):
        EngineFault(kind="crash", start_h=2.0, end_h=2.0)
    with pytest.raises(ValueError, match="server"):
        EngineFault(kind="crash", start_h=0.0, end_h=1.0, server=-1)
    with pytest.raises(ValueError, match="slow_factor"):
        EngineFault(kind="stuck_slow", start_h=0.0, end_h=1.0,
                    slow_factor=0.5)
    with pytest.raises(ValueError, match="region"):
        EngineFault(kind="crash", start_h=0.0, end_h=1.0, region="")


def test_sensor_dropout_validation():
    ev = SensorDropout(start_h=0.5, end_h=1.5)
    assert ev.active(0.5) and not ev.active(1.5)
    with pytest.raises(ValueError, match="window"):
        SensorDropout(start_h=1.0, end_h=0.5)


def test_scenario_accessors_and_region_slicing():
    sc = Scenario((
        EngineFault(kind="crash", start_h=1.0, end_h=2.0, region="west"),
        EngineFault(kind="nan_burst", start_h=0.0, end_h=3.0),
        SensorDropout(start_h=1.0, end_h=2.0, region="east"),
    ))
    kinds = sorted(f.kind for f in sc.engine_faults(1.5))
    assert kinds == ["crash", "nan_burst"]
    assert [f.kind for f in sc.engine_faults(2.5)] == ["nan_burst"]
    assert sc.sensor_dropout(1.5) and not sc.sensor_dropout(0.5)
    west = sc.for_region("west")
    assert [f.kind for f in west.engine_faults(1.5)] == ["crash",
                                                         "nan_burst"]
    assert not west.sensor_dropout(1.5)
    assert sc.for_region("east").sensor_dropout(1.5)


def test_resilience_knobs_validation_and_ablation_preset():
    with pytest.raises(ValueError, match="heartbeat_misses"):
        ResilienceKnobs(heartbeat_misses=0)
    with pytest.raises(ValueError, match="stale_risk_bump"):
        ResilienceKnobs(stale_risk_bump=-0.1)
    off = recovery_off()
    assert not (off.watchdog or off.requeue_on_crash or off.nan_guard
                or off.ladder)
    assert off.stale_risk_bump == 0.0


def test_fault_pick_is_deterministic_and_bounded():
    picks = [fault_pick(7, "nan_burst", t, 0) for t in range(50)]
    assert picks == [fault_pick(7, "nan_burst", t, 0) for t in range(50)]
    assert all(0 <= p < 7 for p in picks)
    assert len(set(picks)) > 1          # actually spreads over targets
    with pytest.raises(ValueError):
        fault_pick(0, "x")


def test_fault_kinds_are_closed():
    assert set(ENGINE_FAULT_KINDS) == {"crash", "nan_burst", "kv_corrupt",
                                       "stuck_slow", "draft_fail"}


# ---------------------------------------------------------------------------
# Request: deadline/retry validation, single terminal transition
# ---------------------------------------------------------------------------

def test_request_deadline_and_retry_validation():
    r = Request(prompt=[1, 2], max_new_tokens=2, arrival_s=10.0,
                deadline_ms=500.0)
    assert r.deadline_s == pytest.approx(10.5)
    assert Request(prompt=[1], max_new_tokens=1).deadline_s is None
    with pytest.raises(ValueError, match="deadline_ms"):
        Request(prompt=[1], max_new_tokens=1, deadline_ms=0.0)
    with pytest.raises(ValueError, match="max_retries"):
        Request(prompt=[1], max_new_tokens=1, max_retries=-1)


def test_request_finish_is_single_shot_and_validated():
    r = Request(prompt=[1], max_new_tokens=1)
    with pytest.raises(ValueError, match="outcome"):
        r.finish(1.0, "vanished")
    r.finish(1.0, "accepted")
    assert r.outcome == "accepted" and r.finish_s == 1.0
    with pytest.raises(RuntimeError, match="finished"):
        r.finish(2.0, "timed_out")


def test_audit_requests_counts_and_flags_lost():
    reqs = [Request(prompt=[1], max_new_tokens=4) for _ in range(4)]
    reqs[0].output = [5, 6]
    reqs[0].finish(1.0, "accepted")
    reqs[1].finish(1.0, "timed_out")
    reqs[2].finish(1.0, "rejected")
    audit = audit_requests(reqs)
    assert audit["outcomes"] == {"accepted": 1, "timed_out": 1,
                                 "rejected": 1}
    assert audit["lost"] == [reqs[3].req_id]
    assert audit["accepted_tokens"] == 2 and audit["total"] == 4


# ---------------------------------------------------------------------------
# degradation ladder: walk order, exact-value unwind, cap re-assertion
# ---------------------------------------------------------------------------

class _StubEngine:
    def __init__(self):
        self.draft_name = "ngram"
        self.horizon = 8
        self.knobs = types.SimpleNamespace(max_batch=8, variant="full")
        self.offline = False

    def set_drafter(self, name):
        self.draft_name = name

    def set_variant(self, name):
        self.knobs.variant = name


def _stub_backend():
    return types.SimpleNamespace(engine=_StubEngine())


def test_ladder_walks_down_and_unwinds_exactly():
    bk = _stub_backend()
    ladder = DegradationLadder(quantized_variant="q8", calm_ticks=2)
    assert ladder.rungs() == ["drop_drafter", "shrink_horizon",
                              "quantized_variant", "cap_batch"]
    for _ in range(5):                       # one extra: bottom is sticky
        ladder.tick(bk, emergency=True)
    eng = bk.engine
    assert ladder.level == 4 and ladder.walks == 4
    assert eng.draft_name is None and eng.horizon == 4
    assert eng.knobs.variant == "q8" and eng.knobs.max_batch == 4
    for _ in range(2 * 4):                   # calm_ticks per rung back up
        ladder.tick(bk, emergency=False)
    assert ladder.level == 0
    assert (eng.draft_name, eng.horizon, eng.knobs.variant,
            eng.knobs.max_batch) == ("ngram", 8, "full", 8)


def test_ladder_skips_quantized_rung_when_unconfigured():
    bk = _stub_backend()
    ladder = DegradationLadder(calm_ticks=1)
    for _ in range(4):
        ladder.tick(bk, emergency=True)
    assert ladder.level == 3                 # 3 rungs without a quant model
    assert bk.engine.knobs.variant == "full"
    assert bk.engine.knobs.max_batch == 4


def test_ladder_reasserts_batch_cap_over_reconfigure():
    bk = _stub_backend()
    ladder = DegradationLadder(calm_ticks=2)
    for _ in range(3):
        ladder.tick(bk, emergency=True)      # bottom rung: cap_batch -> 4
    assert bk.engine.knobs.max_batch == 4
    bk.engine.knobs.max_batch = 8            # a reconfigure raises it back
    ladder.tick(bk, emergency=True)
    assert bk.engine.knobs.max_batch == 4    # the rung's cap wins
    for _ in range(2 * 3):
        ladder.tick(bk, emergency=False)
    assert bk.engine.knobs.max_batch == 8    # exact pre-ladder restore


# ---------------------------------------------------------------------------
# stale-telemetry steering: blind regions are never destinations
# ---------------------------------------------------------------------------

def _fleet_state(telemetry_age, *, risk, price=None):
    names = sorted(risk)
    return FleetState(
        tick=0, now_h=0.0,
        regions={n: types.SimpleNamespace(
            kind=np.array([2, 0]),
            risk=np.array([risk[n], risk[n]])) for n in names},
        specs={}, rtt_ms={(a, b): 0.0 if a == b else 10.0
                          for a in names for b in names},
        risk=dict(risk), emergency=dict.fromkeys(names, False),
        capacity=dict.fromkeys(names, 10.0),
        headroom=dict.fromkeys(names, 5.0),
        demand={}, price=price or dict.fromkeys(names, 1.0),
        carbon=dict.fromkeys(names, 1.0),
        telemetry_age=telemetry_age, wan_penalty_per_ms=0.0)


def test_router_never_steers_toward_stale_region():
    risk = {"hot": 0.9, "stale": 0.1, "fresh": 0.1}
    demands = dict.fromkeys(risk, 1.0)
    fresh_run = GlobalTapasRouter().route_region(
        _fleet_state({}, risk=risk), "ep", dict(demands))
    assert "stale" in fresh_run["hot"]       # trusted when telemetry is live
    k = FleetKnobs()
    stale_run = GlobalTapasRouter().route_region(
        _fleet_state({"stale": k.stale_dest_ticks + 1, "fresh": 0},
                     risk=risk), "ep", dict(demands))
    assert "stale" not in stale_run["hot"]
    assert stale_run["hot"]["fresh"] > 0.0   # steering still relieves hot


def test_cost_route_skips_stale_cheap_region():
    from repro.core.fleet import cost_aware_knobs
    risk = {"home": 0.1, "cheap": 0.1}
    price = {"home": 1.0, "cheap": 0.2}
    demands = dict.fromkeys(risk, 1.0)
    live = GlobalTapasRouter(cost_aware_knobs()).route_region(
        _fleet_state({}, risk=risk, price=price), "ep", dict(demands))
    assert live["home"].get("cheap", 0.0) > 0.0
    stale = GlobalTapasRouter(cost_aware_knobs()).route_region(
        _fleet_state({"cheap": 3}, risk=risk, price=price),
        "ep", dict(demands))
    assert stale["home"] == {"home": 1.0}    # cheap-but-blind stays untrusted


def test_rebalance_skips_stale_drain_destination():
    risk = {"down": 0.9, "stale": 0.1, "fresh": 0.1}
    st = _fleet_state({"stale": 3}, risk=risk)
    st.emergency["down"] = True
    migs = GlobalTapasRouter().rebalance(st)
    assert migs and all(m.dst == "fresh" for m in migs)


# ---------------------------------------------------------------------------
# sensor dropout inside ClusterSim: frozen snapshot, staleness-bumped risk
# ---------------------------------------------------------------------------

def test_sensor_dropout_freezes_telemetry_and_bumps_risk():
    dc = DCConfig(n_rows=1, racks_per_row=2, servers_per_rack=4)
    window = SensorDropout(start_h=0.4, end_h=0.8)
    sim = ClusterSim(SimConfig(
        dc=dc, horizon_h=1.2, tick_min=6.0, seed=3, policy=TAPAS,
        occupancy=0.9, demand_scale=1.0,
        scenario=Scenario((window,
                           FailureEvent(kind="cooling", start_h=0.4,
                                        end_h=0.8, target=0)))))
    snaps = []
    while sim.tick < sim.ticks:
        st = sim.step()
        snaps.append((st.now_h, st.telemetry_age_ticks,
                      np.array(st.inlet_est, copy=True),
                      np.array(st.risk, copy=True)))
    stale = [s for s in snaps if window.active(s[0])]
    fresh_before = [s for s in snaps if s[0] < window.start_h]
    after = [s for s in snaps if s[0] >= window.end_h]
    assert stale and fresh_before and after
    assert all(s[1] == 0 for s in fresh_before)
    ages = [s[1] for s in stale]
    assert ages == list(range(1, len(stale) + 1))      # monotone staleness
    lkg = fresh_before[-1]
    for s in stale:                                    # frozen at LKG...
        np.testing.assert_array_equal(s[2], lkg[2])
        assert (s[3] >= lkg[3] - 1e-12).all()          # ...risk only bumped
    bump = ResilienceKnobs().stale_risk_bump
    np.testing.assert_allclose(
        stale[0][3], np.minimum(lkg[3] + bump, 1.0), rtol=0, atol=1e-9)
    assert all(s[1] == 0 for s in after)               # live again


def test_recovery_off_trusts_stale_telemetry_verbatim():
    dc = DCConfig(n_rows=1, racks_per_row=2, servers_per_rack=4)
    sim = ClusterSim(SimConfig(
        dc=dc, horizon_h=0.8, tick_min=6.0, seed=3, policy=TAPAS,
        occupancy=0.9, demand_scale=1.0,
        scenario=Scenario((SensorDropout(start_h=0.3, end_h=0.8),)),
        resilience=recovery_off()))
    risks = []
    while sim.tick < sim.ticks:
        st = sim.step()
        if st.telemetry_age_ticks:
            risks.append(np.array(st.risk, copy=True))
    assert len(risks) >= 2
    np.testing.assert_array_equal(risks[0], risks[-1])  # no bump at all


def test_engine_fault_server_out_of_range_rejected():
    dc = DCConfig(n_rows=1, racks_per_row=1, servers_per_rack=4)
    with pytest.raises(ValueError, match="server"):
        ClusterSim(SimConfig(
            dc=dc, horizon_h=0.5, tick_min=6.0, seed=0, policy=TAPAS,
            occupancy=0.9, demand_scale=1.0,
            scenario=Scenario((EngineFault(kind="crash", start_h=0.0,
                                           end_h=0.2, server=99),))))


# ---------------------------------------------------------------------------
# live-engine hardening (slow: jitted engines, like the hotpath suites)
# ---------------------------------------------------------------------------

def slow(fn):
    """Live jitted engine: sim-lane only, with the runtime tracer guard."""
    return pytest.mark.slow(pytest.mark.leakcheck(fn))


@pytest.fixture(scope="module")
def tiny_model():
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import build_model, local_plan
    cfg = get_config("llama2-7b").smoke_config()
    return build_model(cfg, local_plan(param_dtype=jnp.bfloat16))


@pytest.fixture(scope="module")
def tiny_params(tiny_model):
    import jax
    return tiny_model.init(jax.random.PRNGKey(0))


def _engine(model, params, **kw):
    from repro.serving import Engine, EngineKnobs
    kw.setdefault("max_seq", 64)
    kw.setdefault("n_slots", 3)
    kw.setdefault("block_size", 8)
    kw.setdefault("paged", True)
    kw.setdefault("knobs", EngineKnobs(max_batch=kw["n_slots"]))
    return Engine(model, params, **kw)


def _submit(eng, vocab, *, n_req=4, max_new=6, seed=0, **req_kw):
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n_req):
        plen = int(rng.integers(4, 16))
        req = Request(prompt=[int(t) for t in rng.integers(0, vocab, plen)],
                      max_new_tokens=max_new, **req_kw)
        eng.submit(req)
        reqs.append(req)
    return reqs


def _streams(reqs):
    return [tuple(r.output) for r in sorted(reqs, key=lambda r: r.req_id)]


def _run_dry(eng, *, now=0.0, max_steps=500):
    for _ in range(max_steps):
        if not (eng.queue or eng.active or eng.prefilling or eng._delayed):
            return
        eng.step(now=now)
        now += 1.0
    raise AssertionError("engine did not drain")


@slow
def test_deadline_evicts_queued_and_active(tiny_model, tiny_params):
    eng = _engine(tiny_model, tiny_params)
    vocab = tiny_model.cfg.vocab_size
    # 2 lanes decode; the third request waits queued past its deadline
    reqs = _submit(eng, vocab, n_req=3, max_new=40, seed=1,
                   arrival_s=0.0, deadline_ms=5_000.0)
    eng.knobs.max_batch = 2
    eng.step(now=0.0)                      # two admitted, one queued
    assert len(eng.active) + len(eng.prefilling) >= 1 and len(eng.queue) >= 1
    eng.step(now=10.0)                     # everyone is past 5s now
    assert eng.stats.timed_out == 3
    assert all(r.outcome == "timed_out" for r in reqs)
    assert not (eng.queue or eng.active or eng.prefilling)
    assert audit_requests(reqs)["lost"] == []


@slow
def test_nan_guard_quarantine_recovers_exact_streams(tiny_model, tiny_params):
    vocab = tiny_model.cfg.vocab_size
    base = _engine(tiny_model, tiny_params)
    base_reqs = _submit(base, vocab, n_req=4, max_new=6, seed=2)
    _run_dry(base)
    assert all(r.outcome == "accepted" for r in base_reqs)

    eng = _engine(tiny_model, tiny_params)
    reqs = _submit(eng, vocab, n_req=4, max_new=6, seed=2)
    eng.step(now=0.0)
    victim = sorted(eng.active)[0]
    eng.inject_kv_corruption(victim, last_block=True)    # NaN-logit burst
    _run_dry(eng, now=1.0)
    assert eng.stats.quarantined == 1 and eng.stats.guard_scans == 1
    assert eng.stats.retried == 1
    assert all(r.outcome == "accepted" for r in reqs)
    # recompute-from-context recovery: bit-identical greedy streams
    assert _streams(reqs) == _streams(base_reqs)

    # ablation: the same corruption unguarded poisons the victim's stream
    off = _engine(tiny_model, tiny_params)
    off_reqs = _submit(off, vocab, n_req=4, max_new=6, seed=2)
    off.step(now=0.0)
    off.inject_kv_corruption(sorted(off.active)[0], last_block=True,
                             arm_guard=False)
    _run_dry(off, now=1.0)
    assert off.stats.quarantined == 0
    assert _streams(off_reqs) != _streams(base_reqs)


@slow
def test_crash_requeues_and_recovers_exact_streams(tiny_model, tiny_params):
    vocab = tiny_model.cfg.vocab_size
    base = _engine(tiny_model, tiny_params)
    base_reqs = _submit(base, vocab, n_req=4, max_new=6, seed=3)
    _run_dry(base)

    eng = _engine(tiny_model, tiny_params)
    reqs = _submit(eng, vocab, n_req=4, max_new=6, seed=3)
    eng.step(now=0.0)
    dropped = eng.crash(1.0)               # requeue mode
    assert dropped == [] and eng.offline
    assert eng.step(now=2.0) == 0          # offline engines do nothing
    eng.restore()
    _run_dry(eng, now=3.0)
    assert eng.stats.crashes == 1
    assert eng.stats.retried == 0          # crash requeue is not a retry
    assert all(r.outcome == "accepted" for r in reqs)
    assert _streams(reqs) == _streams(base_reqs)

    # recovery off: drop mode returns the unfinished work, outcome-less
    off = _engine(tiny_model, tiny_params)
    off_reqs = _submit(off, vocab, n_req=4, max_new=6, seed=3)
    off.step(now=0.0)
    lost = off.crash(1.0, drop=True)
    off.restore()
    _run_dry(off, now=2.0)
    assert lost and all(r.outcome is None for r in lost)
    assert audit_requests(off_reqs)["lost"] == sorted(r.req_id
                                                      for r in lost)


@slow
def test_terminal_outcomes_exclusive_exhaustive_no_stats_drift(
        tiny_model, tiny_params):
    """The stats-drift bug class: a request preempted by a variant swap
    and then timed out must count once as timed_out, zero times as a
    retry, and its tokens must not leak into goodput."""
    import jax
    vocab = tiny_model.cfg.vocab_size
    eng = _engine(tiny_model, tiny_params)
    small = tiny_model.cfg.replace(num_layers=1, d_ff=32, name="t-small")
    from repro.models import build_model, local_plan
    import jax.numpy as jnp
    m2 = build_model(small, local_plan(param_dtype=jnp.bfloat16))
    eng.add_variant("small", m2, m2.init(jax.random.PRNGKey(7)))

    reqs = _submit(eng, vocab, n_req=3, max_new=30, seed=4,
                   arrival_s=0.0, deadline_ms=4_000.0)
    ok = _submit(eng, vocab, n_req=1, max_new=3, seed=5)   # no deadline
    eng.step(now=0.0)
    assert eng.active
    eng.set_variant("small")               # preempts every in-flight lane
    assert eng.stats.preemptions >= 1 and eng.stats.variant_swaps == 1
    assert eng.stats.retried == 0          # preemption is not fault retry
    _run_dry(eng, now=10.0)                # past every deadline
    audit = audit_requests(reqs + ok)
    assert audit["lost"] == []
    assert audit["outcomes"]["timed_out"] == 3
    assert audit["outcomes"]["accepted"] == 1
    # counters agree with per-request terminal outcomes exactly
    assert eng.stats.timed_out == 3
    assert eng.stats.submitted == 4 == len(eng.stats.completed)
    assert eng.stats.retried == 0 and eng.stats.retry_exhausted == 0
    # goodput credits only accepted requests' tokens
    good = eng.stats.goodput(ttft_slo=1e9, tbt_slo=1e9)
    t_max = max(r.finish_s for r in eng.stats.completed)
    assert good == pytest.approx(sum(len(r.output) for r in ok) / t_max)


@slow
def test_no_fault_path_parity(tiny_model, tiny_params):
    """Resilience machinery at rest is invisible: identical greedy
    streams AND identical host-sync counts with or without deadlines
    armed, as long as no fault fires and no deadline expires."""
    vocab = tiny_model.cfg.vocab_size
    plain = _engine(tiny_model, tiny_params)
    plain_reqs = _submit(plain, vocab, n_req=5, max_new=6, seed=6)
    _run_dry(plain)
    armed = _engine(tiny_model, tiny_params)
    armed_reqs = _submit(armed, vocab, n_req=5, max_new=6, seed=6,
                         arrival_s=0.0, deadline_ms=3_600_000.0,
                         max_retries=5)
    _run_dry(armed)
    assert _streams(armed_reqs) == _streams(plain_reqs)
    assert armed.stats.host_syncs == plain.stats.host_syncs
    assert armed.stats.guard_scans == 0 and armed.stats.timed_out == 0


@slow
def test_fault_drill_replays_bit_identically(tiny_model, tiny_params):
    """Same seed + scenario => identical fault timeline, outcomes, and
    recovered token streams across two fresh ClusterSim drills."""
    from repro.serving import EngineBackend

    def drill():
        dc = DCConfig(n_rows=1, racks_per_row=2, servers_per_rack=4)
        probe = ClusterSim(SimConfig(
            dc=dc, horizon_h=1.2, tick_min=6.0, seed=2, policy=TAPAS,
            occupancy=0.95, demand_scale=1.0, scenario=Scenario()))
        attach_tick, saas = None, []
        while probe.tick < probe.ticks:
            st = probe.step()
            saas = [int(s) for s in np.flatnonzero(st.kind == 2)]
            if len(saas) >= 2:
                attach_tick = probe.tick
                break
        assert attach_tick is not None
        events = (
            FailureEvent(kind="cooling", start_h=0.5, end_h=0.8, target=0),
            EngineFault(kind="crash", start_h=0.5, end_h=0.7,
                        server=saas[0]),
            EngineFault(kind="nan_burst", start_h=0.6, end_h=0.7,
                        server=saas[1]),
            SensorDropout(start_h=0.5, end_h=0.9),
        )
        sim = ClusterSim(SimConfig(
            dc=dc, horizon_h=1.2, tick_min=6.0, seed=2, policy=TAPAS,
            occupancy=0.95, demand_scale=1.0,
            scenario=Scenario(events)))
        backends = {}
        while sim.tick < sim.ticks:
            sim.step()
            if sim.tick == attach_tick and not backends:
                for srv in saas[:2]:
                    bk = EngineBackend(
                        _engine(tiny_model, tiny_params), seed=srv,
                        max_new_tokens=8, steps_per_tick=2,
                        ladder=DegradationLadder(),
                        deadline_ms=3_600_000.0)
                    sim.attach_backend(srv, bk)
                    backends[srv] = bk
        for bk in backends.values():
            bk.drain(now_h=float(sim.t_h[-1]) + 1.0)
        issued = [r for bk in backends.values() for r in bk.issued]
        audit = audit_requests(issued)
        counters = tuple(
            (bk.engine.stats.crashes, bk.engine.stats.quarantined,
             bk.engine.stats.retried, bk.engine.stats.timed_out,
             bk.ladder.walks) for bk in backends.values())
        return audit, counters, _streams(issued), sim.watchdog_drains

    a1, c1, s1, w1 = drill()
    a2, c2, s2, w2 = drill()
    assert a1 == a2 and c1 == c2 and s1 == s2 and w1 == w2
    assert a1["lost"] == []                 # zero silent loss, both runs
    assert sum(c[0] for c in c1) >= 1       # the crash actually fired
    assert w1 >= 1                          # the watchdog actually drained
