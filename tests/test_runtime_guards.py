"""Runtime teeth for the tapaslint invariants: the transfer guard trips
on a deliberate implicit host->device leak, the leak checker trips on an
escaped tracer, the steady-state engine drain runs clean under the full
hot-path guard, and the fused spec-decode horizon holds a zero retrace
budget at two horizons."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.lint import runtime as rt
from repro.configs import get_config
from repro.models import build_model, local_plan
from repro.serving import Engine, EngineKnobs, Request


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama2-7b").smoke_config()
    return build_model(cfg, local_plan(param_dtype=jnp.bfloat16))


@pytest.fixture(scope="module")
def tiny_params(tiny_model):
    return tiny_model.init(jax.random.PRNGKey(0))


def _engine(model, params, **kw):
    kw.setdefault("max_seq", 64)
    kw.setdefault("n_slots", 3)
    kw.setdefault("block_size", 8)
    kw.setdefault("paged", True)
    kw.setdefault("knobs", EngineKnobs(max_batch=kw["n_slots"]))
    return Engine(model, params, **kw)


def _submit_load(eng, vocab, *, n_req=4, max_new=10, seed=0, stagger=2):
    rng = np.random.default_rng(seed)
    for i in range(n_req):
        plen = int(rng.integers(4, 20))
        eng.submit(Request(
            prompt=[int(t) for t in rng.integers(0, vocab, plen)],
            max_new_tokens=max_new + stagger * i, temperature=0.0))


def _drain(eng, limit=300):
    steps = 0
    while (eng.queue or eng.active or eng.prefilling) and steps < limit:
        eng.step()
        steps += 1
    assert not (eng.queue or eng.active or eng.prefilling), \
        f"engine did not drain in {limit} steps"
    return steps


# ---------------------------------------------------------------------------
# the guards themselves have teeth
# ---------------------------------------------------------------------------

def test_transfer_guard_trips_on_implicit_upload():
    """A host value smuggled into jitted code (here: an np array argument,
    the per-step upload bug shape) raises inside the guard."""
    f = jax.jit(lambda a: a + 1)
    x_host = np.ones(3, np.float32)
    f(x_host)  # compiles + runs fine unguarded
    with pytest.raises(Exception, match="[Dd]isallowed.*transfer"):
        with rt.no_implicit_transfers():
            f(x_host)


def test_transfer_guard_sanctions_explicit_staging():
    """The sanctioned pattern — device_put before the guarded region,
    sanctioned_readback inside it — passes the same guard."""
    f = jax.jit(lambda a: a + 1)
    x_dev = jax.device_put(np.ones(3, np.float32))
    f(x_dev)
    with rt.no_implicit_transfers():
        y = f(x_dev)
        out = rt.sanctioned_readback(y)
    np.testing.assert_allclose(out, 2.0)


def test_leak_check_trips_on_escaped_tracer():
    """A tracer stashed outside its trace fails at the leak site instead
    of as a deferred ConcretizationError three modules away."""
    leaked = []

    @jax.jit
    def f(a):
        leaked.append(a)      # tapaslint: disable=TL002
        return a * 2

    with pytest.raises(Exception, match="[Ll]eak"):
        with rt.no_leaked_tracers():
            f(jnp.ones(3))


def test_retrace_budget_catches_respecialization():
    """A shape varying per call inside the fenced region exceeds budget 0
    (the PR 6 shrinking-tail failure mode, reproduced in miniature)."""
    f = jax.jit(lambda a: a.sum())
    f(jnp.ones(4))  # warmup: one live bucket
    with pytest.raises(AssertionError, match="retrace budget"):
        with rt.retrace_budget(f):
            f(jnp.ones(5))  # new shape -> new compile


def test_retrace_budget_passes_at_steady_shape():
    f = jax.jit(lambda a: a.sum())
    f(jnp.ones(4))
    with rt.retrace_budget(f):
        for _ in range(5):
            f(jnp.ones(4))


def test_cache_size_and_jit_entries(tiny_model, tiny_params):
    eng = _engine(tiny_model, tiny_params)
    entries = rt.jit_entries(eng)
    assert "_decode_multi_jit" in entries and "_prefill_jit" in entries
    assert all(rt.cache_size(f) == 0 for f in entries.values())


# ---------------------------------------------------------------------------
# the serving hot path holds the invariants (CI sim job)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("draft", [None, "ngram"])
def test_steady_state_drain_is_transfer_clean(tiny_model, tiny_params,
                                              draft):
    """After warmup, a full drain does no implicit host->device transfer:
    every upload on the decode/admission path is explicitly staged
    (kvcache ``_dev_i32`` / ``device_put``).  The engine's per-horizon
    readback is device->host and sanctioned."""
    eng = _engine(tiny_model, tiny_params, draft=draft, horizon=4)
    vocab = tiny_model.cfg.vocab_size
    _submit_load(eng, vocab)
    for _ in range(3):          # warmup: compile prefill + decode paths
        eng.step()
    with rt.no_implicit_transfers():
        _drain(eng)
    assert len(eng.stats.completed) == 4


@pytest.mark.slow
@pytest.mark.parametrize("horizon", [2, 4])
def test_spec_decode_holds_zero_retrace_budget(tiny_model, tiny_params,
                                               horizon):
    """Compile-cache delta of the fused spec-decode entry point
    (``Model.decode_spec_paged`` under jit) is exactly 0 across a
    drained run once the live shape buckets are warm — the shrinking
    tail must park on device, not re-specialize the scan."""
    eng = _engine(tiny_model, tiny_params, draft="ngram", horizon=horizon)
    vocab = tiny_model.cfg.vocab_size
    _submit_load(eng, vocab)
    # warm every live bucket: run until the first spec horizon has
    # compiled, then fence the rest of the drain
    while rt.cache_size(eng._decode_spec_jit) == 0:
        eng.step()
    with rt.retrace_budget(eng._decode_spec_jit, eng._decode_multi_jit):
        _drain(eng)
    assert len(eng.stats.completed) == 4
    assert eng.stats.accepted_per_sync >= 1.0
