"""Hypothesis property-based tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.analysis.hlo_cost import HloModuleCost, _shape_info
from repro.core.power import PowerModel
from repro.core.router import TapasRouter
from repro.core.datacenter import Datacenter, DCConfig
from repro.core.thermal import ThermalModel
from repro.kernels.int8_matmul import quantize_rows

_dc = Datacenter(DCConfig(n_rows=2, racks_per_row=3, servers_per_rack=2))
_th = ThermalModel.calibrate(_dc)
_pm = PowerModel.calibrate(_dc)


@settings(max_examples=40, deadline=None)
@given(demand=st.floats(0.0, 50.0),
       caps=st.lists(st.floats(0.0, 4.0), min_size=1, max_size=12),
       risks=st.lists(st.floats(0.0, 1.0), min_size=1, max_size=12))
def test_router_invariants(demand, caps, risks):
    n = min(len(caps), len(risks))
    cap = np.asarray(caps[:n])
    risk = np.asarray(risks[:n])
    d = TapasRouter().route(demand, cap, risk)
    # conservation: everything routed or accounted as unserved
    np.testing.assert_allclose(d.load.sum() + d.unserved, demand,
                               rtol=1e-5, atol=1e-5)
    assert (d.load >= -1e-9).all()
    assert (d.load <= cap + 1e-6).all()


@settings(max_examples=30, deadline=None)
@given(t_out=st.floats(-20.0, 45.0), load=st.floats(0.0, 1.0),
       d_out=st.floats(0.0, 10.0), d_load=st.floats(0.0, 0.5))
def test_thermal_monotone(t_out, load, d_out, d_load):
    t1 = np.asarray(_th.inlet_temp(t_out, load))
    t2 = np.asarray(_th.inlet_temp(t_out + d_out, min(load + d_load, 1.0)))
    assert (t2 >= t1 - 1e-6).all()


@settings(max_examples=30, deadline=None)
@given(u=st.floats(0.0, 1.0), du=st.floats(0.0, 0.5))
def test_power_monotone(u, du):
    s = _dc.n_servers
    p1 = np.asarray(_pm.server_power(np.full((s, 8), u)))
    p2 = np.asarray(_pm.server_power(np.full((s, 8), min(u + du, 1.0))))
    assert (p2 >= p1 - 1e-6).all()


@settings(max_examples=30, deadline=None)
@given(limit=st.floats(60.0, 100.0))
def test_thermal_inversion_safe(limit):
    inlet = np.asarray(_th.inlet_temp(30.0, 0.5))
    u = np.asarray(_th.max_util_for_temp(inlet, limit))
    assert ((u >= 0) & (u <= 1)).all()
    t = np.asarray(_th.gpu_temp(inlet, np.repeat(u[:, None], 8, 1)))
    hot = u > 0  # if util is clamped to 0, temp may exceed limit at idle
    assert (t.max(axis=1)[hot & (u < 1.0)[...]] <= limit + 1e-3).all()


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(2, 40), cols=st.integers(2, 40), seed=st.integers(0, 99))
def test_int8_quantize_roundtrip(rows, cols, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, cols)).astype(np.float32)
    xq, s = quantize_rows(x)
    back = np.asarray(xq, np.float32) * np.asarray(s)
    err = np.abs(back - x).max()
    assert err <= np.abs(x).max() / 127.0 + 1e-6


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 8), n=st.integers(1, 8), k=st.integers(1, 8),
       trips=st.integers(1, 50))
def test_hlo_parser_scales_loops(m, n, k, trips):
    """Synthetic HLO: dot inside a while body scales with trip count."""
    hlo = f"""
%body (p: (s32[], f32[{m},{k}], f32[{k},{n}])) -> (s32[], f32[{m},{k}], f32[{k},{n}]) {{
  %p = (s32[], f32[{m},{k}], f32[{k},{n}]) parameter(0)
  %a = f32[{m},{k}]{{1,0}} get-tuple-element(%p), index=1
  %b = f32[{k},{n}]{{1,0}} get-tuple-element(%p), index=2
  %d = f32[{m},{n}]{{1,0}} dot(%a, %b), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
  %c = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[{m},{k}], f32[{k},{n}]) tuple(%c, %a, %b)
}}

%cond (p: (s32[], f32[{m},{k}], f32[{k},{n}])) -> pred[] {{
  %p = (s32[], f32[{m},{k}], f32[{k},{n}]) parameter(0)
  %c = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant({trips})
  ROOT %lt = pred[] compare(%c, %k), direction=LT
}}

ENTRY %main (x: f32[{m},{k}], y: f32[{k},{n}]) -> f32[] {{
  %x = f32[{m},{k}]{{1,0}} parameter(0)
  %y = f32[{k},{n}]{{1,0}} parameter(1)
  %init = (s32[], f32[{m},{k}], f32[{k},{n}]) tuple(%x, %x, %y)
  %w = (s32[], f32[{m},{k}], f32[{k},{n}]) while(%init), condition=%cond, body=%body, backend_config={{"known_trip_count":{{"n":"{trips}"}}}}
  ROOT %r = f32[] constant(0)
}}
"""
    cost = HloModuleCost(hlo).cost()
    dot_flops = 2.0 * m * n * k * trips
    # the loop condition's compare costs 1 flop/trip in our accounting
    assert dot_flops <= cost.flops <= dot_flops + 2 * trips + 4


def test_shape_info_tuple():
    b, e = _shape_info("(s32[], f32[2,3]{1,0}, bf16[4])")
    assert b == 4 + 24 + 8
    assert e == 1 + 6 + 4
