"""Speculative decoding + on-device sampling: greedy bit-parity vs the
fused horizon path, rejection-sampling distribution correctness, pool /
history invariants across rejected tails, drafter lifecycle, and the
per-request deterministic RNG seeding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model, local_plan
from repro.models.transformer import (lane_keys, ngram_propose,
                                      rejection_choose, sampling_dist)
from repro.serving import Engine, EngineKnobs, Request
from repro.serving.backend import EngineBackend

# whole-module: live jitted engines + PRNG sweeps (CI sim job);
# leakcheck = tracer escapes fail at the leak site (tapaslint runtime)
pytestmark = [pytest.mark.slow, pytest.mark.leakcheck]


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama2-7b").smoke_config()
    return build_model(cfg, local_plan(param_dtype=jnp.bfloat16))


@pytest.fixture(scope="module")
def tiny_params(tiny_model):
    return tiny_model.init(jax.random.PRNGKey(0))


def _engine(model, params, **kw):
    kw.setdefault("max_seq", 64)
    kw.setdefault("n_slots", 3)
    kw.setdefault("block_size", 8)
    kw.setdefault("paged", True)
    kw.setdefault("knobs", EngineKnobs(max_batch=kw["n_slots"]))
    return Engine(model, params, **kw)


def _submit_load(eng, vocab, *, n_req=5, max_new=12, seed=0, stagger=2,
                 temperature=0.0, top_k=0, seeds=None):
    rng = np.random.default_rng(seed)
    for i in range(n_req):
        plen = int(rng.integers(4, 20))
        eng.submit(Request(
            prompt=[int(t) for t in rng.integers(0, vocab, plen)],
            max_new_tokens=max_new + stagger * i, temperature=temperature,
            top_k=top_k, seed=None if seeds is None else seeds[i]))


def _streams(stats):
    return [tuple(r.output) for r in sorted(stats.completed,
                                            key=lambda r: r.req_id)]


# ---------------------------------------------------------------------------
# rejection sampling: the emitted-token marginal equals the target dist
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec_k", [1, 4])
def test_rejection_sampling_matches_target_dist(spec_k):
    """Slot-0 emitted-token marginal == target p exactly (the losslessness
    theorem), measured over many lanes with q deliberately far from p."""
    V, B = 8, 16384
    rng = np.random.default_rng(42)
    p0 = rng.dirichlet(np.full(V, 0.6), size=spec_k + 1).astype(np.float32)
    q0 = rng.dirichlet(np.full(V, 0.6), size=spec_k).astype(np.float32)
    p = jnp.broadcast_to(jnp.asarray(p0), (B, spec_k + 1, V))
    q = jnp.broadcast_to(jnp.asarray(q0), (B, spec_k, V))
    # drafts ~ q, drawn independently of the accept/bonus key stream
    drafts = jnp.asarray(
        np.stack([rng.choice(V, size=B, p=q0[j] / q0[j].sum())
                  for j in range(spec_k)], axis=1), jnp.int32)
    base = lane_keys(jnp.arange(B, dtype=jnp.int32))
    n_acc, cand = rejection_choose(
        base, jnp.zeros(B, jnp.int32), drafts, q, p,
        jnp.zeros(B, bool), jnp.full(B, spec_k + 1, jnp.int32))
    emitted0 = np.asarray(cand[:, 0])
    freq = np.bincount(emitted0, minlength=V) / B
    tv_p = 0.5 * np.abs(freq - p0[0]).sum()
    tv_q = 0.5 * np.abs(freq - q0[0]).sum()
    assert tv_p < 0.03                       # matches the target...
    assert tv_q > 0.1                        # ...and NOT the proposer
    assert 0 < int(np.asarray(n_acc).sum()) < B * spec_k  # mixed outcomes


def test_rejection_sampling_greedy_degenerates_to_argmax():
    """One-hot dists: accept iff draft == argmax p, and every corrected /
    bonus slot IS the argmax — no randomness survives at temperature 0."""
    V, B, K = 8, 64, 3
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((B, K + 1, V)), jnp.float32)
    zeros = jnp.zeros((B, K + 1))
    p = sampling_dist(logits, zeros, jnp.zeros((B, K + 1), jnp.int32))
    am = np.asarray(jnp.argmax(logits, axis=-1))
    drafts = jnp.asarray(np.where(rng.random((B, K)) < 0.5, am[:, :K],
                                  (am[:, :K] + 1) % V), jnp.int32)
    q = jax.nn.one_hot(drafts, V, dtype=jnp.float32)
    n_acc, cand = rejection_choose(
        lane_keys(jnp.arange(B, dtype=jnp.int32)), jnp.zeros(B, jnp.int32),
        drafts, q, p, jnp.ones(B, bool), jnp.full(B, K + 1, jnp.int32))
    n_acc, cand = np.asarray(n_acc), np.asarray(cand)
    match = np.asarray(drafts) == am[:, :K]
    expect_acc = np.cumprod(match, axis=1).sum(axis=1)
    np.testing.assert_array_equal(n_acc, expect_acc)
    for b in range(B):
        for j in range(n_acc[b], K + 1):     # rejected + bonus slots
            assert cand[b, j] == am[b, j]


def test_ngram_propose_prompt_lookup():
    """The bigram suffix match proposes the continuation of the most
    recent earlier occurrence; no match repeats the last token."""
    hist = jnp.asarray([[7, 8, 9, 1, 2, 5, 6, 1, 2, 0, 0, 0],
                        [3, 3, 3, 3, 3, 3, 3, 3, 4, 0, 0, 0]], jnp.int32)
    pos = jnp.asarray([8, 8], jnp.int32)     # suffixes (1, 2) and (3, 4)
    drafts = np.asarray(ngram_propose(hist, pos, k=3, n=2))
    # row 0: (1, 2) last occurred at 3..4 -> continuation 5, 6, 1
    np.testing.assert_array_equal(drafts[0], [5, 6, 1])
    # row 1: (3, 4) never occurred before -> repeat hist[pos] = 4
    np.testing.assert_array_equal(drafts[1], [4, 4, 4])


# ---------------------------------------------------------------------------
# engine: greedy bit-parity with speculation on
# ---------------------------------------------------------------------------

def test_spec_ngram_greedy_streams_identical(tiny_model, tiny_params):
    """ngram speculation at K=4: exactly the plain fused-horizon streams
    (greedy parity is bitwise — the verify pass folds the candidates into
    the decode-step batch axis), with fewer decode syncs."""
    vocab = tiny_model.cfg.vocab_size
    base = _engine(tiny_model, tiny_params, horizon=8)
    _submit_load(base, vocab)
    st0 = base.run()
    spec = _engine(tiny_model, tiny_params, horizon=8, draft="ngram",
                   spec_k=4)
    _submit_load(spec, vocab)
    st1 = spec.run()
    assert _streams(st0) == _streams(st1)
    assert st1.verify_passes > 0
    assert st1.draft_tokens == 4 * st1.verify_passes
    assert 0 < st1.accepted_tokens <= st1.draft_tokens
    assert st1.accepted_per_sync > 0
    assert spec.pool.used_blocks == 0        # everything reclaimed


def test_spec_model_drafter_greedy_streams_identical(tiny_model,
                                                     tiny_params):
    """A registered model drafter (here: the target itself, the ideal
    proposer) still reproduces the plain streams bit-exactly, and accepts
    nearly everything."""
    vocab = tiny_model.cfg.vocab_size
    base = _engine(tiny_model, tiny_params, horizon=8)
    _submit_load(base, vocab, n_req=3)
    st0 = base.run()
    spec = _engine(tiny_model, tiny_params, horizon=8, spec_k=2)
    spec.add_drafter("self", tiny_model, tiny_params)
    spec.set_drafter("self")
    _submit_load(spec, vocab, n_req=3)
    st1 = spec.run()
    assert _streams(st0) == _streams(st1)
    # a perfect drafter: the only rejections are bonus-slot cutoffs
    assert st1.accepted_tokens > 0.8 * st1.draft_tokens


def test_spec_with_chunked_prefill_and_sharing(tiny_model, tiny_params):
    """Speculation composes with chunked prefill + prefix sharing (the
    draft cache rides the same block tables)."""
    vocab = tiny_model.cfg.vocab_size
    base = _engine(tiny_model, tiny_params, horizon=8)
    _submit_load(base, vocab)
    spec = _engine(tiny_model, tiny_params, horizon=8, draft="ngram",
                   spec_k=4, prefix_share=True, prefill_chunk=16)
    _submit_load(spec, vocab)
    assert _streams(base.run()) == _streams(spec.run())


def test_spec_respects_eos_and_budget(tiny_model, tiny_params):
    """Mid-round finishes stop the emitted run on the right token even
    when later slots were accepted."""
    vocab = tiny_model.cfg.vocab_size
    eng = _engine(tiny_model, tiny_params, horizon=8, draft="ngram",
                  spec_k=4)
    rng = np.random.default_rng(3)
    prompt = [int(t) for t in rng.integers(0, vocab, 9)]
    eng.submit(Request(prompt=list(prompt), max_new_tokens=10))
    free = _streams(eng.run())[0]
    assert len(free) == 10                   # budget exact
    eos = free[4]
    eng2 = _engine(tiny_model, tiny_params, horizon=8, draft="ngram",
                   spec_k=4)
    eng2.submit(Request(prompt=list(prompt), max_new_tokens=10, eos_id=eos))
    got = _streams(eng2.run())[0]
    assert got == free[: free.index(eos) + 1]


# ---------------------------------------------------------------------------
# pool / history invariants across rejected tails
# ---------------------------------------------------------------------------

def test_spec_pool_and_hist_invariants(tiny_model, tiny_params):
    """Stepping a sampled spec engine (rejections guaranteed): lane
    positions, the device mirrors and the token history stay consistent
    with prompt + output after every scheduler step, and the pool drains
    to zero blocks / zero refs."""
    vocab = tiny_model.cfg.vocab_size
    eng = _engine(tiny_model, tiny_params, horizon=4, draft="ngram",
                  spec_k=4)
    _submit_load(eng, vocab, temperature=0.9, top_k=0,
                 seeds=[11, 12, 13, 14, 15])
    steps = 0
    while eng.queue or eng.active or eng.prefilling:
        eng.step(now=float(steps))
        steps += 1
        assert steps < 200
        pool = eng.pool
        np.testing.assert_array_equal(np.asarray(pool.positions()),
                                      pool.lengths)
        np.testing.assert_array_equal(np.asarray(pool.tables()),
                                      pool.block_tables)
        for rid, req in eng.active.items():
            lane = pool.lane_of[rid]
            seq = list(req.prompt) + list(req.output)
            assert pool.lengths[lane] == len(seq) - 1   # next-write slot
            hist = np.asarray(pool.hist_dev())[lane]
            np.testing.assert_array_equal(hist[: len(seq)], seq)
    assert eng.pool.used_blocks == 0
    assert (eng.pool.ref[1:] == 0).all()
    assert eng.stats.accepted_tokens < eng.stats.draft_tokens  # rejections


def test_per_request_seed_determinism(tiny_model, tiny_params):
    """Same request seeds -> identical sampled streams on a fresh engine;
    a different engine seed changes unseeded requests only."""
    vocab = tiny_model.cfg.vocab_size

    def run(engine_seed, req_seeds):
        eng = _engine(tiny_model, tiny_params, horizon=8, draft="ngram",
                      spec_k=4, seed=engine_seed)
        _submit_load(eng, vocab, n_req=3, temperature=0.9, top_k=16,
                     seeds=req_seeds)
        return _streams(eng.run())

    a = run(0, [101, 102, 103])
    b = run(0, [101, 102, 103])
    assert a == b                            # replay-stable
    c = run(0, [101, 102, 999])
    assert a[:2] == c[:2] and a[2] != c[2]   # seed isolates the stream
    d = run(7, [None, None, None])
    e = run(8, [None, None, None])
    assert d != e                            # engine seed feeds the crc fold


def test_mixed_batch_keeps_greedy_lanes_exact(tiny_model, tiny_params):
    """A sampled request in the batch must not perturb its greedy
    neighbours: temps land in the graph but greedy lanes still take the
    exact argmax."""
    vocab = tiny_model.cfg.vocab_size
    base = _engine(tiny_model, tiny_params, horizon=8)
    _submit_load(base, vocab, n_req=3)
    st0 = base.run()
    mix = _engine(tiny_model, tiny_params, horizon=8)
    rng = np.random.default_rng(0)
    for i in range(3):
        plen = int(rng.integers(4, 20))
        mix.submit(Request(
            prompt=[int(t) for t in rng.integers(0, vocab, plen)],
            max_new_tokens=12 + 2 * i,
            temperature=0.9 if i == 1 else 0.0, seed=5))
    st1 = mix.run()
    g0, g1 = _streams(st0), _streams(st1)
    assert g0[0] == g1[0] and g0[2] == g1[2]


# ---------------------------------------------------------------------------
# drafter lifecycle
# ---------------------------------------------------------------------------

def test_drafter_swap_mid_flight(tiny_model, tiny_params):
    """Swapping the proposer mid-run (ngram -> off -> model drafter) never
    perturbs greedy output: proposal quality only moves throughput."""
    vocab = tiny_model.cfg.vocab_size
    base = _engine(tiny_model, tiny_params, horizon=4)
    _submit_load(base, vocab, n_req=3, max_new=18)
    st0 = base.run()
    eng = _engine(tiny_model, tiny_params, horizon=4, draft="ngram",
                  spec_k=2)
    eng.add_drafter("self", tiny_model, tiny_params)
    _submit_load(eng, vocab, n_req=3, max_new=18)
    steps = 0
    while eng.queue or eng.active or eng.prefilling:
        if steps == 3:
            eng.set_drafter(None)            # plain fused decode
        if steps == 5:
            eng.set_drafter("self")          # cold draft cache mid-flight
        eng.step(now=float(steps))
        steps += 1
        assert steps < 200
    assert _streams(eng.stats) == _streams(st0)
    assert eng.pool.used_blocks == 0


def test_drafter_pairing_validation(tiny_model, tiny_params):
    """Mismatched vocab / non-paged drafters are rejected up front."""
    from repro.configs import check_draft_pair, drafter_for, get_config
    assert drafter_for("llama2-70b") == "llama2-7b"
    with pytest.raises(ValueError, match="tokenizer"):
        check_draft_pair(get_config("llama2-7b"), get_config("gemma-7b"))
    with pytest.raises(ValueError, match="paged-servable"):
        check_draft_pair(get_config("rwkv6-3b"), get_config("rwkv6-3b"))
    eng = _engine(tiny_model, tiny_params, horizon=4)
    with pytest.raises(KeyError):
        eng.set_drafter("nope")


def test_backend_drops_drafter_under_freq_cap(tiny_model, tiny_params):
    """Speculation as a reconfigure axis: a deep frequency cap stashes the
    drafter; lifting the cap restores it."""
    from repro.core.profiles import ConfigPoint
    eng = _engine(tiny_model, tiny_params, horizon=4, draft="ngram",
                  spec_k=2)
    bk = EngineBackend(eng, draft_min_freq=0.7)
    lo = ConfigPoint(freq=0.5, tp=8, batch=16, size="7b", quant="bf16")
    hi = ConfigPoint(freq=1.0, tp=8, batch=16, size="7b", quant="bf16")
    bk.apply_config(lo)
    assert eng.draft_name is None and bk.draft_drops == 1
    bk.apply_config(lo)                      # idempotent while capped
    assert bk.draft_drops == 1
    bk.apply_config(hi)
    assert eng.draft_name == "ngram" and bk._stashed_draft is None
