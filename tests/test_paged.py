"""Paged-KV serving stack: kernel equivalence vs the dense flash-decode,
PagedCachePool allocator invariants, and slot-vs-paged engine equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.models import build_model, local_plan
from repro.serving import Engine, EngineKnobs, PagedCachePool, Request

# whole-module: kernel sweeps + live engines (CI sim job);
# leakcheck = tracer escapes fail at the leak site (tapaslint runtime)
pytestmark = [pytest.mark.slow, pytest.mark.leakcheck]


def arr(rng, *s, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(s), dtype)


# ---------------------------------------------------------------------------
# kernel: paged == dense at equal logical context
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,K,D,bs,T", [
    (2, 8, 2, 64, 16, 8),     # GQA 4:1, sequences span 8 blocks
    (1, 4, 4, 128, 32, 4),    # MHA
    (3, 4, 1, 64, 16, 8),     # MQA
])
def test_paged_decode_matches_ref(B, H, K, D, bs, T):
    rng = np.random.default_rng(B * 10 + T)
    n_blocks = 1 + B * T
    kp, vp = arr(rng, n_blocks, bs, K, D), arr(rng, n_blocks, bs, K, D)
    q = arr(rng, B, H, D)
    # ragged positions, scrambled (non-contiguous) physical block layout
    pos = jnp.asarray(rng.integers(0, T * bs, B), jnp.int32)
    ids = rng.permutation(np.arange(1, n_blocks))[: B * T].reshape(B, T)
    bt = jnp.asarray(ids, jnp.int32)
    o = ops.paged_decode_attention(q, kp, vp, bt, pos)
    o_ref = ref.paged_decode_attention_ref(q, kp, vp, bt, pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5)


def test_paged_decode_matches_dense_flash_decode():
    """Same KV content laid out paged vs contiguous -> identical output."""
    rng = np.random.default_rng(0)
    B, H, K, D, bs = 2, 8, 2, 64, 32
    S = 128
    T = S // bs
    k, v = arr(rng, B, S, K, D), arr(rng, B, S, K, D)
    q = arr(rng, B, H, D)
    pos = jnp.asarray([37, 101], jnp.int32)
    o_dense = ops.decode_attention(q, k, v, pos, block_k=bs)
    # scatter the same content into a scrambled pool
    perm = rng.permutation(np.arange(1, 1 + B * T))
    kp = jnp.zeros((1 + B * T, bs, K, D), k.dtype)
    vp = jnp.zeros_like(kp)
    bt = perm.reshape(B, T)
    kp = kp.at[bt.reshape(-1)].set(k.reshape(B * T, bs, K, D))
    vp = vp.at[bt.reshape(-1)].set(v.reshape(B * T, bs, K, D))
    o_paged = ops.paged_decode_attention(q, kp, vp,
                                         jnp.asarray(bt, jnp.int32), pos)
    np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_dense),
                               atol=1e-5)


def test_paged_decode_masks_future():
    """Entries past the position (within the last live block) are masked."""
    rng = np.random.default_rng(1)
    B, H, K, D, bs, T = 1, 2, 2, 32, 16, 4
    kp, vp = arr(rng, 1 + T, bs, K, D), arr(rng, 1 + T, bs, K, D)
    q = arr(rng, B, H, D)
    bt = jnp.arange(1, T + 1, dtype=jnp.int32)[None]
    pos = jnp.asarray([21], jnp.int32)
    o1 = ops.paged_decode_attention(q, kp, vp, bt, pos)
    kp2 = kp.at[2, 6:].set(999.0).at[3].set(999.0).at[4].set(999.0)
    vp2 = vp.at[2, 6:].set(999.0).at[3].set(999.0).at[4].set(999.0)
    o2 = ops.paged_decode_attention(q, kp2, vp2, bt, pos)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


@pytest.mark.parametrize("B,C,H,K,D,bs,T", [
    (2, 8, 8, 2, 64, 16, 8),   # GQA 4:1, 8-token chunk
    (1, 16, 4, 4, 64, 16, 4),  # MHA, chunk spans a block boundary
    (3, 4, 4, 1, 32, 8, 8),    # MQA
])
def test_paged_prefill_matches_ref(B, C, H, K, D, bs, T):
    """Chunk queries at ragged start offsets over a scrambled pool."""
    rng = np.random.default_rng(B * 100 + C)
    n_blocks = 1 + B * T
    kp, vp = arr(rng, n_blocks, bs, K, D), arr(rng, n_blocks, bs, K, D)
    q = arr(rng, B, C, H, D)
    starts = jnp.asarray(rng.integers(0, T * bs - C, B), jnp.int32)
    ids = rng.permutation(np.arange(1, n_blocks))[: B * T].reshape(B, T)
    bt = jnp.asarray(ids, jnp.int32)
    o = ops.paged_prefill_attention(q, kp, vp, bt, starts)
    o_ref = ref.paged_prefill_attention_ref(q, kp, vp, bt, starts)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5)


def test_paged_prefill_chunk_equals_decode_steps():
    """A C-token chunk attends exactly like C successive decode steps
    whose KV is already in place (same pool, same block tables)."""
    rng = np.random.default_rng(5)
    B, H, K, D, bs, T = 2, 4, 2, 32, 8, 4
    C = 6
    n_blocks = 1 + B * T
    kp, vp = arr(rng, n_blocks, bs, K, D), arr(rng, n_blocks, bs, K, D)
    q = arr(rng, B, C, H, D)
    starts = jnp.asarray([5, 11], jnp.int32)
    bt = jnp.asarray(rng.permutation(np.arange(1, n_blocks))
                     .reshape(B, T), jnp.int32)
    o_chunk = ops.paged_prefill_attention(q, kp, vp, bt, starts)
    for c in range(C):
        o_one = ops.paged_decode_attention(q[:, c], kp, vp, bt, starts + c)
        np.testing.assert_allclose(np.asarray(o_chunk[:, c]),
                                   np.asarray(o_one), atol=1e-5)


@pytest.mark.parametrize("B,Q,H,K,D,bs,T", [
    (2, 5, 8, 2, 64, 16, 8),   # GQA 4:1, K=4 speculation (Q = K + 1)
    (1, 2, 4, 4, 64, 16, 4),   # MHA, K=1
    (3, 5, 4, 1, 32, 8, 8),    # MQA
])
def test_paged_verify_matches_ref(B, Q, H, K, D, bs, T):
    """Speculative verify: Q candidate queries per lane, query i at
    absolute position positions[b] + i, against the mask-walk oracle."""
    rng = np.random.default_rng(B * 1000 + Q)
    n_blocks = 1 + B * T
    kp, vp = arr(rng, n_blocks, bs, K, D), arr(rng, n_blocks, bs, K, D)
    q = arr(rng, B, Q, H, D)
    pos = jnp.asarray(rng.integers(0, T * bs - Q, B), jnp.int32)
    bt = jnp.asarray(rng.permutation(np.arange(1, n_blocks))
                     [: B * T].reshape(B, T), jnp.int32)
    o = ops.paged_verify_attention(q, kp, vp, bt, pos)
    o_ref = ref.paged_verify_attention_ref(q, kp, vp, bt, pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5)


def test_paged_verify_equals_decode_steps():
    """Verify query i == one decode step at positions + i with the KV
    already in place: the multi-query pass and the sequential chain see
    the same causal context."""
    rng = np.random.default_rng(7)
    B, Q, H, K, D, bs, T = 2, 5, 4, 2, 32, 8, 4
    n_blocks = 1 + B * T
    kp, vp = arr(rng, n_blocks, bs, K, D), arr(rng, n_blocks, bs, K, D)
    q = arr(rng, B, Q, H, D)
    pos = jnp.asarray([6, 13], jnp.int32)
    bt = jnp.asarray(rng.permutation(np.arange(1, n_blocks))
                     .reshape(B, T), jnp.int32)
    o = ops.paged_verify_attention(q, kp, vp, bt, pos)
    for i in range(Q):
        o_one = ops.paged_decode_attention(q[:, i], kp, vp, bt, pos + i)
        np.testing.assert_allclose(np.asarray(o[:, i]), np.asarray(o_one),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# PagedCachePool allocator invariants
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama2-7b").smoke_config()
    return build_model(cfg, local_plan(param_dtype=jnp.bfloat16))


def _fake_prefill(model, batch, seq, value=1.0):
    cfg = model.cfg
    shape = (cfg.num_layers, batch, seq, cfg.n_kv_heads, cfg.head_dim)
    return {"attn": {"k": jnp.full(shape, value, jnp.bfloat16),
                     "v": jnp.full(shape, 2 * value, jnp.bfloat16)}}


def test_pool_alloc_release_invariants(tiny_model):
    pool = PagedCachePool(tiny_model, n_lanes=3, max_seq=64, block_size=8)
    total = pool.n_blocks - 1          # block 0 reserved for parking
    assert len(pool.free_blocks) == total

    pool.insert(10, _fake_prefill(tiny_model, 1, 20), 0, 20)   # 3 blocks
    pool.insert(11, _fake_prefill(tiny_model, 1, 8), 0, 8)     # 1 block
    assert pool.used_blocks == 4
    held = pool.blocks_of[10] + pool.blocks_of[11]
    assert len(set(held)) == len(held), "double-allocated block"
    assert 0 not in held, "parking block must never be allocated"
    # block tables point parked slots at 0 and live slots at owned blocks
    lane = pool.lane_of[10]
    assert list(pool.block_tables[lane][:3]) == pool.blocks_of[10]
    assert all(b == 0 for b in pool.block_tables[lane][3:])

    pool.release(10)
    assert pool.used_blocks == 1
    assert len(pool.free_blocks) == total - 1
    # released blocks are reusable: fill the pool completely
    while pool.can_admit(16):
        pool.insert(100 + pool.used_blocks, _fake_prefill(tiny_model, 1, 16),
                    0, 16)
    assert not pool.free_lanes or len(pool.free_blocks) < pool.blocks_for(17)


def test_pool_insert_writes_only_touched_blocks(tiny_model):
    """O(blocks-touched) admission: untouched blocks keep their contents
    bit-for-bit (no whole-pool rewrite)."""
    pool = PagedCachePool(tiny_model, n_lanes=2, max_seq=32, block_size=8)
    pool.insert(1, _fake_prefill(tiny_model, 1, 16, value=3.0), 0, 16)
    before = np.asarray(pool.cache["attn"]["k"]).copy()
    blks1 = list(pool.blocks_of[1])
    pool.insert(2, _fake_prefill(tiny_model, 1, 9, value=5.0), 0, 9)
    after = np.asarray(pool.cache["attn"]["k"])
    touched = set(pool.blocks_of[2])
    for b in range(pool.n_blocks):
        if b not in touched:
            np.testing.assert_array_equal(after[:, b], before[:, b])
    # and request 1's blocks still hold its values
    for b in blks1:
        assert float(after[:, b].max()) == 3.0


def test_pool_append_allocation_and_preemption_path(tiny_model):
    pool = PagedCachePool(tiny_model, n_lanes=2, max_seq=32, block_size=8,
                          n_blocks=4)   # 3 usable blocks
    pool.insert(1, _fake_prefill(tiny_model, 1, 8), 0, 8)    # 1 block full
    pool.insert(2, _fake_prefill(tiny_model, 1, 8), 0, 8)    # 1 block full
    # both need an append block; only one is left -> one victim
    victims = pool.ensure_append_blocks([2, 1])
    assert victims == [1]
    assert len(pool.blocks_of[2]) == 2
    pool.release(1)
    assert pool.ensure_append_blocks([2]) == []


# ---------------------------------------------------------------------------
# engine: slot-based and paged serving produce identical streams
# ---------------------------------------------------------------------------

def _run_engine(model, params, vocab, *, paged, n_blocks=None, seed=0,
                n_req=5, max_new=6):
    eng = Engine(model, params, max_seq=64, n_slots=3,
                 knobs=EngineKnobs(max_batch=3), paged=paged, block_size=8,
                 n_blocks=n_blocks)
    rng = np.random.default_rng(seed)
    for _ in range(n_req):
        plen = int(rng.integers(4, 20))
        eng.submit(Request(prompt=[int(t) for t in rng.integers(0, vocab, plen)],
                           max_new_tokens=max_new))
    stats = eng.run()
    outs = [tuple(r.output) for r in sorted(stats.completed,
                                            key=lambda r: r.req_id)]
    return outs, stats


def test_engine_slot_vs_paged_identical(tiny_model):
    params = tiny_model.init(jax.random.PRNGKey(0))
    vocab = tiny_model.cfg.vocab_size
    outs_slot, st_slot = _run_engine(tiny_model, params, vocab, paged=False)
    outs_paged, st_paged = _run_engine(tiny_model, params, vocab, paged=True)
    assert outs_slot == outs_paged
    assert len(outs_paged) == 5
    # batched admission: fewer jitted prefill launches than requests
    assert st_paged.prefill_batches < st_slot.prefill_batches


def test_engine_paged_preemption_recompute(tiny_model):
    """A pool too small to hold all actives preempts + recomputes, and the
    token streams still match the roomy-pool run exactly."""
    params = tiny_model.init(jax.random.PRNGKey(1))
    vocab = tiny_model.cfg.vocab_size
    roomy, _ = _run_engine(tiny_model, params, vocab, paged=True, seed=3,
                           max_new=12)
    tight, st = _run_engine(tiny_model, params, vocab, paged=True, seed=3,
                            max_new=12, n_blocks=8)
    assert tight == roomy
    assert st.preemptions > 0


def test_engine_paged_pool_fully_reclaimed(tiny_model):
    params = tiny_model.init(jax.random.PRNGKey(0))
    eng = Engine(tiny_model, params, max_seq=64, n_slots=2,
                 knobs=EngineKnobs(max_batch=2), paged=True, block_size=8)
    for i in range(3):
        eng.submit(Request(prompt=[1, 2, 3, 4], max_new_tokens=4))
    eng.run()
    assert eng.pool.used_blocks == 0
    assert sorted(eng.pool.free_lanes) == [0, 1]
    assert (eng.pool.block_tables == 0).all()


# ---------------------------------------------------------------------------
# profiles bridge: engine-measured table
# ---------------------------------------------------------------------------

def test_measure_from_engine_calibrates_entry():
    from repro.core import profiles as P
    mp = P.measure_from_engine(batches=(1, 2), freqs=(1.0,),
                               n_requests=3, max_new=4, prompt_len=6)
    assert len(mp.rows) == 4      # 2 variants x 2 batches x 1 freq
    assert all(r["tok_per_s"] > 0 for r in mp.rows)
    assert mp.calibration["source"] == "engine-measured"
    # entries ride the unchanged ProfileEntry/_entry API
    assert max(e.goodput for e in mp.entries) == 1.0
    P.calibrate(mp)
    try:
        assert P._CAL["source"] == "engine-measured"
        e = P._entry(P.NOMINAL)
        assert e.goodput == 1.0    # nominal is the normalization point
        assert P._entry(P.NOMINAL.__class__(
            freq=1.0, tp=8, batch=1, size="70b", quant="bf16")).goodput \
            == pytest.approx(mp.calibration["batch_eff"][1], rel=1e-6)
    finally:
        P.reset_calibration()
