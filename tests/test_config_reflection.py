"""Reflection round-trips over the configs/ and core/datacenter.py
dataclasses: every copy/scale helper must be *total* — no field may
silently revert to its default when a modified copy is built (the
``scale_datacenter`` bug, tapaslint TL004).

The tests are generic over ``dataclasses.fields`` so a field added later
is covered without editing them."""
import dataclasses

import pytest

from repro.configs import ArchConfig, get_config, list_archs
from repro.configs.shapes import Shape
from repro.core.datacenter import DCConfig, HWProfile, scale_datacenter
from repro.core.fleet import FleetConfig, FleetSim, RegionSpec


def _sentinel_for(current):
    """A replacement value distinguishable from ``current`` (and from the
    field's default).  Returns None for kinds we don't perturb."""
    if isinstance(current, bool):
        return not current
    if isinstance(current, int):
        return current + 7
    if isinstance(current, float):
        return current * 1.5 + 0.125
    if isinstance(current, str):
        return current + "_x"
    if isinstance(current, tuple):
        return current + ("sentinel",)
    return None


def _perturbed(instance):
    """A copy with EVERY perturbable field moved off its current (and
    default) value, so a helper that drops a field is caught on any of
    them."""
    kw = {}
    for f in dataclasses.fields(instance):
        s = _sentinel_for(getattr(instance, f.name))
        if s is not None:
            kw[f.name] = s
    return dataclasses.replace(instance, **kw), set(kw)


# ---------------------------------------------------------------------------
# ArchConfig: .replace() totality + smoke_config identity preservation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_archconfig_replace_is_total(arch):
    """Changing one field via ``.replace`` changes that field and ONLY
    that field — nothing reverts to a default."""
    cfg = get_config(arch)
    for f in dataclasses.fields(cfg):
        sentinel = _sentinel_for(getattr(cfg, f.name))
        if sentinel is None:
            continue
        out = cfg.replace(**{f.name: sentinel})
        assert getattr(out, f.name) == sentinel
        for g in dataclasses.fields(cfg):
            if g.name != f.name:
                assert getattr(out, g.name) == getattr(cfg, g.name), \
                    f"{arch}: replace({f.name}=...) perturbed {g.name}"


@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_smoke_config_preserves_family_identity(arch):
    """``smoke_config`` shrinks capacity knobs; everything that defines
    the architecture family must survive the copy unchanged."""
    cfg = get_config(arch)
    smoke = cfg.smoke_config()
    identity = ("name", "family", "attn_kind", "mlp_kind", "norm_kind",
                "activation", "causal", "qk_norm", "norm_plus_one",
                "embed_scale", "tie_embeddings", "encoder_only",
                "input_kind", "rwkv", "router_renorm")
    for name in identity:
        assert getattr(smoke, name) == getattr(cfg, name), \
            f"{arch}: smoke_config reset {name}"
    assert smoke.num_layers < cfg.num_layers
    assert smoke.d_model < cfg.d_model


def test_shape_replace_is_total():
    s = Shape(name="decode-1", kind="decode", seq_len=128, global_batch=8)
    for f in dataclasses.fields(s):
        sentinel = _sentinel_for(getattr(s, f.name))
        out = dataclasses.replace(s, **{f.name: sentinel})
        others = [g.name for g in dataclasses.fields(s) if g.name != f.name]
        assert getattr(out, f.name) == sentinel
        assert all(getattr(out, g) == getattr(s, g) for g in others)


# ---------------------------------------------------------------------------
# DCConfig: scale_datacenter totality (the motivating TL004 bug)
# ---------------------------------------------------------------------------

def test_scale_datacenter_carries_every_field():
    """Scale a DCConfig whose every field is off its default; only the
    rack count and the headrooms may change.  The PR 5 bug (provision
    fractions silently reset to defaults) fails this immediately."""
    src, perturbed = _perturbed(DCConfig(hw=HWProfile(name="h100")))
    assert "power_provision_frac" in perturbed  # the original casualty
    scaled = scale_datacenter(src, oversub=0.4)
    expect_changed = {"racks_per_row", "power_headroom",
                      "airflow_headroom"}
    for f in dataclasses.fields(DCConfig):
        if f.name in expect_changed:
            assert getattr(scaled, f.name) != getattr(src, f.name)
        else:
            assert getattr(scaled, f.name) == getattr(src, f.name), \
                f"scale_datacenter dropped {f.name}"
    # capacity grew; envelopes did not
    assert scaled.n_servers > src.n_servers
    assert scaled.power_headroom * scaled.racks_per_row == pytest.approx(
        src.power_headroom * src.racks_per_row)


def test_scale_datacenter_zero_oversub_is_identity():
    src, _ = _perturbed(DCConfig())
    assert scale_datacenter(src, 0.0) == src


# ---------------------------------------------------------------------------
# RegionSpec -> SimConfig forwarding (FleetSim's per-region copy)
# ---------------------------------------------------------------------------

def test_fleet_forwards_region_spec_fields():
    """The per-region ``SimConfig`` carries the spec's dc and the fleet's
    shared knobs — a dropped forward would revert them to SimConfig
    defaults (this is how ``control``/``iaas_only_capping`` went missing
    before tapaslint TL004)."""
    dc = DCConfig(n_rows=2, racks_per_row=3, servers_per_rack=2, seed=9)
    cfg = FleetConfig(
        regions=(RegionSpec("east", dc=dc, wan_rtt_ms=10.0,
                            iaas_only_capping=True),),
        horizon_h=3.0, tick_min=15.0, seed=4, saas_fraction=0.41)
    sim = FleetSim(cfg).sims["east"]
    assert sim.cfg.dc == dc
    assert sim.cfg.horizon_h == 3.0
    assert sim.cfg.tick_min == 15.0
    assert sim.cfg.seed == 4
    assert sim.cfg.saas_fraction == 0.41
    assert sim.cfg.iaas_only_capping is True
