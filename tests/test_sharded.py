"""Tensor-sharded paged pool + fleet-of-engines coverage.

The multi-device checks (LSE-combine parity vs the unsharded oracle,
bit-identical streams across a live ``set_shards``, per-shard pool
invariants, a sharded ``EngineFleet``) need more than the session's
single pinned CPU device, so they run ``tests/_sharded_parity_main.py``
in a subprocess with ``--xla_force_host_platform_device_count=4``; this
module asserts on its ok-lines and covers everything that works on one
device in-process: the shard-compat validation, the LSE-outputs Pallas
kernel, and the batched pump across 100 simulated servers.
"""
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_sharded_parity_multi_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_ROOT / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, str(_ROOT / "tests" / "_sharded_parity_main.py")],
        capture_output=True, text=True, env=env, timeout=1200)
    assert proc.returncode == 0, \
        f"sharded parity subprocess failed:\n{proc.stdout}\n{proc.stderr}"
    for name in ("core_parity", "engine_streams", "pool_invariants",
                 "set_shards", "sharded_fleet"):
        assert f"ok {name}" in proc.stdout, \
            f"missing check {name!r}:\n{proc.stdout}"
    assert "ALL_OK" in proc.stdout


def test_shard_compat_validation():
    from repro.configs.base import get_config
    from repro.serving import shard_compat

    cfg = get_config("llama2-7b").smoke_config()   # n_kv_heads == 2
    assert shard_compat(1, cfg) is None
    err = shard_compat(4, cfg)
    assert err is not None and "kv" in err.lower()
    # degree above the visible device budget is the engine's (not the
    # config's) problem; the config check is purely about head counts
    assert shard_compat(2, cfg) is None


def test_engine_spec_rejects_unshardable_variant():
    from repro.configs.base import get_config
    from repro.serving import EngineSpec

    cfg = get_config("llama2-7b").smoke_config()
    bad = cfg.replace(n_kv_heads=3, name="odd-kv")
    spec = EngineSpec(cfg, shards=2, variants=(("odd", bad),))
    with pytest.raises(ValueError, match="odd"):
        spec.validate()


def test_paged_decode_lse_kernel_matches_full_pool():
    """Per-shard LSE kernel outputs merge exactly to the full-pool kernel
    (the TPU-kernel counterpart of ``_paged_decode_core``'s psum merge)."""
    import jax.numpy as jnp
    from repro.kernels.ops import (combine_lse, paged_decode_attention,
                                   paged_decode_attention_lse)

    rng = np.random.default_rng(0)
    B, H, K, D, bs, nb, T = 3, 8, 4, 16, 8, 16, 4
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((nb, bs, K, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((nb, bs, K, D)), jnp.float32)
    bt = jnp.asarray(rng.permutation(np.arange(1, nb))[:B * T]
                     .reshape(B, T), jnp.int32)
    pos = jnp.asarray([5, 17, 30], jnp.int32)
    ref = paged_decode_attention(q, kp, vp, bt, pos)
    for shards in (2, 4):
        nb_loc = nb // shards
        os_, lses = [], []
        for r in range(shards):
            local = bt - r * nb_loc
            owned = ((local >= 0) & (local < nb_loc)).astype(jnp.int32)
            safe = jnp.clip(local, 0, nb_loc - 1)
            o, lse = paged_decode_attention_lse(
                q, kp[r * nb_loc:(r + 1) * nb_loc],
                vp[r * nb_loc:(r + 1) * nb_loc], safe, pos, owned)
            os_.append(o)
            lses.append(lse)
        got = combine_lse(jnp.stack(os_), jnp.stack(lses))
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 1e-6, f"shards={shards}: max err {err}"


def _smoke_spec(**kw):
    from repro.configs.base import get_config
    from repro.serving import EngineSpec
    cfg = get_config("llama2-7b").smoke_config()
    return EngineSpec(cfg, max_seq=64, n_slots=4, block_size=8, **kw)


def test_fleet_batched_pump_fairness_100_servers():
    """100 simulated servers on two engines sharing one weight copy:
    every server gets service and equal load means near-equal tokens."""
    from repro.serving import EngineFleet

    fleet = EngineFleet(_smoke_spec(), n_engines=2, steps_per_tick=8,
                        backend_kw=dict(requests_per_load=1.0, prompt_len=4,
                                        max_new_tokens=2))
    backends = [fleet.make_backend() for _ in range(100)]
    p0 = fleet.engines[0].variants["full"][1]
    assert all(e.variants["full"][1] is p0 for e in fleet.engines)
    for tick in range(2):
        for bk in backends:
            assert bk.pump(now=float(tick) / 6.0, load=1.0) == 0
        fleet.flush(now=float(tick) / 6.0)
    fleet.drain(now_h=1.0, max_steps=2000)
    tokens = np.array([sum(len(r.output) for r in bk.issued)
                       for bk in backends], float)
    assert (tokens > 0).all(), "a pumped server was never served"
    cov = float(tokens.std() / tokens.mean())
    assert cov <= 0.25, f"per-server token CoV too high: {cov:.3f}"
    assert fleet.flushes == 2


def test_cluster_sim_flushes_fleet_backends():
    """ClusterSim's two-phase sync: fleet backends submit at pump time and
    the simulator flushes each distinct fleet once per tick, reporting
    engine-measured goodput for the attached servers."""
    from repro.core.datacenter import DCConfig
    from repro.core.simulator import TAPAS, ClusterSim, SimConfig
    from repro.serving import EngineFleet

    fleet = EngineFleet(_smoke_spec(), n_engines=2, steps_per_tick=4,
                        backend_kw=dict(requests_per_load=3.0, prompt_len=4,
                                        max_new_tokens=2))
    sim = ClusterSim(SimConfig(
        dc=DCConfig(n_rows=2, racks_per_row=2, servers_per_rack=4),
        horizon_h=3.0, tick_min=10.0, seed=3, policy=TAPAS,
        occupancy=0.95, demand_scale=1.0))
    attached = {}
    measured = 0
    while sim.tick < sim.ticks:
        st = sim.step()
        for srv in np.flatnonzero(st.kind == 2):
            if int(srv) not in attached:
                bk = fleet.make_backend()
                sim.attach_backend(int(srv), bk)
                attached[int(srv)] = bk
        measured += sum(1 for srv in attached
                        if st.measured_goodput.get(srv, 0.0) > 0.0)
    assert attached, "drill placed no SaaS servers"
    assert fleet.flushes > 0, "simulator never flushed the fleet"
    assert measured > 0, "no attached server reported measured goodput"
    fleet.drain(now_h=2.0)
    assert any(len(r.output) > 0 for bk in attached.values()
               for r in bk.issued)
