import os
import sys

# keep the default 1-device view for smoke tests/benches (the dry-run sets
# its own 512-device flag in-process before importing jax)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
