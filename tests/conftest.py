import os
import sys

import pytest

# keep the default 1-device view for smoke tests/benches (the dry-run sets
# its own 512-device flag in-process before importing jax)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(autouse=True)
def _tapaslint_runtime_guards(request):
    """Runtime teeth for the tapaslint invariants (see
    ``repro.analysis.lint.runtime``): kernel / engine-hot-path test
    modules opt in with ``pytestmark = pytest.mark.leakcheck`` (tracer
    leaks fail at the leak site) or ``pytest.mark.hotpath_guard``
    (additionally, any implicit host<->device transfer fails — inputs
    must be staged with ``jax.device_put`` before the guarded work)."""
    hot = request.node.get_closest_marker("hotpath_guard")
    leak = hot or request.node.get_closest_marker("leakcheck")
    if not leak:
        yield
        return
    from repro.analysis.lint import runtime as rt
    if hot:
        with rt.hot_path_guard():
            yield
    else:
        with rt.no_leaked_tracers():
            yield
