"""Multi-device half of tests/test_sharded.py.

Runs in a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(the in-process test session pins a single CPU device; device count is
fixed at jax import, so sharded checks need their own interpreter).
Prints one "ok <name>" line per passing check and exits nonzero on the
first failure — the parent test asserts on the ok-lines.
"""
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in flags:
    os.environ["XLA_FLAGS"] = \
        (flags + " --xla_force_host_platform_device_count=4").strip()

import numpy as np          # noqa: E402
import jax                  # noqa: E402
import jax.numpy as jnp     # noqa: E402
from functools import partial               # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs.base import get_config                    # noqa: E402
from repro.models.attention import (_paged_decode_core,      # noqa: E402
                                    _paged_prefill_core)
from repro.models.sharding import shard_map_or_call          # noqa: E402
from repro.serving import EngineSpec, serving_plan           # noqa: E402
from repro.serving.request import Request                    # noqa: E402


def ok(name):
    print(f"ok {name}", flush=True)


def check_core_parity():
    """Sharded decode/prefill cores match the unsharded oracle to 1e-6
    at shard in {2, 4}; pool scatters are bit-exact."""
    rng = np.random.default_rng(0)
    B, H, K, hd, bs, T = 3, 4, 2, 16, 8, 4
    kv_idx = jnp.asarray(np.arange(H) % K)
    for shards in (2, 4):
        nb = shards * 4
        q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(nb, bs, K, hd)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(nb, bs, K, hd)), jnp.float32)
        tables = jnp.asarray(
            rng.permutation(np.arange(1, nb))[:B * T].reshape(B, T)
            if nb - 1 >= B * T else rng.integers(1, nb, (B, T)), jnp.int32)
        positions = jnp.asarray([13, 7, 24], jnp.int32)
        kn = jnp.asarray(rng.normal(size=(B, K, hd)), jnp.float32)
        vn = jnp.asarray(rng.normal(size=(B, K, hd)), jnp.float32)
        core = partial(_paged_decode_core, scale=0.25, kv_idx=kv_idx)
        o_ref, kp_ref, vp_ref = core(None, q, kp, vp, tables, positions,
                                     kn, vn)
        plan = serving_plan(shards, param_dtype=jnp.float32)
        o_s, kp_s, vp_s = shard_map_or_call(
            plan, core,
            (P(None), P("model"), P("model"), P(None), P(None), P(None),
             P(None)),
            (P(None), P("model"), P("model")),
            q, kp, vp, tables, positions, kn, vn)
        assert float(jnp.max(jnp.abs(o_s - o_ref))) < 1e-6, shards
        assert float(jnp.max(jnp.abs(kp_s - kp_ref))) == 0.0
        assert float(jnp.max(jnp.abs(vp_s - vp_ref))) == 0.0

        C = 8
        qf = jnp.asarray(rng.normal(size=(B, C, H, hd)), jnp.float32)
        kf = jnp.asarray(rng.normal(size=(B, C, K, hd)), jnp.float32)
        vf = jnp.asarray(rng.normal(size=(B, C, K, hd)), jnp.float32)
        starts = jnp.asarray([0, 5, 11], jnp.int32)
        lengths = jnp.asarray([8, 6, 3], jnp.int32)
        coreP = partial(_paged_prefill_core, scale=0.25, kv_idx=kv_idx)
        o_ref, kp_ref, vp_ref = coreP(None, qf, kp, vp, tables, starts,
                                      lengths, kf, vf)
        o_s, kp_s, vp_s = shard_map_or_call(
            plan, coreP,
            (P(None), P("model"), P("model"), P(None), P(None), P(None),
             P(None), P(None)),
            (P(None), P("model"), P("model")),
            qf, kp, vp, tables, starts, lengths, kf, vf)
        assert float(jnp.max(jnp.abs(o_s - o_ref))) < 1e-6, shards
        assert float(jnp.max(jnp.abs(kp_s - kp_ref))) == 0.0
        assert float(jnp.max(jnp.abs(vp_s - vp_ref))) == 0.0
    ok("core_parity")


def _run_streams(eng, n=3, new=12):
    reqs = [Request(prompt=[3 + i, 7, 11, 13 + i], max_new_tokens=new)
            for i in range(n)]
    for r in reqs:
        eng.submit(r)
    for _ in range(80):
        if not (eng.queue or eng.active or eng.prefilling):
            break
        eng.step()
    return [list(r.output) for r in reqs]


def check_engine_streams():
    """End-to-end: a shards=2 engine reproduces the shards=1 greedy
    streams on the smoke config (same seed, same requests)."""
    cfg = get_config("llama2-7b").smoke_config()
    base = dict(max_seq=64, n_slots=4, block_size=8, seed=0)
    ref = _run_streams(EngineSpec(cfg, shards=1, **base).build())
    s2 = _run_streams(EngineSpec(cfg, shards=2, **base).build())
    assert ref == s2, (ref, s2)
    assert all(len(o) == 12 for o in ref)
    ok("engine_streams")


def check_pool_invariants():
    """Free-list/refcount invariants hold with a sharded pool: blocks
    allocated on submit are returned on completion, per shard stripe."""
    cfg = get_config("llama2-7b").smoke_config()
    eng = EngineSpec(cfg, shards=2, max_seq=64, n_slots=4,
                     block_size=8, seed=0).build()
    pool = eng.pool
    assert pool.n_blocks % pool.shards == 0
    free0 = len(pool.free_blocks)
    lanes0 = len(pool.free_lanes)
    _run_streams(eng)
    assert len(pool.free_blocks) == free0, (free0, len(pool.free_blocks))
    assert len(pool.free_lanes) == lanes0
    assert int(pool.ref[1:].sum()) == 0          # block 0 is the parking block
    assert sorted(pool.free_blocks) == list(range(1, pool.n_blocks))
    ok("pool_invariants")


def check_set_shards():
    """Live reshard: params transfer verbatim and streams still match;
    incompatible degrees reject with a reason instead of crashing."""
    cfg = get_config("llama2-7b").smoke_config()   # n_kv_heads=2
    base = dict(max_seq=64, n_slots=4, block_size=8, seed=0)
    ref = _run_streams(EngineSpec(cfg, shards=1, **base).build())
    eng = EngineSpec(cfg, shards=1, **base).build()
    assert eng.can_shard(2) is None
    eng.set_shards(2)
    assert eng.shards == 2 and eng.stats.shard_swaps == 1
    assert _run_streams(eng) == ref
    assert eng.can_shard(4) is not None       # n_kv_heads=2 % 4 != 0
    assert eng.can_shard(100) is not None     # more shards than devices
    try:
        eng.set_shards(4)
    except ValueError:
        pass
    else:
        raise AssertionError("set_shards(4) should reject on kv heads")
    assert eng.shards == 2                    # unchanged after rejection
    ok("set_shards")


def check_sharded_fleet():
    """An EngineFleet of sharded engines serves a pumped workload."""
    from repro.serving import EngineFleet
    cfg = get_config("llama2-7b").smoke_config()
    spec = EngineSpec(cfg, shards=2, max_seq=64, n_slots=4, block_size=8)
    fleet = EngineFleet(spec, n_engines=2, steps_per_tick=3)
    bks = [fleet.make_backend() for _ in range(8)]
    for t in range(3):
        for bk in bks:
            bk.pump(now=float(t), load=1.0)
        fleet.flush(now=float(t))
    fleet.drain(now_h=3.0)
    per_srv = [sum(len(r.output) for r in bk.issued) for bk in bks]
    assert all(n > 0 for n in per_srv), per_srv
    ok("sharded_fleet")


if __name__ == "__main__":
    assert jax.device_count() >= 4, jax.device_count()
    check_core_parity()
    check_engine_streams()
    check_pool_invariants()
    check_set_shards()
    check_sharded_fleet()
    print("ALL_OK", flush=True)
    sys.exit(0)
