"""End-to-end behaviour: training convergence, serving engine, checkpoint
restart (fault tolerance), elastic re-meshing, launch drivers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.elastic import plan_remesh
from repro.models import build_model, local_plan
from repro.serving import Engine, EngineKnobs, Request
from repro.training.checkpoint import (latest_step, restore_checkpoint,
                                       save_checkpoint)
from repro.training.data import DataConfig, TokenPipeline
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_opt_state, make_train_step

# whole-module: end-to-end training/serving runs (CI sim job)
pytestmark = pytest.mark.slow


def test_training_reduces_loss():
    from repro.launch.train import main
    out = main(["--arch", "qwen3-1.7b", "--smoke", "--steps", "15",
                "--batch", "8", "--seq", "64", "--lr", "3e-3"])
    assert out["last_loss"] < out["first_loss"] - 0.1


def test_serve_driver_completes_requests():
    from repro.launch.serve import main
    out = main(["--arch", "llama2-7b", "--smoke", "--requests", "5",
                "--slots", "3", "--max-new", "8"])
    assert out["completed"] == 5
    assert out["decode_tokens"] > 0


def test_engine_continuous_batching():
    cfg = get_config("llama2-7b").smoke_config()
    model = build_model(cfg, local_plan(param_dtype=jnp.bfloat16))
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, max_seq=64, n_slots=2,
                 knobs=EngineKnobs(max_batch=2))
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(prompt=list(rng.integers(0, cfg.vocab_size, 6)),
                           max_new_tokens=4, customer="custA"))
    stats = eng.run()
    assert len(stats.completed) == 5
    for r in stats.completed:
        assert all(0 <= t < cfg.vocab_size for t in r.output)


def test_engine_variant_swap():
    """Instance Configurator's model-size knob: swap to a smaller variant."""
    cfg_big = get_config("llama2-7b").smoke_config()
    cfg_small = cfg_big.replace(num_layers=1, d_ff=64, name="llama2-tiny")
    plan = local_plan(param_dtype=jnp.bfloat16)
    m_big = build_model(cfg_big, plan)
    m_small = build_model(cfg_small, plan)
    eng = Engine(m_big, m_big.init(jax.random.PRNGKey(0)), max_seq=64,
                 n_slots=2)
    eng.add_variant("small", m_small, m_small.init(jax.random.PRNGKey(1)))
    eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=3))
    eng.run()
    eng.set_variant("small")
    eng.submit(Request(prompt=[4, 5, 6], max_new_tokens=3))
    stats = eng.run()
    assert len(stats.completed) == 2


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("qwen3-1.7b").smoke_config()
    model = build_model(cfg, local_plan(param_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    save_checkpoint(tmp_path, 7, (params, opt), meta={"arch": cfg.name})
    assert latest_step(tmp_path) == 7
    (p2, o2), manifest = restore_checkpoint(tmp_path, (params, opt))
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_resume_deterministic(tmp_path):
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    cfg = get_config("deepseek-7b").smoke_config()
    model = build_model(cfg, local_plan(param_dtype=jnp.float32))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(make_train_step(model, opt_cfg))

    def run(n_start, n_end, params, opt, pipe):
        m = None
        for _ in range(n_start, n_end):
            x, y = pipe.next_batch()
            params, opt, m = step(params, opt, x, y)
        return params, opt, m

    dc = DataConfig(cfg.vocab_size, 4, 32, seed=3)
    p0 = model.init(jax.random.PRNGKey(0))
    o0 = init_opt_state(p0)
    pa, oa, ma = run(0, 6, p0, o0, TokenPipeline(dc))

    pipe = TokenPipeline(dc)
    pb, ob, _ = run(0, 3, p0, o0, pipe)
    save_checkpoint(tmp_path, 3, (pb, ob))
    (pr, onr), _ = restore_checkpoint(tmp_path, (pb, ob))
    pipe2 = TokenPipeline(dc, step=3)
    pc, oc, mc = run(3, 6, pr, onr, pipe2)
    np.testing.assert_allclose(float(ma["loss"]), float(mc["loss"]),
                               rtol=1e-4, atol=1e-5)


def test_checkpoint_atomic_ignores_torn_tmp(tmp_path):
    cfg = get_config("qwen3-1.7b").smoke_config()
    model = build_model(cfg, local_plan())
    params = model.init(jax.random.PRNGKey(0))
    save_checkpoint(tmp_path, 1, params)
    (tmp_path / ".tmp_dead").mkdir()  # simulated torn write
    p2, manifest = restore_checkpoint(tmp_path, params)
    assert manifest["step"] == 1


@pytest.mark.parametrize("survivors,expect_model,expect_data", [
    (512, 16, 32), (496, 16, 31), (256, 16, 16), (17, 16, 1), (8, 8, 1),
    (3, 2, 1),
])
def test_elastic_remesh_policy(survivors, expect_model, expect_data):
    d = plan_remesh(survivors)
    assert d.model == expect_model
    assert d.data == expect_data
    assert d.usable <= survivors
    assert d.usable == d.data * d.model


def test_data_pipeline_checkpointable():
    dc = DataConfig(vocab_size=100, batch=2, seq_len=16, seed=1)
    p1 = TokenPipeline(dc)
    b1 = [p1.next_batch() for _ in range(4)]
    p2 = TokenPipeline(dc, step=2)
    b2 = p2.next_batch()
    np.testing.assert_array_equal(np.asarray(b1[2][0]), np.asarray(b2[0]))
