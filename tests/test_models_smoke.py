"""Per-arch reduced-config smoke: one forward/train step on CPU asserting
output shapes + no NaNs (the assignment-mandated smoke tests), plus a
decode-vs-forward equivalence check per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import build_model, local_plan
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_opt_state, make_train_step

# whole-module: every test builds+jits a model (CI sim job)
pytestmark = pytest.mark.slow

ARCHS = ASSIGNED + ["llama2-7b"]


def _batch(cfg, B=2, S=32, seed=0):
    kr = jax.random.PRNGKey(seed)
    if cfg.input_kind == "embeds":
        x = jax.random.normal(kr, (B, S, cfg.d_model), jnp.bfloat16)
    else:
        x = jax.random.randint(kr, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (B, S), 0,
                                cfg.vocab_size)
    return x, labels


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).smoke_config()
    model = build_model(cfg, local_plan(param_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(model, AdamWConfig(warmup_steps=1,
                                                      total_steps=10)))
    x, y = _batch(cfg)
    params, opt, metrics = step(params, opt, x, y)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params stay finite after an update
    for leaf in jax.tree.leaves(params):
        assert jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch):
    cfg = get_config(arch).smoke_config()
    plan = local_plan(param_dtype=jnp.float32)
    model = build_model(cfg, plan)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    x, _ = _batch(cfg, B, S)
    logits = jax.jit(model.logits)(params, x)
    assert logits.shape[:2] == (B, S)
    assert logits.shape[2] >= cfg.vocab_size
    valid = logits[..., : cfg.vocab_size]
    assert jnp.all(jnp.isfinite(valid))


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_accum_matches_single(arch):
    """grad_accum=2 produces the same loss trajectory as accum=1."""
    cfg = get_config(arch).smoke_config()
    model = build_model(cfg, local_plan(param_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))
    x, y = _batch(cfg, B=4, S=16)
    opt_cfg = AdamWConfig(warmup_steps=1, total_steps=10)
    s1 = jax.jit(make_train_step(model, opt_cfg, grad_accum=1))
    s2 = jax.jit(make_train_step(model, opt_cfg, grad_accum=2))
    p1, _, m1 = s1(params, init_opt_state(params), x, y)
    p2, _, m2 = s2(params, init_opt_state(params), x, y)
    # losses are means over the same tokens; grads averaged identically
    # (MoE aux and capacity effects can differ microscopically per microbatch)
    tol = 0.05 if cfg.n_experts else 2e-3
    assert abs(float(m1["loss"]) - float(m2["loss"])) < tol


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_config(a).encoder_only])
def test_decode_matches_forward(arch):
    """Next-token logits from prefill+decode == full-sequence forward."""
    cfg = get_config(arch).smoke_config()
    plan = local_plan(param_dtype=jnp.float32)
    model = build_model(cfg, plan)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24  # > smoke SWA window (16) to exercise the ring buffer
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S + 1), 0,
                                cfg.vocab_size)
    # reference: full forward on S+1 tokens
    full = model.logits(params, tokens)

    # prefill on first S tokens
    logits_p, cache_p = model.prefill(params, tokens[:, :S])
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(full[:, S - 1], np.float32), atol=2e-2, rtol=2e-2)

    # one decode step with token S at position S, in a larger cache buffer
    bigger = model.init_cache(B, S + 8)
    grow = lambda dst, src: jax.lax.dynamic_update_slice(
        dst, src.astype(dst.dtype), (0,) * src.ndim)
    cache = jax.tree.map(grow, bigger, cache_p)
    pos = jnp.full((B,), S, jnp.int32)
    logits_d, _ = model.decode_step(params, cache, tokens[:, S], pos)
    np.testing.assert_allclose(
        np.asarray(logits_d, np.float32),
        np.asarray(full[:, S], np.float32), atol=2e-2, rtol=2e-2)
