"""Control-plane API redesign: parity with the pre-refactor monolithic
``run()``, protocol invariants through ``ControlPolicy``, scenario
validation, custom-policy plug-in, and the engine-in-the-loop backend."""
import numpy as np
import pytest

from repro.core.datacenter import DCConfig
from repro.core.oversubscribe import max_safe_oversubscription
from repro.core.scenario import (DemandSurge, FailureEvent, Scenario,
                                 VMArrival, WeatherShift)
from repro.core.simulator import (BASELINE, TAPAS, ClusterSim,
                                  CompositeControlPlane, SimConfig,
                                  build_control_policy)

DC = DCConfig(n_rows=4, racks_per_row=5, servers_per_rack=4)

# ---------------------------------------------------------------------------
# parity: the step-wise simulator reproduces the pre-refactor run()
# ---------------------------------------------------------------------------
# Captured from the monolithic ClusterSim.run() at commit 0702485 (with
# process-stable trace seeding), DC=4x5x4, horizon 18h @ 10min ticks,
# seed 0, occupancy 0.97, demand_scale 1.0.  The baseline run exercises
# the thermal-throttling path (195 events); the TAPAS run exercises
# risk-aware routing + instance reconfiguration.
# TAPAS rows re-anchored for PR 4's deterministic routing tie-break
# (equal-(risk, load) packing candidates now fill lowest-server-id first
# instead of endpoint-list insertion order); the baseline rows are
# bit-identical to the 0702485 capture.
GOLDEN = {
    "baseline": {
        "max_temp_c": 90.8908462524414,
        "p99_temp_c": 90.85484657287597,
        "peak_row_power_frac": 0.847109718589516,
        "thermal_events": 195,
        "power_events": 0,
        "thermal_capped_frac": 0.030013852547329536,
        "power_capped_frac": 0.0,
        "unserved_frac": 0.007844065393003393,
        "mean_quality": 1.0,
        "iaas_perf_impact": 0.0,
        "saas_perf_impact": 0.004380975508849042,
    },
    "tapas": {
        "max_temp_c": 82.12344360351562,
        "p99_temp_c": 82.11440078735352,
        "peak_row_power_frac": 0.7113937740726071,
        "thermal_events": 0,
        "power_events": 0,
        "thermal_capped_frac": 0.0,
        "power_capped_frac": 0.0,
        "unserved_frac": 0.034098966566621335,
        "mean_quality": 1.0,
        "iaas_perf_impact": 0.0,
        "saas_perf_impact": 0.0,
    },
}
# TAPAS under a UPS failure (legacy `failures=` channel), horizon 8h, seed 3.
GOLDEN_UPS = {
    "max_temp_c": 78.96106719970703,
    "p99_temp_c": 78.59107559204102,
    "peak_row_power_frac": 0.5865824047168652,
    "thermal_events": 0,
    "power_events": 0,
    "thermal_capped_frac": 0.0,
    "power_capped_frac": 0.0,
    "unserved_frac": 1.1239860243159007e-17,
    "mean_quality": 1.0,
    "iaas_perf_impact": 0.0,
    "saas_perf_impact": 0.0,
}

PARITY_KW = dict(dc=DC, horizon_h=18.0, tick_min=10.0, seed=0,
                 occupancy=0.97, demand_scale=1.0)


def _assert_summary(got: dict, want: dict) -> None:
    for key, ref in want.items():
        assert float(got[key]) == pytest.approx(ref, rel=1e-9, abs=1e-12), key


@pytest.mark.slow
@pytest.mark.parametrize("name,policy", [("baseline", BASELINE),
                                         ("tapas", TAPAS)])
def test_parity_with_prerefactor_run(name, policy):
    res = ClusterSim(SimConfig(policy=policy, **PARITY_KW)).run()
    _assert_summary(res.summary(), GOLDEN[name])


def test_parity_with_failure_scenario():
    ev = FailureEvent(kind="ups", start_h=4.0, end_h=6.0)
    res = ClusterSim(SimConfig(dc=DC, horizon_h=8.0, tick_min=10.0, seed=3,
                               policy=TAPAS, occupancy=0.97,
                               demand_scale=1.0, failures=(ev,))).run()
    _assert_summary(res.summary(), GOLDEN_UPS)


@pytest.mark.slow
def test_stepwise_drive_equals_run():
    """Externally driving step() tick-by-tick == run(), and reset() makes
    a second run deterministic."""
    kw = dict(dc=DC, horizon_h=6.0, tick_min=10.0, seed=2,
              occupancy=0.95, demand_scale=0.98)
    ref = ClusterSim(SimConfig(policy=TAPAS, **kw)).run()
    sim = ClusterSim(SimConfig(policy=TAPAS, **kw))
    states = []
    while sim.tick < sim.ticks:
        states.append(sim.step())
    assert len(states) == sim.ticks
    _assert_summary(sim.result().summary(), ref.summary())
    # per-tick telemetry is populated on every state
    for st in states:
        assert st.risk is not None and st.risk.shape == (DC.n_servers,)
        assert st.row_power_frac is not None
    # rerun after reset reproduces the same result
    sim.reset()
    _assert_summary(sim.run().summary(), ref.summary())


# ---------------------------------------------------------------------------
# protocol invariants through ControlPolicy
# ---------------------------------------------------------------------------

class SpyPolicy(CompositeControlPlane):
    """Wraps the TAPAS control plane and asserts protocol invariants on
    every decision it makes."""

    def __init__(self, inner: CompositeControlPlane):
        super().__init__(inner.placement, inner.routing, inner.reconfig)
        self.live: set = set()
        self.placements = 0
        self.routes = 0

    def place(self, state, vm):
        empty_before = state.kind.copy() == 0
        srv = super().place(state, vm)
        if srv is not None:
            # no placement on an occupied server, ever
            assert empty_before[srv], f"server {srv} double-booked"
            assert srv not in self.live
            self.live.add(srv)
            self.placements += 1
        return srv

    def release(self, state, server):
        self.live.discard(server)
        super().release(state, server)

    def route(self, state, endpoint, demand):
        out = super().route(state, endpoint, demand)
        # demand conservation: routed + unserved == demand
        np.testing.assert_allclose(out.load.sum() + out.unserved, demand,
                                   rtol=1e-6, atol=1e-6)
        assert (out.load >= -1e-9).all()
        # routed load never exceeds the per-server capacity the state
        # telemetry implies (paused -> 0; else goodput-fraction x freq cap)
        for i, srv in enumerate(out.servers):
            inst = state.instances[int(srv)]
            cap = (0.0 if inst.paused else
                   (inst.entry.goodput / state.nominal.goodput)
                   * state.freq_cap[srv])
            assert out.load[i] <= cap + 1e-6
        self.routes += 1
        return out


def test_protocol_invariants_under_tapas():
    kw = dict(dc=DC, horizon_h=8.0, tick_min=10.0, seed=1,
              occupancy=0.97, demand_scale=1.0)
    spy = SpyPolicy(build_control_policy(TAPAS, tick_s=600.0, seed=1))
    sim = ClusterSim(SimConfig(policy=TAPAS, control=spy, **kw))
    res = sim.run()
    assert spy.placements > 0
    assert spy.routes > 0
    assert np.isfinite(res.max_gpu_temp_c).all()


def test_custom_policy_plugs_in():
    """A user-defined ControlPolicy drives the sim through SimConfig."""

    class ColdestFirst(CompositeControlPlane):
        """Places every VM on the coldest empty server."""

        def place(self, state, vm):
            from repro.core.traces import predict_peak_util
            empty = np.flatnonzero(state.kind == 0)
            if empty.size == 0:
                return None
            t_peak = self.placement.allocator._peak_temp(state.alloc, 1.0)
            srv = int(empty[np.argmin(t_peak[empty])])
            state.alloc.place(srv, vm, predict_peak_util(vm, seed=state.seed))
            return srv

    inner = build_control_policy(TAPAS, tick_s=600.0, seed=0)
    sim = ClusterSim(SimConfig(dc=DC, horizon_h=4.0, tick_min=10.0, seed=0,
                               policy=TAPAS, control=ColdestFirst(
                                   inner.placement, inner.routing,
                                   inner.reconfig)))
    res = sim.run()
    assert (res.max_gpu_temp_c > 0).any()


# ---------------------------------------------------------------------------
# scenario validation + composition
# ---------------------------------------------------------------------------

def test_custom_policy_factory_resets_deterministically():
    """A factory control= is rebuilt on reset(), so run() twice agrees."""
    kw = dict(dc=DC, horizon_h=4.0, tick_min=10.0, seed=5, policy=TAPAS,
              control=lambda: build_control_policy(TAPAS, tick_s=600.0,
                                                   seed=5))
    sim = ClusterSim(SimConfig(**kw))
    r1 = sim.run().summary()
    r2 = sim.run().summary()
    _assert_summary(r2, r1)


def test_failure_target_validated_against_topology():
    ev = FailureEvent(kind="ahu", start_h=1.0, end_h=2.0,
                      target=DC.n_rows)   # aisles = rows // 2 -> out of range
    with pytest.raises(ValueError, match="aisle"):
        ClusterSim(SimConfig(dc=DC, policy=TAPAS, failures=(ev,)))


def test_failure_kind_validated_at_construction():
    with pytest.raises(ValueError, match="upss"):
        FailureEvent(kind="upss", start_h=1.0, end_h=2.0)
    with pytest.raises(ValueError):
        FailureEvent(kind="ups", start_h=2.0, end_h=2.0)  # empty window
    with pytest.raises(ValueError, match="cluster-wide"):
        FailureEvent(kind="ups", start_h=1.0, end_h=2.0, target=1)
    with pytest.raises(ValueError):
        DemandSurge(start_h=0.0, end_h=1.0, scale=0.0)
    with pytest.raises(ValueError):
        VMArrival(arrival_h=0.0, kind="sass", customer="ep0", lifetime_h=1.0)
    with pytest.raises(TypeError):
        Scenario(("not-an-event",))


def test_scenario_accessors_and_composition():
    s = Scenario((FailureEvent(kind="ahu", start_h=1.0, end_h=2.0, target=1),
                  DemandSurge(start_h=0.0, end_h=4.0, scale=2.0,
                              endpoint="ep1"),
                  WeatherShift(start_h=0.0, end_h=1.0, delta_c=5.0)))
    assert [f.kind for f in s.failures(1.5)] == ["ahu"]
    assert s.failures(2.5) == []
    assert s.demand_scale(1.0, "ep1") == pytest.approx(2.0)
    assert s.demand_scale(1.0, "ep0") == pytest.approx(1.0)
    assert s.weather_delta(0.5) == pytest.approx(5.0)
    both = s + Scenario((FailureEvent(kind="ups", start_h=1.0, end_h=2.0),))
    assert len(both.failures(1.5)) == 2


@pytest.mark.slow
def test_scenario_events_shape_the_run():
    dc = DCConfig(n_rows=2, racks_per_row=3, servers_per_rack=2)
    kw = dict(dc=dc, horizon_h=4.0, tick_min=10.0, seed=4, policy=BASELINE,
              occupancy=0.9, demand_scale=0.9)
    calm = ClusterSim(SimConfig(**kw)).run()
    hot = ClusterSim(SimConfig(scenario=Scenario((
        WeatherShift(start_h=0.0, end_h=4.0, delta_c=12.0),)), **kw)).run()
    assert hot.max_gpu_temp_c.max() > calm.max_gpu_temp_c.max()
    # scripted VM arrivals join the workload (new endpoint appears)
    sim = ClusterSim(SimConfig(scenario=Scenario((
        VMArrival(arrival_h=0.0, kind="saas", customer="ep-scripted",
                  lifetime_h=10.0),)), **kw))
    assert "ep-scripted" in sim.work.endpoints
    sim.run()
    assert "ep-scripted" in sim._ep_servers


def test_max_safe_oversubscription_is_contiguous():
    rows = [
        {"policy": "tapas", "oversub": 0.0,
         "thermal_capped_pct": 0.0, "power_capped_pct": 0.0},
        {"policy": "tapas", "oversub": 0.2,
         "thermal_capped_pct": 5.0, "power_capped_pct": 0.0},  # fails budget
        {"policy": "tapas", "oversub": 0.4,
         "thermal_capped_pct": 0.0, "power_capped_pct": 0.0},
    ]
    # 0.4 is individually safe but unreachable past the failing 0.2 point
    assert max_safe_oversubscription(rows, "tapas") == 0.0
    rows[1]["thermal_capped_pct"] = 0.0
    assert max_safe_oversubscription(rows, "tapas") == 0.4


# ---------------------------------------------------------------------------
# engine: set_variant preserves in-flight requests; backend knob mapping
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_engine():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import build_model, local_plan
    from repro.serving import Engine, EngineKnobs

    cfg = get_config("llama2-7b").smoke_config()
    small = cfg.replace(num_layers=1, d_ff=64, name="llama2-smaller")
    plan = local_plan(param_dtype=jnp.bfloat16)
    model = build_model(cfg, plan)
    model_small = build_model(small, plan)
    eng = Engine(model, model.init(jax.random.PRNGKey(0)), max_seq=64,
                 n_slots=2, knobs=EngineKnobs(max_batch=2))
    eng.add_variant("small", model_small,
                    model_small.init(jax.random.PRNGKey(1)))
    return eng


@pytest.mark.slow
def test_set_variant_requeues_in_flight(smoke_engine):
    from repro.serving import Request
    eng = smoke_engine
    eng.set_variant("full")        # reset from any earlier test
    eng.stats.__init__()
    for i in range(3):
        eng.submit(Request(prompt=[1 + i, 2, 3], max_new_tokens=6))
    eng.step(now=0.0)              # some requests now in flight
    assert eng.active, "test needs in-flight requests"
    n_active = len(eng.active)
    eng.set_variant("small")
    assert not eng.active
    # in-flight requests were requeued, not dropped
    assert len(eng.queue) >= n_active
    assert eng.stats.variant_swaps == 1
    assert eng.stats.preemptions == n_active
    stats = eng.run()
    done = stats.completed
    assert len(done) == 3          # every submitted request completed
    for r in done:
        assert len(r.output) == 6  # full budget despite the swap


@pytest.mark.slow
def test_engine_backend_maps_config_to_knobs(smoke_engine):
    from repro.core.profiles import ConfigPoint
    from repro.serving import EngineBackend
    eng = smoke_engine
    eng.set_variant("full")
    backend = EngineBackend(eng, variant_for_size={"70b": "full",
                                                   "7b": "small"},
                            steps_per_tick=2, max_new_tokens=2)
    backend.apply_config(ConfigPoint(freq=0.7, tp=8, batch=16, size="70b",
                                     quant="bf16"))
    assert eng.knobs.freq_scale == pytest.approx(0.7)
    assert eng.knobs.max_batch == 1          # 16 -> half of 2 lanes
    assert eng.knobs.variant == "full"
    backend.apply_config(ConfigPoint(freq=0.6, tp=8, batch=64, size="7b",
                                     quant="bf16"))
    assert eng.knobs.variant == "small"      # size knob swapped the model
    assert eng.knobs.max_batch == 2
    produced = backend.pump(now=0.0, load=1.0)
    assert produced > 0
    assert backend.measured_goodput() >= 0.0
    assert len(backend.applied) == 2
    # a reloading decision drains the engine: no admission while paused
    backend.apply_config(ConfigPoint(freq=1.0, tp=8, batch=64, size="7b",
                                     quant="bf16"), paused=True)
    assert eng.knobs.paused
    eng.run()                                  # drain in-flight work
    queued = len(eng.queue)
    assert backend.pump(now=1.0, load=2.0) == 0
    assert len(eng.queue) > queued             # demand queued, not served
    backend.apply_config(ConfigPoint(freq=1.0, tp=8, batch=64, size="7b",
                                     quant="bf16"), paused=False)
    assert backend.pump(now=2.0, load=0.0) > 0  # queue drains again
