"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes.
All kernels run in interpret mode (exact kernel-body execution on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# whole-module: tracer escapes fail at the leak site (tapaslint runtime)
pytestmark = pytest.mark.leakcheck


def arr(rng, *s, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(s), dtype)


@pytest.mark.parametrize("B,H,K,S,D,dtype", [
    (1, 4, 4, 128, 64, jnp.float32),     # MHA
    (2, 8, 2, 256, 64, jnp.float32),     # GQA 4:1
    (1, 4, 1, 128, 128, jnp.float32),    # MQA
    (1, 2, 2, 128, 64, jnp.bfloat16),    # bf16 inputs
])
def test_flash_attention_sweep(B, H, K, S, D, dtype):
    rng = np.random.default_rng(B * 100 + H)
    q, k, v = arr(rng, B, H, S, D, dtype=dtype), arr(rng, B, K, S, D, dtype=dtype), \
        arr(rng, B, K, S, D, dtype=dtype)
    o = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    o_ref = ref.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_windowed():
    rng = np.random.default_rng(7)
    q, k, v = (arr(rng, 1, 4, 256, 64) for _ in range(3))
    o = ops.flash_attention(q, k, v, window=64, block_q=64, block_k=64)
    o_ref = ref.flash_attention_ref(q, k, v, window=64)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5)


@pytest.mark.parametrize("B,H,K,S,D", [
    (2, 8, 2, 256, 64),
    (1, 4, 4, 128, 128),
    (3, 4, 1, 512, 64),
])
def test_decode_attention_sweep(B, H, K, S, D):
    rng = np.random.default_rng(B + S)
    q = arr(rng, B, H, D)
    k, v = arr(rng, B, S, K, D), arr(rng, B, S, K, D)
    pos = jnp.asarray(rng.integers(0, S, B), jnp.int32)
    o = ops.decode_attention(q, k, v, pos, block_k=64)
    o_ref = ref.decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5)


def test_decode_attention_masks_future():
    """Only cache entries <= position may contribute."""
    rng = np.random.default_rng(0)
    q = arr(rng, 1, 2, 32)
    k, v = arr(rng, 1, 128, 2, 32), arr(rng, 1, 128, 2, 32)
    pos = jnp.asarray([5], jnp.int32)
    o1 = ops.decode_attention(q, k, v, pos, block_k=32)
    k2 = k.at[:, 6:].set(999.0)  # poison the future
    v2 = v.at[:, 6:].set(999.0)
    o2 = ops.decode_attention(q, k2, v2, pos, block_k=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


@pytest.mark.parametrize("B,T,H,D,bt", [
    (1, 64, 2, 32, 16),
    (2, 128, 4, 64, 64),
])
def test_rwkv6_wkv_sweep(B, T, H, D, bt):
    rng = np.random.default_rng(T)
    r, k, v = (arr(rng, B, T, H, D) for _ in range(3))
    w = jnp.asarray(rng.uniform(0.8, 0.999, (B, T, H, D)), jnp.float32)
    u, s0 = arr(rng, H, D), arr(rng, B, H, D, D)
    y, sf = ops.rwkv6_wkv(r, k, v, w, u, s0, block_t=bt)
    y_ref, sf_ref = ref.rwkv6_wkv_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sf_ref),
                               atol=1e-4, rtol=1e-4)


def test_rwkv6_state_carry():
    """Running two chunked calls == one long call (state handoff exact)."""
    rng = np.random.default_rng(3)
    B, T, H, D = 1, 64, 2, 32
    r, k, v = (arr(rng, B, T, H, D) for _ in range(3))
    w = jnp.asarray(rng.uniform(0.9, 0.999, (B, T, H, D)), jnp.float32)
    u = arr(rng, H, D)
    s0 = jnp.zeros((B, H, D, D))
    y_full, s_full = ops.rwkv6_wkv(r, k, v, w, u, s0, block_t=32)
    y1, s1 = ops.rwkv6_wkv(r[:, :32], k[:, :32], v[:, :32], w[:, :32], u, s0,
                           block_t=32)
    y2, s2 = ops.rwkv6_wkv(r[:, 32:], k[:, 32:], v[:, 32:], w[:, 32:], u, s1,
                           block_t=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


@pytest.mark.parametrize("M,K,N", [(128, 256, 128), (256, 512, 256)])
def test_int8_matmul_exact(M, K, N):
    rng = np.random.default_rng(M)
    xq = jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8)
    sx = jnp.asarray(rng.uniform(0.01, 0.1, (M, 1)), jnp.float32)
    sw = jnp.asarray(rng.uniform(0.01, 0.1, (1, N)), jnp.float32)
    o = ops.int8_matmul(xq, wq, sx, sw)
    o_ref = ref.int8_matmul_ref(xq, wq, sx, sw)
    np.testing.assert_array_equal(np.asarray(o, np.float32),
                                  np.asarray(o_ref, np.float32))


def test_int8_quantized_matmul_error_bound():
    """w8a8 quantization error stays within a few percent of the f32 GEMM."""
    rng = np.random.default_rng(1)
    x, w = arr(rng, 128, 256), arr(rng, 256, 128)
    o = np.asarray(ops.int8_matmul_quantized(x, w), np.float32)
    o_ref = np.asarray(x @ w, np.float32)
    rel = np.abs(o - o_ref).mean() / np.abs(o_ref).mean()
    assert rel < 0.02, rel
