"""tapaslint rule fixtures: positive (the motivating bug shape fires),
negative (the sanctioned idiom stays quiet), and suppression
(``# tapaslint: disable=TLxxx``) per rule, plus framework behavior
(two-pass registry, baseline multiset diff, line-independent keys).

Pure stdlib — drives ``lint_sources`` over in-memory files; the virtual
paths matter because rules scope by path prefix."""
import textwrap

from repro.analysis.lint import (diff_baseline, lint_sources)

SERVING = "src/repro/serving/mod.py"
MODELS = "src/repro/models/mod.py"
CORE = "src/repro/core/mod.py"


def run(files):
    if isinstance(files, str):
        files = {"src/repro/anywhere.py": files}
    return lint_sources({p: textwrap.dedent(s) for p, s in files.items()})


def codes(files):
    return [f.rule for f in run(files)]


# ---------------------------------------------------------------------------
# TL001 determinism
# ---------------------------------------------------------------------------

def test_tl001_flags_stdlib_random():
    fs = """\
    import random

    def pick(xs):
        return random.choice(xs)
    """
    assert codes(fs) == ["TL001"]


def test_tl001_flags_legacy_np_random_and_unseeded_rng():
    fs = """\
    import numpy as np

    def draw():
        a = np.random.rand(3)
        rng = np.random.default_rng()
        return a, rng
    """
    assert codes(fs) == ["TL001", "TL001"]


def test_tl001_flags_hash_and_set_iteration():
    fs = """\
    def seed_of(name, servers):
        for s in set(servers):
            yield hash(name) ^ s
    """
    assert codes(fs) == ["TL001", "TL001"]


def test_tl001_quiet_on_sanctioned_idioms():
    fs = """\
    import zlib
    import numpy as np

    def draw(seed, servers):
        rng = np.random.default_rng(seed)
        for s in sorted(set(servers)):
            yield zlib.crc32(s.encode()), rng.integers(10)
    """
    assert codes(fs) == []


def test_tl001_line_suppression():
    fs = """\
    def seed_of(name):
        return hash(name)  # tapaslint: disable=TL001
    """
    assert codes(fs) == []


# ---------------------------------------------------------------------------
# TL002 host-sync leak (scoped to serving/models/kernels)
# ---------------------------------------------------------------------------

def test_tl002_flags_item_anywhere_in_scope():
    fs = {SERVING: """\
    def schedule(scores):
        return scores[0].item()
    """}
    assert codes(fs) == ["TL002"]


def test_tl002_flags_coercions_inside_traced_fn():
    fs = {MODELS: """\
    import numpy as np

    def decode_step(params, x):
        n = float(x)
        return np.asarray(x) + n
    """}
    assert codes(fs) == ["TL002", "TL002"]


def test_tl002_quiet_outside_scope_and_outside_trace():
    # same coercions in core/ (out of scope) and in an untraced serving
    # helper (np.asarray there is the sanctioned per-horizon readback)
    fs = {CORE: """\
    def decode_step(params, x):
        return float(x)
    """, SERVING: """\
    import numpy as np

    def drain(dev):
        return np.asarray(dev)
    """}
    assert codes(fs) == []


def test_tl002_suppression_on_def_line():
    fs = {SERVING: """\
    def stats_probe(x):  # tapaslint: disable=TL002
        return x.item()
    """}
    assert codes(fs) == []


# ---------------------------------------------------------------------------
# TL003 retrace hazard (scoped to serving/models/kernels)
# ---------------------------------------------------------------------------

def test_tl003_flags_branch_on_runtime_param():
    fs = {MODELS: """\
    import jax

    @jax.jit
    def f(x):
        if x > 0:
            return x
        return -x
    """}
    assert codes(fs) == ["TL003"]


def test_tl003_quiet_on_static_branches():
    fs = {MODELS: """\
    import jax

    @jax.jit
    def f(x, cfg, causal: bool, w=None):
        if causal:            # annotated scalar: static by convention
            x = x + 1
        if w is None:         # structure check
            x = x + 2
        if cfg.deep:          # config: static
            x = x + 3
        if x.ndim > 1:        # shape probe: trace-time constant
            x = x + 4
        return x
    """}
    assert codes(fs) == []


def test_tl003_flags_computed_static_kwarg_at_jit_callsite():
    fs = {SERVING: """\
    class Engine:
        def drain(self, toks, left):
            return self._decode_multi_jit(
                toks, num_steps=min(self.horizon, left))
    """}
    fnd = run(fs)
    assert [f.rule for f in fnd] == ["TL003"]
    assert "num_steps" in fnd[0].message


def test_tl003_quiet_on_stable_static_kwarg():
    fs = {SERVING: """\
    class Engine:
        def drain(self, toks):
            return self._decode_multi_jit(toks, num_steps=self.horizon)
    """}
    assert codes(fs) == []


def test_tl003_suppression():
    fs = {MODELS: """\
    import jax

    @jax.jit
    def f(x):
        if x > 0:  # tapaslint: disable=TL003
            return x
        return -x
    """}
    assert codes(fs) == []


# ---------------------------------------------------------------------------
# TL004 dataclass-copy completeness (needs the registry pass)
# ---------------------------------------------------------------------------

_CFG_DEF = """\
from dataclasses import dataclass

@dataclass
class Cfg:
    a: int
    b: int
    c: int = 0
"""


def test_tl004_flags_copy_dropping_a_field():
    fs = {CORE: _CFG_DEF, SERVING: """\
    def scale(src, k):
        return Cfg(a=src.a * k, b=src.b, c=src.c)

    def broken(src):
        return Cfg(a=src.a, b=src.b)
    """}
    fnd = run(fs)
    assert [f.rule for f in fnd] == ["TL004"]
    assert fnd[0].symbol == "broken" and "c" in fnd[0].message
    assert "dataclasses.replace(src" in fnd[0].message


def test_tl004_quiet_on_total_copy_splat_and_fresh_construction():
    fs = {CORE: _CFG_DEF, SERVING: """\
    def total(src):
        return Cfg(a=src.a, b=src.b, c=2 * src.c)

    def splat(src, over):
        return Cfg(**{**vars(src), **over})

    def fresh(a):
        return Cfg(a=a, b=0)     # not copy-shaped: no verbatim reads
    """}
    assert codes(fs) == []


def test_tl004_suppression():
    fs = {CORE: _CFG_DEF, SERVING: """\
    def partial_view(src):  # tapaslint: disable=TL004
        return Cfg(a=src.a, b=src.b)
    """}
    assert codes(fs) == []


# ---------------------------------------------------------------------------
# TL005 unit-suffix discipline (scoped to core/)
# ---------------------------------------------------------------------------

def test_tl005_flags_cross_unit_and_cross_scale_arithmetic():
    fs = {CORE: """\
    def f(temp_c, power_w, rtt_ms, wait_s):
        meaning_bug = temp_c + power_w
        scale_bug = rtt_ms - wait_s
        return meaning_bug, scale_bug
    """}
    fnd = run(fs)
    assert [f.rule for f in fnd] == ["TL005", "TL005"]
    assert "temperature with power" in fnd[0].message
    assert "different scales of time" in fnd[1].message


def test_tl005_quiet_on_same_unit_products_and_out_of_scope():
    fs = {CORE: """\
    def f(a_w, b_w, dt_h):
        return a_w + b_w, a_w * dt_h
    """, SERVING: """\
    def g(temp_c, power_w):
        return temp_c + power_w
    """}
    assert codes(fs) == []


def test_tl005_flags_suffixless_quantity_field():
    fs = {CORE: """\
    from dataclasses import dataclass

    @dataclass
    class Server:
        gpu_temp: float
        power_cap_w: float
        power_headroom: float
        thermals: object
    """}
    fnd = run(fs)
    assert [f.rule for f in fnd] == ["TL005"]
    assert "gpu_temp" in fnd[0].message


def test_tl005_file_suppression():
    fs = {CORE: """\
    # tapaslint: disable-file=TL005

    def f(temp_c, power_w):
        return temp_c + power_w
    """}
    assert codes(fs) == []


# ---------------------------------------------------------------------------
# TL006 protocol conformance (needs the registry pass)
# ---------------------------------------------------------------------------

_PROTO_DEF = """\
from typing import Protocol, runtime_checkable

@runtime_checkable
class ControlPolicy(Protocol):
    def begin_tick(self, state, now): ...
    def place(self, state, req): ...
    def route(self, state, req): ...
    def reconfigure(self, state): ...
    def release(self, state, server): ...
"""


def test_tl006_flags_near_complete_implementor_missing_method():
    fs = {CORE: _PROTO_DEF, SERVING: """\
    class AlmostPolicy:
        def begin_tick(self, state, now): ...
        def place(self, state, req): ...
        def route(self, state, req): ...
        def reconfigure(self, state): ...
    """}
    fnd = run(fs)
    assert [f.rule for f in fnd] == ["TL006"]
    assert "release" in fnd[0].message


def test_tl006_flags_signature_drift_on_declared_implementor():
    fs = {CORE: _PROTO_DEF, SERVING: """\
    class MyPolicy(ControlPolicy):
        def begin_tick(self, state, now): ...
        def place(self, state): ...
        def route(self, state, req): ...
        def reconfigure(self, state): ...
        def release(self, state, server): ...
    """}
    fnd = run(fs)
    assert [f.rule for f in fnd] == ["TL006"]
    assert "place" in fnd[0].message


def test_tl006_flags_required_extra_param():
    fs = {CORE: _PROTO_DEF, SERVING: """\
    class EagerPolicy(ControlPolicy):
        def begin_tick(self, state, now): ...
        def place(self, state, req, budget): ...
        def route(self, state, req): ...
        def reconfigure(self, state): ...
        def release(self, state, server): ...
    """}
    fnd = run(fs)
    assert [f.rule for f in fnd] == ["TL006"]
    assert "budget" in fnd[0].message


def test_tl006_quiet_on_conforming_and_unrelated_classes():
    fs = {CORE: _PROTO_DEF, SERVING: """\
    class FullPolicy:
        def begin_tick(self, state, now): ...
        def place(self, state, req): ...
        def route(self, state, req): ...
        def reconfigure(self, state): ...
        def release(self, state, server, verbose=False): ...

    class KwargsPolicy(ControlPolicy):
        def begin_tick(self, state, now, **kw): ...
        def place(self, state, req): ...
        def route(self, state, req): ...
        def reconfigure(self, state): ...
        def release(self, state, server): ...

    class Adapter:
        # shares two hook names; below the all-but-one threshold
        def begin_tick(self, state, now): ...
        def release(self, state, server): ...
    """}
    assert codes(fs) == []


def test_tl006_suppression_on_class_line():
    fs = {CORE: _PROTO_DEF, SERVING: """\
    class Partial:  # tapaslint: disable=TL006
        def begin_tick(self, state, now): ...
        def place(self, state, req): ...
        def route(self, state, req): ...
        def reconfigure(self, state): ...
    """}
    assert codes(fs) == []


# ---------------------------------------------------------------------------
# TL007 swallowed error (scoped to serving/ and core/)
# ---------------------------------------------------------------------------

def test_tl007_flags_bare_except():
    fs = {SERVING: """\
    def drain(eng):
        try:
            eng.step()
        except:
            return 0
    """}
    fnd = run(fs)
    assert [f.rule for f in fnd] == ["TL007"]
    assert "bare 'except:'" in fnd[0].message


def test_tl007_flags_broad_swallows():
    fs = {CORE: """\
    def pump(reqs):
        for r in reqs:
            try:
                r.run()
            except Exception:
                continue
        try:
            reqs.audit()
        except (ValueError, BaseException):
            pass
        try:
            reqs.close()
        except Exception:
            ...
    """}
    assert codes(fs) == ["TL007", "TL007", "TL007"]


def test_tl007_quiet_on_narrow_or_handled_and_out_of_scope():
    fs = {SERVING: """\
    def finish(reqs, stats):
        try:
            reqs.pop()
        except KeyError:
            pass                    # narrow: an expected failure
        try:
            reqs.flush()
        except Exception:
            stats.flush_errors += 1  # broad but recorded
            raise
    """, MODELS: """\
    def load(path):
        try:
            return open(path)
        except Exception:
            pass                    # out of scope for TL007
    """}
    assert codes(fs) == []


def test_tl007_suppression():
    fs = {CORE: """\
    def probe(dev):
        try:
            return dev.read()
        except Exception:  # tapaslint: disable=TL007
            pass
    """}
    assert codes(fs) == []


# ---------------------------------------------------------------------------
# TL008 host-constant hazard (scoped to serving/models/kernels)
# ---------------------------------------------------------------------------

def test_tl008_flags_np_ctor_inside_traced_function():
    fs = {SERVING: """\
    import jax
    import numpy as np

    @jax.jit
    def decode_mask(x):
        idx = np.arange(x.shape[-1])
        return x * np.full(2, 0.5, np.float32)[idx % 2]
    """}
    fnd = run(fs)
    assert [f.rule for f in fnd] == ["TL008", "TL008"]
    assert "np.arange" in fnd[0].message
    assert "np.full" in fnd[1].message


def test_tl008_flags_captured_module_constants():
    fs = {MODELS: """\
    import jax
    import numpy as np

    FREQS = np.linspace(0.0, 1.0, 64)
    WARP = [1.0, 0.5, 0.25]

    @jax.jit
    def decode_step(x):
        return x * FREQS + WARP[0]
    """}
    fnd = run(fs)
    assert [f.rule for f in fnd] == ["TL008", "TL008"]
    assert "'FREQS'" in fnd[0].message and "np.linspace" in fnd[0].message
    assert "'WARP'" in fnd[1].message and "list" in fnd[1].message


def test_tl008_quiet_on_jnp_host_code_and_out_of_scope():
    fs = {SERVING: """\
    import jax
    import jax.numpy as jnp
    import numpy as np

    TABLE = np.zeros(8)          # host mirror: fine outside a trace

    @jax.jit
    def decode_step(x):
        return x + jnp.arange(x.shape[-1])   # jnp stays on device

    def host_pump(reqs):
        lanes = np.zeros(len(reqs), np.int32)
        return lanes, TABLE
    """, CORE: """\
    import jax
    import numpy as np

    @jax.jit
    def blend(x):
        return x * np.asarray([0.5])   # core/ is out of TL008 scope
    """}
    assert codes(fs) == []


def test_tl008_suppression():
    fs = {MODELS: """\
    import jax
    import numpy as np

    @jax.jit
    def decode_step(x):  # tapaslint: disable=TL008
        return x + np.arange(2.0)
    """}
    assert codes(fs) == []


# ---------------------------------------------------------------------------
# framework: syntax errors, baseline diff, key stability
# ---------------------------------------------------------------------------

def test_syntax_error_yields_tl000_without_aborting():
    fnd = run({"src/repro/bad.py": "def f(:\n",
               "src/repro/ok.py": "def g():\n    return hash('x')\n"})
    assert [f.rule for f in fnd] == ["TL000", "TL001"]


def test_baseline_diff_multiset_semantics():
    fnd = run({"src/repro/a.py": "def f(x):\n    return hash(x)\n"})
    keys = [f.key() for f in fnd]
    new, matched, stale = diff_baseline(fnd, keys + ["TL001 gone.py:: x"])
    assert new == [] and matched == keys
    assert stale == ["TL001 gone.py:: x"]
    new, _, _ = diff_baseline(fnd, [])
    assert [f.key() for f in new] == keys


def test_finding_key_is_line_independent():
    a = run({"src/repro/a.py": "def f(x):\n    return hash(x)\n"})
    b = run({"src/repro/a.py": "\n\n\ndef f(x):\n    return hash(x)\n"})
    assert a[0].key() == b[0].key()
    assert a[0].line != b[0].line
