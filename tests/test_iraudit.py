"""iraudit lane tests: golden op-census snapshots, invariant teeth on
synthetic entrypoints, and budget pins for the defects the audit caught.

The golden snapshots and the full-registry gate compare against
``benchmarks/BUDGET_ir.json`` and therefore skip under a jax/jaxlib
toolchain other than the one the budgets were recorded under (CI installs
the pinned pair, so there they always run).  The synthetic-entrypoint and
synthetic-HLO tests are toolchain-independent.
"""
from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import jaxlib
import numpy as np
import pytest

from repro.analysis.hlo_cost import HloModuleCost
from repro.analysis.iraudit import (ENTRYPOINTS, ENTRYPOINTS_BY_NAME,
                                    AuditContext, Entrypoint, audit_entry,
                                    census_diff, check_budgets, cost_metrics,
                                    load_budgets, run_invariants)

pytestmark = pytest.mark.slow

BUDGETS = pathlib.Path(__file__).resolve().parent.parent / "benchmarks" \
    / "BUDGET_ir.json"


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _pinned_entries():
    payload = load_budgets(BUDGETS)
    meta = payload["meta"]
    if (meta["jax"], meta["jaxlib"]) != (jax.__version__, jaxlib.__version__):
        pytest.skip(f"budgets pinned under jax {meta['jax']} / jaxlib "
                    f"{meta['jaxlib']}; running {jax.__version__} / "
                    f"{jaxlib.__version__}")
    return payload


@pytest.fixture(scope="module")
def ctx():
    return AuditContext()


@pytest.fixture(scope="module")
def audits(ctx):
    """Lazily audit registry entries once per module."""
    cache = {}

    def get(name):
        if name not in cache:
            cache[name] = audit_entry(ENTRYPOINTS_BY_NAME[name], ctx)
        return cache[name]

    return get


# ---------------------------------------------------------------------------
# golden op-census snapshots
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["decode_step_paged", "decode_spec_paged_k4"])
def test_golden_op_census(audits, name):
    """The primitive census of the decode hot paths is a golden snapshot:
    any added/removed/changed primitive fails with the diff."""
    pinned = _pinned_entries()["entries"][name]["census"]
    got = cost_metrics(audits(name))["census"]
    assert got == pinned, \
        f"op census drift for {name}: {census_diff(pinned, got)}"


def test_registry_invariants_clean_and_budgets_hold(audits):
    """The real registry: zero invariant findings, and every cost row
    within its pinned budget — the same gate CI runs.  Mesh-geometry
    entries need more devices than the single-device test session has;
    they are filtered symmetrically out of the registry sweep and the
    pinned rows (scripts/iraudit.py audits them under a forced 4-device
    view, as does tests/_sharded_parity_main.py for the numerics)."""
    pinned = _pinned_entries()
    avail = jax.device_count()
    usable = [e for e in ENTRYPOINTS if e.min_devices <= avail]
    skipped = {e.name for e in ENTRYPOINTS if e.min_devices > avail}
    pinned = {"meta": pinned["meta"],
              "entries": {k: v for k, v in pinned["entries"].items()
                          if k not in skipped}}
    rows = {}
    for e in usable:
        a = audits(e.name)
        findings = run_invariants(a)
        assert findings == [], "\n".join(str(f) for f in findings)
        rows[e.name] = cost_metrics(a)
    problems = check_budgets(rows, pinned)
    assert problems == [], "\n".join(problems)


# ---------------------------------------------------------------------------
# regression pins for the defects the audit caught
# ---------------------------------------------------------------------------

def test_bad_lane_scan_keeps_isfinite_in_bf16(audits):
    """Defect pin: the quarantine sweep once upcast every gathered pool
    view to f32 just to call isfinite (bf16->f32 is exact, the upcast
    only cost bytes).  The f32 output surface of the scan must stay 0."""
    m = cost_metrics(audits("pool_bad_lane_scan"))
    assert m["f32_out_bytes"] == 0
    pinned = _pinned_entries()["entries"]["pool_bad_lane_scan"]
    assert pinned["f32_out_bytes"] == 0


def test_horizon_flops_scale_with_steps(audits):
    """Defect pin: hlo_cost once skipped ``conditional`` branch bodies
    entirely, so the fused horizon (whose hot loop sits behind a
    lax.cond) costed ~0 FLOPs.  num_steps=4 must cost ~4x one step."""
    step = cost_metrics(audits("decode_step_paged"))["flops"]
    multi = cost_metrics(audits("decode_multi_paged_h4"))["flops"]
    assert 3.0 * step <= multi <= 6.0 * step, (step, multi)


def test_hlo_cost_counts_conditional_and_call_bodies():
    """Synthetic HLO: a dot behind ``branch_computations`` and one behind
    ``to_apply`` both count (max-cost branch; called body inline)."""
    hlo = """
%noop (p: f32[4,4]) -> f32[4,4] {
  ROOT %p = f32[4,4]{1,0} parameter(0)
}

%branch_dot (q: f32[4,4]) -> f32[4,4] {
  %q = f32[4,4]{1,0} parameter(0)
  ROOT %d = f32[4,4]{1,0} dot(%q, %q), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%called_dot (r: f32[4,4]) -> f32[4,4] {
  %r = f32[4,4]{1,0} parameter(0)
  ROOT %d2 = f32[4,4]{1,0} dot(%r, %r), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

ENTRY %main (i: s32[], x: f32[4,4]) -> f32[4,4] {
  %i = s32[] parameter(0)
  %x = f32[4,4]{1,0} parameter(1)
  %c = f32[4,4]{1,0} conditional(%i, %x, %x), branch_computations={%noop, %branch_dot}
  ROOT %call = f32[4,4]{1,0} call(%c), to_apply=%called_dot
}
"""
    cost = HloModuleCost(hlo).cost()
    # two dots at 2*4*4*4 flops each; the empty branch contributes nothing
    assert cost.flops == 2 * (2 * 4 * 4 * 4)


# ---------------------------------------------------------------------------
# invariant teeth (synthetic entrypoints; no AuditContext needed)
# ---------------------------------------------------------------------------

def _synthetic(name, fn, args, kwargs=None, **entry_kw):
    e = Entrypoint(name, "model",
                   lambda _ctx: (fn, args, kwargs or {}), **entry_kw)
    return audit_entry(e, None)


def test_ir001_flags_host_callbacks():
    def f(x):
        jax.debug.callback(lambda v: None, x)
        return x + 1.0

    audit = _synthetic("syn_cb", jax.jit(f), (_sds((4,), jnp.float32),))
    fnd = run_invariants(audit)
    assert any(f.code == "IR001" and "debug_callback" in f.message
               for f in fnd), fnd


@pytest.mark.filterwarnings(
    "ignore:Some donated buffers were not usable")
def test_ir002_flags_unconsumed_donation():
    def f(a, b):
        return a[:2] * b[:2]     # output too small to alias the donated a

    fn = jax.jit(f, donate_argnums=(0,))
    audit = _synthetic("syn_don", fn,
                       (_sds((4,), jnp.float32), _sds((4,), jnp.float32)))
    fnd = run_invariants(audit)
    assert any(f.code == "IR002" for f in fnd), fnd


def test_ir003_flags_wide_dot_inputs():
    def f(a, b):
        return a @ b

    audit = _synthetic("syn_f32dot", jax.jit(f),
                       (_sds((4, 4), jnp.float32), _sds((4, 4), jnp.float32)))
    fnd = run_invariants(audit)
    assert any(f.code == "IR003" for f in fnd), fnd
    # the same graph is clean when the registry opts it out
    waived = _synthetic("syn_f32dot_ok", jax.jit(f),
                        (_sds((4, 4), jnp.float32),
                         _sds((4, 4), jnp.float32)), f32_dot_ok=True)
    assert [f for f in run_invariants(waived) if f.code == "IR003"] == []


def test_ir004_flags_closure_constants_over_cap():
    table = np.arange(1024, dtype=np.float32)   # 4096 B closure constant

    def f(x):
        return x * table

    audit = _synthetic("syn_const", jax.jit(f),
                       (_sds((1024,), jnp.float32),), const_cap_bytes=256)
    fnd = run_invariants(audit)
    assert any(f.code == "IR004" and "4096B" in f.message
               for f in fnd), fnd


def test_clean_synthetic_has_no_findings():
    def f(a, b):
        c = (a * b).astype(jnp.bfloat16)
        return c @ c.T

    audit = _synthetic("syn_clean", jax.jit(f),
                       (_sds((4, 4), jnp.bfloat16), _sds((4, 4),
                                                         jnp.bfloat16)))
    assert run_invariants(audit) == []
