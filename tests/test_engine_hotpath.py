"""Device-resident decode hot path: fused multi-step decode parity,
prefix-shared block refcount invariants, chunked-prefill interleaving, and
the persistent device-buffer mirrors."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model, local_plan
from repro.serving import Engine, EngineKnobs, PagedCachePool, Request

# whole-module: every test drives a live jitted engine (CI sim job);
# leakcheck = tracer escapes fail at the leak site (tapaslint runtime)
pytestmark = [pytest.mark.slow, pytest.mark.leakcheck]


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama2-7b").smoke_config()
    return build_model(cfg, local_plan(param_dtype=jnp.bfloat16))


@pytest.fixture(scope="module")
def tiny_params(tiny_model):
    return tiny_model.init(jax.random.PRNGKey(0))


def _submit_load(eng, vocab, *, n_req=5, max_new=6, seed=0, shared=0,
                 stagger=0):
    rng = np.random.default_rng(seed)
    head = [int(t) for t in rng.integers(0, vocab, shared)]
    for i in range(n_req):
        plen = int(rng.integers(4, 20))
        tail = [int(t) for t in rng.integers(0, vocab, plen)]
        eng.submit(Request(prompt=head + tail,
                           max_new_tokens=max_new + stagger * i))


def _streams(stats):
    return [tuple(r.output) for r in sorted(stats.completed,
                                            key=lambda r: r.req_id)]


def _engine(model, params, **kw):
    kw.setdefault("max_seq", 64)
    kw.setdefault("n_slots", 3)
    kw.setdefault("block_size", 8)
    kw.setdefault("paged", True)
    kw.setdefault("knobs", EngineKnobs(max_batch=kw["n_slots"]))
    return Engine(model, params, **kw)


# ---------------------------------------------------------------------------
# fused multi-step decode: parity vs the per-step path
# ---------------------------------------------------------------------------

def test_fused_decode_matches_per_step_path(tiny_model, tiny_params):
    """N fused steps == N independent decode_step_paged launches: identical
    tokens and matching logits (model-level, one lane active + one parked)."""
    model, params = tiny_model, tiny_params
    vocab = model.cfg.vocab_size
    bs, T, n_lanes, max_seq = 8, 8, 2, 64
    pool = PagedCachePool(model, n_lanes, max_seq, block_size=bs)
    rng = np.random.default_rng(0)
    prompt = [int(t) for t in rng.integers(0, vocab, 11)]

    logits, cache = jax.jit(model.prefill)(
        params, jnp.asarray([prompt], jnp.int32))
    tok0 = int(jnp.argmax(logits[0, :vocab]))
    pool.insert(1, cache, 0, len(prompt))
    pool.ensure_append_blocks([1], horizon=5)

    n = 5
    # per-step reference: N sequential single-token launches
    step = jax.jit(model.decode_step_paged)
    cache_a = jax.tree.map(jnp.copy, pool.cache)
    toks_ref, tok, pos = [], tok0, len(prompt)
    tables = pool.tables()
    for _ in range(n):
        lg, cache_a = step(params, cache_a,
                           jnp.asarray([tok, 0], jnp.int32),
                           jnp.asarray([pos, 0], jnp.int32), tables)
        tok = int(jnp.argmax(lg[0, :vocab]))
        toks_ref.append(tok)
        pos += 1
    # fused: one launch, horizon N
    out = model.decode_multi_paged(
        params, jax.tree.map(jnp.copy, pool.cache),
        jnp.asarray([tok0, 0], jnp.int32),
        jnp.asarray([len(prompt), 0], jnp.int32), tables,
        jnp.asarray([True, False]), jnp.asarray([100, 0], jnp.int32),
        jnp.asarray([-1, -1], jnp.int32), num_steps=n, max_len=max_seq)
    toks_f, emitted, last_logits, (_, pos_f, act_f, _), _ = out
    assert [int(t) for t in np.asarray(toks_f)[:, 0]] == toks_ref
    assert bool(np.asarray(emitted)[:, 0].all())
    assert not np.asarray(emitted)[:, 1].any()          # parked lane silent
    assert int(np.asarray(pos_f)[0]) == len(prompt) + n
    np.testing.assert_allclose(np.asarray(last_logits[0, :vocab], np.float32),
                               np.asarray(lg[0, :vocab], np.float32),
                               atol=1e-6)


def test_engine_horizon_streams_identical(tiny_model, tiny_params):
    """Engine-level: horizon-8 fused serving produces exactly the per-step
    token streams, with ~horizon-fold fewer decode host syncs."""
    vocab = tiny_model.cfg.vocab_size
    runs = {}
    for hz in (1, 8):
        eng = _engine(tiny_model, tiny_params, horizon=hz)
        _submit_load(eng, vocab, max_new=12, stagger=2)
        stats = eng.run()
        runs[hz] = (_streams(stats), stats)
    assert runs[1][0] == runs[8][0]
    assert len(runs[8][0]) == 5
    assert runs[8][1].decode_syncs * 2 <= runs[1][1].decode_syncs


def test_fused_decode_respects_eos_and_budget(tiny_model, tiny_params):
    """Mid-horizon finishes (eos / budget) stop emission on the right token
    even though the device loop keeps spinning."""
    vocab = tiny_model.cfg.vocab_size
    eng = _engine(tiny_model, tiny_params, horizon=8)
    rng = np.random.default_rng(3)
    prompt = [int(t) for t in rng.integers(0, vocab, 9)]
    # discover the greedy stream, then replay with eos set to a mid token
    eng.submit(Request(prompt=list(prompt), max_new_tokens=10))
    free = _streams(eng.run())[0]
    eos = free[4]
    eng2 = _engine(tiny_model, tiny_params, horizon=8)
    eng2.submit(Request(prompt=list(prompt), max_new_tokens=10, eos_id=eos))
    got = _streams(eng2.run())[0]
    # stops exactly at the FIRST occurrence of the eos token
    assert got == free[: free.index(eos) + 1]


# ---------------------------------------------------------------------------
# prefix sharing: refcount invariants
# ---------------------------------------------------------------------------

def test_prefix_refcount_invariants(tiny_model):
    pool = PagedCachePool(tiny_model, n_lanes=3, max_seq=64, block_size=8)
    toks = list(range(20))                 # 2 full blocks + 4 tail tokens
    lane = pool.admit_prefill(1, len(toks), [])
    assert lane is not None
    assert pool.lengths[lane] == 0         # nothing valid until prefill
    pool.register_prefix(1, toks)
    assert len(pool.prefix_index) == 2     # only FULL blocks are published

    shared = pool.shared_prefix(toks)
    assert shared == pool.blocks_of[1][:2]
    before = pool.used_blocks
    pool.admit_prefill(2, len(toks), shared)
    # 3 blocks needed for ctx+1, two reused -> only one fresh allocation
    assert pool.used_blocks == before + 1
    assert all(pool.ref[b] == 2 for b in shared)

    # release with a live sharer keeps the shared blocks and the index
    pool.release(1)
    assert all(pool.ref[b] == 1 for b in shared)
    assert len(pool.prefix_index) == 2
    assert all(b not in pool.free_blocks for b in shared)
    # last release frees them and prunes the index
    pool.release(2)
    assert pool.used_blocks == 0
    assert not pool.prefix_index and not pool.key_of
    assert (pool.ref[1:] == 0).all()


def test_prefix_sharing_engine_streams_and_savings(tiny_model, tiny_params):
    """Prefix-shared serving yields identical tokens while prefilling
    fewer tokens (the shared head is skipped)."""
    vocab = tiny_model.cfg.vocab_size
    base = _engine(tiny_model, tiny_params)
    _submit_load(base, vocab, shared=17, max_new=6, stagger=3)
    st0 = base.run()
    shr = _engine(tiny_model, tiny_params, prefix_share=True,
                  prefill_chunk=16, horizon=4)
    _submit_load(shr, vocab, shared=17, max_new=6, stagger=3)
    st1 = shr.run()
    assert _streams(st0) == _streams(st1)
    assert shr.pool.shared_block_hits > 0
    assert st1.prefill_tokens < st0.prefill_tokens
    assert shr.pool.used_blocks == 0       # everything reclaimed


def test_pending_share_dedups_same_wave_admissions(tiny_model, tiny_params):
    """Two requests with an identical prompt head submitted in the same
    wave: the second waits on the first's in-flight prefill and attaches
    to its blocks (register-at-admit), instead of both writing the head."""
    vocab = tiny_model.cfg.vocab_size
    rng = np.random.default_rng(3)
    head = [int(t) for t in rng.integers(0, vocab, 24)]  # 3 full blocks @8
    tails = [[int(t) for t in rng.integers(0, vocab, 4 + i)]
             for i in range(3)]

    def serve(prefix_share):
        eng = _engine(tiny_model, tiny_params, max_seq=128, n_slots=4,
                      knobs=EngineKnobs(max_batch=4),
                      prefix_share=prefix_share, prefill_chunk=16)
        for t in tails:                    # one wave, identical heads
            eng.submit(Request(prompt=head + t, max_new_tokens=4))
        stats = eng.run()
        return eng, stats

    base, st0 = serve(False)
    shr, st1 = serve(True)
    assert _streams(st0) == _streams(st1)
    # the two waiters deferred admission, then attached to the 3 head
    # blocks the first request prefilled — none of them recomputed it
    assert shr.pool.pending_share_waits > 0
    assert shr.pool.shared_block_hits >= 6
    assert st1.prefill_tokens <= st0.prefill_tokens - 2 * len(head)
    assert shr.pool.used_blocks == 0       # everything reclaimed
    assert not shr.pool.pending_index and not shr.pool.pending_of


def test_pending_claims_cleared_on_release(tiny_model):
    """A preempted/failed prefill releases its pending chain-key claims so
    waiters cannot deadlock on a dead owner."""
    pool = PagedCachePool(tiny_model, n_lanes=3, max_seq=64, block_size=8)
    toks = list(range(20))                 # 2 full blocks + tail
    assert pool.admit_prefill(1, len(toks), []) is not None
    pool.register_pending(1, toks)
    assert pool.pending_shared(toks, have=0)
    pool.release(1)                        # preemption path
    assert not pool.pending_shared(toks, have=0)
    assert not pool.pending_index and not pool.pending_of


# ---------------------------------------------------------------------------
# chunked prefill: interleaving + TBT non-regression
# ---------------------------------------------------------------------------

def test_chunked_prefill_streams_identical(tiny_model, tiny_params):
    vocab = tiny_model.cfg.vocab_size
    a = _engine(tiny_model, tiny_params)
    _submit_load(a, vocab, seed=5)
    b = _engine(tiny_model, tiny_params, prefill_chunk=8)
    _submit_load(b, vocab, seed=5)
    assert _streams(a.run()) == _streams(b.run())


def test_chunked_prefill_interleaves_decode(tiny_model, tiny_params):
    """While a long prompt streams in chunk by chunk, already-active
    requests keep producing decode tokens every scheduler step (the long
    prefill never blocks decode for more than one chunk)."""
    vocab = tiny_model.cfg.vocab_size
    eng = _engine(tiny_model, tiny_params, max_seq=128, prefill_chunk=8,
                  n_slots=2, knobs=EngineKnobs(max_batch=2))
    rng = np.random.default_rng(0)
    eng.submit(Request(prompt=[int(t) for t in rng.integers(0, vocab, 6)],
                       max_new_tokens=40))
    eng.step(now=0.0)                      # short request starts decoding
    assert len(eng.active) == 1
    long_prompt = [int(t) for t in rng.integers(0, vocab, 60)]
    eng.submit(Request(prompt=long_prompt, max_new_tokens=4))
    decode_during_prefill = 0
    steps = 0
    while eng.prefilling or eng.queue:
        produced = eng.step(now=float(steps + 1))
        if eng.prefilling:
            decode_during_prefill += produced
        steps += 1
        assert steps < 100
    # 60 tokens / 8-token chunks = several steps of overlap, with the short
    # request emitting on every one of them
    assert decode_during_prefill >= 5


def test_chunked_prefill_tbt_non_regression(tiny_model, tiny_params):
    """Wall-clock TBT of a decoding request spanning a long admission:
    chunked prefill caps the stall at ~one chunk, so the worst inter-token
    gap must not exceed the monolithic-prefill gap (generous 1.5x margin
    for CI noise)."""
    vocab = tiny_model.cfg.vocab_size
    rng = np.random.default_rng(1)
    short = [int(t) for t in rng.integers(0, vocab, 6)]
    long_prompt = [int(t) for t in rng.integers(0, vocab, 480)]

    def worst_gap(chunk):
        eng = _engine(tiny_model, tiny_params, max_seq=512, n_slots=2,
                      knobs=EngineKnobs(max_batch=2), prefill_chunk=chunk)
        # warmup pass: compile every prefill/decode shape this config hits
        eng.submit(Request(prompt=list(short), max_new_tokens=20))
        eng.step()
        eng.submit(Request(prompt=list(long_prompt), max_new_tokens=2))
        eng.run(max_steps=200)
        # measured pass: a decoding victim spans the long admission
        eng.submit(Request(prompt=list(short), max_new_tokens=60))
        eng.step()
        victim = next(iter(eng.active.values()))
        # anchor the stamp window *before* the long prompt goes in: the
        # admission stall lands in the very first step, and np.diff
        # discards everything before the first stamp, so without this
        # anchor the monolithic stall would fall in a blind spot and the
        # comparison would reduce to scheduler noise
        stamps = [time.perf_counter()]
        eng.submit(Request(prompt=list(long_prompt), max_new_tokens=2))
        seen = len(victim.output)
        for _ in range(200):
            eng.step()
            if len(victim.output) > seen:
                seen = len(victim.output)
                stamps.append(time.perf_counter())
            if victim.done and not (eng.queue or eng.prefilling
                                    or eng.active):
                break
        return max(np.diff(stamps)) if len(stamps) > 2 else 0.0

    # wall-clock comparison: a background stall (GC, a noisy CI neighbor)
    # during either pass flips the verdict, so retry a bounded number of
    # times and pass on the first clean measurement
    for attempt in range(3):
        monolithic = worst_gap(None)
        chunked = worst_gap(32)
        if chunked <= monolithic * 1.5:
            break
    assert chunked <= monolithic * 1.5, \
        f"after {attempt + 1} attempts: {chunked} !<= 1.5 * {monolithic}"


# ---------------------------------------------------------------------------
# persistent device mirrors + misc satellites
# ---------------------------------------------------------------------------

def test_device_mirrors_track_host_state(tiny_model, tiny_params):
    """tables()/positions()/last_tokens_dev() stay consistent with the
    numpy source of truth through admit / decode / release, without bulk
    re-uploads (the mirror object is updated incrementally)."""
    vocab = tiny_model.cfg.vocab_size
    eng = _engine(tiny_model, tiny_params, horizon=4)
    _submit_load(eng, vocab, n_req=4, max_new=8)
    steps = 0
    while eng.queue or eng.active:
        eng.step(now=float(steps))
        steps += 1
        pool = eng.pool
        np.testing.assert_array_equal(np.asarray(pool.tables()),
                                      pool.block_tables)
        np.testing.assert_array_equal(np.asarray(pool.positions()),
                                      pool.lengths)
        np.testing.assert_array_equal(np.asarray(pool.last_tokens_dev()),
                                      pool.last_tokens)
    assert eng.pool.used_blocks == 0


def test_bucket_clamps_to_max_seq(tiny_model, tiny_params):
    """Oversized contexts are rejected (never bucketed past the cache) and
    legal ones near the cap bucket to max_seq, not past it."""
    from repro.serving.engine import _bucket
    assert _bucket(70, hi=96) == 96
    assert _bucket(70, hi=128) == 128
    assert _bucket(7) == 16
    vocab = tiny_model.cfg.vocab_size
    eng = _engine(tiny_model, tiny_params)
    rng = np.random.default_rng(0)
    eng.submit(Request(prompt=[int(t) for t in rng.integers(0, vocab, 64)],
                       max_new_tokens=4))     # == max_seq: can never fit
    eng.submit(Request(prompt=[int(t) for t in rng.integers(0, vocab, 5)],
                       max_new_tokens=4))
    stats = eng.run()
    assert stats.rejected == 1
    assert len(stats.completed) == 2
    served = [r for r in stats.completed if r.output]
    assert len(served) == 1 and len(served[0].output) == 4


def test_stats_bounded_and_goodput_incremental(tiny_model, tiny_params):
    from repro.serving.engine import STEP_WINDOW, EngineStats
    st = EngineStats()
    for i in range(STEP_WINDOW + 100):
        st.record_step(0.5)
    assert len(st.step_times) == STEP_WINDOW          # ring buffer
    assert st.n_steps == STEP_WINDOW + 100
    assert st.step_time_total == pytest.approx(0.5 * (STEP_WINDOW + 100))

    vocab = tiny_model.cfg.vocab_size
    eng = _engine(tiny_model, tiny_params, horizon=2)
    _submit_load(eng, vocab)
    eng.run()
    g1 = eng.goodput(ttft_slo=50, tbt_slo=50)
    acc = eng.stats._good_acc[(50, 50)]
    assert acc[0] == len(eng.stats.completed)         # folded exactly once
    assert eng.goodput(ttft_slo=50, tbt_slo=50) == g1  # cached, no rescan
    assert g1 > 0
