"""Fleet control plane: single-region parity with ``ClusterSim``,
region-scoped scenario validation, per-region trace seeding, cross-region
failover/steering, fleet admission, migration mechanics, deterministic
routing tie-breaks, and the engine backend inside a fleet."""
import numpy as np
import pytest

from repro.core.datacenter import DCConfig
from repro.core.fleet import (FleetConfig, FleetSim, GlobalTapasRouter,
                              LatencyOnlyRouter, Migration, RegionSpec)
from repro.core.router import TapasRouter
from repro.core.scenario import (DemandSurge, FailureEvent, Scenario,
                                 VMArrival, WeatherShift)
from repro.core.simulator import BASELINE, TAPAS, ClusterSim, SimConfig
from repro.core.traces import trace_seed
from test_control_plane import GOLDEN, PARITY_KW, _assert_summary

# whole-module: multi-region FleetSim drills (CI sim job)
pytestmark = pytest.mark.slow

SMALL = DCConfig(n_rows=2, racks_per_row=3, servers_per_rack=2)


def _two_regions(dc=SMALL, **kw):
    return FleetConfig(regions=(RegionSpec("east", dc=dc, wan_rtt_ms=10.0),
                                RegionSpec("west", dc=dc, wan_rtt_ms=30.0)),
                       horizon_h=4.0, tick_min=10.0, seed=0, **kw)


# ---------------------------------------------------------------------------
# parity: a single-region fleet IS the standalone cluster sim
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,policy", [("baseline", BASELINE),
                                         ("tapas", TAPAS)])
def test_single_region_fleet_matches_cluster_sim(name, policy):
    """One region under the identity fleet policy reproduces the standalone
    ``ClusterSim.run()`` golden numbers to 1e-9 — the fleet layer steers
    demand through the exact single-cluster code path, never a fork of it.
    ``trace_namespace=""`` opts into the shared global traces the goldens
    were captured with."""
    spec = RegionSpec("solo", dc=PARITY_KW["dc"], wan_rtt_ms=0.0,
                      trace_namespace="")
    kw = {k: v for k, v in PARITY_KW.items() if k != "dc"}
    fs = FleetSim(FleetConfig(regions=(spec,), policy=policy, **kw))
    res = fs.run()
    _assert_summary(res.regions["solo"].summary(), GOLDEN[name])
    # fleet-level aggregates agree with the single cluster's
    assert res.moved_load == 0.0 and res.migrations == 0
    assert float(res.unserved_frac) == pytest.approx(
        GOLDEN[name]["unserved_frac"], rel=1e-9, abs=1e-12)


def test_single_region_golden_router_is_also_parity():
    """The risk-weighted router never steers with nowhere to go: one
    region under ``GlobalTapasRouter`` is still bit-compatible."""
    spec = RegionSpec("solo", dc=PARITY_KW["dc"], wan_rtt_ms=0.0,
                      trace_namespace="")
    kw = {k: v for k, v in PARITY_KW.items() if k != "dc"}
    kw["horizon_h"] = 6.0
    ref = ClusterSim(SimConfig(dc=PARITY_KW["dc"], policy=TAPAS, **kw)).run()
    fs = FleetSim(FleetConfig(regions=(spec,), policy=TAPAS,
                              fleet=GlobalTapasRouter, **kw))
    _assert_summary(fs.run().regions["solo"].summary(), ref.summary())


def test_per_region_control_policy_plugs_in():
    """``RegionSpec.control`` forwards to the region's ``SimConfig``: a
    custom control plane (here a factory building a counting spy around
    the TAPAS plane) actually drives its region while siblings keep the
    flag-built default.  Regression for the field-by-field SimConfig
    construction that silently dropped ``control`` (tapaslint TL004)."""
    from repro.core.simulator import (CompositeControlPlane,
                                      build_control_policy)

    calls = {"begin_tick": 0, "place": 0}

    class CountingPlane(CompositeControlPlane):
        def begin_tick(self, state):
            calls["begin_tick"] += 1
            super().begin_tick(state)

        def place(self, state, vm):
            calls["place"] += 1
            return super().place(state, vm)

    def factory():
        inner = build_control_policy(TAPAS, tick_s=600.0, seed=0)
        return CountingPlane(inner.placement, inner.routing,
                             inner.reconfig)

    cfg = FleetConfig(
        regions=(RegionSpec("east", dc=SMALL, wan_rtt_ms=10.0,
                            control=factory),
                 RegionSpec("west", dc=SMALL, wan_rtt_ms=30.0)),
        horizon_h=2.0, tick_min=10.0, seed=0, policy=TAPAS)
    res = FleetSim(cfg).run()
    assert set(res.regions) == {"east", "west"}
    assert calls["begin_tick"] > 0 and calls["place"] > 0


# ---------------------------------------------------------------------------
# fleet state + stepping
# ---------------------------------------------------------------------------

def test_fleet_state_telemetry_populated():
    fs = FleetSim(_two_regions(policy=TAPAS, occupancy=0.9))
    st = None
    for _ in range(6):
        st = fs.step()
    assert set(st.regions) == {"east", "west"}
    for name, cs in st.regions.items():
        assert cs.region == name
        assert cs.risk is not None and cs.risk.shape == (SMALL.n_servers,)
    assert all(0.0 <= r <= 1.0 for r in st.risk.values())
    assert st.rtt_ms[("east", "west")] == 40.0       # star topology sum
    assert st.rtt_ms[("east", "east")] == 0.0
    assert st.capacity["east"] >= 0.0
    for ep, by_region in st.demand.items():
        for region, d in by_region.items():
            assert d >= 0.0
            assert st.regions[region].endpoints[ep]
    assert 0 <= st.free_servers("east") <= SMALL.n_servers


def test_fleet_rtt_overrides():
    cfg = _two_regions()
    cfg.rtt_ms = {("east", "west"): 5.0}
    fs = FleetSim(cfg)
    assert fs.rtt_ms[("east", "west")] == 5.0
    assert fs.rtt_ms[("west", "east")] == 5.0
    cfg.rtt_ms = {("east", "nowhere"): 5.0}
    with pytest.raises(ValueError, match="unknown region"):
        FleetSim(cfg)


def test_fleet_reset_reruns_deterministically():
    fs = FleetSim(_two_regions(policy=TAPAS, fleet=GlobalTapasRouter,
                               occupancy=0.95, demand_scale=1.0))
    r1 = fs.run().summary()
    r2 = fs.run().summary()     # run() resets, incl. the stateful policy
    assert r1 == r2


def test_rerun_after_injections_is_deterministic():
    """Mid-run inject_vm calls (migrations / fleet admissions) must not
    leak into the next run's workload: reset() truncates back to the
    pristine arrivals."""
    fs = FleetSim(_two_regions(policy=TAPAS, fleet=_ForcedDrain,
                               occupancy=0.9))
    r1 = fs.run().summary()
    n_vms = {n: len(s.work.vms) for n, s in fs.sims.items()}
    r2 = fs.run().summary()
    assert r1 == r2
    assert {n: len(s.work.vms) for n, s in fs.sims.items()} == n_vms
    assert fs._migrations == 1  # the drain replayed identically


# ---------------------------------------------------------------------------
# region-scoped scenario validation
# ---------------------------------------------------------------------------

def test_region_tags_validated_at_construction():
    with pytest.raises(ValueError, match="region"):
        FailureEvent(kind="ahu", start_h=0.0, end_h=1.0, region="")
    with pytest.raises(ValueError, match="region"):
        WeatherShift(start_h=0.0, end_h=1.0, delta_c=1.0, region=7)
    # unknown region name rejected when the fleet is built
    scen = Scenario((FailureEvent(kind="cooling", start_h=0.0, end_h=1.0,
                                  region="mars"),))
    with pytest.raises(ValueError, match="mars"):
        FleetSim(_two_regions(scenario=scen))


def test_cluster_sim_rejects_region_tagged_events():
    ev = WeatherShift(start_h=0.0, end_h=1.0, delta_c=2.0, region="east")
    with pytest.raises(ValueError, match="single-cluster"):
        ClusterSim(SimConfig(dc=SMALL, scenario=Scenario((ev,))))


def test_scenario_for_region_slices_and_strips():
    scen = Scenario((
        FailureEvent(kind="cooling", start_h=0.0, end_h=1.0, region="east"),
        WeatherShift(start_h=0.0, end_h=1.0, delta_c=3.0),      # fleet-wide
        DemandSurge(start_h=0.0, end_h=1.0, scale=2.0, region="west"),
        VMArrival(arrival_h=0.5, kind="saas", customer="epX",
                  lifetime_h=2.0, region="east"),
        VMArrival(arrival_h=0.5, kind="iaas", customer="cust0",
                  lifetime_h=2.0),                    # fleet-admitted
    ))
    east = scen.for_region("east")
    assert {type(ev).__name__ for ev in east.events} == \
        {"FailureEvent", "WeatherShift", "VMArrival"}
    assert all(ev.region is None for ev in east.events)
    west = scen.for_region("west")
    assert {type(ev).__name__ for ev in west.events} == \
        {"WeatherShift", "DemandSurge"}
    assert len(scen.fleet_arrivals()) == 1
    assert scen.regions_named() == {"east", "west"}


def test_region_spec_validation():
    with pytest.raises(ValueError, match="name"):
        RegionSpec("")
    with pytest.raises(ValueError, match="wan_rtt_ms"):
        RegionSpec("x", wan_rtt_ms=-1.0)
    with pytest.raises(ValueError, match="power_price_scale"):
        RegionSpec("x", power_price_scale=0.0)
    with pytest.raises(TypeError, match="WeatherShift"):
        RegionSpec("x", weather=(DemandSurge(start_h=0.0, end_h=1.0,
                                             scale=2.0),))
    with pytest.raises(ValueError, match="attached"):
        RegionSpec("x", weather=(WeatherShift(start_h=0.0, end_h=1.0,
                                              delta_c=1.0, region="y"),))
    with pytest.raises(ValueError, match="duplicate"):
        FleetSim(FleetConfig(regions=(RegionSpec("a"), RegionSpec("a"))))
    with pytest.raises(ValueError, match="itself"):
        Migration(src="a", server=0, dst="a")


# ---------------------------------------------------------------------------
# per-region trace seeding
# ---------------------------------------------------------------------------

def test_trace_seed_namespacing():
    assert trace_seed(7, "") == 7                     # parity path
    assert trace_seed(7, "east") == trace_seed(7, "east")
    assert trace_seed(7, "east") != trace_seed(7, "west")
    assert trace_seed(7, "east") != trace_seed(8, "east")
    assert 0 <= trace_seed(7, "east") < 2 ** 31       # int32-safe for jit


def test_regions_with_same_config_diverge():
    """Two regions built from the same DCConfig and seed must not replay
    identical weather noise or endpoint demand (that would make every
    cross-region decision trivially symmetric)."""
    fs = FleetSim(_two_regions())
    east, west = fs.sims["east"], fs.sims["west"]
    assert not np.allclose(east._t_out, west._t_out)
    ep = next(iter(east.work.endpoints))
    de = [east.endpoint_demand(ep, h) for h in (1.0, 2.0, 3.0)] \
        if east._ep_servers[ep] else []
    # endpoint demand uses the namespaced seed: phases differ
    if de and west._ep_servers.get(ep):
        dw = [west.endpoint_demand(ep, h) for h in (1.0, 2.0, 3.0)]
        assert de != dw


# ---------------------------------------------------------------------------
# cross-region failover, admission, migration
# ---------------------------------------------------------------------------

def test_cross_region_failover_steers_load():
    """A regional cooling failure makes the global router move SaaS demand
    off the failing region (and the latency-only baseline never does)."""
    dc = DCConfig(n_rows=4, racks_per_row=3, servers_per_rack=2,
                  region="hot")
    cold = DCConfig(n_rows=4, racks_per_row=3, servers_per_rack=2,
                    region="cold")
    scen = Scenario((
        FailureEvent(kind="thermal", start_h=1.0, end_h=5.0, target=0,
                     region="hot-r"),
        WeatherShift(start_h=1.0, end_h=5.0, delta_c=10.0, region="hot-r"),
    ))
    kw = dict(horizon_h=6.0, tick_min=10.0, seed=0, policy=TAPAS,
              scenario=scen, occupancy=0.95, demand_scale=1.0)

    def mk(fleet):
        return FleetSim(FleetConfig(
            regions=(RegionSpec("hot-r", dc=dc, wan_rtt_ms=10.0),
                     RegionSpec("cold-r", dc=cold, wan_rtt_ms=20.0)),
            fleet=fleet, **kw))
    greedy = mk(LatencyOnlyRouter)
    greedy.run()
    assert greedy._moved == 0.0
    glob = mk(GlobalTapasRouter)
    during, before = 0.0, 0.0
    prev = 0.0
    while glob.tick < glob.ticks:
        st = glob.step()
        moved = glob._moved - prev
        prev = glob._moved
        if 1.0 <= st.now_h < 5.0:
            during += moved
        else:
            before += moved
    res = glob.result()
    assert during > 0.0, "no load steered during the regional failure"
    assert res.moved_load == pytest.approx(during + before)
    assert res.wan_overhead > 0.0          # the WAN penalty was paid
    s = res.summary()
    assert s["regions"]["hot-r"]["thermal_events"] >= 0  # well-formed


def test_fleet_admission_picks_a_region():
    """An untagged VMArrival is admitted through ``admit_region``; the
    latency-only policy sends it to the lowest-RTT region with space."""
    scen = Scenario((VMArrival(arrival_h=0.5, kind="saas",
                               customer="ep-geo", lifetime_h=3.0),))
    fs = FleetSim(_two_regions(policy=TAPAS, fleet=LatencyOnlyRouter,
                               scenario=scen, occupancy=0.5))
    fs.run()
    res = fs.result()
    assert res.fleet_admissions == 1
    assert "ep-geo" in fs.sims["east"].work.endpoints   # rtt 10 < 30
    assert "ep-geo" not in fs.sims["west"].work.endpoints
    assert fs.sims["east"]._ep_servers.get("ep-geo") is not None


class _ForcedDrain:
    """Migrates the first SaaS server of ``src`` once, at the first tick
    where one exists."""

    def __init__(self):
        self.done = False

    def admit_region(self, fleet, vm):
        return None

    def route_region(self, fleet, endpoint, demands):
        return {h: {h: 1.0} for h in demands}

    def rebalance(self, fleet):
        if self.done:
            return []
        saas = np.flatnonzero(fleet.regions["east"].kind == 2)
        if saas.size == 0:
            return []
        self.done = True
        return [Migration(src="east", server=int(saas[0]), dst="west")]


class _MoveEverything:
    """Contract-legal extreme: every origin steers 100% of its demand to
    the lexicographically-first other hosting region."""

    def admit_region(self, fleet, vm):
        return None

    def route_region(self, fleet, endpoint, demands):
        shares = {}
        for h in sorted(demands):
            others = [q for q in sorted(demands) if q != h]
            shares[h] = {others[0]: 1.0} if others else {h: 1.0}
        return shares

    def rebalance(self, fleet):
        return []


def test_full_move_does_not_double_serve():
    """An origin whose demand is entirely steered away serves ZERO load —
    the override pins it to 0.0 instead of falling back to the natural
    demand (which would serve the moved load twice fleet-wide).  Demand is
    conserved: total routed == total natural + the WAN tax, never 2x."""
    kw = dict(policy=TAPAS, occupancy=0.9, demand_scale=1.0)
    ref = FleetSim(_two_regions(fleet=LatencyOnlyRouter, **kw))
    ref.run()
    natural = sum(s._demand_total for s in ref.sims.values())
    fs = FleetSim(_two_regions(fleet=_MoveEverything, **kw))
    res = fs.run()
    routed = sum(s._demand_total for s in fs.sims.values())
    assert res.moved_load > 0.0
    assert routed == pytest.approx(natural + res.wan_overhead, rel=1e-9)


def test_migration_evicts_and_reinjects():
    fs = FleetSim(_two_regions(policy=TAPAS, fleet=_ForcedDrain,
                               occupancy=0.9))
    east, west = fs.sims["east"], fs.sims["west"]
    n_west_vms = len(west.work.vms)
    while fs.tick < fs.ticks:
        fs.step()
    assert fs.policy.done
    assert fs._migrations == 1
    assert len(west.work.vms) == n_west_vms + 1       # re-injected
    mig_vm = west.work.vms[-1]
    assert mig_vm.kind == "saas"
    # the stale departure event of the evicted VM never corrupts east
    assert (east.alloc_state.kind_of >= 0).all()
    fs.result()                                       # aggregates well-formed


# ---------------------------------------------------------------------------
# deterministic routing tie-breaks
# ---------------------------------------------------------------------------

def test_tapas_router_tie_break_is_by_server_id():
    """Equal-(risk, load) packing candidates fill lowest server id first,
    independent of their position in the endpoint's server list."""
    r = TapasRouter()
    cap = np.ones(4)
    risk = np.zeros(4)
    demand = 1.0                            # < 0.4 * 4 -> packing mode
    ids_sorted = np.array([10, 11, 12, 13])
    d1 = r.route(demand, cap, risk, ids=ids_sorted)
    ids_shuffled = np.array([13, 10, 12, 11])
    d2 = r.route(demand, cap, risk, ids=ids_shuffled)
    by_id1 = dict(zip(ids_sorted.tolist(), d1.load))
    by_id2 = dict(zip(ids_shuffled.tolist(), d2.load))
    assert by_id1 == by_id2                 # same per-server assignment
    assert by_id1[10] == pytest.approx(1.0)  # lowest id packed first
    assert d1.unserved == d2.unserved == 0.0


def test_sim_results_stable_across_runs():
    """Two fresh sims of the same config agree exactly (no ordering
    nondeterminism anywhere in the decision path)."""
    kw = dict(dc=SMALL, horizon_h=4.0, tick_min=10.0, seed=6, policy=TAPAS,
              occupancy=0.95, demand_scale=1.0)
    a = ClusterSim(SimConfig(**kw)).run().summary()
    b = ClusterSim(SimConfig(**kw)).run().summary()
    assert a == b


# ---------------------------------------------------------------------------
# engine backend inside a fleet
# ---------------------------------------------------------------------------

def test_engine_backend_runs_inside_fleet():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import build_model, local_plan
    from repro.serving import Engine, EngineBackend, EngineKnobs

    cfg = get_config("llama2-7b").smoke_config().replace(num_layers=1,
                                                         d_ff=32)
    model = build_model(cfg, local_plan(param_dtype=jnp.bfloat16))
    eng = Engine(model, model.init(jax.random.PRNGKey(0)), max_seq=64,
                 n_slots=2, knobs=EngineKnobs(max_batch=2))
    fs = FleetSim(_two_regions(policy=TAPAS, occupancy=0.9))
    backend = None
    while fs.tick < fs.ticks:
        st = fs.step()
        if backend is None:
            saas = np.flatnonzero(st.regions["east"].kind == 2)
            if saas.size:
                backend = EngineBackend(eng, steps_per_tick=1,
                                        max_new_tokens=2)
                fs.attach_backend("east", int(saas[0]), backend)
                srv = int(saas[0])
    assert backend is not None, "no SaaS server appeared in east"
    assert len(backend.applied) >= 1        # attach-time config sync ran
    assert srv in fs.sims["east"].backends
    with pytest.raises(ValueError, match="unknown region"):
        fs.attach_backend("nowhere", 0, backend)
