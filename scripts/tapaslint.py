"""tapaslint CLI — run the repo-specific static-analysis pass.

    PYTHONPATH=src python scripts/tapaslint.py [paths...]
        lint (default: src benchmarks examples scripts); exit 1 on any
        finding not grandfathered in the baseline
    python scripts/tapaslint.py --explain TL003
        print a rule's motivation, detection and fix guidance
    python scripts/tapaslint.py --update-baseline
        rewrite scripts/tapaslint_baseline.txt with the current findings
    python scripts/tapaslint.py --no-baseline
        show every finding, grandfathered or not

The baseline is a multiset of line-number-independent finding keys; CI
fails on *new* findings only, and stale entries (fixed findings still
listed) are reported so the file only ever shrinks.  Suppress a single
deliberate violation inline with ``# tapaslint: disable=TLxxx`` on the
flagged (or enclosing ``def``) line.
"""
from __future__ import annotations

import argparse
import os
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.lint import (ALL_RULES, RULES_BY_CODE, collect_files,
                                 diff_baseline, format_baseline,
                                 lint_sources, load_baseline)  # noqa: E402

DEFAULT_PATHS = ["src", "benchmarks", "examples", "scripts"]
DEFAULT_BASELINE = ROOT / "scripts" / "tapaslint_baseline.txt"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="tapaslint",
        description="repo-specific static analysis (TL001-TL008)")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to lint (default: {DEFAULT_PATHS})")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="grandfathered-findings file")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline; report everything")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current findings")
    ap.add_argument("--explain", metavar="TLxxx",
                    help="print a rule's motivation + fix guidance")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--github", action="store_true",
                    help="emit ::error workflow annotations for new "
                         "findings and a markdown summary to "
                         "$GITHUB_STEP_SUMMARY")
    ap.add_argument("--fail-on-baseline", action="store_true",
                    help="fail if the baseline grandfathers anything: the "
                         "debt was paid down to zero, and this keeps new "
                         "findings from being waved through by re-running "
                         "--update-baseline")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            first = r.EXPLAIN.strip().splitlines()[0]
            print(f"{r.code}  {r.name:22s} {first}")
        return 0
    if args.explain:
        rule = RULES_BY_CODE.get(args.explain.upper())
        if rule is None:
            print(f"unknown rule {args.explain!r}; known: "
                  f"{', '.join(sorted(RULES_BY_CODE))}", file=sys.stderr)
            return 2
        print(rule.EXPLAIN.rstrip())
        return 0

    files = collect_files(ROOT, args.paths or DEFAULT_PATHS)
    findings = lint_sources(files)

    if args.update_baseline:
        pathlib.Path(args.baseline).write_text(format_baseline(findings))
        print(f"baseline rewritten: {len(findings)} grandfathered "
              f"finding(s) -> {args.baseline}")
        return 0

    baseline = [] if args.no_baseline else load_baseline(args.baseline)
    if args.fail_on_baseline and baseline:
        print(f"baseline is not empty ({len(baseline)} grandfathered "
              f"entr{'y' if len(baseline) == 1 else 'ies'} in "
              f"{args.baseline}); the debt was burned to zero — fix the "
              f"findings instead of re-grandfathering them")
        if args.github:
            print(f"::error title=tapaslint baseline::{len(baseline)} "
                  f"grandfathered entries re-appeared in {args.baseline}")
        return 1
    new, matched, stale = diff_baseline(findings, baseline)

    for f in new:
        print(f.render())
        if args.github:
            print(f"::error file={f.path},line={f.line},"
                  f"title=tapaslint {f.rule}::{f.message}")
    if stale:
        print(f"\n{len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} (fixed findings — "
              "remove them, or run --update-baseline):")
        for k in stale:
            print(f"  {k}")
    summary = (f"tapaslint: {len(files)} files, {len(findings)} finding(s) "
               f"({len(new)} new, {len(matched)} grandfathered, "
               f"{len(stale)} stale baseline)")
    print(("\n" if new or stale else "") + summary)
    if args.github:
        step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
        if step_summary:
            with open(step_summary, "a") as fh:
                fh.write(f"### tapaslint\n\n{summary}\n\n")
                if new:
                    fh.write("| file | rule | finding |\n|---|---|---|\n")
                    for f in new:
                        fh.write(f"| `{f.path}:{f.line}` | {f.rule} | "
                                 f"{f.message} |\n")
    if new:
        print(f"\nnew findings fail the run; explain a rule with "
              f"`python scripts/tapaslint.py --explain {new[0].rule}`, "
              "suppress a deliberate one with "
              "`# tapaslint: disable=<rule>`.")
    return 1 if new else 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:      # e.g. `--explain TLxxx | head`
        sys.exit(0)
