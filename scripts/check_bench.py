"""Unified bench-regression gate: one CI step for every checked-in bench.

Runs every ``benchmarks/bench_*.py`` that records a committed
``BENCH_*.json`` in ``--smoke`` mode (each smoke already asserts its own
acceptance criteria), then compares the smoke run's key metrics against
the checked-in trajectory within the tolerances declared below, and
prints a one-line pass/fail table per metric.

Declared gates per bench:

* ``value``   — the smoke metric itself must satisfy a bound
  (``min``/``max``/``eq``), e.g. "host-sync reduction >= 2x".
* ``vs``      — the smoke metric must match the *recorded* metric (a
  dotted path into the checked-in JSON) within ``tol_abs``/``tol_rel``;
  simulation metrics are deterministic, so tolerances are tight and a
  drift means the physics or a policy changed without re-recording.
* ``lt_metric`` — cross-metric ordering inside the smoke payload, e.g.
  "global router throttles strictly less than latency-only".

A checked-in ``BENCH_*.json`` with no gate spec fails the run: every
recorded benchmark must be covered here (CI acceptance criterion).

    PYTHONPATH=src python scripts/check_bench.py [--skip-run]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH = ROOT / "benchmarks"
RESULTS = BENCH / "results"

#: bench name -> list of gate dicts.  ``metric`` paths index the *smoke*
#: payload; ``vs`` paths index the checked-in payload.
SPECS = {
    "engine": [
        {"metric": "streams_identical", "eq": True},
        {"metric": "host_sync_reduction", "min": 2.0},
        # the fused path must stay below the recorded pre-PR3 per-step
        # baseline (smoke and full run different workload sizes, so the
        # comparison is against the recorded *baseline*, not equality)
        {"metric": "fused.host_syncs_per_1k_tokens",
         "vs": "baseline.host_syncs_per_1k_tokens", "max_ratio": 0.5},
        # speculative decode: real end-to-end win over the horizon-only
        # fused path at bit-identical greedy streams, and each readback
        # must amortise a healthy run of free (accepted-draft) tokens
        {"metric": "spec_speedup", "min": 1.5},
        {"metric": "spec.accepted_tokens_per_sync", "min": 10.0},
        {"metric": "spec.acceptance_rate", "min": 0.3},
        # batched pump: one process (two real engines, one weight copy)
        # backs >= 100 simulated SaaS servers, every server gets service,
        # and equal load comes back as near-equal per-server tokens
        {"metric": "fleet_pump.servers", "min": 100},
        {"metric": "fleet_pump.all_servers_served", "eq": True},
        {"metric": "fleet_pump.tokens_per_server_cov", "max": 0.25},
        {"metric": "fleet_pump.decode_tok_per_s", "min": 1e-9},
    ],
    "fleet": [
        {"metric": "per_seed.0.global.throttle_events",
         "lt_metric": "per_seed.0.latency.throttle_events"},
        {"metric": "per_seed.0.global.moved_load", "min": 1e-9},
        # deterministic drill: the smoke seed-0 trajectory must replay the
        # recorded one (2-event slack for BLAS/platform jitter)
        {"metric": "per_seed.0.global.throttle_events",
         "vs": "per_seed.0.global.throttle_events", "tol_abs": 2},
        {"metric": "per_seed.0.latency.throttle_events",
         "vs": "per_seed.0.latency.throttle_events", "tol_abs": 2},
        {"metric": "per_seed.0.global.unserved_frac",
         "vs": "per_seed.0.global.unserved_frac", "tol_abs": 0.01},
    ],
    "resilience": [
        # the recovery contract, re-asserted over the fresh smoke run:
        # nothing vanishes, the storm barely dents goodput, and turning
        # recovery off demonstrably loses >= 3x more
        {"metric": "aggregates.lost_requests_on", "eq": 0},
        {"metric": "aggregates.min_recovery_goodput_ratio", "min": 0.9},
        {"metric": "aggregates.min_loss_ratio_off_vs_on", "min": 3.0},
        {"metric": "aggregates.lost_or_dropped_off", "min": 1},
        # deterministic drill: the smoke seed-0 goodputs must replay the
        # recorded trajectory (token-exact — the audit ledger is seeded)
        {"metric": "per_seed.0.arms.fault_free.goodput_tokens",
         "vs": "per_seed.0.arms.fault_free.goodput_tokens", "tol_abs": 0},
        {"metric": "per_seed.0.arms.recovery_on.goodput_tokens",
         "vs": "per_seed.0.arms.recovery_on.goodput_tokens", "tol_abs": 0},
        {"metric": "per_seed.0.arms.recovery_off.goodput_tokens",
         "vs": "per_seed.0.arms.recovery_off.goodput_tokens",
         "tol_abs": 0},
    ],
    "fleet_oversub": [
        {"metric": "per_seed.0.planner.coordinated_safe", "eq": True},
        # the headline claims, re-asserted over the fresh smoke run
        {"metric": "per_seed.0.planner.gain", "min": 1e-9},
        {"metric": "per_seed.0.cost.saving_frac", "min": 1e-9},
        {"metric": "per_seed.0.cost.goodput_ratio", "min": 0.99},
        # deterministic planner: the plan must replay the recorded one
        # (a grid step of slack covers platform float jitter)
        {"metric": "per_seed.0.planner.coordinated_total",
         "vs": "per_seed.0.planner.coordinated_total", "tol_abs": 0.125},
        {"metric": "per_seed.0.planner.isolated_total",
         "vs": "per_seed.0.planner.isolated_total", "tol_abs": 0.125},
        {"metric": "per_seed.0.cost.saving_frac",
         "vs": "per_seed.0.cost.saving_frac", "tol_abs": 0.03},
    ],
}


def lookup(payload: dict, path: str):
    cur = payload
    for part in path.split("."):
        if isinstance(cur, list):
            cur = cur[int(part)]
        else:
            cur = cur[part]
    return cur


def check_gate(name: str, gate: dict, smoke: dict, recorded: dict) -> tuple:
    """Returns (ok, one-line description)."""
    got = lookup(smoke, gate["metric"])
    if "eq" in gate:
        want = gate["eq"]
        return (got == want, f"{gate['metric']} == {want!r} (got {got!r})")
    if "lt_metric" in gate:
        bound = lookup(smoke, gate["lt_metric"])
        return (got < bound,
                f"{gate['metric']} ({got}) < {gate['lt_metric']} ({bound})")
    if "vs" in gate:
        ref = lookup(recorded, gate["vs"])
        if "max_ratio" in gate:
            bound = ref * gate["max_ratio"]
            return (got <= bound,
                    f"{gate['metric']} ({got:.4g}) <= "
                    f"{gate['max_ratio']} x recorded {gate['vs']} "
                    f"({ref:.4g})")
        tol = gate.get("tol_abs", 0.0) + gate.get("tol_rel", 0.0) * abs(ref)
        return (abs(got - ref) <= tol,
                f"{gate['metric']} ({got:.4g}) == recorded ({ref:.4g}) "
                f"+- {tol:.4g}")
    if "min" in gate:
        return (got >= gate["min"],
                f"{gate['metric']} ({got:.4g}) >= {gate['min']:.4g}")
    if "max" in gate:
        return (got <= gate["max"],
                f"{gate['metric']} ({got:.4g}) <= {gate['max']:.4g}")
    raise ValueError(f"{name}: gate {gate} declares no check")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-run", action="store_true",
                    help="gate existing smoke outputs in benchmarks/results/"
                         " without re-running the benches")
    ap.add_argument("--only", default="",
                    help="comma list of bench names (default: all specs)")
    args = ap.parse_args()
    only = {s for s in args.only.split(",") if s}

    checked_in = {p.name[len("BENCH_"):-len(".json")]
                  for p in BENCH.glob("BENCH_*.json")}
    uncovered = checked_in - set(SPECS)
    if uncovered:
        print(f"FAIL: checked-in BENCH files with no gate spec: "
              f"{sorted(uncovered)} — declare tolerances in {__file__}")
        return 1

    failures = []
    rows = []
    for name in sorted(SPECS):
        if only and name not in only:
            continue
        script = BENCH / f"bench_{name}.py"
        recorded_path = BENCH / f"BENCH_{name}.json"
        smoke_path = RESULTS / f"BENCH_{name}.json"
        if not args.skip_run:
            proc = subprocess.run(
                [sys.executable, str(script), "--smoke"],
                cwd=ROOT, capture_output=True, text=True)
            if proc.returncode != 0:
                rows.append((name, "smoke run", False,
                             proc.stdout[-400:] + proc.stderr[-400:]))
                failures.append(name)
                continue
            rows.append((name, "smoke run", True, "asserts passed"))
        if not smoke_path.exists():
            rows.append((name, "smoke output", False,
                         f"{smoke_path} missing — run the bench with "
                         f"--smoke first (or drop --skip-run)"))
            failures.append(name)
            continue
        recorded = json.loads(recorded_path.read_text())
        smoke = json.loads(smoke_path.read_text())
        for gate in SPECS[name]:
            try:
                ok, desc = check_gate(name, gate, smoke, recorded)
            except (KeyError, IndexError) as e:
                ok, desc = False, f"missing metric {e!r} for gate {gate}"
            rows.append((name, gate["metric"], ok, desc))
            if not ok:
                failures.append(name)

    width = max(len(r[1]) for r in rows) if rows else 10
    for name, metric, ok, desc in rows:
        print(f"{'PASS' if ok else 'FAIL'}  {name:<14} "
              f"{metric:<{width}}  {desc}")
    if failures:
        print(f"\nbench gate FAILED: {sorted(set(failures))}")
        return 1
    print(f"\nbench gate OK: {len(rows)} checks over "
          f"{len(checked_in)} recorded benchmarks")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
