"""iraudit CLI — jaxpr/HLO static audit of the jitted serving hot paths.

    JAX_PLATFORMS=cpu PYTHONPATH=src python scripts/iraudit.py [entries...]
        audit every registered entrypoint (or the named subset): run the
        IR001-IR004 invariants and gate the cost metrics against
        benchmarks/BUDGET_ir.json; exit 1 on any finding or drift
    python scripts/iraudit.py --explain IR002
        print an invariant's motivation and fix guidance
    python scripts/iraudit.py --update-budgets
        re-record BUDGET_ir.json from the current build (commit the diff —
        reviewers see the cost delta next to the code that caused it)
    python scripts/iraudit.py --list
        show the registry (name, kind, donation declaration, doc)

Everything runs on CPU under abstract shapes: no parameters are
materialised, Pallas kernels are audited in interpret mode, and nothing
executes — trace + lower + compile only (~15 s for the full registry).
Unlike tapaslint there is no baseline and no waiver file: an invariant
finding on a serving hot path either gets fixed or the entry's registry
declaration changes in review.
"""
from __future__ import annotations

import argparse
import fnmatch
import os
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

os.environ.setdefault("JAX_PLATFORMS", "cpu")
# mesh-geometry entries (min_devices > 1) trace under a (1, N) mesh; give
# the CPU backend enough fake devices before jax is imported
_flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=4").strip()

DEFAULT_BUDGETS = ROOT / "benchmarks" / "BUDGET_ir.json"


def _fmt_row(name: str, m: dict) -> str:
    return (f"{name:26s} {m['flops'] / 1e6:8.3f} {m['bytes'] / 1e6:8.3f} "
            f"{m['peak_live_bytes'] / 1e6:8.3f} {m['n_eqns']:6d} "
            f"{m['const_bytes']:7d} {m['f32_out_bytes']:8d} "
            f"{m['aliased_leaves']}/{m['donated_leaves']}")


_HEADER = (f"{'entrypoint':26s} {'MFLOPs':>8s} {'MB':>8s} {'peakMB':>8s} "
           f"{'eqns':>6s} {'constB':>7s} {'f32outB':>8s} alias/don")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="iraudit",
        description="jaxpr/HLO audit of jitted hot paths (IR001-IR005)")
    ap.add_argument("entries", nargs="*",
                    help="entrypoint names or globs (default: all)")
    ap.add_argument("--budgets", default=str(DEFAULT_BUDGETS),
                    help="pinned budget file (benchmarks/BUDGET_ir.json)")
    ap.add_argument("--no-budgets", action="store_true",
                    help="skip the budget gate; invariants only")
    ap.add_argument("--update-budgets", action="store_true",
                    help="re-record the budget file from this build")
    ap.add_argument("--explain", metavar="IRxxx",
                    help="print an invariant's motivation + fix guidance")
    ap.add_argument("--list", action="store_true", dest="list_entries",
                    help="list registered entrypoints and exit")
    ap.add_argument("--github", action="store_true",
                    help="emit ::error workflow annotations and a markdown "
                         "budget table to $GITHUB_STEP_SUMMARY")
    args = ap.parse_args(argv)

    # import late: --explain/--list must work without a usable jax
    from repro.analysis.iraudit import (ENTRYPOINTS, INVARIANTS,
                                        AuditContext, audit_entry,
                                        check_budgets, cost_metrics,
                                        load_budgets, run_invariants,
                                        write_budgets)

    if args.explain:
        code = args.explain.upper()
        if code not in INVARIANTS:
            print(f"unknown invariant {args.explain!r}; known: "
                  f"{', '.join(sorted(INVARIANTS))}", file=sys.stderr)
            return 2
        name, text = INVARIANTS[code]
        print(f"{code}  {name}\n\n{text.rstrip()}")
        return 0
    if args.list_entries:
        for e in ENTRYPOINTS:
            don = f" donate={e.donate}" if e.donate else ""
            f32 = " f32_dot_ok" if e.f32_dot_ok else ""
            print(f"{e.name:26s} [{e.kind}]{don}{f32}  {e.doc}")
        return 0

    import jax
    avail = jax.device_count()
    names = [e.name for e in ENTRYPOINTS if e.min_devices <= avail]
    skipped = [e.name for e in ENTRYPOINTS if e.min_devices > avail]
    if skipped:
        print(f"note: {len(skipped)} mesh entr{'y' if len(skipped) == 1 else 'ies'} "
              f"skipped ({', '.join(skipped)}): need more than {avail} "
              f"devices", file=sys.stderr)
    if args.entries:
        picked = [n for n in names
                  if any(fnmatch.fnmatch(n, p) for p in args.entries)]
        unknown = [p for p in args.entries
                   if not any(fnmatch.fnmatch(n, p) for n in names)]
        if unknown:
            print(f"no entrypoint matches {unknown}; see --list",
                  file=sys.stderr)
            return 2
    else:
        picked = names

    ctx = AuditContext()
    findings = []
    rows: dict = {}
    by_name = {e.name: e for e in ENTRYPOINTS}
    for name in picked:
        audit = audit_entry(by_name[name], ctx)
        findings.extend(run_invariants(audit))
        rows[name] = cost_metrics(audit)

    if args.update_budgets:
        if picked != names or skipped:
            print("--update-budgets requires auditing the full registry "
                  "(drop the entry filter; mesh entries need a multi-device "
                  "view)", file=sys.stderr)
            return 2
        write_budgets(rows, ctx, args.budgets)
        print(f"budgets re-recorded for {len(rows)} entrypoints -> "
              f"{args.budgets}")
        return 0

    problems = []
    if not args.no_budgets:
        try:
            pinned = load_budgets(args.budgets)
        except FileNotFoundError:
            problems.append(f"budget file missing: {args.budgets} "
                            f"(record it with --update-budgets)")
        else:
            if picked != names:
                keep = set(picked)
                pinned = {"meta": pinned.get("meta", {}),
                          "entries": {k: v
                                      for k, v in pinned["entries"].items()
                                      if k in keep}}
            elif skipped:
                # device-limited view: mesh rows pinned under a wider
                # view are not stale, just unauditable here; anything
                # else unknown still flags
                pinned = {"meta": pinned.get("meta", {}),
                          "entries": {k: v
                                      for k, v in pinned["entries"].items()
                                      if k not in set(skipped)}}
            problems = check_budgets(rows, pinned)

    print(_HEADER)
    for name in picked:
        print(_fmt_row(name, rows[name]))
    for f in findings:
        print(f"FINDING {f}")
        if args.github:
            print(f"::error title=iraudit {f.code}::{f.entry}: {f.message}")
    for p in problems:
        print(f"BUDGET IR005 {p}")
        if args.github:
            print(f"::error title=iraudit IR005::{p}")

    n_bad = len(findings) + len(problems)
    summary = (f"iraudit: {len(picked)} entrypoints, {len(findings)} "
               f"invariant finding(s), {len(problems)} budget problem(s)")
    print(summary)
    if args.github:
        step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
        if step_summary:
            with open(step_summary, "a") as fh:
                fh.write(f"### iraudit\n\n{summary}\n\n")
                fh.write("| entrypoint | MFLOPs | MB moved | peak-live MB "
                         "| eqns | const B | f32-out B | aliased/donated "
                         "|\n|---|---|---|---|---|---|---|---|\n")
                for name in picked:
                    m = rows[name]
                    fh.write(
                        f"| `{name}` | {m['flops'] / 1e6:.3f} "
                        f"| {m['bytes'] / 1e6:.3f} "
                        f"| {m['peak_live_bytes'] / 1e6:.3f} "
                        f"| {m['n_eqns']} | {m['const_bytes']} "
                        f"| {m['f32_out_bytes']} "
                        f"| {m['aliased_leaves']}/{m['donated_leaves']} "
                        f"|\n")
                if findings or problems:
                    fh.write("\n| kind | detail |\n|---|---|\n")
                    for f in findings:
                        fh.write(f"| {f.code} | `{f.entry}`: {f.message} "
                                 f"|\n")
                    for p in problems:
                        fh.write(f"| IR005 | {p} |\n")
    if n_bad:
        print(f"\nfindings fail the run; explain an invariant with "
              f"`python scripts/iraudit.py --explain IR001`, re-record "
              f"intended cost changes with --update-budgets.")
    return 1 if n_bad else 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:
        sys.exit(0)
