"""Ad-hoc developer smoke: every arch, reduced config, loss+prefill+decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config
from repro.models import build_model, local_plan


def run(name: str) -> None:
    cfg = get_config(name).smoke_config()
    plan = local_plan(param_dtype=jnp.float32)
    model = build_model(cfg, plan)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    n_leaves = len(jax.tree.leaves(params))
    B, S = 2, 32
    if cfg.input_kind == "embeds":
        inputs = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.bfloat16)
    else:
        inputs = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    loss = jax.jit(model.loss)(params, inputs, labels)
    assert jnp.isfinite(loss), f"{name}: loss not finite: {loss}"
    msgs = [f"loss={float(loss):.3f}"]
    if not cfg.encoder_only:
        logits, cache = jax.jit(model.prefill)(params, inputs)
        assert jnp.all(jnp.isfinite(logits[:, : cfg.vocab_size]))
        tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
        pos = jnp.full((B,), S, jnp.int32)
        cache2 = model.init_cache(B, S + 8)
        # copy prefill cache into the bigger decode buffer is engine work;
        # here just run a decode step on a fresh cache for shape sanity
        logits2, cache2 = jax.jit(model.decode_step)(params, cache2, tok, pos % (S + 8))
        assert logits2.shape[0] == B
        assert jnp.all(jnp.isfinite(logits2[:, : cfg.vocab_size]))
        msgs.append("decode ok")
    print(f"[ok] {name}: params={n_leaves} leaves, " + ", ".join(msgs))


if __name__ == "__main__":
    names = sys.argv[1:] or ASSIGNED + ["llama2-7b"]
    for n in names:
        run(n)
