"""Paper Figs. 15–16: instance profiles + Pareto frontier, cross-checked
against the real serving engine (reduced-size llama2) for relative goodput
vs batch size."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save, timed
from repro.configs import get_config
from repro.core import profiles as P
from repro.models import build_model, local_plan
from repro.serving import Engine, EngineKnobs, Request


def engine_goodput_vs_batch(batches=(1, 2, 4)) -> dict:
    """Relative engine throughput at different max-batch knobs (the
    batch-size column of Fig. 15b at smoke scale)."""
    cfg = get_config("llama2-7b").smoke_config()
    model = build_model(cfg, local_plan(param_dtype=jnp.bfloat16))
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    out = {}
    for b in batches:
        eng = Engine(model, params, max_seq=96, n_slots=max(batches),
                     knobs=EngineKnobs(max_batch=b))
        for i in range(8):
            eng.submit(Request(prompt=list(rng.integers(0, cfg.vocab_size, 8)),
                               max_new_tokens=12))
        stats = eng.run()
        steps = max(len(stats.step_times), 1)
        out[b] = stats.decode_tokens / steps
    base = out[batches[0]]
    return {f"batch_{b}": round(v / base, 2) for b, v in out.items()}


def main(quick: bool = True) -> list:
    rows = []
    entries, us = timed(P.build_profile)
    front = P.pareto_frontier(entries)
    # paper claims: model size dominates the quality axis; frontier exists
    best = max(entries, key=lambda e: e.goodput)
    derived = {
        "config_points": len(entries),
        "pareto_points": len(front),
        "best_goodput_cfg": f"{best.cfg.size}/tp{best.cfg.tp}/b{best.cfg.batch}",
        "quality_7b_vs_70b": round(
            next(e.quality for e in entries if e.cfg.size == "7b"
                 and e.cfg.quant == "bf16"), 2),
    }
    rows.append(emit("profiles_pareto", us, derived))

    gp, us = timed(engine_goodput_vs_batch)
    gp["monotone"] = bool(gp["batch_4"] >= gp["batch_1"])
    rows.append(emit("profiles_engine_batch_knob", us, gp))
    save("bench_profiles", {"pareto": derived, "engine": gp})
    return rows


if __name__ == "__main__":
    main()
