"""Paper Figs. 15–16: instance profiles + Pareto frontier, with the profile
table calibrated from the REAL serving engine (paged-KV, reduced-size
llama2) via ``profiles.measure_from_engine`` — the offline profiling phase
the paper runs on hardware."""
from __future__ import annotations

from benchmarks.common import emit, save, timed
from repro.core import profiles as P


def main(quick: bool = True) -> list:
    rows = []
    # --- engine-measured profiling sweep (max_batch x freq x variant) ----
    mp, us = timed(P.measure_from_engine,
                   batches=(1, 2, 4), freqs=(0.6, 0.8, 1.0),
                   n_requests=6, max_new=8)
    cal = mp.calibration
    effs = {f"batch_eff_{k}": round(v, 3) for k, v in cal["batch_eff"].items()}
    rows.append(emit("profiles_measured_sweep", us, {
        "points": len(mp.rows), **effs,
        "freq_exp": round(cal["freq_exp"], 3),
        "size_speed_7b": round(cal["size_speed"].get("7b", 0.0), 3),
        "monotone_batch": bool(
            cal["batch_eff"][64] >= cal["batch_eff"][16]
            >= cal["batch_eff"][1]),
    }))

    # --- fold measurements into the _entry physics and rebuild the table -
    P.calibrate(mp)
    try:
        entries, us = timed(P.build_profile)
        front = P.pareto_frontier(entries)
        best = max(entries, key=lambda e: e.goodput)
        nominal = P._entry(P.NOMINAL)
        derived = {
            "config_points": len(entries),
            "pareto_points": len(front),
            "best_goodput_cfg": f"{best.cfg.size}/tp{best.cfg.tp}/b{best.cfg.batch}",
            "nominal_goodput": round(nominal.goodput, 3),
            "quality_7b_vs_70b": round(
                next(e.quality for e in entries if e.cfg.size == "7b"
                     and e.cfg.quant == "bf16"), 2),
            "source": P._CAL["source"],
        }
        rows.append(emit("profiles_pareto", us, derived))
        save("bench_profiles", {"pareto": derived, "calibration": {
            k: v for k, v in cal.items()}, "measured_rows": mp.rows})
    finally:
        P.reset_calibration()
    return rows


if __name__ == "__main__":
    main()
