"""Pallas kernel microbench: interpret-mode allclose vs oracle + timing.
(Wall time here is CPU interpret-mode — correctness gate, not TPU perf.)"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels import ops, ref


def main(quick: bool = True) -> list:
    rows = []
    rng = np.random.default_rng(0)
    arr = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)

    B, H, K, S, D = 1, 4, 2, 256, 64
    q, k, v = arr(B, H, S, D), arr(B, K, S, D), arr(B, K, S, D)
    o, us = timed(lambda: np.asarray(
        ops.flash_attention(q, k, v, block_q=64, block_k=64)))
    err = float(jnp.max(jnp.abs(o - ref.flash_attention_ref(q, k, v))))
    rows.append(emit("kernel_flash_attention", us,
                     {"max_err": err, "ok": err < 1e-4}))

    q1 = arr(B, H, D)
    pos = jnp.asarray([200], jnp.int32)
    kd, vd = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)

    def best_of(fn, n=5):
        fn()  # warm the jit cache so timings are steady-state
        return min(timed(fn)[1] for _ in range(n))

    o = np.asarray(ops.decode_attention(q1, kd, vd, pos, block_k=64))
    us = best_of(lambda: np.asarray(
        ops.decode_attention(q1, kd, vd, pos, block_k=64)))
    err = float(jnp.max(jnp.abs(o - ref.decode_attention_ref(q1, kd, vd, pos))))
    rows.append(emit("kernel_decode_attention", us,
                     {"max_err": err, "ok": err < 1e-4}))

    # paged decode over the same context: S=256 split into 64-token blocks
    bs = 64
    t_blk = S // bs
    kp = jnp.concatenate([jnp.zeros((1, bs, K, D), kd.dtype),
                          kd.reshape(t_blk, bs, K, D)])
    vp = jnp.concatenate([jnp.zeros((1, bs, K, D), vd.dtype),
                          vd.reshape(t_blk, bs, K, D)])
    bt = jnp.arange(1, t_blk + 1, dtype=jnp.int32)[None, :]
    op = np.asarray(ops.paged_decode_attention(q1, kp, vp, bt, pos))
    us_p = best_of(lambda: np.asarray(
        ops.paged_decode_attention(q1, kp, vp, bt, pos)))
    err = float(np.max(np.abs(op - o)))       # must equal the dense result
    ratio = us_p / max(us, 1e-9)
    rows.append(emit("kernel_paged_decode_attention", us_p,
                     {"max_err_vs_dense": err, "time_vs_dense": round(ratio, 3),
                      "ok": err < 1e-4 and ratio <= 1.10}))

    T, Hn, Dn = 128, 2, 32
    r, kk, vv = arr(B, T, Hn, Dn), arr(B, T, Hn, Dn), arr(B, T, Hn, Dn)
    w = jnp.asarray(rng.uniform(0.85, 0.999, (B, T, Hn, Dn)), jnp.float32)
    u, s0 = arr(Hn, Dn), arr(B, Hn, Dn, Dn)
    (y, sf), us = timed(lambda: jax.tree.map(
        np.asarray, ops.rwkv6_wkv(r, kk, vv, w, u, s0, block_t=32)))
    y_ref, sf_ref = ref.rwkv6_wkv_ref(r, kk, vv, w, u, s0)
    err = float(jnp.max(jnp.abs(y - y_ref)))
    rows.append(emit("kernel_rwkv6_wkv", us, {"max_err": err, "ok": err < 1e-3}))

    x, wmat = arr(128, 256), arr(256, 128)
    o, us = timed(lambda: np.asarray(ops.int8_matmul_quantized(x, wmat)))
    xq, sx = ops.quantize_rows(x)
    wq, sw = ops.quantize_cols(wmat)
    err = float(jnp.max(jnp.abs(
        o.astype(jnp.float32)
        - ref.int8_matmul_ref(xq, wq, sx, sw).astype(jnp.float32))))
    rows.append(emit("kernel_int8_matmul", us, {"max_err": err, "ok": err == 0.0}))
    return rows


if __name__ == "__main__":
    main()
