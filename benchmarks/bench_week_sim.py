"""Paper Fig. 19: large-scale simulation — max temperature and peak row
power over one week (paper: TAPAS -15% temp, -24% power vs Baseline)."""
from __future__ import annotations

from benchmarks.common import emit, save, timed
from repro.core.datacenter import DCConfig
from repro.core.simulator import BASELINE, TAPAS, ClusterSim, SimConfig


def run(policy, *, horizon_h, tick_min, n_racks, seed=0):
    dc = DCConfig(n_rows=8, racks_per_row=n_racks, servers_per_rack=4)
    cfg = SimConfig(dc=dc, horizon_h=horizon_h, tick_min=tick_min,
                    seed=seed, policy=policy)
    return ClusterSim(cfg).run()


def main(quick: bool = True) -> list:
    rows = []
    # quick: 2 days x 320 servers @10min; full: 7 days x 992 servers @5min
    kw = (dict(horizon_h=48.0, tick_min=10.0, n_racks=10) if quick
          else dict(horizon_h=168.0, tick_min=5.0, n_racks=31))
    base, us_b = timed(run, BASELINE, **kw)
    tap, us_t = timed(run, TAPAS, **kw)
    bs, ts = base.summary(), tap.summary()
    derived = {
        "servers": 8 * kw["n_racks"] * 4,
        "temp_reduction_pct": round(
            100 * (1 - ts["max_temp_c"] / bs["max_temp_c"]), 1),
        "power_reduction_pct": round(
            100 * (1 - ts["peak_row_power_frac"] / bs["peak_row_power_frac"]), 1),
        "thermal_event_reduction_pct": round(
            100 * (1 - (ts["thermal_events"] + 1e-9)
                   / max(bs["thermal_events"], 1e-9)), 1),
        "paper_claims": {"temp": 15.0, "power": 24.0},
        "baseline": {k: round(float(v), 3) for k, v in bs.items()},
        "tapas": {k: round(float(v), 3) for k, v in ts.items()},
    }
    rows.append(emit("week_sim_fig19", us_b + us_t, derived))
    save("bench_week_sim", derived)
    return rows


if __name__ == "__main__":
    main(quick=False)
