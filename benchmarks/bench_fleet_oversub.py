"""Fleet oversubscription + carbon/price-aware steering benchmark.

Records the two fleet-level TCO results (paper §4.4, Fig. 19/20) the
``fleet_oversub_planner`` example demonstrates, with the drills imported
from the example so the CI smoke and the recorded numbers can never drift
apart:

* ``planner`` — ``FleetOversubPlanner`` over the regional-UPS-failure
  drill: per-region isolated safe ratios vs the fleet-coordinated plan.
  The claim: the coordinated total strictly exceeds the isolated total —
  cross-region draining converts a neighbor's headroom into admitted
  racks.
* ``cost`` — the coal-vs-hydro steering drill under the thermal-only
  ``GlobalTapasRouter`` vs ``cost_aware_knobs()``: blended price/carbon
  energy cost, energy, carbon and goodput for both.  The claim: the
  blended cost drops while goodput stays within 1%.

All metrics are deterministic simulation outcomes.  Emits
``benchmarks/BENCH_fleet_oversub.json`` (checked in, the recorded
trajectory).  ``--smoke`` runs one seed and asserts both claims.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.common import RESULTS  # noqa: E402
from examples.fleet_oversub_planner import (CARBON_WEIGHT,  # noqa: E402
                                            RATIOS, make_cost_fleet,
                                            make_planner_fleet)
from repro.core.fleet import GlobalTapasRouter, cost_aware_knobs  # noqa: E402
from repro.core.oversubscribe import FleetOversubPlanner  # noqa: E402

CHECKED_IN = _ROOT / "benchmarks" / "BENCH_fleet_oversub.json"


def run_planner(seed: int) -> dict:
    plan = FleetOversubPlanner(make_planner_fleet(seed), ratios=RATIOS).plan()
    s = plan.summary()
    print(f"seed={seed} planner  isolated={s['isolated_total']:.3f} "
          f"coordinated={s['coordinated_total']:.3f} "
          f"gain={s['gain']:+.3f} evals={s['evaluations']}")
    return s


def run_cost(seed: int) -> dict:
    rows = {}
    for label, policy in (
            ("thermal_only", GlobalTapasRouter),
            ("cost_aware", lambda: GlobalTapasRouter(
                cost_aware_knobs(cost_shift_max=0.6)))):
        res = make_cost_fleet(policy, seed=seed).run()
        s = res.summary()
        rows[label] = {
            "blended_cost": res.blended_cost(CARBON_WEIGHT),
            "energy_kwh": s["energy_kwh"],
            "energy_cost": s["energy_cost"],
            "carbon_kg": s["carbon_kg"],
            "moved_load": s["moved_load"],
            "wan_overhead": s["wan_overhead"],
            "unserved_frac": s["unserved_frac"],
            "mean_quality": s["mean_quality"],
            "throttle_events": s["throttle_events"],
        }
        print(f"seed={seed} {label:13s} "
              f"blended={rows[label]['blended_cost']:8.1f} "
              f"moved={rows[label]['moved_load']:6.1f} "
              f"unserved={rows[label]['unserved_frac']:.5f}")
    rows["saving_frac"] = 1.0 - (rows["cost_aware"]["blended_cost"]
                                 / rows["thermal_only"]["blended_cost"])
    rows["goodput_ratio"] = ((1.0 - rows["cost_aware"]["unserved_frac"])
                             / (1.0 - rows["thermal_only"]["unserved_frac"]))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one seed + assert the two fleet TCO claims")
    ap.add_argument("--seeds", type=int, default=2)
    args = ap.parse_args()

    seeds = [0] if args.smoke else list(range(args.seeds))
    per_seed = {seed: {"planner": run_planner(seed), "cost": run_cost(seed)}
                for seed in seeds}
    payload = {
        "bench": "fleet_oversub",
        "mode": "smoke" if args.smoke else "full",
        "drills": {
            "planner": "2 regions, ridge UPS failover + heat wave + surge "
                       "hours 7-11 of 12; ratio grid "
                       + ",".join(f"{r:.3f}" for r in RATIOS),
            "cost": "coal (price 1.3, carbon 1.5) vs hydro (price 0.6, "
                    "carbon 0.4), price shock x1.6 on coal hours 6-10",
        },
        "carbon_weight": CARBON_WEIGHT,
        "per_seed": per_seed,
    }
    out = RESULTS / "BENCH_fleet_oversub.json" if args.smoke else CHECKED_IN
    out.write_text(json.dumps(payload, indent=1) + "\n")
    print(f"wrote {out}")

    if args.smoke:
        assert out.exists(), "BENCH_fleet_oversub.json not produced"
        plan = per_seed[0]["planner"]
        cost = per_seed[0]["cost"]
        assert plan["coordinated_safe"], \
            "the coordinated plan blew the capping budget"
        assert plan["coordinated_total"] > plan["isolated_total"], (
            f"fleet-coordinated planning must admit strictly more "
            f"oversubscription than per-region planning: "
            f"{plan['coordinated_total']} !> {plan['isolated_total']}")
        assert cost["cost_aware"]["moved_load"] > 0.0, \
            "cost-aware steering never engaged"
        assert cost["saving_frac"] > 0.0, (
            f"cost-aware steering must cut the blended energy cost: "
            f"saving {cost['saving_frac']:.4f}")
        assert cost["goodput_ratio"] >= 0.99, (
            f"goodput dropped more than 1% under cost-aware steering: "
            f"{cost['goodput_ratio']:.4f}")
        print("smoke OK")


if __name__ == "__main__":
    main()
