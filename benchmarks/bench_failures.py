"""Paper Table 2: power (UPS) and thermal (AHU) emergencies —
Baseline vs TAPAS, perf + quality impact on IaaS and SaaS.

Also drills the UPS emergency under a scripted demand surge (Scenario
composition: the failure window stacked with 1.3x endpoint demand) to
check TAPAS still absorbs the emergency when the fleet is busier than the
diurnal trace predicts."""
from __future__ import annotations

from benchmarks.common import emit, save, timed
from repro.core.datacenter import DCConfig
from repro.core.failures import run_drill, table2
from repro.core.scenario import DemandSurge, Scenario
from repro.core.simulator import TAPAS


def main(quick: bool = True) -> list:
    rows = []
    dc = DCConfig(n_rows=4 if quick else 8, racks_per_row=10,
                  servers_per_rack=4)
    table, us = timed(table2, seed=1, dc=dc)
    surge = Scenario((DemandSurge(start_h=13.0, end_h=17.0, scale=1.3),))
    surged, us_s = timed(run_drill, "ups", TAPAS, seed=1, dc=dc,
                         extra=surge)
    table.append({**surged.row(), "failure": "ups+surge"})
    by = {f"{r['failure']}_{r['policy']}": r for r in table}
    tapas_ups = by.get("ups_place+route+config", {})
    base_ups = by.get("ups_baseline", {})
    derived = {
        "ups_baseline_iaas_perf_pct": base_ups.get("iaas_perf_pct"),
        "ups_tapas_iaas_perf_pct": tapas_ups.get("iaas_perf_pct"),
        "ups_tapas_quality_pct": tapas_ups.get("quality_pct"),
        "ups_surge_tapas_saas_perf_pct":
            by.get("ups+surge_place+route+config", {}).get("saas_perf_pct"),
        "paper_claims": {"baseline_perf": -35.0, "tapas_iaas_perf": 0.0,
                         "tapas_quality": -12.0},
    }
    rows.append(emit("failures_table2", us + us_s, derived))
    save("bench_failures", table)
    return rows


if __name__ == "__main__":
    main(quick=False)
