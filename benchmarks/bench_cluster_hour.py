"""Paper Fig. 18: two rows x 40 servers over one hour at 1-minute ticks —
Baseline vs TAPAS peak row power (paper: ~20% reduction, 4% sim error)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save, timed
from repro.core.datacenter import DCConfig
from repro.core.simulator import BASELINE, TAPAS, ClusterSim, SimConfig


def run(policy, seed=1):
    dc = DCConfig(n_rows=2, racks_per_row=10, servers_per_rack=4)
    cfg = SimConfig(dc=dc, horizon_h=1.0, tick_min=1.0, seed=seed,
                    policy=policy, occupancy=0.95, demand_scale=0.95)
    return ClusterSim(cfg).run()


def main(quick: bool = True) -> list:
    rows = []
    seeds = (1,) if quick else (1, 2, 3)
    red = []
    for seed in seeds:
        base, us_b = timed(run, BASELINE, seed)
        tap, us_t = timed(run, TAPAS, seed)
        red.append(1.0 - tap.peak_row_power_frac.max()
                   / max(base.peak_row_power_frac.max(), 1e-9))
    derived = {
        "peak_power_reduction_pct": round(100 * float(np.mean(red)), 1),
        "paper_claim_pct": 20.0,
        "baseline_peak_frac": round(float(base.peak_row_power_frac.max()), 3),
        "tapas_peak_frac": round(float(tap.peak_row_power_frac.max()), 3),
    }
    rows.append(emit("cluster_hour_fig18", us_b + us_t, derived))
    save("bench_cluster_hour", derived)
    return rows


if __name__ == "__main__":
    main(quick=False)
