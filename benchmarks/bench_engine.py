"""Serving hot-path benchmark: device-resident decode vs per-step decode.

Runs the same shared-prefix workload (every request opens with the same
system prompt, then a random tail) through two engine configurations:

* ``baseline``  — the pre-PR hot path: one decode step per host sync
  (``horizon=1``), whole-prompt bucketed prefill, no prefix sharing.
* ``fused``     — the device-resident path: fused multi-step decode
  (``horizon=8``), chunked prefill interleaved with decode, and
  refcounted prefix-shared blocks.

Measures decode tokens/s, scheduler steps/s, **host syncs per 1k decode
tokens** (the number of device->host readbacks the decode path needs —
deterministic, machine-independent), prefill tokens actually computed
(prefix sharing shrinks this), and wall-clock TTFT / TBT.

Emits ``benchmarks/BENCH_engine.json`` (checked in, so the perf trajectory
has data).  ``--smoke`` runs a small workload and asserts (a) the file is
produced and (b) the fused engine's host-syncs-per-1k-tokens stays below
the pre-PR per-step baseline recorded in the checked-in file, with at
least a 2x reduction.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

_ROOT = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.common import RESULTS  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.models import build_model, local_plan  # noqa: E402
from repro.serving import Engine, EngineKnobs, EngineStats, Request  # noqa: E402

# the full run's output is checked in (the recorded perf trajectory + the
# baseline the CI smoke gates against); smoke runs write next to the other
# transient bench outputs so they never clobber the committed numbers
CHECKED_IN = _ROOT / "benchmarks" / "BENCH_engine.json"


def make_workload(vocab: int, *, n_req: int, shared_len: int, tail_lo: int,
                  tail_hi: int, max_new: int, seed: int = 0) -> list:
    """Fresh Request objects (they are mutated by serving) for one run:
    a common system prompt + per-request random tail."""
    rng = np.random.default_rng(seed)
    shared = [int(t) for t in rng.integers(0, vocab, shared_len)]
    reqs = []
    for i in range(n_req):
        tail = [int(t) for t in
                rng.integers(0, vocab, int(rng.integers(tail_lo, tail_hi)))]
        reqs.append(Request(prompt=shared + tail,
                            max_new_tokens=max_new + (i % 5)))
    return reqs


def run_config(model, params, workload_fn, *, label: str, max_seq: int,
               n_lanes: int, block_size: int, **engine_kw) -> dict:
    eng = Engine(model, params, max_seq=max_seq, n_slots=n_lanes,
                 knobs=EngineKnobs(max_batch=n_lanes), paged=True,
                 block_size=block_size, **engine_kw)
    # warm the jit caches with a miniature run so the measured pass times
    # steady-state steps, not traces
    for req in workload_fn(seed=99)[: min(3, n_lanes)]:
        eng.submit(req)
    eng.run()
    eng.stats = EngineStats()
    for req in workload_fn(seed=0):
        req.arrival_s = time.perf_counter()   # step() runs on the same clock
        eng.submit(req)
    t0 = time.perf_counter()
    while eng.queue or eng.active or eng.prefilling:
        eng.step()                       # real wall-clock `now` for TTFT/TBT
    wall = time.perf_counter() - t0
    st = eng.stats
    ttfts = [r.ttft() for r in st.completed if r.ttft() is not None]
    tbts = [r.tbt() for r in st.completed if r.tbt() is not None]
    out = {
        "label": label,
        "engine": {"horizon": eng.horizon, "prefill_chunk": eng.prefill_chunk,
                   "prefix_share": eng.prefix_share, "n_lanes": n_lanes,
                   "block_size": block_size, "max_seq": max_seq},
        "completed": len(st.completed),
        "decode_tokens": st.decode_tokens,
        "prefill_tokens": st.prefill_tokens,
        "shared_block_hits": eng.pool.shared_block_hits,
        "preemptions": st.preemptions,
        "wall_s": wall,
        "decode_tok_per_s": st.decode_tokens / max(wall, 1e-9),
        "steps_per_s": st.n_steps / max(wall, 1e-9),
        "host_syncs": st.host_syncs,
        "decode_syncs": st.decode_syncs,
        "host_syncs_per_1k_tokens":
            1000.0 * st.decode_syncs / max(st.decode_tokens, 1),
        "ttft_mean_s": float(np.mean(ttfts)) if ttfts else None,
        "ttft_p95_s": float(np.percentile(ttfts, 95)) if ttfts else None,
        "tbt_mean_s": float(np.mean(tbts)) if tbts else None,
    }
    # identical greedy streams regardless of scheduling: return them so the
    # harness can cross-check the two configurations served the same tokens
    out["_streams"] = sorted(
        (tuple(r.prompt), tuple(r.output)) for r in st.completed)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload + assert vs the recorded baseline")
    ap.add_argument("--horizon", type=int, default=8)
    args = ap.parse_args()

    out = RESULTS / "BENCH_engine.json" if args.smoke else CHECKED_IN
    prior = json.loads(CHECKED_IN.read_text()) if CHECKED_IN.exists() \
        else None

    cfg = get_config("llama2-7b").smoke_config()
    model = build_model(cfg, local_plan(param_dtype=jnp.bfloat16))
    params = model.init(jax.random.PRNGKey(0))

    if args.smoke:
        shape = dict(n_req=8, shared_len=24, tail_lo=4, tail_hi=16,
                     max_new=10)
        max_seq, n_lanes, block_size, chunk = 96, 4, 8, 16
    else:
        shape = dict(n_req=24, shared_len=48, tail_lo=8, tail_hi=48,
                     max_new=24)
        max_seq, n_lanes, block_size, chunk = 192, 8, 8, 32

    def workload_fn(seed=0):
        return make_workload(cfg.vocab_size, seed=seed, **shape)

    common = dict(max_seq=max_seq, n_lanes=n_lanes, block_size=block_size)
    baseline = run_config(model, params, workload_fn, label="per-step",
                          horizon=1, **common)
    fused = run_config(model, params, workload_fn, label="fused",
                       horizon=args.horizon, prefill_chunk=chunk,
                       prefix_share=True, **common)

    streams_equal = baseline.pop("_streams") == fused.pop("_streams")
    reduction = baseline["host_syncs_per_1k_tokens"] \
        / max(fused["host_syncs_per_1k_tokens"], 1e-9)
    payload = {
        "bench": "engine_hot_path",
        "mode": "smoke" if args.smoke else "full",
        "workload": shape | {"shared_prefix_len": shape.pop("shared_len")},
        "streams_identical": streams_equal,
        "baseline": baseline,
        "fused": fused,
        "host_sync_reduction": reduction,
    }
    out.write_text(json.dumps(payload, indent=1) + "\n")
    print(f"wrote {out}")
    print(f"decode tok/s: baseline {baseline['decode_tok_per_s']:.1f} "
          f"-> fused {fused['decode_tok_per_s']:.1f}")
    print(f"host syncs /1k tokens: {baseline['host_syncs_per_1k_tokens']:.1f}"
          f" -> {fused['host_syncs_per_1k_tokens']:.1f}"
          f"  ({reduction:.1f}x reduction)")
    print(f"prefill tokens: {baseline['prefill_tokens']} -> "
          f"{fused['prefill_tokens']} "
          f"(shared block hits: {fused['shared_block_hits']})")

    if args.smoke:
        assert out.exists(), "BENCH_engine.json not produced"
        assert streams_equal, "fused engine changed the served tokens"
        # the hot-path acceptance gate: stay below the pre-PR per-step
        # baseline recorded in the checked-in file, and by >= 2x
        recorded = (prior or payload)["baseline"]["host_syncs_per_1k_tokens"]
        measured = fused["host_syncs_per_1k_tokens"]
        assert measured < recorded, \
            f"host syncs regressed: {measured:.1f} !< recorded {recorded:.1f}"
        assert reduction >= 2.0, f"expected >=2x sync reduction, got {reduction:.2f}x"
        print("smoke OK")


if __name__ == "__main__":
    main()
