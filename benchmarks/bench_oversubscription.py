"""Paper Fig. 21: time under thermal/power capping vs oversubscription
ratio (paper: TAPAS sustains +40% servers at <0.7% capping time)."""
from __future__ import annotations

from benchmarks.common import emit, save, timed
from repro.core.datacenter import DCConfig
from repro.core.oversubscribe import max_safe_oversubscription, sweep
from repro.core.simulator import BASELINE, TAPAS


def main(quick: bool = True) -> list:
    rows = []
    ratios = (0.0, 0.2, 0.4) if quick else (0.0, 0.1, 0.2, 0.3, 0.4, 0.5)
    dc = DCConfig(n_rows=8, racks_per_row=10, servers_per_rack=4)
    table, us = timed(sweep, [BASELINE, TAPAS], ratios, dc=dc,
                      horizon_h=24.0)
    safe_base = max_safe_oversubscription(table, "baseline")
    safe_tapas = max_safe_oversubscription(table, TAPAS.name)
    derived = {
        "max_safe_oversub_baseline": safe_base,
        "max_safe_oversub_tapas": safe_tapas,
        "paper_claim": {"tapas": 0.4, "capping_budget_pct": 0.7},
        "points": table,
    }
    rows.append(emit("oversubscription_fig21", us, {
        k: v for k, v in derived.items() if k != "points"}))
    save("bench_oversubscription", derived)
    return rows


if __name__ == "__main__":
    main(quick=False)
