"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import pathlib
import time

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
RESULTS.mkdir(exist_ok=True)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6  # us


def emit(name: str, us: float, derived: dict) -> str:
    line = f"{name},{us:.0f},{json.dumps(derived, default=str)}"
    print(line)
    return line


def save(name: str, payload) -> None:
    (RESULTS / f"{name}.json").write_text(
        json.dumps(payload, indent=1, default=str))
