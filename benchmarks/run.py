"""Benchmark harness — one bench per paper table/figure + roofline/kernels.

Prints ``name,us_per_call,derived`` CSV.  Default = quick mode (CI-sized);
``--full`` reproduces the paper-scale settings (week-long sim, 992 servers,
all SaaS fractions, 6-point oversubscription sweep).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="", help="comma list of bench names")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (bench_ablation, bench_cluster_hour,
                            bench_failures, bench_kernels,
                            bench_oversubscription, bench_profiles,
                            bench_roofline, bench_week_sim)
    benches = {
        "profiles": bench_profiles,          # Fig. 15/16
        "cluster_hour": bench_cluster_hour,  # Fig. 18
        "week_sim": bench_week_sim,          # Fig. 19
        "ablation": bench_ablation,          # Fig. 20
        "oversubscription": bench_oversubscription,  # Fig. 21
        "failures": bench_failures,          # Table 2
        "kernels": bench_kernels,            # Pallas vs oracle
        "roofline": bench_roofline,          # dry-run aggregation
    }
    only = {s for s in args.only.split(",") if s}
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    for name, mod in benches.items():
        if only and name not in only:
            continue
        try:
            mod.main(quick=quick)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"{name},0,{{\"error\": \"{e!r}\"}}", file=sys.stderr)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"bench failures: {failures}")


if __name__ == "__main__":
    main()
