"""Fleet benchmark: global risk-weighted routing vs per-region greedy.

Runs the scripted regional-cooling-failure drill (the ``geo_fleet``
example scenario: three regions with divergent weather, a thermal
emergency + heat wave + demand surge hitting the hot region) under the
two fleet policies, with the per-region TAPAS control planes held fixed:

* ``latency`` — ``LatencyOnlyRouter``, the per-region-greedy baseline.
* ``global``  — ``GlobalTapasRouter``, risk-weighted cross-region
  steering + emergency VM drains.

Metrics are deterministic simulation outcomes (throttle events, unserved
fraction, served quality, load moved, WAN overhead, migrations) — no
wall-clock noise.  Emits ``benchmarks/BENCH_fleet.json`` (checked in, the
recorded trajectory).  ``--smoke`` runs the drill at one seed and asserts
the global router finishes with strictly fewer throttle events than the
latency-only baseline.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.common import RESULTS  # noqa: E402
# the drill itself lives with the example so the CI example smoke and the
# recorded bench numbers can never drift apart
from examples.geo_fleet import make_fleet  # noqa: E402
from repro.core.fleet import (GlobalTapasRouter,  # noqa: E402
                              LatencyOnlyRouter)

CHECKED_IN = _ROOT / "benchmarks" / "BENCH_fleet.json"


def run_pair(seed: int) -> dict:
    rows = {}
    for label, policy in (("latency", LatencyOnlyRouter),
                          ("global", GlobalTapasRouter)):
        s = make_fleet(policy, seed=seed).run().summary()
        rows[label] = {
            "throttle_events": s["throttle_events"],
            "thermal_events": s["thermal_events"],
            "power_events": s["power_events"],
            "unserved_frac": s["unserved_frac"],
            "mean_quality": s["mean_quality"],
            "moved_load": s["moved_load"],
            "wan_overhead": s["wan_overhead"],
            "migrations": s["migrations"],
            "per_region_thermal": {n: r["thermal_events"]
                                   for n, r in s["regions"].items()},
        }
        print(f"seed={seed} {label:8s} "
              f"throttle={rows[label]['throttle_events']:3d} "
              f"unserved={rows[label]['unserved_frac']:.4f} "
              f"moved={rows[label]['moved_load']:.1f} "
              f"migs={rows[label]['migrations']}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one seed + assert global beats latency-only "
                         "on throttle events")
    ap.add_argument("--seeds", type=int, default=3)
    args = ap.parse_args()

    seeds = [0] if args.smoke else list(range(args.seeds))
    per_seed = {seed: run_pair(seed) for seed in seeds}
    agg = {label: sum(per_seed[s][label]["throttle_events"] for s in seeds)
           for label in ("latency", "global")}
    payload = {
        "bench": "fleet_regional_failure",
        "mode": "smoke" if args.smoke else "full",
        "drill": "3 regions (hot/mild/cold), thermal emergency + heat wave "
                 "+ surge on the hot region, hours 3-10 of 12",
        "per_seed": per_seed,
        "throttle_events_total": agg,
    }
    out = RESULTS / "BENCH_fleet.json" if args.smoke else CHECKED_IN
    out.write_text(json.dumps(payload, indent=1) + "\n")
    print(f"wrote {out}")
    print(f"throttle events (all seeds): latency {agg['latency']} "
          f"-> global {agg['global']}")

    if args.smoke:
        assert out.exists(), "BENCH_fleet.json not produced"
        lat = per_seed[0]["latency"]
        glo = per_seed[0]["global"]
        assert glo["moved_load"] > 0.0, \
            "the global router never steered load during the drill"
        assert glo["throttle_events"] < lat["throttle_events"], (
            f"global router must beat the latency-only baseline on "
            f"throttle events: {glo['throttle_events']} !< "
            f"{lat['throttle_events']}")
        print("smoke OK")


if __name__ == "__main__":
    main()
