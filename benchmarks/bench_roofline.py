"""Roofline table (deliverable g): aggregates the dry-run cell JSONs into
the per-(arch x shape x mesh) three-term table for EXPERIMENTS.md."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit, save

DRYRUN = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"


def load_cells(mesh: str = "16x16", tag: str = "") -> list:
    cells = []
    suffix = f"_{tag}.json" if tag else ".json"
    for f in sorted(DRYRUN.glob(f"*_{mesh}{suffix}")):
        if not tag and f.stem.count("_") > 2:  # skip tagged variants
            parts = f.stem.split("_")
            if parts[-1] != mesh.replace("x", "x"):
                continue
        try:
            cells.append(json.loads(f.read_text()))
        except json.JSONDecodeError:
            continue
    return cells


def table_rows(cells: list) -> list:
    rows = []
    for c in cells:
        rows.append({
            "arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
            "t_compute_s": round(c["t_compute_s"], 4),
            "t_memory_s": round(c["t_memory_s"], 4),
            "t_collective_s": round(c["t_collective_s"], 4),
            "bottleneck": c["bottleneck"],
            "model_flops": f"{c['model_flops']:.3e}",
            "useful_flops_ratio": round(c["useful_flops_ratio"], 3),
            "roofline_fraction": round(c["roofline_fraction"], 4),
            "mem_gb": round(c.get("peak_mem_per_dev_gb", 0.0), 2),
        })
    return rows


def main(quick: bool = True) -> list:
    out = []
    cells = load_cells("16x16")
    rows = table_rows(cells)
    if not rows:
        out.append(emit("roofline_table", 0, {"cells": 0,
                                              "note": "run launch/dryrun first"}))
        return out
    worst = min(rows, key=lambda r: r["roofline_fraction"] or 1e9)
    coll_bound = [r for r in rows if r["bottleneck"] == "collective"]
    derived = {
        "cells_single_pod": len(rows),
        "worst_fraction": f"{worst['arch']}x{worst['shape']}"
                          f"={worst['roofline_fraction']}",
        "collective_bound_cells": len(coll_bound),
        "bottleneck_histogram": {
            b: sum(1 for r in rows if r["bottleneck"] == b)
            for b in ("compute", "memory", "collective")},
    }
    out.append(emit("roofline_table", 0, derived))
    save("bench_roofline", {"rows": rows, "summary": derived})
    return out


if __name__ == "__main__":
    main()
