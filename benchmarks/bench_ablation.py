"""Paper Fig. 20: normalized max temperature + peak power per policy
(Place / Route / Config and combinations) x SaaS fraction {0, 0.5, 1}."""
from __future__ import annotations

from benchmarks.common import emit, save, timed
from repro.core.datacenter import DCConfig
from repro.core.simulator import (BASELINE, TAPAS, ClusterSim, Policy,
                                  SimConfig)

POLICIES = [
    BASELINE,
    Policy(place=True), Policy(route=True), Policy(config=True),
    Policy(place=True, route=True), Policy(route=True, config=True),
    TAPAS,
]


def run(policy, saas_fraction, *, quick=True, seed=1):
    dc = DCConfig(n_rows=8, racks_per_row=10, servers_per_rack=4)
    cfg = SimConfig(dc=dc, horizon_h=24.0 if quick else 72.0,
                    tick_min=10.0 if quick else 5.0, seed=seed,
                    policy=policy, saas_fraction=saas_fraction)
    return ClusterSim(cfg).run()


def main(quick: bool = True) -> list:
    rows = []
    fractions = (0.5,) if quick else (0.0, 0.5, 1.0)
    table = {}
    total_us = 0.0
    for frac in fractions:
        base = None
        for pol in (POLICIES if not quick else
                    [BASELINE, Policy(place=True), Policy(route=True),
                     Policy(config=True), TAPAS]):
            res, us = timed(run, pol, frac, quick=quick)
            total_us += us
            s = res.summary()
            if base is None:
                base = s
            table[f"saas{frac}_{pol.name}"] = {
                "temp_norm": round(s["max_temp_c"] / 85.0, 3),
                "power_norm": round(s["peak_row_power_frac"], 3),
                "temp_red_pct": round(
                    100 * (1 - s["max_temp_c"] / base["max_temp_c"]), 1),
                "power_red_pct": round(
                    100 * (1 - s["peak_row_power_frac"]
                           / base["peak_row_power_frac"]), 1),
                "thermal_events": int(s["thermal_events"]),
                "quality": round(float(s["mean_quality"]), 3),
                "unserved": round(float(s["unserved_frac"]), 4),
            }
    key = f"saas{fractions[-1]}_{TAPAS.name}"
    derived = {
        "tapas_temp_red_pct": table[key]["temp_red_pct"],
        "tapas_power_red_pct": table[key]["power_red_pct"],
        "paper_claims": {"temp": 17.0, "power": 23.0},
        "cells": len(table),
    }
    rows.append(emit("ablation_fig20", total_us, derived))
    save("bench_ablation", table)
    return rows


if __name__ == "__main__":
    main(quick=False)
