"""Resilience benchmark: fault-storm drill, recovery on vs off.

Runs the scripted fault-storm drill (the ``fault_storm`` example: engine
crash + NaN-logit burst + sensor dropout landing inside a cooling
emergency) in three arms over an identical per-seed workload:

* ``fault_free``   — the cooling emergency only (goodput yardstick).
* ``recovery_on``  — the storm with the full recovery stack (watchdog
  re-homing, NaN quarantine + recompute, stale-telemetry risk bump,
  degradation ladder).
* ``recovery_off`` — the same storm with ``faults.recovery_off()``.

Metrics are audited simulation outcomes (accepted-token goodput, the
zero-silent-loss ledger, fault/recovery counters) — deterministic per
seed, no wall-clock noise.  Emits ``benchmarks/BENCH_resilience.json``
(checked in).  ``--smoke`` runs one seed and asserts the recovery
contract: zero lost requests, goodput within 10% of fault-free, and
recovery-off losing at least 3x more goodput than recovery-on.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.common import RESULTS  # noqa: E402
# the drill itself lives with the example so the CI example smoke and the
# recorded bench numbers can never drift apart
from examples.fault_storm import drill_spec, run_drill  # noqa: E402
from repro.core.faults import recovery_off  # noqa: E402

CHECKED_IN = _ROOT / "benchmarks" / "BENCH_resilience.json"

#: a fault-free arm can take zero storm damage (ratio_on == 1.0); the
#: floor keeps the off-vs-on loss ratio finite and conservative
MIN_LOSS = 1e-3


def run_arms(seed: int, share) -> dict:
    arms = {}
    for label, storm, knobs in (("fault_free", False, None),
                                ("recovery_on", True, None),
                                ("recovery_off", True, recovery_off())):
        arms[label] = run_drill(seed=seed, storm=storm, knobs=knobs,
                                share=share)
    free = max(arms["fault_free"]["goodput_tokens"], 1)
    ratio_on = arms["recovery_on"]["goodput_tokens"] / free
    ratio_off = arms["recovery_off"]["goodput_tokens"] / free
    row = {
        "arms": arms,
        "recovery_goodput_ratio": ratio_on,
        "no_recovery_goodput_ratio": ratio_off,
        "loss_ratio_off_vs_on": (1.0 - ratio_off) / max(1.0 - ratio_on,
                                                        MIN_LOSS),
        "lost_requests_on": arms["recovery_on"]["lost_requests"],
        "lost_or_dropped_off": (arms["recovery_off"]["lost_requests"]
                                + arms["recovery_off"]["dropped"]),
    }
    print(f"seed={seed} goodput tok: free="
          f"{arms['fault_free']['goodput_tokens']} "
          f"on={arms['recovery_on']['goodput_tokens']} "
          f"off={arms['recovery_off']['goodput_tokens']}  "
          f"ratio_on={ratio_on:.3f} ratio_off={ratio_off:.3f} "
          f"loss_x={row['loss_ratio_off_vs_on']:.1f} "
          f"lost_on={row['lost_requests_on']}")
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one seed + assert the recovery contract")
    ap.add_argument("--seeds", type=int, default=2)
    args = ap.parse_args()

    share = drill_spec().build()
    seeds = [0] if args.smoke else list(range(args.seeds))
    per_seed = {seed: run_arms(seed, share) for seed in seeds}
    agg = {
        "min_recovery_goodput_ratio": min(
            per_seed[s]["recovery_goodput_ratio"] for s in seeds),
        "min_loss_ratio_off_vs_on": min(
            per_seed[s]["loss_ratio_off_vs_on"] for s in seeds),
        "lost_requests_on": sum(
            per_seed[s]["lost_requests_on"] for s in seeds),
        "lost_or_dropped_off": sum(
            per_seed[s]["lost_or_dropped_off"] for s in seeds),
        "watchdog_drains_on": sum(
            per_seed[s]["arms"]["recovery_on"]["watchdog_drains"]
            for s in seeds),
        "quarantined_on": sum(
            per_seed[s]["arms"]["recovery_on"]["quarantined"]
            for s in seeds),
    }
    payload = {
        "bench": "resilience_fault_storm",
        "mode": "smoke" if args.smoke else "full",
        "drill": "2x2x4 hot DC, cooling failure hours 0.8-1.2 of 2; storm: "
                 "engine crash 0.9-1.1 + NaN burst 1.0-1.1 + sensor "
                 "dropout 0.8-1.3; 2 engine backends on the SaaS servers",
        "per_seed": per_seed,
        "aggregates": agg,
    }
    out = RESULTS / "BENCH_resilience.json" if args.smoke else CHECKED_IN
    out.write_text(json.dumps(payload, indent=1) + "\n")
    print(f"wrote {out}")
    print(f"aggregates: min ratio_on "
          f"{agg['min_recovery_goodput_ratio']:.3f}, min off-vs-on loss "
          f"{agg['min_loss_ratio_off_vs_on']:.1f}x, lost(on) "
          f"{agg['lost_requests_on']}, lost+dropped(off) "
          f"{agg['lost_or_dropped_off']}")

    if args.smoke:
        assert out.exists(), "BENCH_resilience.json not produced"
        assert agg["lost_requests_on"] == 0, \
            "recovery-on arm silently lost requests"
        assert agg["min_recovery_goodput_ratio"] >= 0.9, (
            f"recovery-on goodput fell below 90% of fault-free: "
            f"{agg['min_recovery_goodput_ratio']:.3f}")
        assert agg["min_loss_ratio_off_vs_on"] >= 3.0, (
            f"recovery-off must lose >= 3x more goodput than recovery-on: "
            f"{agg['min_loss_ratio_off_vs_on']:.1f}x")
        assert agg["lost_or_dropped_off"] > 0, \
            "recovery-off lost nothing — the storm has no teeth"
        print("smoke OK")


if __name__ == "__main__":
    main()
