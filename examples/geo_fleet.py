"""Geo-distributed fleet: three regions, one global router, one regional
cooling failure.

Three regions with divergent weather — a hot-climate ``gulf``, a mild
``plains``, a cold ``fjord`` — each run their own TAPAS control plane
(placement / routing / instance configuration) over their own cluster
physics.  At hour 3 the gulf region suffers a thermal emergency (an AHU
loss plus DC-level cooling strain) in the middle of a heat wave and a
fleet-wide demand surge.

The drill runs twice with the per-region control planes held fixed:

* ``latency``  — ``LatencyOnlyRouter``: the per-region-greedy baseline.
  Every region serves its own demand; the failing region fights alone.
* ``global``   — ``GlobalTapasRouter``: ``server_risk`` lifted to region
  granularity.  Demand is steered off the failing region toward cooler
  regions (paying the WAN-latency goodput penalty), and sustained
  emergency risk drains whole VMs cross-region.

The printed trace shows routing visibly shift during the failure window,
and the run asserts the global router finishes the drill with fewer
throttle events than the per-region-greedy baseline.

    PYTHONPATH=src python examples/geo_fleet.py
"""
import numpy as np

from repro.configs import get_config
from repro.core.datacenter import DCConfig
from repro.core.fleet import (FleetConfig, FleetSim, GlobalTapasRouter,
                              LatencyOnlyRouter, RegionSpec)
from repro.core.scenario import (DemandSurge, FailureEvent, Scenario,
                                 WeatherShift)
from repro.core.simulator import TAPAS
from repro.serving import EngineFleet, EngineSpec


def make_fleet(fleet_policy, seed: int = 0, *,
               servers_per_rack: int = 4) -> FleetSim:
    """The drill: 3 regions, gulf loses cooling mid-heat-wave.  At the
    default size this is also the workload ``benchmarks/bench_fleet.py``
    records and CI gates on; the measured drill below runs it bigger."""
    def dc(climate):
        return DCConfig(n_rows=4, racks_per_row=4,
                        servers_per_rack=servers_per_rack, region=climate)

    regions = (
        RegionSpec("gulf", dc=dc("hot"), wan_rtt_ms=10.0, power_price_scale=1.2),
        RegionSpec("plains", dc=dc("mild"), wan_rtt_ms=25.0),
        RegionSpec("fjord", dc=dc("cold"), wan_rtt_ms=45.0,
                   power_price_scale=0.7),
    )
    scenario = Scenario((
        # hour 3-10: gulf loses an AHU + DC cooling strain, mid-heat-wave
        FailureEvent(kind="thermal", start_h=3.0, end_h=10.0, target=0,
                     region="gulf"),
        FailureEvent(kind="cooling", start_h=3.0, end_h=10.0, region="gulf"),
        WeatherShift(start_h=2.0, end_h=11.0, delta_c=12.0, region="gulf"),
        DemandSurge(start_h=3.0, end_h=9.0, scale=1.3),
    ))
    return FleetSim(FleetConfig(
        regions=regions, horizon_h=12.0, tick_min=10.0, seed=seed,
        policy=TAPAS, fleet=fleet_policy, scenario=scenario,
        occupancy=0.97, demand_scale=1.05))


def run_drill(label: str, fleet_policy, *, verbose: bool) -> dict:
    fs = make_fleet(fleet_policy)
    if verbose:
        print(f"  {'h':>5} {'gulf':>22} {'plains':>16} {'fjord':>16} "
              f"{'moved':>8}")
    prev_moved = 0.0
    while fs.tick < fs.ticks:
        st = fs.step()
        if verbose and fs.tick % 6 == 0:
            moved = fs._moved - prev_moved     # since the last printed row
            prev_moved = fs._moved
            cells = []
            for name in ("gulf", "plains", "fjord"):
                cs = st.regions[name]
                load = float(cs.saas_load[cs.kind == 2].sum())
                flag = "!" if st.emergency[name] else " "
                cells.append(f"risk={st.risk[name]:.2f}{flag} "
                             f"load={load:5.1f}")
            print(f"  {st.now_h:5.1f} {cells[0]:>22} {cells[1]:>16} "
                  f"{cells[2]:>16} {moved:8.1f}")
    res = fs.result()
    s = res.summary()
    print(f"{label:8s} throttle={s['throttle_events']:3d} "
          f"(per region { {n: r['thermal_events'] for n, r in s['regions'].items()} }) "
          f"unserved={s['unserved_frac']:.4f} quality={s['mean_quality']:.3f} "
          f"moved={s['moved_load']:.1f} migrations={s['migrations']}\n")
    return s


def run_measured_drill(*, min_servers: int = 100) -> dict:
    """The same 3-region drill on *measured* goodput: every SaaS server
    that ever appears gets a real serving backend.

    One ``EngineFleet`` per region (two engines each) backs the region's
    whole SaaS tier through the batched pump — all six engines alias ONE
    copy of the model weights (``EngineSpec.build(share=...)``), and each
    tick every attached ``FleetBackend`` submits its server's routed
    demand before a single ``flush`` per fleet steps the engines for all
    of them together.  Attachment is progressive (servers churn), so the
    drill ends with well past ``min_servers`` simulated servers having
    run on engine-measured goodput instead of profile physics."""
    spec = EngineSpec(get_config("llama2-7b").smoke_config(),
                      max_seq=64, n_slots=4, block_size=8)
    fs = make_fleet(GlobalTapasRouter, servers_per_rack=6)
    fleets: dict[str, EngineFleet] = {}
    share = None
    for name in sorted(fs.sims):
        fleets[name] = EngineFleet(
            spec, n_engines=2, steps_per_tick=4, share=share,
            backend_kw=dict(requests_per_load=1.0, prompt_len=4,
                            max_new_tokens=2))
        share = share or fleets[name].engines[0]
    attached: dict[tuple, object] = {}
    measured_ticks = 0
    while fs.tick < fs.ticks:
        st = fs.step()
        for name, cs in st.regions.items():
            for srv in np.flatnonzero(cs.kind == 2):
                key = (name, int(srv))
                if key not in attached:
                    bk = fleets[name].make_backend()
                    fs.attach_backend(name, int(srv), bk)
                    attached[key] = bk
        measured_ticks += sum(
            1 for name, cs in st.regions.items()
            if any(k[0] == name and cs.measured_goodput.get(k[1], 0.0) > 0
                   for k in attached))
    for fl in fleets.values():
        fl.drain(now_h=12.0 + 1.0)

    share_params = fleets[sorted(fleets)[0]].engines[0].variants["full"][1]
    engines = [e for fl in fleets.values() for e in fl.engines]
    served = sum(1 for bk in (b for fl in fleets.values()
                              for b in fl.backends)
                 if any(len(r.output) > 0 for r in bk.issued))
    tokens = sum(len(r.output) for fl in fleets.values()
                 for bk in fl.backends for r in bk.issued)
    out = {
        "attached": len(attached),
        "engines": len(engines),
        "one_weight_copy": all(e.variants["full"][1] is share_params
                               for e in engines),
        "served_servers": served,
        "decode_tokens": tokens,
        "flushes": {n: fl.flushes for n, fl in fleets.items()},
        "measured_region_ticks": measured_ticks,
    }
    print(f"measured  attached={out['attached']} servers on "
          f"{out['engines']} engines (one weight copy: "
          f"{out['one_weight_copy']})  served={served} servers, "
          f"{tokens} tokens  flushes={out['flushes']}")
    return out


def main() -> None:
    print("== per-region-greedy baseline (LatencyOnlyRouter) ==")
    base = run_drill("latency", LatencyOnlyRouter, verbose=False)
    print("== global risk-weighted router (GlobalTapasRouter) ==")
    glob = run_drill("global", GlobalTapasRouter, verbose=True)

    # the routing shift must be real and must pay off in throttling
    assert glob["moved_load"] > 0.0, \
        "the global router steered nothing during a regional emergency"
    assert base["moved_load"] == 0.0
    assert glob["throttle_events"] < base["throttle_events"], (
        f"global router did not reduce throttling: "
        f"{glob['throttle_events']} vs {base['throttle_events']}")
    print(f"regional cooling failure: global router cut throttle events "
          f"{base['throttle_events']} -> {glob['throttle_events']} by "
          f"steering {glob['moved_load']:.0f} VM-ticks of load "
          f"(+{glob['migrations']} VM migrations) across regions")

    print("\n== same drill on measured goodput (fleet of real engines) ==")
    m = run_measured_drill()
    assert m["attached"] >= 100, \
        f"only {m['attached']} servers ever ran on a real backend"
    assert m["one_weight_copy"], "engines did not share one params copy"
    assert m["decode_tokens"] > 0 and m["served_servers"] >= 50
    assert all(n > 0 for n in m["flushes"].values()), \
        "a region's fleet was never flushed by the batched pump"
    assert m["measured_region_ticks"] > 0, \
        "no region ever reported engine-measured goodput"
    print(f"{m['attached']} simulated servers served by "
          f"{m['engines']} real engines through the batched pump")


if __name__ == "__main__":
    main()
